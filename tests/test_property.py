"""Hypothesis property tests over the system's core invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    BlockCost, Constraints, GraphCostModel, MSP430, fitness, held_karp_order,
    optimal_order,
)
from repro.core.task_graph import TaskGraph, enumerate_task_graphs, variety_score


# ---------------------------------------------------------------- strategies

@st.composite
def task_graphs(draw):
    n = draw(st.integers(2, 5))
    d = draw(st.integers(1, 3))
    graphs = enumerate_task_graphs(n, d)
    idx = draw(st.integers(0, len(graphs) - 1))
    return graphs[idx]


@st.composite
def cost_matrices(draw):
    n = draw(st.integers(2, 6))
    vals = draw(
        st.lists(st.floats(0.1, 100.0), min_size=n * n, max_size=n * n)
    )
    c = np.array(vals).reshape(n, n)
    c = (c + c.T) / 2
    np.fill_diagonal(c, 0.0)
    return c


@st.composite
def affinities(draw):
    n = draw(st.integers(2, 5))
    d = draw(st.integers(1, 3))
    vals = draw(st.lists(st.floats(-1.0, 1.0), min_size=d * n * n, max_size=d * n * n))
    a = np.array(vals).reshape(d, n, n)
    a = (a + a.transpose(0, 2, 1)) / 2
    for k in range(d):
        np.fill_diagonal(a[k], 1.0)
    return a


# ------------------------------------------------------------------- checks

@settings(max_examples=40, deadline=None)
@given(task_graphs())
def test_graphs_always_valid_and_prefix_closed(g: TaskGraph):
    g.validate()
    for i in range(g.num_tasks):
        for j in range(g.num_tasks):
            s = g.shared_prefix_depth(i, j)
            # prefix-closed: every depth below s is shared, s itself is not
            for d in range(s):
                assert g.group_of(d, i) == g.group_of(d, j)
            if s < g.depth:
                assert g.group_of(s, i) != g.group_of(s, j) or i == j


@settings(max_examples=30, deadline=None)
@given(task_graphs())
def test_cost_matrix_symmetric_nonnegative(g: TaskGraph):
    costs = [BlockCost(weight_bytes=10 * (d + 1), flops=5.0) for d in range(g.depth)]
    c = GraphCostModel(g, costs, MSP430).cost_matrix()
    assert np.allclose(c, c.T)
    assert (c >= 0).all()
    assert np.allclose(np.diag(c), 0.0)


@settings(max_examples=30, deadline=None)
@given(task_graphs())
def test_predicted_stats_conserve_blocks(g: TaskGraph):
    costs = [BlockCost(weight_bytes=1.0, flops=1.0) for _ in range(g.depth)]
    cm = GraphCostModel(g, costs, MSP430)
    order = list(range(g.num_tasks))
    stats = cm.predicted_stats(order)
    assert stats.blocks_executed + stats.blocks_skipped == g.num_tasks * g.depth
    # executed blocks >= number of distinct nodes on the union of paths
    assert stats.blocks_executed >= len(
        {node for t in order for node in g.path(t)}
    ) - g.depth + 1 if g.num_tasks else True


@settings(max_examples=25, deadline=None)
@given(cost_matrices())
def test_optimal_never_worse_than_identity(c):
    n = c.shape[0]
    r = optimal_order(c)
    assert r.cost <= fitness(list(range(n)), c) + 1e-9
    assert sorted(r.order) == list(range(n))


@settings(max_examples=15, deadline=None)
@given(cost_matrices(), st.integers(0, 10_000))
def test_optimal_beats_random_perms(c, seed):
    n = c.shape[0]
    r = held_karp_order(c)
    rng = np.random.default_rng(seed)
    for _ in range(10):
        perm = rng.permutation(n).tolist()
        assert r.cost <= fitness(perm, c) + 1e-9


@settings(max_examples=25, deadline=None)
@given(task_graphs(), affinities())
def test_variety_nonnegative_and_bounded(g, aff):
    if aff.shape[1] < g.num_tasks:
        return  # mismatched draw; skip silently
    a = aff[:, : g.num_tasks, : g.num_tasks]
    v = variety_score(g, a)
    assert v >= 0.0
    # each branch node contributes at most max dissimilarity (2.0)
    assert v <= 2.0 * g.depth + 1e-9


@settings(max_examples=20, deadline=None)
@given(cost_matrices())
def test_precedence_restricts_feasible_set(c):
    n = c.shape[0]
    cons = Constraints.make(n, precedence=[(0, n - 1)])
    r_free = held_karp_order(c)
    r_cons = held_karp_order(c, cons)
    assert cons.is_valid_order(r_cons.order)
    assert r_cons.cost >= r_free.cost - 1e-9
