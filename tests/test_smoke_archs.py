"""Per-architecture smoke tests (deliverable f).

For each of the 10 assigned architectures: instantiate the REDUCED
same-family variant (<= 4 layers, d_model <= 512, <= 4 experts), run one
forward pass and one train step on CPU, and assert output shapes + no NaNs.
Decode-capable families also run a prefill + one decode step.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config, list_archs
from repro.models import get_model
from repro.sharding.policy import TP_POLICY
from repro.training import AdamWConfig, adamw_init, make_train_step

ARCHS = list_archs()


def _batch_for(cfg, batch=2, seq=32):
    key = jax.random.PRNGKey(0)
    tokens = jax.random.randint(key, (batch, seq), 0, cfg.raw_vocab_size)
    if cfg.family == "encdec":
        feats = jax.random.normal(jax.random.PRNGKey(1), (batch, seq, cfg.enc_inputs))
        return {"features": feats, "tokens": tokens}
    return tokens


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_no_nans(arch):
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    logits, aux = model.forward(params, batch, TP_POLICY)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux).any())


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(
        make_train_step(model, AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10), TP_POLICY)
    )
    batch = _batch_for(cfg)
    new_params, new_opt, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_opt.step) == 1
    # params actually changed
    deltas = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()),
        params, new_params,
    )
    assert max(jax.tree.leaves(deltas)) > 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_and_decode_step(arch):
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg, batch=2, seq=16)
    logits, cache = model.prefill(params, batch, TP_POLICY)
    assert logits.shape == (2, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    # Grow KV-style caches so one more token fits.
    from repro.serving.engine import _grow_cache

    cache = _grow_cache(model, cache, 17, 16)
    logits2, cache2 = model.decode_step(params, tok, cache, jnp.asarray(16), TP_POLICY)
    assert logits2.shape == (2, cfg.vocab_size)
    assert not bool(jnp.isnan(logits2).any())
