"""Extended hypothesis property tests: GA under random constraints,
tradeoff-selection invariants, randomized decode equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    BlockCost, Constraints, GAConfig, MSP430, genetic_order, held_karp_order,
)
from repro.core.tradeoff import select_task_graph
from repro.models import make_config
from repro.models import transformer as T
from repro.models.cache import KVCache
from repro.sharding.policy import TP_POLICY


@st.composite
def constrained_instance(draw):
    n = draw(st.integers(3, 7))
    vals = draw(st.lists(st.floats(0.5, 50.0), min_size=n * n, max_size=n * n))
    c = np.array(vals).reshape(n, n)
    c = (c + c.T) / 2
    np.fill_diagonal(c, 0.0)
    # random DAG-consistent precedence edges
    order = draw(st.permutations(list(range(n))))
    n_edges = draw(st.integers(0, n - 1))
    edges = []
    for _ in range(n_edges):
        i = draw(st.integers(0, n - 2))
        j = draw(st.integers(i + 1, n - 1))
        edges.append((order[i], order[j]))
    # random conditional probabilities on a subset of the edges
    conds = [
        (i, j, draw(st.floats(0.1, 0.95)))
        for (i, j) in edges[: draw(st.integers(0, len(edges)))]
    ]
    return c, Constraints.make(n, precedence=edges, conditional=conds)


@settings(max_examples=20, deadline=None)
@given(constrained_instance())
def test_ga_always_valid_and_bounded(inst):
    c, cons = inst
    ga = genetic_order(c, cons, GAConfig(population=48, elite_pairs=12,
                                         patience=10, max_rounds=60, seed=0))
    assert cons.is_valid_order(ga.order)
    exact = held_karp_order(c, cons)
    assert ga.cost >= exact.cost - 1e-9          # exact is a lower bound
    assert ga.cost <= exact.cost * 1.5 + 1e-9    # and GA is never far off


@settings(max_examples=20, deadline=None)
@given(constrained_instance())
def test_conditional_probabilities_discount_cost(inst):
    c, cons = inst
    if not cons.conditional:
        return
    exact_cond = held_karp_order(c, cons)
    # Dropping the probabilities (pure precedence) can only raise the
    # optimal expected cost: every edge gets weight 1 instead of p <= 1.
    pure = Constraints.make(
        cons.num_tasks, precedence=list(cons.precedence)
    )
    exact_pure = held_karp_order(c, pure)
    assert exact_cond.cost <= exact_pure.cost + 1e-9


@settings(max_examples=8, deadline=None)
@given(st.integers(2, 4), st.integers(0, 10_000))
def test_tradeoff_selected_is_pareto_feasible(n, seed):
    rng = np.random.default_rng(seed)
    aff = rng.uniform(0.3, 0.9, (2, n, n))
    aff = (aff + aff.transpose(0, 2, 1)) / 2
    for k in range(2):
        np.fill_diagonal(aff[k], 1.0)
    costs = [BlockCost(weight_bytes=100, flops=200) for _ in range(3)]
    res = select_task_graph(n, 2, aff, costs, MSP430)
    sel = res.selected
    # no candidate strictly dominates the selection on (variety, cost, size)
    for cand in res.candidates:
        strictly_better = (
            cand.variety < sel.variety - 1e-12
            and cand.exec_cost < sel.exec_cost - 1e-12
            and cand.storage_bytes < sel.storage_bytes - 1e-12
        )
        assert not strictly_better


@settings(max_examples=6, deadline=None)
@given(st.integers(8, 24), st.integers(1, 4), st.integers(0, 2**31 - 1))
def test_decode_equals_forward_random_lengths(prompt_len, extra, seed):
    cfg = make_config(
        name="t", family="dense", num_layers=2, d_model=32, n_heads=4,
        n_kv_heads=2, d_ff=64, vocab_size=256, dtype="float32",
        param_dtype="float32", remat=False, attn_chunk=8, loss_chunk=8,
    )
    key = jax.random.PRNGKey(seed % 1000)
    params = T.init(key, cfg)
    total = prompt_len + extra
    toks = jax.random.randint(jax.random.fold_in(key, 1), (1, total), 0, 256)
    full, _ = T.forward(params, toks, cfg, TP_POLICY)
    _, cache = T.prefill(params, toks[:, :prompt_len], cfg, TP_POLICY)
    k = jnp.zeros((2, 1, total, 2, 8))
    v = jnp.zeros_like(k)
    cache = KVCache(
        k=k.at[:, :, :prompt_len].set(cache.k),
        v=v.at[:, :, :prompt_len].set(cache.v),
    )
    cl = jnp.asarray(prompt_len)
    for t in range(prompt_len, total):
        step, cache = T.decode_step(params, toks[:, t], cache, cl, cfg, TP_POLICY)
        np.testing.assert_allclose(
            np.asarray(step), np.asarray(full[:, t]), atol=5e-3, rtol=5e-3
        )
        cl = cl + 1
