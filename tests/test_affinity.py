"""Unit tests for the affinity computation (paper §3.1)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.affinity import (
    _rankdata, affinity_matrix, pairwise_pearson_dissimilarity, profile_task,
    spearman,
)


def test_pearson_dissimilarity_perfect_correlation():
    x = jnp.array([[1.0, 2.0, 3.0], [2.0, 4.0, 6.0], [-1.0, -2.0, -3.0]])
    d = pairwise_pearson_dissimilarity(x)
    # rows 0,1 perfectly correlated -> dissimilarity 0; row 2 anti -> 2.
    np.testing.assert_allclose(float(d[0, 1]), 0.0, atol=1e-5)
    np.testing.assert_allclose(float(d[0, 2]), 2.0, atol=1e-5)
    np.testing.assert_allclose(np.diag(np.asarray(d)), 0.0, atol=1e-5)


def test_pearson_symmetry_and_range():
    x = jax.random.normal(jax.random.PRNGKey(0), (10, 32))
    d = np.asarray(pairwise_pearson_dissimilarity(x))
    np.testing.assert_allclose(d, d.T, atol=1e-5)
    assert (d >= -1e-5).all() and (d <= 2 + 1e-5).all()


def test_rankdata_no_ties_matches_argsort():
    x = jnp.array([3.0, 1.0, 2.0, 10.0, -5.0])
    r = np.asarray(_rankdata(x))
    expected = np.empty(5)
    expected[np.argsort(np.asarray(x))] = np.arange(1, 6)
    np.testing.assert_allclose(r, expected)


def test_rankdata_ties_average():
    x = jnp.array([1.0, 2.0, 2.0, 3.0])
    r = np.asarray(_rankdata(x))
    np.testing.assert_allclose(r, [1.0, 2.5, 2.5, 4.0])


def test_spearman_monotone_invariance():
    key = jax.random.PRNGKey(1)
    a = jax.random.normal(key, (50,))
    b = jnp.exp(a)  # monotone transform -> Spearman == 1
    np.testing.assert_allclose(float(spearman(a, b)), 1.0, atol=1e-5)
    np.testing.assert_allclose(float(spearman(a, -b)), -1.0, atol=1e-5)


def test_affinity_matrix_identical_tasks():
    reps = [jax.random.normal(jax.random.PRNGKey(2), (8, 16)) for _ in range(2)]
    prof = profile_task(reps)
    s = affinity_matrix([prof, prof, prof])
    assert s.shape == (2, 3, 3)
    # identical profiles -> affinity 1 everywhere
    np.testing.assert_allclose(np.asarray(s), 1.0, atol=1e-4)


def test_affinity_symmetric():
    profs = [
        profile_task([jax.random.normal(jax.random.PRNGKey(i), (6, 12))])
        for i in range(4)
    ]
    s = np.asarray(affinity_matrix(profs))
    np.testing.assert_allclose(s, s.transpose(0, 2, 1), atol=1e-4)
