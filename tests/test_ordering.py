"""Ordering solvers: agreement, optimality, constraints (paper §4)."""
import numpy as np
import pytest

from repro.core import (
    BlockCost, Constraints, GAConfig, GraphCostModel, ILPFormulation,
    branch_and_bound_order, brute_force_order, fitness, genetic_order,
    held_karp_order, optimal_order, uniform_block_costs,
)
from repro.core.task_graph import TaskGraph, enumerate_task_graphs


def _random_cost(n, seed=0):
    rng = np.random.default_rng(seed)
    c = rng.uniform(1, 50, size=(n, n))
    c = (c + c.T) / 2
    np.fill_diagonal(c, 0)
    return c


@pytest.mark.parametrize("n", [2, 4, 6, 7])
def test_solvers_agree_unconstrained(n):
    c = _random_cost(n, seed=n)
    bf = brute_force_order(c)
    hk = held_karp_order(c)
    bb = branch_and_bound_order(c)
    assert np.isclose(bf.cost, hk.cost)
    assert np.isclose(bf.cost, bb.cost)


def test_solvers_agree_with_precedence():
    c = _random_cost(6, seed=42)
    cons = Constraints.make(6, precedence=[(0, 3), (1, 4), (0, 5)])
    bf = brute_force_order(c, cons)
    hk = held_karp_order(c, cons)
    bb = branch_and_bound_order(c, cons)
    assert np.isclose(bf.cost, hk.cost) and np.isclose(bf.cost, bb.cost)
    for r in (bf, hk, bb):
        assert cons.is_valid_order(r.order)


def test_conditional_expected_cost():
    c = _random_cost(5, seed=7)
    cons = Constraints.make(5, conditional=[(0, 2, 0.5)])
    r = brute_force_order(c, cons)
    # Eq. 8: fitness uses p * c on edges into task 2.
    manual = fitness(r.order, c, cons)
    assert np.isclose(r.cost, manual)
    # The conditional edge makes switches into task 2 half-price, so the
    # constrained optimum can only be <= the unconstrained-evaluated cost.
    assert r.cost <= fitness(r.order, c, None) + 1e-9


def test_precedence_infeasible_cycle_rejected():
    with pytest.raises(ValueError):
        Constraints.make(3, precedence=[(0, 1), (1, 2), (2, 0)])


def test_ilp_formulation_degree_constraints():
    c = _random_cost(4, seed=3)
    ilp = ILPFormulation(c)
    r = brute_force_order(c)
    # encode the optimal tour (cyclic) as an assignment x
    x = np.zeros(16)
    order = list(r.order)
    for a, b in zip(order, order[1:] + [order[0]]):
        x[a * 4 + b] = 1.0
    assert ilp.check_assignment(x)
    # objective of x == cyclic tour cost
    cm_cost = sum(c[a, b] for a, b in zip(order, order[1:] + [order[0]]))
    assert np.isclose(ilp.objective() @ x, cm_cost)
    # subtour row: any 2-subset constraint must hold
    row, rhs = ilp.subtour_constraint([0, 1])
    assert row @ x <= rhs + 1e-9


def test_genetic_matches_optimal_small():
    c = _random_cost(7, seed=11)
    opt = brute_force_order(c)
    ga = genetic_order(c, config=GAConfig(seed=0))
    assert np.isclose(ga.cost, opt.cost)


def test_genetic_paper_crossover_mode_valid():
    c = _random_cost(6, seed=13)
    cons = Constraints.make(6, precedence=[(0, 1)])
    ga = genetic_order(c, cons, GAConfig(crossover="paper", seed=1))
    assert cons.is_valid_order(ga.order)
    opt = brute_force_order(c, cons)
    assert ga.cost <= opt.cost * 1.25 + 1e-9  # sane even in faithful mode


def test_figure4_ordering_matters():
    """Paper Fig. 4: on a shared-prefix graph with unit block costs the
    optimal order beats bad orders, and the cost matrix is symmetric."""
    graphs = enumerate_task_graphs(5, 3)
    # pick a graph with non-trivial sharing: the paper notes ordering only
    # matters when tasks are neither all-identical nor all-disjoint, so take
    # the most-sharing graph whose cost matrix is NOT constant.
    def spread(gr):
        c = GraphCostModel(gr, uniform_block_costs(4)).cost_matrix()
        off = c[~np.eye(5, dtype=bool)]
        return (len(np.unique(off)) > 1, off.sum())

    g = max(
        (gr for gr in graphs if spread(gr)[0]),
        key=lambda gr: sum(
            gr.shared_prefix_depth(i, j) for i in range(5) for j in range(i + 1, 5)
        ),
    )
    cm = GraphCostModel(g, uniform_block_costs(4))
    c = cm.cost_matrix()
    assert np.allclose(c, c.T)
    best = optimal_order(c)
    rng = np.random.default_rng(0)
    worst = -np.inf
    for _ in range(50):
        perm = rng.permutation(5).tolist()
        worst = max(worst, fitness(perm, c))
        assert best.cost <= fitness(perm, c) + 1e-9
    assert best.cost < worst  # ordering genuinely matters


def test_optimal_order_dispatch():
    c = _random_cost(10, seed=5)
    r1 = optimal_order(c, solver="held_karp")
    r2 = optimal_order(c, solver="auto")
    assert np.isclose(r1.cost, r2.cost)
