"""Input-adaptive serving: confidence gating inside fused suffixes, the
expected-cost model, and their composition with the rest of the stack.

The contracts under test:

* **Exactness** — the adaptive fused scan program (masked per-row gating)
  returns outputs identical to the eager per-block reference with the same
  gater, and its realized counters equal
  ``GraphCostModel.predicted_stats(..., gate_trace=executor.last_trace)``
  field for field.  ``threshold=inf`` reproduces the ungated engine's
  outputs and flops exactly (the all-blocks floor).
* **Modes equivalence** — for shape-preserving blocks and a pure
  confidence function, ``early_exit`` and ``per_block`` gating coincide on
  scan suffixes: a skipped row's activation is unchanged, so its
  confidence is unchanged, so it keeps skipping.
* **Expected == enumeration** (the probability-model contract) — expected
  counters equal the probability-weighted average of realized-trace
  predictions over the *full exact enumeration* of per-block Bernoulli
  gate outcomes; hypothesis-driven when installed, fixed-seed fallback
  always.
* **Composition** — adaptive gating composes with warm-start residency,
  segmented checkpoint dispatch, crash-restored activations, and
  mesh-sharded execution without breaking output equality or counter
  exactness.
"""
import dataclasses
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.adaptive import (
    ALWAYS_FIRE, AdaptivePolicy, BlockGater, GateModel, GateModelCalibrator,
)
from repro.core import BlockCost, GraphCostModel, MSP430, MultitaskProgram
from repro.core.executor import TaskGraphExecutor
from repro.core.task_graph import TaskGraph
from repro.core.types import ExecutionStats, TaskGateRecord
from repro.serving import (
    EnginePolicy, MultitaskEngine, MultitaskRequest, RequestGroupScheduler,
)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

DIM = 8
GRAPH6 = TaskGraph.from_groups([
    [[0, 1, 2, 3, 4, 5]],
    [[0, 1, 2], [3, 4, 5]],
    [[0, 1], [2], [3], [4, 5]],
    [[0], [1], [2], [3], [4], [5]],
])


def _program(graph=GRAPH6, seed=0):
    rng = np.random.default_rng(seed)
    costs = [BlockCost(weight_bytes=100.0 * (d + 1), flops=10.0 * (d + 1))
             for d in range(graph.depth)]

    def block(p, x):
        return jnp.tanh(x @ p)

    node_params = {
        node: jnp.asarray(rng.normal(size=(DIM, DIM)), jnp.float32)
        for node in graph.nodes()
    }
    heads = [lambda p, x: x @ p] * graph.num_tasks
    head_params = [jnp.asarray(rng.normal(size=(DIM, 3)), jnp.float32)
                   for _ in range(graph.num_tasks)]
    return MultitaskProgram(
        graph, [block] * graph.depth, node_params, heads, head_params, costs
    )


PROGRAM = _program()
# Mixed-difficulty inputs: small-norm rows stay under the confidence
# threshold (keep firing); large-norm tanh activations exit early.
def _inputs(rng, n):
    scale = np.where(np.arange(n) % 3 == 0, 0.2, 2.0)[:, None]
    xs = rng.normal(size=(n, DIM)) * scale
    return jnp.asarray(xs, jnp.float32)


def _gater(**kw):
    kw.setdefault("threshold", 0.5)
    return BlockGater(**kw)


def _outputs_allclose(a, b):
    assert set(a) == set(b)
    for t in a:
        np.testing.assert_allclose(
            np.asarray(a[t]), np.asarray(b[t]), rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------------
# Executor: fused == reference, counters == trace replay
# --------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["early_exit", "per_block"])
def test_adaptive_fused_matches_per_block_reference(mode):
    rng = np.random.default_rng(0)
    xs = _inputs(rng, 6)
    order = list(range(GRAPH6.num_tasks))

    fused = TaskGraphExecutor(PROGRAM, gater=_gater(mode=mode))
    ref = TaskGraphExecutor(PROGRAM, fused=False, gater=_gater(mode=mode))

    of, sf = fused.run_batch(xs, order)
    orf, sr = ref.run_batch(xs, order)
    _outputs_allclose(of, orf)
    assert sf == sr
    assert fused.last_trace == ref.last_trace
    assert sf.block_rows_gated > 0  # the stream actually exercised gating
    assert sf.flops_gated > 0


def test_early_exit_equals_per_block_on_scan_suffixes():
    # Shape-preserving blocks + pure confidence: a skipped row's activation
    # (and therefore confidence) never changes, so per-block re-evaluation
    # decides exactly what the sticky early-exit mask decides.
    rng = np.random.default_rng(1)
    xs = _inputs(rng, 5)
    order = [0, 3, 1, 4, 2, 5]
    ee = TaskGraphExecutor(PROGRAM, gater=_gater(mode="early_exit"))
    pb = TaskGraphExecutor(PROGRAM, gater=_gater(mode="per_block"))
    oe, se = ee.run_batch(xs, order)
    ob, sb = pb.run_batch(xs, order)
    _outputs_allclose(oe, ob)
    assert se == sb
    assert ee.last_trace == pb.last_trace


def test_executor_stats_equal_trace_replay():
    rng = np.random.default_rng(2)
    xs = _inputs(rng, 4)
    order = [2, 0, 5, 3, 1, 4]
    ex = TaskGraphExecutor(PROGRAM, gater=_gater())
    _, stats = ex.run_batch(xs, order)
    cm = GraphCostModel(GRAPH6, PROGRAM.block_costs, MSP430)
    predicted = cm.predicted_stats(
        order, batch_size=4, gate_trace=ex.last_trace)
    assert stats == predicted


def test_inf_threshold_is_all_blocks_floor():
    rng = np.random.default_rng(3)
    xs = _inputs(rng, 4)
    order = list(range(GRAPH6.num_tasks))
    gated = TaskGraphExecutor(PROGRAM, gater=_gater(threshold=ALWAYS_FIRE))
    plain = TaskGraphExecutor(PROGRAM)
    og, sg = gated.run_batch(xs, order)
    op, sp = plain.run_batch(xs, order)
    _outputs_allclose(og, op)
    assert sg.flops_gated == 0
    assert sg.block_rows_gated == 0
    assert sg.flops_executed == sp.flops_executed
    assert sg.weight_bytes_loaded == sp.weight_bytes_loaded


def test_min_blocks_floor_is_respected():
    # threshold=0 exits every row as early as allowed; min_blocks keeps the
    # first blocks of every suffix firing unconditionally.
    rng = np.random.default_rng(4)
    xs = _inputs(rng, 4)
    ex = TaskGraphExecutor(PROGRAM, gater=_gater(threshold=0.0, min_blocks=2))
    _, stats = ex.run_batch(xs, [0, 1, 2, 3, 4, 5])
    for rec in ex.last_trace:
        for i, fired in enumerate(rec.fired):
            depth = rec.resume + i
            if depth < 2:
                assert fired == rec.weight
            else:
                assert fired == 0


# --------------------------------------------------------------------------
# Expected counters == exact enumeration of gate outcomes (satellite S2)
# --------------------------------------------------------------------------

TINY = TaskGraph.from_groups([[[0, 1]], [[0], [1]]])
TINY_COSTS = [BlockCost(weight_bytes=64.0, flops=16.0),
              BlockCost(weight_bytes=32.0, flops=8.0)]


def check_expected_equals_enumeration(qs, order=(0, 1)):
    """Expected counters == sum_w P(w) * realized-trace prediction, where w
    ranges over the full product of per-(task, depth) Bernoulli outcomes.

    Per-block gating, batch 1, all task probabilities 1: every task runs,
    every executed block independently fires with probability q(t, d) —
    exactly the regime where the expectation is an exact mean by linearity.
    """
    cm = GraphCostModel(TINY, TINY_COSTS, MSP430)
    gm = GateModel(fire={
        (t, d): qs[(t, d)] for t in range(2) for d in range(2)
    })
    # Executed (task, depth) slots under `order`'s activation-resume walk.
    slots = []
    prev = None
    resumes = {}
    for t in order:
        shared = 0 if prev is None else TINY.shared_prefix_depth(prev, t)
        resumes[t] = shared
        slots.extend((t, d) for d in range(shared, TINY.depth))
        prev = t
    expected = cm.expected_stats(order, batch_size=1, gate_model=gm)
    acc = {f.name: 0.0 for f in dataclasses.fields(ExecutionStats)}
    for bits in itertools.product((0, 1), repeat=len(slots)):
        p = 1.0
        fired = {t: [] for t in order}
        for (t, d), bit in zip(slots, bits):
            q = qs[(t, d)]
            p *= q if bit else (1.0 - q)
            fired[t].append(bit)
        trace = [
            TaskGateRecord(task=t, weight=1, fired=tuple(fired[t]),
                           resume=resumes[t])
            for t in order
        ]
        stats = cm.predicted_stats(order, batch_size=1, gate_trace=trace)
        for f in dataclasses.fields(ExecutionStats):
            acc[f.name] += p * getattr(stats, f.name)
    for f in dataclasses.fields(ExecutionStats):
        assert getattr(expected, f.name) == pytest.approx(
            acc[f.name], rel=1e-9, abs=1e-9), f.name


def test_expected_equals_enumeration_fixed_seeds():
    rng = np.random.default_rng(5)
    for trial in range(6):
        qs = {(t, d): float(rng.uniform(0.0, 1.0))
              for t in range(2) for d in range(2)}
        check_expected_equals_enumeration(qs, order=(0, 1) if trial % 2
                                          else (1, 0))
    # Degenerate corners stay exact too.
    check_expected_equals_enumeration(
        {(t, d): 1.0 for t in range(2) for d in range(2)})
    check_expected_equals_enumeration(
        {(t, d): 0.0 for t in range(2) for d in range(2)})


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(
        qs=st.lists(st.floats(min_value=0.0, max_value=1.0), min_size=4,
                    max_size=4),
        flip=st.booleans(),
    )
    def test_expected_equals_enumeration_hypothesis(qs, flip):
        table = {(t, d): qs[2 * t + d] for t in range(2) for d in range(2)}
        check_expected_equals_enumeration(
            table, order=(1, 0) if flip else (0, 1))


def test_calibrated_expected_matches_measured_mean():
    # Calibrate on realized traffic, re-predict the same traffic: the
    # expected flop/fire counters must land on the measured means.
    rng = np.random.default_rng(6)
    xs = _inputs(rng, 8)
    order = list(range(GRAPH6.num_tasks))
    ex = TaskGraphExecutor(PROGRAM, gater=_gater())
    _, stats = ex.run_batch(xs, order)
    cal = GateModelCalibrator()
    cal.observe(ex.last_trace)
    cm = GraphCostModel(GRAPH6, PROGRAM.block_costs, MSP430,
                        gate_model=cal.model())
    expected = cm.expected_stats(order, batch_size=8)
    assert expected.flops_executed == pytest.approx(stats.flops_executed)
    assert expected.block_rows_fired == pytest.approx(stats.block_rows_fired)
    assert expected.block_rows_gated == pytest.approx(stats.block_rows_gated)


# --------------------------------------------------------------------------
# Composition with the rest of the stack (satellite S3)
# --------------------------------------------------------------------------

def _adaptive_engine(**engine_kw):
    policy = engine_kw.pop("policy", EnginePolicy())
    policy = dataclasses.replace(
        policy, adaptive=AdaptivePolicy(threshold=0.5))
    return MultitaskEngine(PROGRAM, hw=MSP430, policy=policy, **engine_kw)


def test_adaptive_composes_with_warm_start():
    rng = np.random.default_rng(7)
    reqs = [MultitaskRequest(x=x, tasks=s)
            for x, s in zip(_inputs(rng, 6), [None, (0, 1), (4, 5),
                                              None, (2, 3), (0, 5)])]
    warm = _adaptive_engine()
    cold = _adaptive_engine(policy=EnginePolicy(warm_start=False))
    sw = warm.session()
    fw = [sw.submit(r) for r in reqs]
    sw.drain()
    sc = cold.session()
    fc = [sc.submit(r) for r in reqs]
    sc.drain()
    assert sw.stats == sw.predicted
    assert sc.stats == sc.predicted
    # Warmth changes loads, never results.
    for a, b in zip(fw, fc):
        _outputs_allclose(a.result().outputs, b.result().outputs)
    assert sw.stats.weight_bytes_loaded <= sc.stats.weight_bytes_loaded


def test_adaptive_composes_with_segmented_checkpoints():
    # Gated segmented dispatch (the intermittent path's program shape) must
    # equal the one-shot gated suffix: each segment re-derives its alive
    # mask from the carried activation, which is exact for shape-preserving
    # confidence gating.
    rng = np.random.default_rng(8)
    xs = _inputs(rng, 4)
    one = TaskGraphExecutor(PROGRAM, gater=_gater())
    seg = TaskGraphExecutor(PROGRAM, gater=_gater())
    s1, s2 = ExecutionStats(), ExecutionStats()
    hook_depths = []
    out1 = one.run_task_batch(0, xs, s1)
    out2 = seg.run_task_batch(
        0, xs, s2, checkpoint_depths=[1, 2],
        checkpoint_hook=hook_depths.append,
    )
    np.testing.assert_allclose(
        np.asarray(out1), np.asarray(out2), rtol=1e-5, atol=1e-6)
    assert hook_depths == [1, 2]
    assert one.last_gate_record == seg.last_gate_record
    assert s1 == s2


def test_adaptive_composes_with_restored_checkpoint():
    # Crash recovery: restore the deepest cached activation into a fresh
    # executor and re-run — the gated resumed suffix must reproduce the
    # uninterrupted gated run (same outputs, same realized fire counts for
    # the resumed blocks).
    rng = np.random.default_rng(9)
    x = _inputs(rng, 4)
    full = TaskGraphExecutor(PROGRAM, gater=_gater())
    out_full = full.run_task_batch(0, x, ExecutionStats())
    rec_full = full.last_gate_record

    # A segmented run's commit hook is where the journal snapshots the
    # activation; capture the same mid-suffix checkpoint here.
    seg = TaskGraphExecutor(PROGRAM, gater=_gater())
    cks = []
    seg.run_task_batch(
        0, x, ExecutionStats(), checkpoint_depths=[2],
        checkpoint_hook=lambda _d: cks.append(seg.activation_checkpoint(0)),
    )
    ck = cks[0]
    assert ck is not None and 0 < ck.depth + 1 < GRAPH6.depth

    resumed = TaskGraphExecutor(PROGRAM, gater=_gater())
    resumed.restore_activation(ck)
    stats = ExecutionStats()
    out_res = resumed.run_task_batch(0, x, stats)
    np.testing.assert_allclose(
        np.asarray(out_full), np.asarray(out_res), rtol=1e-5, atol=1e-6)
    rec = resumed.last_gate_record
    assert rec.resume == ck.depth + 1
    # The resumed suffix's fire counts equal the tail of the full run's.
    assert rec.fired == rec_full.fired[rec.resume - rec_full.resume:]
    # And the replayed prediction stays exact for the resumed shape.
    cm = GraphCostModel(GRAPH6, PROGRAM.block_costs, MSP430)
    predicted = cm.predicted_stats(
        [0], batch_size=4, gate_trace=[rec],
        first_task_resume=rec.resume)
    assert stats == predicted


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 (forced host) devices")
def test_adaptive_composes_with_mesh():
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(10)
    reqs = [MultitaskRequest(x=x, tasks=s)
            for x, s in zip(_inputs(rng, 4), [None, (0, 1), (2, 3, 4), None])]
    sharded = _adaptive_engine(
        policy=EnginePolicy(mesh=mesh),
        scheduler=RequestGroupScheduler(batch_shapes=(2, 4)),
    )
    single = _adaptive_engine(
        scheduler=RequestGroupScheduler(batch_shapes=(2, 4)),
    )
    ss = sharded.session()
    fs = [ss.submit(r) for r in reqs]
    ss.drain()
    s1 = single.session()
    f1 = [s1.submit(r) for r in reqs]
    s1.drain()
    assert ss.stats == ss.predicted   # collective bytes included
    assert ss.stats.all_gather_bytes + ss.stats.all_reduce_bytes > 0
    for a, b in zip(fs, f1):
        _outputs_allclose(a.result().outputs, b.result().outputs)


@pytest.mark.slow
def test_adaptive_benchmark_full_size():
    """Nightly (cron ``pytest -m slow``): the adaptive sweep at its
    non-dry-run dimensions — all its gates (counter exactness both arms,
    >= 1.3x modelled per-request speedup, >= 99% argmax agreement,
    calibrated expected flops within 5%) must hold at full size."""
    import benchmarks.serving_adaptive as bench

    assert bench.main(["--json", ""]) == 0


def test_gate_deps_enable_resolve_for_gated_engines():
    # A gated engine with explicit gate_deps re-solves per-plan orders, and
    # every solved order keeps the gate's inputs ahead of the gated task.
    def gate(outputs):
        return bool(np.asarray(outputs[0])[0] > 0) if 0 in outputs else True

    eng = MultitaskEngine(
        PROGRAM, hw=MSP430, gates={3: gate}, gate_deps={3: (0,)},
        policy=EnginePolicy(resolve_order_per_plan=True),
    )
    rng = np.random.default_rng(11)
    reqs = [MultitaskRequest(x=x, tasks=s)
            for x, s in zip(_inputs(rng, 4), [None, (0, 3), (0, 3, 4), None])]
    groups = eng.plan_groups(reqs)
    assert any(g.order is not None for g in groups)
    for g in groups:
        order = eng.group_order(g)
        if 0 in order and 3 in order:
            assert order.index(0) < order.index(3)
    sess = eng.session()
    futs = [sess.submit(r) for r in reqs]
    sess.drain()
    assert sess.stats == sess.predicted
    for f in futs:
        assert f.result().outputs
