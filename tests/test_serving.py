"""Serving: LM greedy generation == argmax of teacher-forced forward;
MultitaskEngine ordering, gating, and stats."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Constraints, TaskGraph
from repro.core.types import MSP430
from repro.models import get_model, make_config
from repro.models.multitask import build_cnn_program
from repro.serving import LMServer, MultitaskEngine, MultitaskRequest
from repro.sharding.policy import TP_POLICY


def test_lm_server_greedy_matches_teacher_forcing():
    cfg = make_config(
        name="tiny", family="dense", num_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=128, dtype="float32",
        param_dtype="float32", remat=False, attn_chunk=16,
    )
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 128)
    srv = LMServer(model, params, TP_POLICY)
    gen = srv.generate(prompts, steps=6)
    # Teacher-forced re-check: feeding prompt+gen reproduces gen greedily.
    toks = jnp.concatenate([prompts, jnp.asarray(gen)], axis=1)
    logits, _ = model.forward(params, toks, TP_POLICY)
    greedy = np.asarray(jnp.argmax(logits, axis=-1))
    for b in range(2):
        for i in range(6):
            assert greedy[b, 8 + i - 1] == gen[b, i]


def _engine(gates=None, constraints=None, order=None):
    graph = TaskGraph.from_groups([
        [[0, 1, 2, 3]],
        [[0, 1], [2, 3]],
        [[0, 1], [2, 3]],
        [[0], [1], [2], [3]],
    ])
    prog = build_cnn_program(jax.random.PRNGKey(0), graph, [3] * 4)
    return MultitaskEngine(prog, constraints=constraints, hw=MSP430,
                           gates=gates, order=order)


def test_engine_serves_all_tasks_and_counts():
    eng = _engine()
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 28, 28, 1))
    resp = eng.serve(MultitaskRequest(x=x))
    assert set(resp.outputs) == {0, 1, 2, 3}
    assert resp.stats.blocks_skipped > 0          # sharing was exploited
    assert resp.predicted_seconds > 0


def test_engine_respects_precedence_order():
    cons = Constraints.make(4, precedence=[(3, 0)])
    eng = _engine(constraints=cons)
    assert eng.order.index(3) < eng.order.index(0)


def test_engine_conditional_gate_skips():
    # Task 0 is a presence detector; others run only if it fires class 0.
    def dependent_gate(outputs):
        return bool(jnp.argmax(outputs[0][0]) == 0)

    gates = {t: dependent_gate for t in (1, 2, 3)}
    eng = _engine(gates=gates, order=[0, 1, 2, 3])
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 28, 28, 1))
    resp = eng.serve(MultitaskRequest(x=x))
    fired = bool(jnp.argmax(resp.outputs[0][0]) == 0)
    if fired:
        assert set(resp.outputs) == {0, 1, 2, 3}
    else:
        assert set(resp.outputs) == {0}
        assert resp.stats.tasks_skipped == 3


def test_engine_task_subset_requests():
    eng = _engine()
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 28, 28, 1))
    resp = eng.serve(MultitaskRequest(x=x, tasks=[1, 2]))
    assert set(resp.outputs) == {1, 2}
