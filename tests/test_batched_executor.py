"""Batched execution path: run_batch == per-request run loop, and batched
ExecutionStats == the cost model's batch-extended predictions."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    BlockCost, GraphCostModel, MSP430, MultitaskProgram, TaskGraphExecutor,
)
from repro.core.task_graph import TaskGraph, enumerate_task_graphs
from repro.core.types import ExecutionStats
from repro.serving import (
    MultitaskEngine, MultitaskRequest, RequestGroupScheduler,
)

DIM = 8


def _program(graph, dim=DIM, seed=0):
    rng = np.random.default_rng(seed)
    costs = [BlockCost(weight_bytes=100.0 * (d + 1), flops=10.0 * (d + 1))
             for d in range(graph.depth)]

    def block(p, x):
        return jnp.tanh(x @ p)

    node_params = {
        node: jnp.asarray(rng.normal(size=(dim, dim)), jnp.float32)
        for node in graph.nodes()
    }
    heads = [lambda p, x: x @ p] * graph.num_tasks
    head_params = [
        jnp.asarray(rng.normal(size=(dim, 3)), jnp.float32)
        for _ in range(graph.num_tasks)
    ]
    return MultitaskProgram(
        graph, [block] * graph.depth, node_params, heads, head_params, costs
    )


def _sequential_reference(ex, xs, order, gate=None):
    """Per-request run loop (reset between requests, like engine.serve)."""
    outs, stats = [], ExecutionStats()
    for i in range(xs.shape[0]):
        ex.reset()
        o, s = ex.run(xs[i], order, gate)
        outs.append(o)
        stats = stats.merge(s)
    return outs, stats


def _random_cases(seed=0, n_graphs=6):
    rng = np.random.default_rng(seed)
    graphs = enumerate_task_graphs(4, 2)
    idx = rng.choice(len(graphs), size=min(n_graphs, len(graphs)),
                     replace=False)
    for k, gi in enumerate(idx):
        graph = graphs[int(gi)]
        order = list(rng.permutation(graph.num_tasks))
        b = int(rng.integers(1, 7))
        yield k, graph, order, b, rng


def test_run_batch_matches_per_request_run():
    for k, graph, order, b, rng in _random_cases():
        prog = _program(graph, seed=k)
        ex = TaskGraphExecutor(prog)
        xs = jnp.asarray(rng.normal(size=(b, DIM)), jnp.float32)
        ex.reset()
        out_b, _ = ex.run_batch(xs, order)
        outs_seq, _ = _sequential_reference(ex, xs, order)
        for t in order:
            ref = np.stack([np.asarray(outs_seq[i][t]) for i in range(b)])
            np.testing.assert_allclose(
                np.asarray(out_b[t]), ref, rtol=1e-5, atol=1e-6)


def test_run_batch_with_task_subset_gate():
    graph = TaskGraph.from_groups([
        [[0, 1, 2, 3]], [[0, 1], [2, 3]], [[0], [1], [2, 3]],
    ])
    prog = _program(graph)
    ex = TaskGraphExecutor(prog)
    rng = np.random.default_rng(3)
    xs = jnp.asarray(rng.normal(size=(4, DIM)), jnp.float32)
    wanted = {1, 3}

    def gate(t, results):
        return t in wanted

    ex.reset()
    out_b, stats_b = ex.run_batch(xs, [0, 1, 2, 3], gate)
    assert set(out_b) == wanted
    assert stats_b.tasks_skipped == 2 * 4  # two gated-off tasks x batch
    outs_seq, _ = _sequential_reference(ex, xs, [0, 1, 2, 3], gate)
    for t in wanted:
        ref = np.stack([np.asarray(outs_seq[i][t]) for i in range(4)])
        np.testing.assert_allclose(
            np.asarray(out_b[t]), ref, rtol=1e-5, atol=1e-6)


def test_batched_stats_equal_batch_extended_prediction():
    for k, graph, order, b, rng in _random_cases(seed=1):
        prog = _program(graph, seed=k)
        cm = GraphCostModel(graph, prog.block_costs, MSP430)
        ex = TaskGraphExecutor(prog)
        xs = jnp.asarray(rng.normal(size=(b, DIM)), jnp.float32)
        ex.reset()
        _, stats = ex.run_batch(xs, order)
        assert stats == cm.predicted_stats(order, batch_size=b)


def test_batched_stats_vs_sum_of_per_request_predictions():
    """Per-request counters sum across the batch; load counters amortise.

    ``sum_i predicted_stats(order)`` over the B requests equals the batched
    stats on every per-request counter (flops, block skips, task counts);
    the batched weight loads are the *single*-request loads (paid once per
    group), which is exactly the block-loads-saved of batching.
    """
    for k, graph, order, b, rng in _random_cases(seed=2):
        prog = _program(graph, seed=k)
        cm = GraphCostModel(graph, prog.block_costs, MSP430)
        ex = TaskGraphExecutor(prog)
        xs = jnp.asarray(rng.normal(size=(b, DIM)), jnp.float32)
        ex.reset()
        _, stats = ex.run_batch(xs, order)

        per_req = cm.predicted_stats(order)
        summed = ExecutionStats()
        for _ in range(b):
            summed = summed.merge(per_req)
        assert stats.flops_executed == summed.flops_executed
        assert stats.flops_skipped == summed.flops_skipped
        assert stats.tasks_run == summed.tasks_run
        # Loads are physical: paid once per group, not once per request.
        assert stats.weight_bytes_loaded == per_req.weight_bytes_loaded
        saved = summed.weight_bytes_loaded - stats.weight_bytes_loaded
        assert saved == (b - 1) * per_req.weight_bytes_loaded


def test_run_batch_padding_rows_do_not_change_results():
    graph = TaskGraph.from_groups([
        [[0, 1, 2, 3]], [[0, 1], [2, 3]], [[0], [1], [2, 3]],
    ])
    prog = _program(graph)
    ex = TaskGraphExecutor(prog)
    rng = np.random.default_rng(5)
    xs = jnp.asarray(rng.normal(size=(3, DIM)), jnp.float32)
    padded = jnp.concatenate([xs, jnp.tile(xs[-1:], (5, 1))])
    order = [2, 0, 3, 1]
    ex.reset()
    out_exact, stats_exact = ex.run_batch(xs, order)
    ex.reset()
    out_pad, stats_pad = ex.run_batch(padded, order, valid=3)
    for t in order:
        np.testing.assert_allclose(
            np.asarray(out_pad[t][:3]), np.asarray(out_exact[t]),
            rtol=1e-5, atol=1e-6)
    # Logical accounting ignores the padding rows entirely.
    assert stats_pad == stats_exact


def test_run_batch_never_resumes_from_previous_input():
    """Back-to-back run_batch calls with same-shape, different inputs must
    not reuse the first call's cached activations."""
    graph = TaskGraph.from_groups([
        [[0, 1, 2, 3]], [[0, 1], [2, 3]], [[0], [1], [2, 3]],
    ])
    prog = _program(graph)
    ex = TaskGraphExecutor(prog)
    rng = np.random.default_rng(13)
    xs1 = jnp.asarray(rng.normal(size=(4, DIM)), jnp.float32)
    xs2 = jnp.asarray(rng.normal(size=(4, DIM)), jnp.float32)
    order = [0, 1, 2, 3]
    ex.run_batch(xs1, order)
    out2, _ = ex.run_batch(xs2, order)  # no reset in between
    ex.reset()
    ref2, _ = ex.run_batch(xs2, order)
    for t in order:
        np.testing.assert_allclose(
            np.asarray(out2[t]), np.asarray(ref2[t]), rtol=1e-5, atol=1e-6)
    # Same property for the single-request path.
    ex.reset()
    ex.run(xs1[0], order)
    out_s, _ = ex.run(xs2[0], order)
    for t in order:
        np.testing.assert_allclose(
            np.asarray(out_s[t]), np.asarray(ref2[t][0]),
            rtol=1e-5, atol=1e-6)


def test_engine_groups_none_with_explicit_full_subset():
    graph = TaskGraph.from_groups([
        [[0, 1, 2, 3]], [[0, 1], [2, 3]], [[0], [1], [2, 3]],
    ])
    prog = _program(graph, seed=15)
    eng = MultitaskEngine(prog, hw=MSP430)
    rng = np.random.default_rng(15)
    reqs = [
        MultitaskRequest(
            x=jnp.asarray(rng.normal(size=(DIM,)), jnp.float32), tasks=s)
        for s in (None, (0, 1, 2, 3), (3, 2, 1, 0), None)
    ]
    resp = eng.serve_batch(reqs)
    # All four are semantically all-tasks: one group, loads amortised.
    assert [r.group_size for r in resp] == [4, 4, 4, 4]
    # Each response owns its stats object.
    assert len({id(r.stats) for r in resp}) == len(resp)
    for r in resp:
        assert set(r.outputs) == {0, 1, 2, 3}


def test_engine_serve_batch_matches_per_request_serve():
    graph = TaskGraph.from_groups([
        [[0, 1, 2, 3]], [[0, 1], [2, 3]], [[0], [1], [2, 3]],
    ])
    prog = _program(graph, seed=7)
    eng = MultitaskEngine(prog, hw=MSP430)
    solo = MultitaskEngine(prog, hw=MSP430,
                           scheduler=RequestGroupScheduler(batch_shapes=(1,)))
    rng = np.random.default_rng(7)
    subsets = [None, (1, 2), None, (0, 3), (1, 2), None, (1, 2)]
    reqs = [
        MultitaskRequest(
            x=jnp.asarray(rng.normal(size=(DIM,)), jnp.float32), tasks=s)
        for s in subsets
    ]
    batched = eng.serve_batch(reqs)
    for rb, req in zip(batched, reqs):
        rs = solo.serve(req)
        assert set(rb.outputs) == set(rs.outputs)
        for t in rb.outputs:
            np.testing.assert_allclose(
                np.asarray(rb.outputs[t]), np.asarray(rs.outputs[t]),
                rtol=1e-5, atol=1e-6)
    # Requests sharing (subset=None) were actually grouped.
    assert max(r.group_size for r in batched) > 1


def test_engine_serve_batch_per_element_gates():
    """A gate firing for only some rows of a group stays exact per row."""
    graph = TaskGraph.from_groups([
        [[0, 1, 2, 3]], [[0, 1], [2, 3]], [[0], [1], [2, 3]],
    ])
    prog = _program(graph, seed=9)

    def gate(outputs):  # fire on the sign of task 0's first logit
        return bool(np.asarray(outputs[0])[0] > 0)

    gates = {t: gate for t in (1, 2, 3)}
    order = [0, 1, 2, 3]
    eng = MultitaskEngine(prog, hw=MSP430, gates=gates, order=order)
    solo = MultitaskEngine(prog, hw=MSP430, gates=gates, order=order,
                           scheduler=RequestGroupScheduler(batch_shapes=(1,)))
    rng = np.random.default_rng(11)
    reqs = [
        MultitaskRequest(x=jnp.asarray(rng.normal(size=(DIM,)), jnp.float32))
        for _ in range(8)
    ]
    batched = eng.serve_batch(reqs)
    fired = {frozenset(r.outputs) for r in batched}
    for rb, req in zip(batched, reqs):
        rs = solo.serve(req)
        assert set(rb.outputs) == set(rs.outputs)
        for t in rb.outputs:
            np.testing.assert_allclose(
                np.asarray(rb.outputs[t]), np.asarray(rs.outputs[t]),
                rtol=1e-5, atol=1e-6)
    # The seed is chosen so both gate outcomes occur within one group.
    assert len(fired) > 1
