"""Task-graph structure, enumeration and variety score (paper §3)."""
import numpy as np
import pytest

from repro.core.task_graph import (
    TaskGraph, enumerate_task_graphs, variety_score,
)


def test_extreme_graphs():
    g1 = TaskGraph.fully_shared(4, 3)
    g2 = TaskGraph.fully_separate(4, 3)
    g1.validate(); g2.validate()
    assert g1.num_blocks() == 4           # one block per depth
    assert g2.num_blocks() == 16          # 4 tasks x 4 depths
    assert g1.shared_prefix_depth(0, 3) == 4
    assert g2.shared_prefix_depth(0, 3) == 0


def test_nesting_validation_rejects_bad_partitions():
    with pytest.raises(ValueError):
        TaskGraph.from_groups([
            [[0, 1]],
            [[0], [1]],
            [[0, 1]],          # coarsens again -> not nested
        ])


def test_enumeration_small_counts():
    # n=2, D=1: task 1 attaches at virtual root or under the depth-0 block.
    assert len(enumerate_task_graphs(2, 1)) == 2
    # n=2, D=2: share nothing / depth-0 only / depth-0 and depth-1.
    assert len(enumerate_task_graphs(2, 2)) == 3
    # growth is monotone in n and all graphs are valid + deduped
    g4 = enumerate_task_graphs(4, 2)
    assert len({g.partitions for g in g4}) == len(g4)


def test_enumeration_beam_prunes():
    aff = np.ones((3, 6, 6)) * 0.5
    full = enumerate_task_graphs(5, 3)
    beamed = enumerate_task_graphs(
        5, 3, beam=50, variety_fn=lambda g: variety_score(g, aff)
    )
    assert len(beamed) <= 50 < len(full)


def test_variety_extremes():
    n, d = 4, 2
    rng = np.random.default_rng(0)
    aff = rng.uniform(0.2, 0.8, size=(d, n, n))
    aff = (aff + aff.transpose(0, 2, 1)) / 2
    for k in range(d):
        np.fill_diagonal(aff[k], 1.0)
    v_shared = variety_score(TaskGraph.fully_shared(n, d - 1), aff)
    v_sep = variety_score(TaskGraph.fully_separate(n, d - 1), aff)
    # Fig 2: all-shared graph has the highest variety; fully separate zero.
    assert v_sep == 0.0
    assert v_shared > 0.0
    for g in enumerate_task_graphs(n, d - 1):
        assert 0.0 <= variety_score(g, aff) <= v_shared + 1e-9


def test_branch_nodes_and_children():
    g = TaskGraph.from_groups([
        [[0, 1, 2]],
        [[0, 1], [2]],
        [[0], [1], [2]],
    ])
    nodes = dict((tuple(n), True) for n in g.branch_nodes())
    # depth-0 group (0,1,2) splits -> branch node; depth-1 (0,1) splits too.
    assert (0, (0, 1, 2)) in nodes
    assert (1, (0, 1)) in nodes
    assert g.children_of(0, (0, 1, 2)) == [(0, 1), (2,)]
