"""Block-cached executor vs cost model, Vanilla baseline, runtime gates."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BlockCost, GraphCostModel, MSP430, MultitaskProgram, TaskGraphExecutor,
    VanillaExecutor, optimal_order,
)
from repro.core.task_graph import TaskGraph


def _program(graph, dim=8, seed=0):
    rng = np.random.default_rng(seed)
    costs = [BlockCost(weight_bytes=100.0 * (d + 1), flops=10.0 * (d + 1))
             for d in range(graph.depth)]

    def block(p, x):
        return jnp.tanh(x @ p)

    node_params = {
        node: jnp.asarray(rng.normal(size=(dim, dim)), jnp.float32)
        for node in graph.nodes()
    }
    heads = [lambda p, x: x @ p] * graph.num_tasks
    head_params = [
        jnp.asarray(rng.normal(size=(dim, 3)), jnp.float32)
        for _ in range(graph.num_tasks)
    ]
    return MultitaskProgram(
        graph, [block] * graph.depth, node_params, heads, head_params, costs
    )


GRAPH = TaskGraph.from_groups([
    [[0, 1, 2, 3]],
    [[0, 1], [2, 3]],
    [[0], [1], [2, 3]],
])


def test_stats_match_cost_model_prediction():
    prog = _program(GRAPH)
    ex = TaskGraphExecutor(prog)
    x = jnp.ones((2, 8))
    cm = GraphCostModel(GRAPH, prog.block_costs, MSP430)
    for order in ([0, 1, 2, 3], [3, 2, 1, 0], [1, 3, 0, 2]):
        ex.reset()
        _, stats = ex.run(x, order)
        pred = cm.predicted_stats(order)
        assert stats.blocks_executed == pred.blocks_executed
        assert stats.blocks_skipped == pred.blocks_skipped
        assert np.isclose(stats.weight_bytes_loaded, pred.weight_bytes_loaded)
        assert np.isclose(stats.flops_executed, pred.flops_executed)


def test_outputs_order_independent():
    prog = _program(GRAPH)
    ex = TaskGraphExecutor(prog)
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8))
    out_a, _ = ex.run(x, [0, 1, 2, 3])
    ex.reset()
    out_b, _ = ex.run(x, [3, 1, 0, 2])
    for t in range(4):
        np.testing.assert_allclose(
            np.asarray(out_a[t]), np.asarray(out_b[t]), rtol=1e-5, atol=1e-5
        )


def test_vanilla_never_cheaper():
    prog = _program(GRAPH)
    x = jnp.ones((2, 8))
    order = list(optimal_order(
        GraphCostModel(GRAPH, prog.block_costs, MSP430).cost_matrix()
    ).order)
    _, s_ant = TaskGraphExecutor(prog).run(x, order)
    _, s_van = VanillaExecutor(prog).run(x, order)
    assert s_van.seconds(MSP430) >= s_ant.seconds(MSP430)
    assert s_van.blocks_executed > s_ant.blocks_executed
    assert s_ant.blocks_skipped > 0


def test_runtime_gate_skips_dependents():
    prog = _program(GRAPH)
    ex = TaskGraphExecutor(prog)
    x = jnp.ones((2, 8))

    def gate(task, outputs):
        return task == 0 or 0 in outputs  # everything depends on task 0

    out, stats = ex.run(x, [0, 1, 2, 3], gate)
    assert set(out) == {0, 1, 2, 3}
    ex.reset()

    def gate_none(task, outputs):
        return task == 0  # others never run

    out2, stats2 = ex.run(x, [0, 1, 2, 3], gate_none)
    assert set(out2) == {0}
    assert stats2.tasks_skipped == 3


# ---------------------------------------------------------- scan eligibility

def _program_with_block(block, dim=8, seed=0):
    """A GRAPH program whose every depth shares one custom block fn (the
    homogeneous shape the scan-eligibility probe triggers on)."""
    rng = np.random.default_rng(seed)
    costs = [BlockCost(weight_bytes=10.0, flops=1.0)] * GRAPH.depth
    node_params = {
        node: jnp.asarray(rng.normal(size=(dim, dim)), jnp.float32)
        for node in GRAPH.nodes()
    }
    heads = [lambda p, x: x @ p] * GRAPH.num_tasks
    head_params = [
        jnp.asarray(rng.normal(size=(dim, 3)), jnp.float32)
        for _ in range(GRAPH.num_tasks)
    ]
    return MultitaskProgram(
        GRAPH, [block] * GRAPH.depth, node_params, heads, head_params, costs
    )


def test_scan_probe_value_dependent_block_falls_back_to_unrolled():
    """A value-dependent block fn cannot be abstractly traced, so the probe
    sees ConcretizationTypeError -> the fused dispatch must fall back to
    "unrolled" eagerly (jit_blocks=False keeps the fn itself legal) rather
    than crash or misclassify."""

    def block(p, x):
        if float(jnp.sum(x)) >= 0:  # concretizes the tracer on purpose
            return jnp.tanh(x @ p)
        return jnp.tanh(x @ p) * 0.5

    prog = _program_with_block(block)
    ex = TaskGraphExecutor(prog, jit_blocks=False)
    x = jnp.ones((8,))
    out, _ = ex.run(x, [0, 1, 2, 3])
    assert set(out) == {0, 1, 2, 3}
    modes = {mode for (_fn, mode) in ex._compiled_fused.values()}
    assert modes == {"unrolled"}


def test_scan_probe_reraises_real_block_bugs():
    """Regression: the probe used to catch *every* exception and silently
    demote to unrolled — hiding genuine block-fn bugs until (or past)
    execution.  Non-tracing errors must propagate from the probe."""

    def block(p, x):
        raise RuntimeError("boom")

    prog = _program_with_block(block)
    ex = TaskGraphExecutor(prog, jit_blocks=False)
    with pytest.raises(RuntimeError, match="boom"):
        ex.run(jnp.ones((8,)), [0, 1, 2, 3])


def test_scan_probe_homogeneous_block_uses_scan():
    prog = _program(GRAPH)
    ex = TaskGraphExecutor(prog)
    ex.run(jnp.ones((2, 8)), [0, 1, 2, 3])
    modes = {mode for (_fn, mode) in ex._compiled_fused.values()}
    assert "scan" in modes  # depth-3 suffixes of a homogeneous program
