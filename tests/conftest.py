"""Test-session environment setup.

Force 8 placeholder host devices so the mesh-sharded serving tests get a
real multi-device topology on CPU.  Must run before jax initialises (jax
locks the device count at first init), which importing conftest before any
test module guarantees; appended rather than assigned so externally supplied
XLA_FLAGS survive, and skipped entirely when a device count is already
forced (e.g. by the harness).
"""
import os

if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=8"
    ).strip()
