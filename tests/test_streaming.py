"""Double-buffered weight streaming: the WeightStreamer, the cost model's
prefetch schedule/stall terms, and the streaming serving pipeline.

The contract under test: streaming changes *when* weight bytes move (behind
the previous group's compute instead of on the group's critical path),
never *what* gets computed or *how many* bytes move — outputs stay
identical to synchronous serving, ``weight_bytes_loaded`` is unchanged, and
``session.stats == session.predicted`` stays exact including the new
``prefetched_bytes`` / ``stream_stall_seconds`` counters.  Cancellation
(reset / rollback via ``set_residency``) must leave no half-committed
residency or streamed state behind.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BlockCost, GraphCostModel, MSP430, MultitaskProgram, TaskGraphExecutor,
)
from repro.core.task_graph import TaskGraph
from repro.core.types import ExecutionStats
from repro.serving import (
    EnginePolicy, FaultInjector, InjectedFault, MultitaskEngine,
    MultitaskRequest, RequestGroupScheduler, RetryPolicy,
)

DIM = 8
SUBSETS = ((0, 1), (3, 4), (0, 1, 2), (3, 4, 5), (0, 2), (4, 5))


def _graph():
    # Depth-4 split: tasks {0,1,2} and {3,4,5} share nothing past depth 0 —
    # the prefix structure that makes loads group-boundary dependent.
    return TaskGraph.from_groups([
        [[0, 1, 2, 3, 4, 5]],
        [[0, 1, 2], [3, 4, 5]],
        [[0, 1], [2], [3, 4], [5]],
        [[0], [1], [2], [3], [4], [5]],
    ])


def _program(graph, dim=DIM, seed=0):
    rng = np.random.default_rng(seed)
    costs = [BlockCost(weight_bytes=100.0 * (d + 1), flops=10.0 * (d + 1))
             for d in range(graph.depth)]

    def block(p, x):
        return jnp.tanh(x @ p)

    node_params = {
        node: jnp.asarray(rng.normal(size=(dim, dim)), jnp.float32)
        for node in graph.nodes()
    }
    heads = [lambda p, x: x @ p] * graph.num_tasks
    head_params = [
        jnp.asarray(rng.normal(size=(dim, 3)), jnp.float32)
        for _ in range(graph.num_tasks)
    ]
    return MultitaskProgram(
        graph, [block] * graph.depth, node_params, heads, head_params, costs
    )


def _requests(rng, n, subsets=SUBSETS, dim=DIM):
    return [
        MultitaskRequest(
            x=jnp.asarray(rng.normal(size=(dim,)), jnp.float32),
            tasks=subsets[i % len(subsets)],
        )
        for i in range(n)
    ]


# --------------------------------------------------------------------------
# Cost model: prefetch schedule + stall accounting
# --------------------------------------------------------------------------

def test_plan_loads_matches_predicted_load_bytes():
    graph = _graph()
    prog = _program(graph)
    cm = GraphCostModel(graph, prog.block_costs, MSP430)
    rng = np.random.default_rng(1)
    resident = (None,) * graph.depth
    order = None
    for _ in range(5):
        # Subtree-contiguous orders (what cost-aware group ordering emits)
        # never revisit an evicted block, so the schedule's bytes equal the
        # prediction's loaded bytes exactly.
        order = sorted(rng.permutation(graph.num_tasks)[
            : int(rng.integers(1, 7))])
        loads = cm.plan_loads(order, resident)
        predicted = cm.predicted_stats(order, resume=resident)
        assert sum(prog.block_costs[d].weight_bytes for d, _n in loads) == \
            predicted.weight_bytes_loaded
        # No duplicates: a block is staged at most once per plan.
        assert len({node for _d, node in loads}) == len(loads)
        resident = cm.residency_after(order, resident)
    # A fully-resident replay loads nothing.
    assert cm.plan_loads([order[-1]], resident) == []


def test_plan_loads_dedupes_revisited_blocks():
    """An interleaved order evicts and re-loads shared prefix blocks; the
    prediction pays for both loads but the streamer stages one copy, so the
    schedule lists the block once and the revisit loads synchronously."""
    graph = _graph()
    prog = _program(graph)
    cm = GraphCostModel(graph, prog.block_costs, MSP430)
    order = [0, 3, 1]  # task 3 evicts {0,1,2}'s prefix; task 1 reloads it
    loads = cm.plan_loads(order)
    assert len({node for _d, node in loads}) == len(loads)
    predicted = cm.predicted_stats(order)
    scheduled = sum(prog.block_costs[d].weight_bytes for d, _n in loads)
    assert scheduled < predicted.weight_bytes_loaded
    # The gap is exactly the revisited prefix blocks (depths 1 and 2 of
    # task 1's path, reloaded after task 3 evicted them).
    revisit = sum(prog.block_costs[d].weight_bytes for d in (1, 2))
    assert predicted.weight_bytes_loaded - scheduled == revisit
    # Executed side agrees: one commit per staged node, revisits load
    # synchronously, and the counters stay exact.
    ex = TaskGraphExecutor(prog)
    ex.streamer.stage(loads)
    rng = np.random.default_rng(0)
    _, stats = ex.run_batch(
        jnp.asarray(rng.normal(size=(2, DIM)), jnp.float32), order)
    assert stats.prefetched_bytes == scheduled
    assert stats.weight_bytes_loaded == predicted.weight_bytes_loaded


def test_plan_loads_rejects_bad_residency_length():
    graph = _graph()
    cm = GraphCostModel(graph, _program(graph).block_costs, MSP430)
    with pytest.raises(ValueError, match="slots"):
        cm.plan_loads([0], (None,) * (graph.depth + 1))


def test_prefetch_stall_is_load_seconds_minus_overlap():
    graph = _graph()
    cm = GraphCostModel(graph, _program(graph).block_costs, MSP430)
    depths = [0, 2, 3]
    total = sum(cm.load_cost(d) for d in depths)
    assert cm.prefetch_stall_seconds(depths, 0.0) == pytest.approx(total)
    assert cm.prefetch_stall_seconds(depths, total / 2) == \
        pytest.approx(total / 2)
    # A window bigger than the loads hides them fully; negative windows
    # clamp to no overlap.
    assert cm.prefetch_stall_seconds(depths, 10 * total) == 0.0
    assert cm.prefetch_stall_seconds(depths, -1.0) == pytest.approx(total)
    assert cm.prefetch_stall_seconds([], 0.0) == 0.0


def test_plan_predictor_overlap_marks_loads_prefetched():
    graph = _graph()
    prog = _program(graph)
    cm = GraphCostModel(graph, prog.block_costs, MSP430)
    sync = cm.plan_predictor()
    streamed = cm.plan_predictor()
    orders = ([0, 1], [3, 4], [2, 0], [5])
    for i, order in enumerate(orders):
        loads = cm.plan_loads(order, streamed.residency)
        d_sync = sync.append(order, batch_size=2)
        # First group synchronous (no window yet), rest fully streamed.
        overlap = None if i == 0 else 1e9
        d_strm = streamed.append(order, batch_size=2, overlap_seconds=overlap)
        assert d_strm.weight_bytes_loaded == d_sync.weight_bytes_loaded
        if overlap is None:
            assert d_strm.prefetched_bytes == 0.0
        else:
            assert d_strm.prefetched_bytes == d_strm.weight_bytes_loaded == \
                sum(prog.block_costs[d].weight_bytes for d, _n in loads)
            assert d_strm.stream_stall_seconds == 0.0
    # Totals: identical bytes, every post-first load prefetched.
    assert streamed.stats.weight_bytes_loaded == sync.stats.weight_bytes_loaded
    first = cm.predicted_stats(orders[0], batch_size=2)
    assert streamed.stats.prefetched_bytes == \
        streamed.stats.weight_bytes_loaded - first.weight_bytes_loaded
    # A tight window leaves the residual as stall.
    tight = cm.plan_predictor()
    tight.append(orders[0], batch_size=2)
    loads = cm.plan_loads(orders[1], tight.residency)
    load_s = sum(cm.load_cost(d) for d, _n in loads)
    delta = tight.append(orders[1], batch_size=2, overlap_seconds=load_s / 4)
    assert delta.stream_stall_seconds == pytest.approx(0.75 * load_s)


def test_stats_seconds_subtracts_prefetched_and_adds_stall():
    hw = MSP430
    base = ExecutionStats(flops_executed=1e6, weight_bytes_loaded=8e5)
    streamed = ExecutionStats(
        flops_executed=1e6, weight_bytes_loaded=8e5,
        prefetched_bytes=6e5, stream_stall_seconds=0.01,
    )
    assert streamed.compute_seconds(hw) == pytest.approx(
        hw.exec_seconds(1e6))
    assert streamed.seconds(hw) == pytest.approx(
        hw.exec_seconds(1e6) + hw.load_seconds(2e5) + 0.01)
    assert streamed.seconds(hw) < base.seconds(hw)
    # merge carries the streaming fields.
    merged = base.merge(streamed)
    assert merged.prefetched_bytes == 6e5
    assert merged.stream_stall_seconds == pytest.approx(0.01)


# --------------------------------------------------------------------------
# WeightStreamer: staging slots, commit-on-use, cancellation
# --------------------------------------------------------------------------

def test_streamer_commit_on_use_cycle():
    graph = _graph()
    prog = _program(graph)
    ex = TaskGraphExecutor(prog)
    cm = GraphCostModel(graph, prog.block_costs, MSP430)
    loads = cm.plan_loads([0, 1], ex.residency_state())
    st = ex.streamer
    st.stage(loads, stall_seconds=0.25)
    assert st.staged_nodes() == {node for _d, node in loads}
    assert st.pending_stall_seconds == 0.25
    d0, n0 = loads[0]
    assert st.commit(n0) is True
    assert st.commit(n0) is False          # single staged copy per node
    assert st.commit((0, (9,))) is False   # never-staged node
    assert n0 not in st.staged_nodes()
    # Stall charged exactly once, because something committed.
    assert st.finish_group() == 0.25
    assert st.finish_group() == 0.0
    assert st.staged_nodes() == frozenset()


def test_streamer_unconsumed_batch_charges_no_stall():
    graph = _graph()
    prog = _program(graph)
    ex = TaskGraphExecutor(prog)
    cm = GraphCostModel(graph, prog.block_costs, MSP430)
    ex.streamer.stage(cm.plan_loads([3], ex.residency_state()), 0.5)
    # Nothing committed (e.g. every staged task gated off): no stall.
    assert ex.streamer.finish_group() == 0.0


def test_streamer_restage_replaces_previous_batch():
    graph = _graph()
    prog = _program(graph)
    ex = TaskGraphExecutor(prog)
    cm = GraphCostModel(graph, prog.block_costs, MSP430)
    first = cm.plan_loads([0], ex.residency_state())
    second = cm.plan_loads([5], ex.residency_state())
    st = ex.streamer
    st.stage(first, 0.1)
    st.stage(second, 0.2)   # double buffer: one staging batch at a time
    assert st.staged_nodes() == {node for _d, node in second}
    assert st.pending_stall_seconds == 0.2
    assert st.cancels == 1  # replacing an unconsumed batch is a cancel


def test_executor_prefetch_counts_bytes_and_keeps_outputs():
    graph = _graph()
    prog = _program(graph)
    cm = GraphCostModel(graph, prog.block_costs, MSP430)
    rng = np.random.default_rng(7)
    xs = jnp.asarray(rng.normal(size=(3, DIM)), jnp.float32)
    order = [0, 1, 2]

    ref = TaskGraphExecutor(prog)
    ref_out, ref_stats = ref.run_batch(xs, order)

    ex = TaskGraphExecutor(prog)
    loads = cm.plan_loads(order, ex.residency_state())
    ex.streamer.stage(loads, stall_seconds=0.125)
    out, stats = ex.run_batch(xs, order)
    for t in ref_out:
        np.testing.assert_allclose(
            np.asarray(out[t]), np.asarray(ref_out[t]), rtol=1e-6)
    assert stats.prefetched_bytes == stats.weight_bytes_loaded == \
        ref_stats.weight_bytes_loaded
    # The executor's own run_batch does not close the batch (that is the
    # engine's per-group hook); closing it here yields the staged stall.
    assert ex.streamer.finish_group() == 0.125
    # Committed single-device copies actually back the parameter lookups.
    assert ex._streamed_node and all(
        node in ex._streamed_node for _d, node in loads)


# --------------------------------------------------------------------------
# Residency edge cases: mismatched depth, restore-then-prefetch, rollback
# --------------------------------------------------------------------------

def test_set_residency_rejects_mismatched_depth():
    graph = _graph()
    ex = TaskGraphExecutor(_program(graph))
    for bad in ((None,) * (graph.depth - 1), (None,) * (graph.depth + 1), ()):
        with pytest.raises(ValueError, match="slots"):
            ex.set_residency(bad)
    # A rejected restore leaves the executor usable and its state intact.
    before = ex.residency_state()
    assert ex.residency_state() == before


def test_restore_cancels_inflight_prefetch():
    graph = _graph()
    prog = _program(graph)
    cm = GraphCostModel(graph, prog.block_costs, MSP430)
    ex = TaskGraphExecutor(prog)
    rng = np.random.default_rng(3)
    xs = jnp.asarray(rng.normal(size=(2, DIM)), jnp.float32)
    _, _ = ex.run_batch(xs, [0, 1])
    snapshot = ex.residency_state()
    ex.streamer.stage(cm.plan_loads([3, 4], snapshot), stall_seconds=0.5)
    # Restore-then-prefetch cancellation: the rollback boundary drops the
    # staged batch and its pending stall.
    ex.set_residency(snapshot)
    assert ex.streamer.staged_nodes() == frozenset()
    assert ex.streamer.pending_stall_seconds == 0.0
    assert ex.streamer.cancels == 1
    # The next group loads synchronously and stays counter-exact.
    resume = ex.residency_state()
    _out, stats = ex.run_batch(xs, [3, 4])
    predicted = cm.predicted_stats([3, 4], batch_size=2, resume=resume)
    assert stats == predicted
    assert stats.prefetched_bytes == 0.0 and stats.stream_stall_seconds == 0.0


def test_reset_drops_streamed_state():
    graph = _graph()
    prog = _program(graph)
    cm = GraphCostModel(graph, prog.block_costs, MSP430)
    ex = TaskGraphExecutor(prog)
    loads = cm.plan_loads([0], ex.residency_state())
    ex.streamer.stage(loads, 0.1)
    rng = np.random.default_rng(5)
    ex.run_batch(jnp.asarray(rng.normal(size=(1, DIM)), jnp.float32), [0])
    assert ex._streamed_node  # committed copies in use
    ex.reset()
    assert ex.streamer.staged_nodes() == frozenset()
    assert ex._streamed_node == {}
    assert ex.streamer.pending_stall_seconds == 0.0


def test_rollback_mid_prefetch_leaves_no_half_committed_residency():
    """A group that crashes after committing part of its prefetched stream
    must roll back to the snapshot with nothing streamed left behind, and
    the session's counters must stay exact through the recovery."""
    graph = _graph()
    prog = _program(graph)
    rng = np.random.default_rng(11)
    # Second dispatch of the trace fails: by then the first group has
    # executed (building a stream budget), so the failing group is mid-way
    # through consuming its own prefetched weights.
    injector = FaultInjector(script={"dispatch": (2,)})
    eng = MultitaskEngine(
        prog, hw=MSP430, policy=EnginePolicy(streaming=True),
        scheduler=RequestGroupScheduler(batch_shapes=(1, 2)),
        fault_injector=injector,
    )
    session = eng.session(retry=RetryPolicy(max_retries=2, degrade=True))
    futures = [session.submit(r) for r in _requests(rng, 8)]
    session.drain()
    assert injector.total_injected == 1
    assert all(f.done() for f in futures)
    assert all(f.error() is None for f in futures)
    assert session.stats == session.predicted
    assert session.group_retries >= 1
    # Post-drain: no staged leftovers, no dangling stall.
    st = eng.executor.streamer
    assert st.staged_nodes() == frozenset()
    assert st.pending_stall_seconds == 0.0
    assert st.cancels >= 1  # the rollback cancelled the in-flight stream
    # Outputs equal solo serving despite the mid-prefetch crash.
    solo = MultitaskEngine(prog, hw=MSP430, warm_start=False,
                           group_ordering=False,
                           scheduler=RequestGroupScheduler(batch_shapes=(1,)))
    for f, req in zip(futures, _requests(np.random.default_rng(11), 8)):
        ref = solo.serve(MultitaskRequest(x=req.x, tasks=req.tasks))
        resp = f.result()
        for t in ref.outputs:
            np.testing.assert_allclose(np.asarray(resp.outputs[t]),
                                       np.asarray(ref.outputs[t]),
                                       rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------------
# Serving pipeline: streamed sessions vs synchronous sessions
# --------------------------------------------------------------------------

def _run_session(prog, reqs, streaming, **kwargs):
    eng = MultitaskEngine(
        prog, hw=MSP430, policy=EnginePolicy(streaming=streaming),
        scheduler=RequestGroupScheduler(batch_shapes=(1, 2, 4)),
        **kwargs,
    )
    session = eng.session()
    futures = [session.submit(r) for r in reqs]
    session.drain()
    return eng, session, [f.result() for f in futures]


def test_streaming_session_matches_synchronous():
    graph = _graph()
    prog = _program(graph)
    rng = np.random.default_rng(2)
    reqs = _requests(rng, 12)
    _, sync, sync_resp = _run_session(prog, reqs, streaming=False)
    _, strm, strm_resp = _run_session(prog, reqs, streaming=True)
    for a, b in zip(sync_resp, strm_resp):
        assert set(a.outputs) == set(b.outputs)
        for t in a.outputs:
            np.testing.assert_allclose(np.asarray(b.outputs[t]),
                                       np.asarray(a.outputs[t]), rtol=1e-6)
    # Exact on both sides, including the streaming counters.
    assert sync.stats == sync.predicted
    assert strm.stats == strm.predicted
    # Same bytes move; a strict subset of them move synchronously.
    assert strm.stats.weight_bytes_loaded == sync.stats.weight_bytes_loaded
    assert strm.stats.prefetched_bytes > 0.0
    assert sync.stats.prefetched_bytes == 0.0
    assert strm.prefetches_issued > 0
    assert strm.prefetch_scheduled_bytes == strm.stats.prefetched_bytes
    # Streaming can only help the modelled wall-clock.
    assert strm.stats.seconds(MSP430) <= sync.stats.seconds(MSP430)


def test_streaming_requires_warm_start():
    graph = _graph()
    prog = _program(graph)
    with pytest.raises(ValueError, match="warm_start"):
        MultitaskEngine(
            prog, hw=MSP430,
            policy=EnginePolicy(streaming=True, warm_start=False),
        )
    cold = MultitaskEngine(prog, hw=MSP430, warm_start=False)
    with pytest.raises(ValueError, match="warm-start"):
        cold.session(streaming=True)


def test_session_streaming_kwarg_overrides_policy():
    graph = _graph()
    prog = _program(graph)
    rng = np.random.default_rng(4)
    reqs = _requests(rng, 8)
    eng = MultitaskEngine(
        prog, hw=MSP430, policy=EnginePolicy(streaming=True),
        scheduler=RequestGroupScheduler(batch_shapes=(1, 2, 4)),
    )
    session = eng.session(streaming=False)  # opt out per session
    for r in reqs:
        session.submit(r)
    session.drain()
    assert session.stats == session.predicted
    assert session.stats.prefetched_bytes == 0.0
    assert session.prefetches_issued == 0


def test_prefetch_fault_degrades_to_synchronous_loads():
    graph = _graph()
    prog = _program(graph)
    rng = np.random.default_rng(6)
    reqs = _requests(rng, 12)
    injector = FaultInjector(script={"prefetch": (0, 1)})
    eng, session, responses = _run_session(
        prog, reqs, streaming=True, fault_injector=injector)
    assert injector.injected["prefetch"] == 2
    assert session.prefetch_failures == 2
    # The last swallowed error is retained for operators (the counter says
    # *that* streaming degraded; the exception says *why*).
    assert isinstance(session.last_prefetch_error, InjectedFault)
    assert session.last_prefetch_error.site == "prefetch"
    # Faulted prefetches degrade those groups to synchronous loads — the
    # session never fails a request over a prefetch.
    assert all(r is not None for r in responses)
    assert session.requests_failed == 0
    assert session.stats == session.predicted
    # Later groups still streamed.
    assert session.prefetches_issued > 0
    assert session.stats.prefetched_bytes > 0.0


def test_streaming_with_gates_stays_self_consistent():
    """Gated engines cannot be prediction-exact (gates are input-dependent),
    but a gated streamed run must still count only committed bytes and
    match the synchronous gated run's outputs."""
    graph = _graph()
    prog = _program(graph)
    rng = np.random.default_rng(9)
    reqs = _requests(rng, 10)
    gates = {1: lambda outs: bool(np.asarray(outs[0])[0] > 0) if 0 in outs
             else True}
    syncs = []
    for streaming in (False, True):
        eng = MultitaskEngine(
            prog, hw=MSP430, gates=gates,
            policy=EnginePolicy(streaming=streaming),
            scheduler=RequestGroupScheduler(batch_shapes=(1, 2)),
        )
        session = eng.session()
        futs = [session.submit(r) for r in reqs]
        session.drain()
        syncs.append((session, [f.result() for f in futs]))
    (s0, r0), (s1, r1) = syncs
    for a, b in zip(r0, r1):
        assert set(a.outputs) == set(b.outputs)
        for t in a.outputs:
            np.testing.assert_allclose(np.asarray(b.outputs[t]),
                                       np.asarray(a.outputs[t]), rtol=1e-6)
    assert s1.stats.weight_bytes_loaded == s0.stats.weight_bytes_loaded
    assert s1.stats.prefetched_bytes <= s1.stats.weight_bytes_loaded
