"""Integration test of the dry-run plumbing at reduced scale.

Runs in a SUBPROCESS with 8 placeholder host devices (the device count must
be set before jax initialises, which pytest's process already did), builds a
(2, 4) mesh, and lowers+compiles train/prefill/decode plans for reduced
configs through the exact code path the production dry-run uses.
"""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json
import jax
from repro.configs import get_smoke_config
from repro.launch.specs import make_plan
from repro.launch.hlo_cost import analyze_hlo
from repro.models.config import InputShape

from repro.launch.mesh import make_mesh, set_mesh

mesh = make_mesh((2, 4), ("data", "model"))
out = {}
cases = [
    ("granite-34b", InputShape("t", 64, 8, "train")),
    ("mixtral-8x22b", InputShape("p", 64, 8, "prefill")),
    ("mamba2-780m", InputShape("d", 64, 8, "decode")),
    ("zamba2-2.7b", InputShape("d", 64, 8, "decode")),
    ("whisper-medium", InputShape("t", 64, 8, "train")),
]
with set_mesh(mesh):
    for arch, shape in cases:
        cfg = get_smoke_config(arch)
        plan = make_plan(cfg, shape, mesh, "tp")
        compiled = jax.jit(
            plan.step_fn, in_shardings=plan.in_shardings,
            out_shardings=plan.out_shardings,
        ).lower(*plan.args_sds).compile()
        acc = analyze_hlo(compiled.as_text())
        out[f"{arch}/{shape.kind}"] = {
            "flops": acc["flops"], "bytes": acc["bytes"],
            "coll": acc["collective_bytes"],
        }
print(json.dumps(out))
"""


@pytest.mark.slow
def test_make_plan_lowers_on_8_device_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True,
        env=env, timeout=560, cwd=os.path.dirname(os.path.dirname(__file__)),
    )
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert len(out) == 5
    for k, v in out.items():
        assert v["flops"] > 0, k
        assert v["bytes"] > 0, k
        # sharded models must communicate on a >1-device mesh
    assert sum(v["coll"] > 0 for v in out.values()) >= 3
