"""Session-based serving API: async admission, pluggable scheduling
policies, futures, and residency-aware per-plan order re-solving.

The contract under test: admission timing, scheduling policy, and per-plan
re-solving change *what gets batched together, in what order, and what gets
loaded* — never *what gets computed*.  Sessioned ``submit()`` + ``drain()``
outputs are allclose to sequential ``serve()`` for random gate outcomes,
task subsets, and admission orders, and the session's cumulative executed
counters equal its incremental cost-model prediction exactly — gated
engines included, since the prediction replays each group's realized gate
trace (``session.expected`` keeps the a-priori all-gates-fire view).

Property tests run under hypothesis when installed and always under a
fixed-seed randomized fallback.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BlockCost, Constraints, GraphCostModel, MSP430, MultitaskProgram,
)
from repro.core.cost_model import PlanPredictor
from repro.core.ordering import solve_suborder
from repro.core.task_graph import TaskGraph
from repro.serving import (
    AffinityPolicy, EnginePolicy, GreedyBatchPolicy, MultitaskEngine,
    MultitaskRequest, RequestError, RequestGroupScheduler, RetryPolicy,
    ServingSession, WindowPolicy,
)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

DIM = 8
GRAPH = TaskGraph.from_groups([
    [[0, 1, 2, 3]], [[0, 1], [2, 3]], [[0], [1], [2, 3]],
])
GRAPH6 = TaskGraph.from_groups([
    [[0, 1, 2, 3, 4, 5]],
    [[0, 1, 2], [3, 4, 5]],
    [[0, 1], [2], [3], [4, 5]],
    [[0], [1], [2], [3], [4], [5]],
])
SUBSET_CHOICES = (None, (0,), (1, 2), (0, 3), (2, 1), (0, 1, 2, 3))


def _program(graph=GRAPH, seed=0, uniform_costs=False):
    rng = np.random.default_rng(seed)
    costs = [
        BlockCost(weight_bytes=10.0, flops=1.0) if uniform_costs
        else BlockCost(weight_bytes=100.0 * (d + 1), flops=10.0 * (d + 1))
        for d in range(graph.depth)
    ]

    def block(p, x):
        return jnp.tanh(x @ p)

    node_params = {
        node: jnp.asarray(rng.normal(size=(DIM, DIM)), jnp.float32)
        for node in graph.nodes()
    }
    heads = [lambda p, x: x @ p] * graph.num_tasks
    head_params = [jnp.asarray(rng.normal(size=(DIM, 3)), jnp.float32)
                   for _ in range(graph.num_tasks)]
    return MultitaskProgram(
        graph, [block] * graph.depth, node_params, heads, head_params, costs
    )


PROGRAM = _program()


class FakeClock:
    """Deterministic session clock for admission-window tests."""

    def __init__(self, t: float = 0.0):
        self.t = t

    def advance(self, dt: float) -> float:
        self.t += dt
        return self.t

    def __call__(self) -> float:
        return self.t


def _requests(rng, subsets):
    return [MultitaskRequest(
        x=jnp.asarray(rng.normal(size=(DIM,)), jnp.float32), tasks=s)
        for s in subsets]


# --------------------------------------------------------------------------
# One-shot parity: sessions reproduce serve_batch exactly
# --------------------------------------------------------------------------

def test_greedy_session_reproduces_serve_batch_exactly():
    rng = np.random.default_rng(0)
    subsets = [None, (0,), (1, 2), (0, 3), (2, 1), None, (1, 2)]
    reqs = _requests(rng, subsets)
    ref = MultitaskEngine(PROGRAM, hw=MSP430,
                          scheduler=RequestGroupScheduler(batch_shapes=(1, 4)))
    eng = MultitaskEngine(PROGRAM, hw=MSP430,
                          scheduler=RequestGroupScheduler(batch_shapes=(1, 4)))
    ref_resp = ref.serve_batch(reqs)

    session = eng.session()  # defaults to GreedyBatchPolicy
    futures = [session.submit(r) for r in reqs]
    assert not any(f.done() for f in futures)  # nothing runs before a pump
    session.drain()
    assert all(f.done() for f in futures)
    assert session.stats == ref.last_batch_stats
    assert session.stats == session.predicted  # no gates: counters exact
    assert session.admission_rounds == 1       # greedy = one planning batch
    assert session.requests_admitted == len(reqs)
    for f, rr in zip(futures, ref_resp):
        rs = f.result()
        assert set(rs.outputs) == set(rr.outputs)
        assert rs.group_size == rr.group_size
        # No gates: the effective order is the global order filtered to the
        # group's subset, i.e. exactly the tasks that produced outputs.
        assert rs.effective_order == tuple(
            t for t in eng.order if t in rs.outputs)
        assert rs.stats == rr.stats
        for t in rs.outputs:
            np.testing.assert_allclose(
                np.asarray(rs.outputs[t]), np.asarray(rr.outputs[t]),
                rtol=1e-5, atol=1e-6)


def test_response_effective_order_reports_group_suborder():
    rng = np.random.default_rng(1)
    eng = MultitaskEngine(PROGRAM, hw=MSP430)
    resp = eng.serve(MultitaskRequest(
        x=jnp.asarray(rng.normal(size=(DIM,)), jnp.float32), tasks=(1, 2)))
    # order stays the global order; effective_order is what actually ran.
    assert resp.order == eng.order
    assert resp.effective_order == tuple(
        t for t in eng.order if t in (1, 2))
    assert set(resp.effective_order) == set(resp.outputs)
    # The group's stats describe the effective order's execution: two tasks
    # ran, the other two were subset-skipped.
    assert resp.stats.tasks_run == 2
    assert resp.stats.tasks_skipped == 2


def test_future_result_drives_drain():
    rng = np.random.default_rng(2)
    eng = MultitaskEngine(PROGRAM, hw=MSP430)
    session = eng.session()
    fut = session.submit(MultitaskRequest(
        x=jnp.asarray(rng.normal(size=(DIM,)), jnp.float32)))
    assert not fut.done()
    resp = fut.result()  # drains the session on demand
    assert fut.done() and set(resp.outputs) == {0, 1, 2, 3}
    assert session.pending_count() == 0


def test_serve_many_deprecated_but_equivalent():
    rng = np.random.default_rng(3)
    reqs = _requests(rng, [None, (1, 2)])
    eng = MultitaskEngine(PROGRAM, hw=MSP430)
    ref = MultitaskEngine(PROGRAM, hw=MSP430)
    ref_resp = ref.serve_batch(reqs)
    with pytest.warns(DeprecationWarning, match="serve_many is deprecated"):
        resp = eng.serve_many(reqs)
    for rm, rr in zip(resp, ref_resp):
        assert set(rm.outputs) == set(rr.outputs)
        for t in rm.outputs:
            np.testing.assert_allclose(
                np.asarray(rm.outputs[t]), np.asarray(rr.outputs[t]),
                rtol=1e-5, atol=1e-6)


def test_pump_failure_isolated_to_failing_group():
    # A mid-pump failure (here: a gate that raises during execution) is
    # isolated to the failing *group*: its futures fail with a typed
    # RequestError chaining the original exception, drain() does not
    # raise, and requests in other groups are served normally.
    def bad_gate(outputs):
        raise ValueError("gate exploded")

    rng = np.random.default_rng(14)
    eng = MultitaskEngine(PROGRAM, hw=MSP430, gates={1: bad_gate},
                          order=[0, 1, 2, 3])
    session = eng.session(retry=RetryPolicy(max_retries=0, degrade=False))
    f_ok = session.submit(MultitaskRequest(  # no task 1: gate never runs
        x=jnp.asarray(rng.normal(size=(DIM,)), jnp.float32), tasks=(0,)))
    f_bad = session.submit(MultitaskRequest(
        x=jnp.asarray(rng.normal(size=(DIM,)), jnp.float32)))
    session.drain()  # must NOT raise: the failure rides the futures
    # Every admitted future is terminal: resolved or failed, never stuck.
    assert f_ok.done() and f_bad.done()
    assert f_ok.error() is None and f_ok.result().outputs.keys() == {0}
    with pytest.raises(RequestError, match="gate exploded") as exc:
        f_bad.result()
    assert isinstance(exc.value.__cause__, ValueError)
    assert exc.value.seq == f_bad.seq
    assert exc.value.group_id is not None
    assert session.groups_failed == 1 and session.requests_failed == 1


def test_drain_raises_on_noncompliant_policy():
    class StubbornPolicy:
        """Violates the flush contract: never admits anything."""

        def admit(self, queue, engine, now, flush):
            return []

    rng = np.random.default_rng(15)
    eng = MultitaskEngine(PROGRAM, hw=MSP430)
    session = eng.session(policy=StubbornPolicy())
    session.submit(MultitaskRequest(
        x=jnp.asarray(rng.normal(size=(DIM,)), jnp.float32)))
    with pytest.raises(RuntimeError, match="drain incomplete"):
        session.drain()


# --------------------------------------------------------------------------
# EnginePolicy config object
# --------------------------------------------------------------------------

def test_engine_policy_folds_legacy_flags():
    eng = MultitaskEngine(PROGRAM, hw=MSP430, warm_start=False,
                          group_ordering=False)
    assert eng.policy == EnginePolicy(
        warm_start=False, group_ordering=False,
        scheduling=eng.policy.scheduling, scheduler=eng.policy.scheduler)
    assert not eng.warm_start and not eng.group_ordering
    assert isinstance(eng.policy.scheduling, GreedyBatchPolicy)
    # The default scheduler is folded back into the policy: engine.policy
    # alone describes the engine's full scheduling behavior.
    assert isinstance(eng.policy.scheduler, RequestGroupScheduler)
    assert eng.scheduler is eng.policy.scheduler

    sched = RequestGroupScheduler(batch_shapes=(1, 2))
    pol = EnginePolicy(warm_start=False, scheduling=WindowPolicy(max_wait=1.0))
    eng = MultitaskEngine(PROGRAM, hw=MSP430, policy=pol, scheduler=sched)
    assert not eng.warm_start and eng.group_ordering
    assert eng.scheduler is sched
    assert eng.policy.scheduler is sched
    assert isinstance(eng.policy.scheduling, WindowPolicy)
    # Legacy kwargs override the policy object field-by-field.
    eng = MultitaskEngine(PROGRAM, hw=MSP430, policy=pol, warm_start=True)
    assert eng.warm_start


def test_policy_validation():
    with pytest.raises(ValueError):
        WindowPolicy(max_group_size=0)
    with pytest.raises(ValueError):
        WindowPolicy(max_wait=-1.0)
    with pytest.raises(ValueError):
        AffinityPolicy(max_group_size=0)


# --------------------------------------------------------------------------
# WindowPolicy: admission by max-wait / max-group-size
# --------------------------------------------------------------------------

def test_window_policy_admits_by_size_and_age():
    rng = np.random.default_rng(4)
    clock = FakeClock()
    eng = MultitaskEngine(
        PROGRAM, hw=MSP430,
        policy=EnginePolicy(
            scheduling=WindowPolicy(max_wait=1.0, max_group_size=3)),
        scheduler=RequestGroupScheduler(batch_shapes=(1, 2, 4)),
    )
    session = eng.session(clock=clock)
    f1 = session.submit(MultitaskRequest(
        x=jnp.asarray(rng.normal(size=(DIM,)), jnp.float32)))
    f2 = session.submit(MultitaskRequest(
        x=jnp.asarray(rng.normal(size=(DIM,)), jnp.float32)))
    # Below both thresholds: the window holds.
    assert session.step() == []
    assert not f1.done() and session.pending_count() == 2
    # Third submission fills the window: admit all three.
    f3 = session.submit(MultitaskRequest(
        x=jnp.asarray(rng.normal(size=(DIM,)), jnp.float32)))
    done = session.step()
    assert len(done) == 3 and all(f.done() for f in (f1, f2, f3))
    # A lone request is admitted once it ages past max_wait.
    f4 = session.submit(MultitaskRequest(
        x=jnp.asarray(rng.normal(size=(DIM,)), jnp.float32)))
    assert session.step() == [] and not f4.done()
    clock.advance(1.5)
    assert len(session.step()) == 1 and f4.done()
    # Admission latency was recorded.
    assert len(session.waits) == 4
    assert session.waits[-1] == pytest.approx(1.5)


def test_window_policy_respects_group_size_cap():
    rng = np.random.default_rng(5)
    eng = MultitaskEngine(
        PROGRAM, hw=MSP430,
        policy=EnginePolicy(
            scheduling=WindowPolicy(max_wait=10.0, max_group_size=2)),
        scheduler=RequestGroupScheduler(batch_shapes=(1, 2)),
    )
    session = eng.session(clock=FakeClock())
    for _ in range(5):
        session.submit(MultitaskRequest(
            x=jnp.asarray(rng.normal(size=(DIM,)), jnp.float32)))
    session.drain()
    # 5 pending drain as ceil(5/2) = 3 arrival-order admission rounds.
    assert session.admission_rounds == 3
    assert session.requests_admitted == 5


# --------------------------------------------------------------------------
# AffinityPolicy: residency-aware admission
# --------------------------------------------------------------------------

def test_affinity_policy_picks_residency_nearest_bucket():
    prog = _program(GRAPH6, seed=6)
    rng = np.random.default_rng(6)
    eng = MultitaskEngine(
        prog, hw=MSP430,
        policy=EnginePolicy(
            scheduling=AffinityPolicy(max_group_size=2), group_ordering=False),
        scheduler=RequestGroupScheduler(batch_shapes=(1, 2)),
    )
    # Warm the engine on subset (0, 1): residency ends deep in that subtree.
    eng.serve(MultitaskRequest(
        x=jnp.asarray(rng.normal(size=(DIM,)), jnp.float32), tasks=(0, 1)))
    session = eng.session()
    far = session.submit(MultitaskRequest(  # other subtree, arrived first
        x=jnp.asarray(rng.normal(size=(DIM,)), jnp.float32), tasks=(3, 4)))
    near = session.submit(MultitaskRequest(  # same subtree as the residency
        x=jnp.asarray(rng.normal(size=(DIM,)), jnp.float32), tasks=(0, 1)))
    completed = session.flush()
    assert len(completed) == 2
    # The residency-affine bucket was admitted (and thus executed) first
    # even though the far bucket arrived earlier.
    assert near.result().effective_order[0] in (0, 1)
    assert session.admission_rounds == 2
    first_round_stats = completed[0].stats
    assert set(completed[0].outputs) == {0, 1}
    # Starting affine costs strictly fewer loads than starting cold-far:
    # the shared prefix with the previous serve stays resident.
    assert first_round_stats.weight_bytes_skipped > 0


def test_affinity_policy_min_pending_zero_admits_immediately():
    # min_pending=0 means "admit as soon as anything is pending" — it must
    # not fall back to the max_group_size threshold.
    rng = np.random.default_rng(13)
    eng = MultitaskEngine(
        PROGRAM, hw=MSP430,
        policy=EnginePolicy(scheduling=AffinityPolicy(
            max_group_size=4, min_pending=0)),
    )
    session = eng.session(clock=FakeClock())
    f = session.submit(MultitaskRequest(
        x=jnp.asarray(rng.normal(size=(DIM,)), jnp.float32)))
    assert len(session.step()) == 1 and f.done()


def test_affinity_policy_waits_below_threshold():
    rng = np.random.default_rng(7)
    eng = MultitaskEngine(
        PROGRAM, hw=MSP430,
        policy=EnginePolicy(scheduling=AffinityPolicy(
            max_group_size=4, min_pending=3, max_wait=5.0)),
    )
    clock = FakeClock()
    session = eng.session(clock=clock)
    f = session.submit(MultitaskRequest(
        x=jnp.asarray(rng.normal(size=(DIM,)), jnp.float32)))
    assert session.step() == []        # 1 < min_pending, not aged
    clock.advance(6.0)
    assert len(session.step()) == 1    # aged out
    assert f.done()


# --------------------------------------------------------------------------
# Per-plan order re-solving
# --------------------------------------------------------------------------

def test_solve_suborder_restricts_and_seeds():
    cm = GraphCostModel(GRAPH6, _program(GRAPH6).block_costs, MSP430)
    cost = cm.cost_matrix()
    # Singleton and empty subsets pass through.
    assert solve_suborder(cost, []) == []
    assert solve_suborder(cost, [3]) == [3]
    # A subset is returned as a permutation of itself.
    sub = solve_suborder(cost, [0, 3, 1, 4])
    assert sorted(sub) == [0, 1, 3, 4]
    # Warm seeding: residency deep in {3,4,5} pulls that subtree first.
    resident = tuple(GRAPH6.path(4))
    starts = [cm.resume_load_cost(resident, t) for t in (0, 3, 1, 4)]
    sub = solve_suborder(cost, [0, 3, 1, 4], start_costs=starts)
    assert sub[0] in (3, 4) and sorted(sub) == [0, 1, 3, 4]
    # In-subset precedence pairs are kept.
    cons = Constraints.make(6, precedence=[(1, 0), (5, 2)])  # (5,2) outside
    sub = solve_suborder(cost, [0, 3, 1, 4], start_costs=starts,
                         constraints=cons)
    assert sub.index(1) < sub.index(0)
    with pytest.raises(ValueError):
        solve_suborder(cost, [0, 1], start_costs=[1.0])


def test_resolve_order_per_plan_reduces_loads_not_outputs():
    prog = _program(GRAPH6, seed=8)
    rng = np.random.default_rng(8)
    # Subsets whose filtered global order starts in the wrong subtree for a
    # warm engine: re-solving should begin at the resident subtree instead.
    subsets = [(2, 3), (0, 5), (1, 4), None, (2, 5)]
    reqs = _requests(rng, subsets)

    def engine(resolve):
        return MultitaskEngine(
            prog, hw=MSP430,
            policy=EnginePolicy(resolve_order_per_plan=resolve),
            scheduler=RequestGroupScheduler(batch_shapes=(1,)),
        )

    base, resolved = engine(False), engine(True)
    for _round in range(2):  # second round runs warm from the first
        groups = resolved.plan_groups(reqs)
        pred = resolved.predicted_group_stats(groups)
        r_resp = resolved.serve_batch(reqs)
        b_resp = base.serve_batch(reqs)
        # Counters stay exactly predictable with re-solved orders.
        assert resolved.last_batch_stats == pred
        # Re-solving picks residency-aware entry points: on this stream it
        # must not load more than the filtered-global-order baseline.
        assert (resolved.last_batch_stats.weight_bytes_loaded
                <= base.last_batch_stats.weight_bytes_loaded)
        # Work conservation: the same tasks ran, whatever the order.
        assert (resolved.last_batch_stats.tasks_run
                == base.last_batch_stats.tasks_run)
        for rr, rb in zip(r_resp, b_resp):
            assert set(rr.outputs) == set(rb.outputs)
            assert sorted(rr.effective_order) == sorted(rb.effective_order)
            for t in rr.outputs:
                np.testing.assert_allclose(
                    np.asarray(rr.outputs[t]), np.asarray(rb.outputs[t]),
                    rtol=1e-5, atol=1e-6)
    # And it actually helped somewhere on this adversarial stream.
    assert (resolved.last_batch_stats.weight_bytes_loaded
            < base.last_batch_stats.weight_bytes_loaded)


def test_resolve_order_respects_precedence_constraints():
    cons = Constraints.make(4, precedence=[(3, 1)])
    eng = MultitaskEngine(
        PROGRAM, hw=MSP430, constraints=cons,
        policy=EnginePolicy(resolve_order_per_plan=True),
        scheduler=RequestGroupScheduler(batch_shapes=(1,)),
    )
    rng = np.random.default_rng(9)
    for subset in [(1, 3), (0, 1, 3), None]:
        resp = eng.serve(MultitaskRequest(
            x=jnp.asarray(rng.normal(size=(DIM,)), jnp.float32),
            tasks=subset))
        eff = resp.effective_order
        assert eff.index(3) < eff.index(1)


def test_resolve_order_disabled_with_gates():
    def gate(outputs):
        return bool(np.asarray(outputs[0])[0] > 0)

    eng = MultitaskEngine(
        PROGRAM, hw=MSP430, gates={1: gate}, order=[0, 1, 2, 3],
        policy=EnginePolicy(resolve_order_per_plan=True),
    )
    rng = np.random.default_rng(10)
    groups = eng.plan_groups(_requests(rng, [None, (0, 1)]))
    assert all(g.order is None for g in groups)  # gate order preserved


def test_resolve_order_with_conditional_constraints_uses_expected_costs():
    # The global order was solved under conditional execution probabilities
    # (Eq. 8).  solve_suborder rebuilds precedence-only constraints (the
    # probabilities would be dropped), so the engine re-solves over the
    # *expected* cost matrix instead — the probabilities folded into a
    # GateModel — and per-plan re-solving now runs for these engines.
    cons = Constraints.make(4, conditional=[(0, 1, 0.5)])
    eng = MultitaskEngine(
        PROGRAM, hw=MSP430, constraints=cons,
        policy=EnginePolicy(resolve_order_per_plan=True),
    )
    rng = np.random.default_rng(16)
    groups = eng.plan_groups(_requests(rng, [None, (0, 1)]))
    # Multi-task groups get re-solved per-plan orders now.
    assert any(g.order is not None for g in groups)
    # Every re-solved order still satisfies the (precedence-folded) edges.
    for g in groups:
        if g.order is not None:
            pos = {t: k for k, t in enumerate(g.order)}
            assert all(
                pos[i] < pos[j] for (i, j) in cons.precedence
                if i in pos and j in pos
            )
    # The matrix the re-solve priced: expected switching costs, i.e. edges
    # into task 1 weighted by its 0.5 execution probability.
    mat = eng._resolve_matrix()
    exact = eng.cost_model.cost_matrix()
    for i in range(4):
        if i == 1:
            continue
        assert mat[i, 1] == pytest.approx(0.5 * exact[i, 1])
        assert mat[1, i] == pytest.approx(exact[1, i])
    # Serving through the re-solving engine stays output-identical to a
    # non-resolving one and keeps the counter-exactness invariant.
    reqs = _requests(rng, [None, (0, 1), (1, 2, 3)])
    base = MultitaskEngine(PROGRAM, hw=MSP430, constraints=cons)
    s1 = eng.session()
    f1 = [s1.submit(r) for r in reqs]
    s1.drain()
    assert s1.stats == s1.predicted
    for fa, rb in zip(f1, base.serve_batch(reqs)):
        ra = fa.result()
        assert set(ra.outputs) == set(rb.outputs)
        for t in ra.outputs:
            np.testing.assert_allclose(
                np.asarray(ra.outputs[t]), np.asarray(rb.outputs[t]),
                rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------------
# Incremental plan prediction
# --------------------------------------------------------------------------

def test_plan_predictor_matches_one_shot_prediction():
    prog = _program(GRAPH6, seed=11)
    cm = GraphCostModel(GRAPH6, prog.block_costs, MSP430)
    rng = np.random.default_rng(11)
    plan = [(list(rng.permutation(6)), int(rng.integers(1, 5)))
            for _ in range(4)]
    resume = tuple(GRAPH6.path(2))
    one_shot = cm.predicted_group_stats(plan, resume=resume)
    inc = cm.plan_predictor(resume=resume)
    deltas = [inc.append(order, b) for order, b in plan]
    assert inc.stats == one_shot
    assert inc.groups == len(plan)
    # Deltas partition the total.
    merged = deltas[0]
    for d in deltas[1:]:
        merged = merged.merge(d)
    assert merged == one_shot
    # Residency tracks the last executed task's path.
    assert inc.residency == tuple(GRAPH6.path(plan[-1][0][-1]))
    # Cold mode re-predicts each group from scratch.
    cold = cm.plan_predictor(carry_residency=False)
    for order, b in plan:
        cold.append(order, b)
    per_group = None
    for order, b in plan:
        s = cm.predicted_stats(order, batch_size=b)
        per_group = s if per_group is None else per_group.merge(s)
    assert cold.stats == per_group
    with pytest.raises(ValueError):
        PlanPredictor(cm, resume=(None,))


# --------------------------------------------------------------------------
# Property: sessioned serving == sequential serve(), counters exact
# --------------------------------------------------------------------------

POLICY_MAKERS = (
    lambda: GreedyBatchPolicy(),
    lambda: WindowPolicy(max_wait=0.5, max_group_size=3),
    lambda: AffinityPolicy(max_group_size=4, min_pending=2, max_wait=2.0),
)


def check_session_matches_sequential(spec, data_seed, policy_idx,
                                     gated, resolve):
    """Core property: any admission order/policy/gating, same outputs.

    ``spec`` is a list of (subset_index, inter-arrival-time) pairs.  The
    session serves the stream under the chosen policy with per-arrival
    ``step()`` pumps; a fresh solo engine serves each request sequentially.
    """
    rng = np.random.default_rng(data_seed)
    subsets = [SUBSET_CHOICES[i] for i, _dt in spec]
    reqs = _requests(rng, subsets)

    gates = {}
    if gated:
        # Random-but-deterministic gate outcomes keyed on the input row via
        # task 0's output (so solo and sessioned serving agree per request);
        # subsets that skip task 0 leave the gate open.
        def gate(outputs):
            if 0 not in outputs:
                return True
            return bool(np.asarray(outputs[0])[0] > 0)

        gates = {t: gate for t in range(1, 4)}
    order = [0, 1, 2, 3] if gated else None
    policy = EnginePolicy(
        scheduling=POLICY_MAKERS[policy_idx](),
        resolve_order_per_plan=resolve,
    )
    eng = MultitaskEngine(
        PROGRAM, hw=MSP430, gates=gates, order=order, policy=policy,
        scheduler=RequestGroupScheduler(batch_shapes=(1, 2, 4)),
    )
    solo = MultitaskEngine(
        PROGRAM, hw=MSP430, gates=gates, order=order,
        warm_start=False, group_ordering=False,
        scheduler=RequestGroupScheduler(batch_shapes=(1,)),
    )

    clock = FakeClock()
    session = eng.session(clock=clock)
    futures = []
    for req, (_si, dt) in zip(reqs, spec):
        clock.advance(dt)
        futures.append(session.submit(req))
        session.step()  # policy decides; may or may not admit
    session.drain()

    assert all(f.done() for f in futures)
    assert session.requests_admitted == len(reqs)
    # Cumulative executed counters == incremental prediction, exactly —
    # gated runs included: the prediction replays each group's realized
    # gate trace (legacy gate= skips carry weight-0 records).
    assert session.stats == session.predicted
    # A non-adaptive engine's a-priori expectation is the prediction's
    # all-gates-fire floor: equal when nothing gated, an upper bound else.
    assert session.expected.flops_executed >= session.stats.flops_executed
    for f, req in zip(futures, reqs):
        rs = f.result()
        ss = solo.serve(req)
        assert set(rs.outputs) == set(ss.outputs)
        assert set(rs.effective_order) >= set(rs.outputs)
        for t in rs.outputs:
            np.testing.assert_allclose(
                np.asarray(rs.outputs[t]), np.asarray(ss.outputs[t]),
                rtol=1e-5, atol=1e-6)


def test_session_matches_sequential_fixed_seeds():
    rng = np.random.default_rng(12)
    for trial in range(8):
        n = int(rng.integers(1, 9))
        spec = [(int(rng.integers(0, len(SUBSET_CHOICES))),
                 float(rng.uniform(0.0, 1.0))) for _ in range(n)]
        check_session_matches_sequential(
            spec,
            data_seed=trial,
            policy_idx=trial % len(POLICY_MAKERS),
            gated=bool(trial % 2),
            resolve=bool((trial // 2) % 2),
        )


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(
        spec=st.lists(
            st.tuples(st.integers(0, len(SUBSET_CHOICES) - 1),
                      st.floats(0.0, 2.0, allow_nan=False)),
            min_size=1, max_size=8,
        ),
        data_seed=st.integers(0, 2**16),
        policy_idx=st.integers(0, len(POLICY_MAKERS) - 1),
        gated=st.booleans(),
        resolve=st.booleans(),
    )
    def test_session_matches_sequential_hypothesis(
            spec, data_seed, policy_idx, gated, resolve):
        check_session_matches_sequential(
            spec, data_seed, policy_idx, gated, resolve)
