"""Chaos serving at scale: the 10^4-request fault-injected soak.

The chaos benchmark's dry run keeps CI fast with a 30-request trace; this
slow-marked test drives the same deterministic chaos machinery (Poisson
arrivals, three tenants, deadlines, seeded ~10% fault rate plus a scripted
burst) through four orders of magnitude more requests and asserts the
invariants that only show up at scale: no stranded futures, counters that
stay exact across thousands of rollback/retry cycles, and a served
fraction that survives sustained fault pressure.

Runs in ~20s; deselect with ``-m 'not slow'`` for quick iteration.
"""
import pytest

from benchmarks.serving_chaos import (
    FAULT_RATES, FAULT_SCRIPT, chaos_trace, run_trace,
)
from benchmarks.serving_batch import build_program
from repro.serving import FaultInjector

N_REQUESTS = 10_000
DIM = 16


@pytest.mark.slow
def test_ten_thousand_request_chaos_soak():
    prog = build_program(DIM)
    trace = chaos_trace(N_REQUESTS, DIM, rate=40.0, seed=3)
    injector = FaultInjector(rates=FAULT_RATES, script=FAULT_SCRIPT, seed=7)
    session, futures = run_trace(prog, trace, shapes=(1, 2, 4),
                                 injector=injector)

    # Liveness: every submitted future resolved one way or the other —
    # served, shed, expired, or failed — none stranded.
    assert len(futures) == N_REQUESTS
    stranded = [f for f in futures if not f.done()]
    assert not stranded, f"{len(stranded)} futures never resolved"

    # The chaos actually happened: the seeded rates inject on the order of
    # a thousand faults over this trace, and the scripted burst fired.
    assert injector.total_injected > 100
    assert injector.injected["plan"] >= len(FAULT_SCRIPT["plan"])

    # Exactness survives scale: thousands of groups, retries, degraded
    # re-runs and rollbacks later, executed counters still equal the
    # prediction field for field.
    assert session.stats == session.predicted

    # Under ~10% combined fault pressure with bounded retries + degrade,
    # the overwhelming majority of requests must still be served; shed /
    # expired / failed requests are SLO outcomes, not crashes.
    served = sum(1 for f in futures if f.done() and f.error() is None)
    assert served >= 0.8 * N_REQUESTS, f"only {served}/{N_REQUESTS} served"

    # Recovery machinery exercised, not bypassed.
    assert session.group_retries > 0
    assert session.groups_executed > N_REQUESTS / 8  # max group size 4 x
    # batch shapes <=4 bounds requests per group well under 8
