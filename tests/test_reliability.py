"""Fault-tolerant serving: deadlines, backpressure, fault injection, and
crash-consistent group recovery.

The contract under test extends tests/test_session.py's "scheduling never
changes results" invariant through failures:

* a mid-pump failure is isolated to the failing *group* — its futures fail
  with typed ``RequestError``s (seq, task subset, tenant, group id, original
  traceback chained) while every other group serves normally, and the
  session stays fully usable afterwards;
* recovery is crash-consistent — each failed attempt rolls the executor's
  residency back to its pre-attempt snapshot, every retry re-predicts from
  the actual post-rollback residency, and only successful attempts merge
  into the counters, so ``session.stats == session.predicted`` stays exact,
  field for field, across rollbacks, retries, and degraded runs;
* under *random* fault schedules, deadlines, priorities, and admission
  orders, every submitted future reaches a terminal state (never stranded)
  and every successful response's outputs are allclose to a fault-free
  sequential serve of the same request.

Property tests run under hypothesis when installed and always under a
fixed-seed randomized fallback.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import MSP430
from repro.serving import (
    AffinityPolicy, DeadlineExceeded, EnginePolicy, FaultInjector,
    GreedyBatchPolicy, InjectedFault, MultitaskEngine, MultitaskRequest,
    QueueFull, RequestError, RequestGroupScheduler, RetryPolicy,
    SloAwarePolicy, TenantStats, WindowPolicy,
)
from tests.test_session import DIM, PROGRAM, FakeClock, _requests

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

SUBSET_CHOICES = (None, (0,), (1, 2), (0, 3), (2, 1), (0, 1, 2, 3))
NO_RECOVERY = RetryPolicy(max_retries=0, degrade=False)


def _engine(**kwargs):
    kwargs.setdefault("scheduler", RequestGroupScheduler(batch_shapes=(1, 4)))
    return MultitaskEngine(PROGRAM, hw=MSP430, **kwargs)


def _reference_outputs(requests):
    """Fault-free sequential serve: the ground truth for every scenario.

    SLO metadata is stripped — the reference defines what the *outputs*
    should be, and a one-shot serve on the wall clock would spuriously
    expire any simulated-clock deadline.
    """
    eng = _engine()
    return [
        eng.serve(MultitaskRequest(x=r.x, tasks=r.tasks)) for r in requests
    ]


def _assert_allclose_response(got, ref):
    assert set(got.outputs) == set(ref.outputs)
    for t in ref.outputs:
        np.testing.assert_allclose(
            np.asarray(got.outputs[t]), np.asarray(ref.outputs[t]),
            rtol=1e-5, atol=1e-6,
        )


# --------------------------------------------------------------------------
# Unit coverage: injector, retry policy, tenant stats
# --------------------------------------------------------------------------

def test_fault_injector_script_and_determinism():
    inj = FaultInjector(script={"plan": {1}}, rates={"dispatch": 0.5}, seed=7)
    inj.check("plan")  # invocation 0: not scripted
    with pytest.raises(InjectedFault) as exc:
        inj.check("plan", group_tasks=(0, 1))
    assert exc.value.site == "plan" and exc.value.index == 1
    assert exc.value.context == {"group_tasks": (0, 1)}
    # Same seed + same call sequence => identical Bernoulli schedule.
    fires = []
    for trial in range(2):
        t = FaultInjector(rates={"dispatch": 0.5}, seed=7)
        row = []
        for i in range(50):
            try:
                t.check("dispatch")
                row.append(False)
            except InjectedFault:
                row.append(True)
        fires.append(row)
    assert fires[0] == fires[1]
    assert any(fires[0]) and not all(fires[0])


def test_fault_injector_validation_and_cap():
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultInjector(rates={"teleport": 0.1})
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultInjector(script={"teleport": {0}})
    with pytest.raises(ValueError, match="must be in"):
        FaultInjector(rates={"plan": 1.5})
    inj = FaultInjector(rates={"plan": 1.0}, max_faults=2)
    for _ in range(2):
        with pytest.raises(InjectedFault):
            inj.check("plan")
    inj.check("plan")  # capped: no more faults
    assert inj.total_injected == 2 and inj.invocations["plan"] == 3


def test_retry_policy_backoff():
    with pytest.raises(ValueError):
        RetryPolicy(max_retries=-1)
    with pytest.raises(ValueError):
        RetryPolicy(backoff_base=-0.1)
    p = RetryPolicy(backoff_base=0.1, backoff_factor=2.0, backoff_max=0.3)
    assert p.backoff_seconds(0) == pytest.approx(0.1)
    assert p.backoff_seconds(1) == pytest.approx(0.2)
    assert p.backoff_seconds(5) == pytest.approx(0.3)  # capped
    assert RetryPolicy().backoff_seconds(3) == 0.0     # base 0 => no sleep


def test_session_backoff_uses_sleep_hook():
    slept = []
    inj = FaultInjector(script={"load": {0, 1}})
    eng = _engine(fault_injector=inj)
    s = eng.session(
        retry=RetryPolicy(max_retries=2, backoff_base=0.25, backoff_max=1.0),
        sleep=slept.append,
    )
    fut = s.submit(MultitaskRequest(
        x=jnp.asarray(np.zeros(DIM), jnp.float32)))
    s.drain()
    assert fut.error() is None
    assert slept == [pytest.approx(0.25), pytest.approx(0.5)]
    assert s.backoff_seconds == pytest.approx(0.75)


# --------------------------------------------------------------------------
# Regression: session stays usable after a mid-pump failure
# --------------------------------------------------------------------------

def test_session_usable_after_mid_pump_failure():
    """The ISSUE's named regression: poison one group mid-drain, then keep
    serving.  The queue stays consistent, subsequent submits serve
    correctly, and stats == predicted exactly over the succeeded groups."""
    rng = np.random.default_rng(21)
    subsets = [None, (0,), (1, 2), (0, 3), None, (1, 2)]
    reqs = _requests(rng, subsets)
    ref = _reference_outputs(reqs)

    # Script faults dense enough to exhaust retries AND the unfused rung
    # for whichever group dispatches first (plan fires on every attempt's
    # entry into _execute_group; the unfused rung re-enters it too).
    inj = FaultInjector(script={"plan": {0, 1, 2}})
    eng = _engine(fault_injector=inj)
    session = eng.session(retry=RetryPolicy(max_retries=1, degrade=True))
    futs = [session.submit(r) for r in reqs]
    session.drain()

    failed = [f for f in futs if f.error() is not None]
    served = [(f, r) for f, r in zip(futs, ref) if f.error() is None]
    assert failed, "the scripted faults must sink at least one group"
    assert served, "only one group may fail; the rest must serve"
    for f in failed:
        err = f.error()
        assert isinstance(err, RequestError)
        assert err.seq == f.seq and err.group_id is not None
        assert isinstance(err.__cause__, InjectedFault)
    for f, r in served:
        _assert_allclose_response(f.result(), r)
    assert session.groups_failed == 1
    assert session.stats == session.predicted

    # The session keeps serving: new submits drain to correct outputs and
    # the counter-exact invariant extends across the recovery boundary.
    eng.fault_injector = None
    futs2 = [session.submit(r) for r in reqs]
    session.drain()
    for f, r in zip(futs2, ref):
        _assert_allclose_response(f.result(), r)
    assert session.pending_count() == 0
    assert session.stats == session.predicted


def test_rollback_keeps_counters_exact_through_transient_faults():
    """Every group eventually succeeds (transient faults only): outputs
    match the fault-free run and stats == predicted stays exact even
    though several attempts were rolled back mid-group."""
    rng = np.random.default_rng(22)
    subsets = [None, (1, 2), (0, 3), None, (0,), (1, 2), (2, 1)]
    reqs = _requests(rng, subsets)
    ref = _reference_outputs(reqs)
    # One fault at each site, spread over early invocations: each fails a
    # different attempt once, then the retry goes through.
    inj = FaultInjector(script={"plan": {1}, "load": {2}, "dispatch": {3}})
    eng = _engine(fault_injector=inj)
    session = eng.session(retry=RetryPolicy(max_retries=3))
    futs = [session.submit(r) for r in reqs]
    session.drain()
    for f, r in zip(futs, ref):
        resp = f.result()
        assert resp.degraded is None
        _assert_allclose_response(resp, r)
    assert session.group_retries >= 1
    assert session.groups_failed == 0
    assert session.stats == session.predicted


def test_degraded_unfused_run_matches_and_stays_exact():
    # dispatch faults fire inside _run_group on the fused path; the
    # unfused rung re-dispatches through the same site, so cap the faults
    # to exhaust the primary attempts only.
    rng = np.random.default_rng(23)
    reqs = _requests(rng, [None, None])
    ref = _reference_outputs(reqs)
    inj = FaultInjector(rates={"dispatch": 1.0}, max_faults=2, seed=5)
    eng = _engine(fault_injector=inj)
    session = eng.session(retry=RetryPolicy(max_retries=1, degrade=True))
    futs = [session.submit(r) for r in reqs]
    session.drain()
    resp = futs[0].result()
    assert resp.degraded == "unfused" and resp.retries == 2
    for f, r in zip(futs, ref):
        _assert_allclose_response(f.result(), r)
    assert session.degraded_runs == 1
    assert session.stats == session.predicted


# --------------------------------------------------------------------------
# Mesh degradation ladder: single-device fallback rung
# --------------------------------------------------------------------------

needs_mesh = pytest.mark.skipif(
    jax.device_count() < 8, reason="needs 8 (forced host) devices"
)


def _mesh_engine(**kwargs):
    from repro.launch.mesh import make_mesh
    from repro.sharding.policy import TP_POLICY

    return MultitaskEngine(PROGRAM, hw=MSP430, policy=EnginePolicy(
        mesh=make_mesh((4, 2), ("data", "model")),
        sharding=TP_POLICY,
        scheduler=RequestGroupScheduler(batch_shapes=(1, 4)),
    ), **kwargs)


@needs_mesh
def test_mesh_fallback_rung_serves_group_on_single_device():
    """When every sharded attempt fails, the ladder's single_device rung
    serves the group cold on the off-mesh fallback executor: outputs match
    the fault-free reference, the counters stay exact (no collective bytes
    — the fallback has no mesh), and the *primary* executor keeps its
    rolled-back residency."""
    rng = np.random.default_rng(31)
    reqs = _requests(rng, [None, (1, 2)])
    ref = _reference_outputs(reqs)
    # Two primary attempts fault at dispatch; the third dispatch is the
    # fallback rung, which must go through.
    inj = FaultInjector(rates={"dispatch": 1.0}, max_faults=2, seed=9)
    eng = _mesh_engine(fault_injector=inj)
    session = eng.session(retry=RetryPolicy(max_retries=1, degrade=True))
    pre = eng.executor.residency_state()
    f0 = session.submit(reqs[0])
    session.drain()
    resp = f0.result()
    assert resp.degraded == "single_device" and resp.retries == 2
    _assert_allclose_response(resp, ref[0])
    assert session.degraded_runs == 1
    assert session.groups_failed == 0
    assert session.stats == session.predicted
    # The degraded group ran cold off-mesh: its counters carry no
    # collective traffic and the sharded executor's residency is exactly
    # the pre-attempt snapshot the rollback restored.
    assert session.stats.collective_bytes == 0
    assert eng.executor.residency_state() == pre
    # Later groups go back to the sharded primary path.
    f1 = session.submit(reqs[1])
    session.drain()
    resp1 = f1.result()
    assert resp1.degraded is None
    _assert_allclose_response(resp1, ref[1])
    assert session.stats == session.predicted
    assert session.stats.collective_bytes > 0


@needs_mesh
def test_mesh_fallback_failure_rolls_back_and_keeps_serving():
    """If the fallback rung itself fails, the residency snapshot restore
    runs, the members fail cleanly, and the session serves the next group
    normally with exact counters."""
    rng = np.random.default_rng(32)
    reqs = _requests(rng, [None, (0, 3)])
    ref = _reference_outputs(reqs)
    # 3 faults: two primary attempts + the fallback rung for group 0 only.
    inj = FaultInjector(rates={"dispatch": 1.0}, max_faults=3, seed=9)
    eng = _mesh_engine(fault_injector=inj)
    session = eng.session(retry=RetryPolicy(max_retries=1, degrade=True))
    pre = eng.executor.residency_state()
    f0 = session.submit(reqs[0])
    session.drain()
    with pytest.raises(RequestError) as exc_info:
        f0.result()
    assert isinstance(exc_info.value.__cause__, InjectedFault)
    assert session.groups_failed == 1
    assert session.degraded_runs == 0
    # Ladder exhausted without merging anything: counters untouched and
    # the sharded executor rolled back to its pre-group residency.
    assert session.stats == session.predicted
    assert eng.executor.residency_state() == pre
    # The session is still fully usable on the mesh path afterwards.
    f1 = session.submit(reqs[1])
    session.drain()
    resp = f1.result()
    assert resp.degraded is None
    _assert_allclose_response(resp, ref[1])
    assert session.stats == session.predicted
    assert session.stats.collective_bytes > 0


# --------------------------------------------------------------------------
# Deadlines, backpressure, tenants
# --------------------------------------------------------------------------

def test_deadline_expiry_before_planning():
    clock = FakeClock()
    eng = _engine()
    session = eng.session(
        policy=WindowPolicy(max_wait=10.0, max_group_size=4), clock=clock)
    rng = np.random.default_rng(24)
    x = jnp.asarray(rng.normal(size=(DIM,)), jnp.float32)
    f_dead = session.submit(MultitaskRequest(x, deadline=1.0, tenant="a"))
    f_live = session.submit(MultitaskRequest(x, tenant="b"))
    clock.advance(2.0)  # past f_dead's deadline, below the window's max_wait
    session.step()
    assert isinstance(f_dead.error(), DeadlineExceeded)
    assert f_dead.error().tenant == "a"
    assert not f_live.done()  # still pending, not expired
    session.drain()
    assert f_live.error() is None
    assert session.requests_expired == 1
    assert session.tenant_stats("a").expired == 1
    assert session.tenant_stats("b").admitted == 1
    assert session.stats == session.predicted


def test_backpressure_reject_and_shed():
    rng = np.random.default_rng(25)
    x = jnp.asarray(rng.normal(size=(DIM,)), jnp.float32)

    # reject: over-limit submission fails immediately, queue untouched
    s_rej = _engine().session(max_pending=2, overload="reject")
    f1, f2 = (s_rej.submit(MultitaskRequest(x)) for _ in range(2))
    f3 = s_rej.submit(MultitaskRequest(x, priority=99))
    err = f3.error()
    assert isinstance(err, QueueFull) and not err.shed
    assert s_rej.pending_count() == 2 and s_rej.requests_rejected == 1
    s_rej.drain()
    assert f1.error() is None and f2.error() is None

    # shed: a higher-priority arrival evicts the youngest lowest-priority
    # pending entry; equal priority falls back to reject
    s_shed = _engine().session(max_pending=2, overload="shed")
    f_old = s_shed.submit(MultitaskRequest(x, priority=0))
    f_young = s_shed.submit(MultitaskRequest(x, priority=0))
    f_vip = s_shed.submit(MultitaskRequest(x, priority=1))
    assert isinstance(f_young.error(), QueueFull) and f_young.error().shed
    assert not f_old.done() and not f_vip.done()
    f_equal = s_shed.submit(MultitaskRequest(x, priority=0))
    assert isinstance(f_equal.error(), QueueFull) and not f_equal.error().shed
    s_shed.drain()
    assert f_old.error() is None and f_vip.error() is None
    assert s_shed.requests_shed == 1 and s_shed.requests_rejected == 1


def test_per_tenant_quota_and_wait_aggregates():
    clock = FakeClock()
    eng = _engine()
    session = eng.session(clock=clock, max_pending_per_tenant=2)
    rng = np.random.default_rng(26)
    x = jnp.asarray(rng.normal(size=(DIM,)), jnp.float32)
    fa = [session.submit(MultitaskRequest(x, tenant="a")) for _ in range(3)]
    fb = session.submit(MultitaskRequest(x, tenant="b"))
    # tenant a's third submit breaches its quota; tenant b is unaffected
    assert isinstance(fa[2].error(), QueueFull)
    assert fa[2].error().tenant == "a"
    assert not fb.done()
    clock.advance(1.5)
    session.drain()
    ts_a, ts_b = session.tenant_stats("a"), session.tenant_stats("b")
    assert ts_a.submitted == 3 and ts_a.admitted == 2 and ts_a.rejected == 1
    assert ts_b.submitted == 1 and ts_b.admitted == 1
    assert ts_a.mean_admission_wait == pytest.approx(1.5)
    assert ts_a.max_admission_wait == pytest.approx(1.5)
    assert session.tenant_mean_admission_wait("b") == pytest.approx(1.5)
    # global aggregates cover both tenants
    assert session.mean_admission_wait == pytest.approx(1.5)
    assert TenantStats().mean_admission_wait == 0.0


def test_slo_aware_policy_orders_by_urgency_and_affinity():
    clock = FakeClock()
    eng = _engine()
    policy = SloAwarePolicy(max_group_size=4, min_pending=99,
                            slack_threshold=0.5)
    session = eng.session(policy=policy, clock=clock)
    rng = np.random.default_rng(27)

    def req(subset, **kw):
        return MultitaskRequest(
            x=jnp.asarray(rng.normal(size=(DIM,)), jnp.float32),
            tasks=subset, **kw)

    f_lazy = session.submit(req((0,)))
    f_urgent = session.submit(req((1, 2), deadline=0.4))
    # Below min_pending and no urgency at t=0... deadline slack 0.4 <= 0.5
    # makes the (1, 2) bucket fire immediately despite thresholds.
    done = session.step()
    assert f_urgent.done() and f_urgent.error() is None
    assert not f_lazy.done()
    assert len(done) == 1
    session.drain()
    assert f_lazy.error() is None
    assert session.stats == session.predicted


def test_slo_aware_policy_starvation_override():
    clock = FakeClock()
    eng = _engine()
    policy = SloAwarePolicy(max_group_size=2, min_pending=2,
                            starvation_wait=5.0)
    session = eng.session(policy=policy, clock=clock)
    rng = np.random.default_rng(28)
    x = jnp.asarray(rng.normal(size=(DIM,)), jnp.float32)
    f_starved = session.submit(MultitaskRequest(x, tasks=(0, 3), tenant="b"))
    clock.advance(6.0)
    # Fresh affinity-friendly work arrives; the starved request has waited
    # past starvation_wait, so its bucket is admitted first regardless.
    f_fresh = session.submit(MultitaskRequest(x, tasks=(0,), tenant="a"))
    session.step()
    assert f_starved.done() and f_starved.error() is None
    assert not f_fresh.done()
    session.drain()
    assert f_fresh.error() is None


def test_session_validation():
    eng = _engine()
    with pytest.raises(ValueError, match="overload"):
        eng.session(overload="panic")
    with pytest.raises(ValueError, match="max_pending"):
        eng.session(max_pending=0)
    with pytest.raises(ValueError, match="max_pending_per_tenant"):
        eng.session(max_pending_per_tenant=0)


# --------------------------------------------------------------------------
# Property: never stranded, correct when served, exact when succeeded
# --------------------------------------------------------------------------

def _run_chaos_scenario(subset_idx, deadlines, priorities, fault_seed,
                        rates, policy_idx, max_retries):
    """One random scenario: every future terminal; successful outputs
    allclose to the fault-free sequential run; stats == predicted."""
    rng = np.random.default_rng(fault_seed)
    subsets = [SUBSET_CHOICES[i % len(SUBSET_CHOICES)] for i in subset_idx]
    reqs = []
    for i, s in enumerate(subsets):
        reqs.append(MultitaskRequest(
            x=jnp.asarray(rng.normal(size=(DIM,)), jnp.float32), tasks=s,
            deadline=deadlines[i % len(deadlines)] if deadlines else None,
            priority=priorities[i % len(priorities)] if priorities else 0,
            tenant=("t0", "t1", None)[i % 3],
        ))
    ref = _reference_outputs(reqs)
    policy = (
        GreedyBatchPolicy(),
        WindowPolicy(max_wait=0.5, max_group_size=4),
        AffinityPolicy(max_group_size=4, min_pending=2),
        SloAwarePolicy(max_group_size=4, min_pending=2, slack_threshold=0.25),
    )[policy_idx % 4]
    inj = FaultInjector(rates=rates, seed=fault_seed)
    eng = _engine(fault_injector=inj)
    clock = FakeClock()
    session = eng.session(
        policy=policy, clock=clock, max_pending=6, overload="shed",
        retry=RetryPolicy(max_retries=max_retries),
    )
    futs = []
    for r in reqs:
        futs.append(session.submit(r))
        clock.advance(0.125)
        session.step()
    session.drain()

    for f, r in zip(futs, ref):
        assert f.done(), f"future {f.seq} stranded"
        if f.error() is None:
            _assert_allclose_response(f.result(), r)
        else:
            assert isinstance(f.error(), RequestError)
    assert session.pending_count() == 0
    assert session.stats == session.predicted
    # Accounting closes: every submission is admitted, rejected, or shed,
    # and every admitted request either resolved, expired... expiry happens
    # pre-admission, so: submitted = admitted + rejected + shed + expired
    # + still-pending (none after drain).
    assert session.requests_submitted == (
        session.requests_admitted + session.requests_rejected
        + session.requests_shed + session.requests_expired
    )


def test_chaos_property_fallback():
    rng = np.random.default_rng(99)
    for trial in range(6):
        n = int(rng.integers(3, 10))
        _run_chaos_scenario(
            subset_idx=list(rng.integers(0, len(SUBSET_CHOICES), n)),
            deadlines=(
                [float(d) for d in rng.uniform(0.1, 3.0, 3)]
                if trial % 2 else []
            ),
            priorities=[int(p) for p in rng.integers(0, 3, 3)],
            fault_seed=int(rng.integers(0, 2**31)),
            rates={
                "plan": float(rng.uniform(0, 0.2)),
                "load": float(rng.uniform(0, 0.2)),
                "dispatch": float(rng.uniform(0, 0.1)),
            },
            policy_idx=trial,
            max_retries=int(rng.integers(0, 3)),
        )


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(
        subset_idx=st.lists(
            st.integers(0, len(SUBSET_CHOICES) - 1), min_size=2, max_size=8),
        deadlines=st.lists(
            st.floats(0.1, 3.0, allow_nan=False), max_size=3),
        priorities=st.lists(st.integers(0, 3), max_size=3),
        fault_seed=st.integers(0, 2**31 - 1),
        plan_rate=st.floats(0.0, 0.25),
        dispatch_rate=st.floats(0.0, 0.15),
        policy_idx=st.integers(0, 3),
        max_retries=st.integers(0, 2),
    )
    def test_chaos_property(subset_idx, deadlines, priorities, fault_seed,
                            plan_rate, dispatch_rate, policy_idx,
                            max_retries):
        _run_chaos_scenario(
            subset_idx, deadlines, priorities, fault_seed,
            {"plan": plan_rate, "dispatch": dispatch_rate},
            policy_idx, max_retries,
        )
