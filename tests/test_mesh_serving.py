"""Mesh-sharded request-group serving (EnginePolicy.mesh).

The contract under test, on a forced-8-device CPU mesh (see conftest.py):

* sharding is invisible to results — sharded group serving returns outputs
  allclose to the single-device engine for random task subsets;
* cost prediction stays counter-exact — ``session.stats`` equals
  ``session.predicted`` field for field, *including* the per-kind collective
  byte counters, which are nonzero on a >1-device mesh;
* the predicted collective bytes are real, not modelled: summing
  ``HloCostModel`` (``analyze_hlo``) over the lowered suffix programs the
  plan actually dispatches reproduces the session's counters exactly.

Property-tested under hypothesis when installed, always under a fixed-seed
randomized fallback, in the style of tests/test_session.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import BlockCost, MSP430, MultitaskProgram
from repro.core.task_graph import TaskGraph
from repro.launch.hlo_cost import analyze_hlo
from repro.launch.mesh import make_mesh
from repro.serving import (
    EnginePolicy, MultitaskEngine, MultitaskRequest, RequestGroupScheduler,
)
from repro.sharding.policy import FSDP_TP_POLICY, TP_POLICY

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

pytestmark = pytest.mark.skipif(
    jax.device_count() < 8, reason="needs 8 (forced host) devices"
)

DIM = 8
GRAPH = TaskGraph.from_groups([
    [[0, 1, 2, 3]], [[0, 1], [2, 3]], [[0], [1], [2, 3]],
])
SUBSET_CHOICES = (None, (0,), (1, 2), (0, 3), (2, 1), (0, 1, 2, 3))
COLLECTIVE_FIELDS = {
    "all-gather": "all_gather_bytes",
    "all-reduce": "all_reduce_bytes",
    "reduce-scatter": "reduce_scatter_bytes",
}


def _program(graph=GRAPH, seed=0):
    rng = np.random.default_rng(seed)
    costs = [BlockCost(weight_bytes=100.0 * (d + 1), flops=10.0 * (d + 1))
             for d in range(graph.depth)]

    def block(p, x):
        return jnp.tanh(x @ p)

    node_params = {
        node: jnp.asarray(rng.normal(size=(DIM, DIM)), jnp.float32)
        for node in graph.nodes()
    }
    heads = [lambda p, x: x @ p] * graph.num_tasks
    head_params = [jnp.asarray(rng.normal(size=(DIM, 3)), jnp.float32)
                   for _ in range(graph.num_tasks)]
    return MultitaskProgram(
        graph, [block] * graph.depth, node_params, heads, head_params, costs
    )


PROGRAM = _program()


def _requests(rng, subsets):
    return [MultitaskRequest(
        x=jnp.asarray(rng.normal(size=(DIM,)), jnp.float32), tasks=s)
        for s in subsets]


def _mesh_engine(sharding):
    return MultitaskEngine(PROGRAM, hw=MSP430, policy=EnginePolicy(
        mesh=make_mesh((4, 2), ("data", "model")),
        sharding=sharding,
        scheduler=RequestGroupScheduler(batch_shapes=(1, 4)),
    ))


def _measured_collectives(engine, groups):
    """Independent re-measurement: per dispatched suffix program, run the
    HLO analyzer over the exact lowered text and sum per kind.  ``prev``
    resets at every group boundary — activations never cross groups, so a
    group's first task always dispatches its full path."""
    totals = {kind: 0.0 for kind in COLLECTIVE_FIELDS}
    other = 0.0
    for g in groups:
        prev = None
        for t in engine.group_order(g):
            shared = (
                engine.program.graph.shared_prefix_depth(prev, t)
                if prev is not None else 0
            )
            acc = analyze_hlo(engine.executor.suffix_hlo(t, shared, g.xs))
            seen = 0.0
            for kind in COLLECTIVE_FIELDS:
                v = acc.get(f"coll_{kind}", 0.0)
                totals[kind] += v
                seen += v
            other += acc["collective_bytes"] - seen
            prev = t
    return totals, other


def _check_roundtrip(subsets, seed):
    rng = np.random.default_rng(seed)
    reqs = _requests(rng, subsets)
    solo = MultitaskEngine(
        PROGRAM, hw=MSP430,
        scheduler=RequestGroupScheduler(batch_shapes=(1, 4)),
    )
    solo_resp = solo.serve_batch(reqs)
    for sharding in (TP_POLICY, FSDP_TP_POLICY):
        eng = _mesh_engine(sharding)
        # Padded widths must split evenly over the 4-way data axis.
        assert all(s % eng.data_shards == 0 for s in eng.scheduler.batch_shapes)
        groups = eng.plan_groups(reqs)
        measured, measured_other = _measured_collectives(eng, groups)

        session = eng.session()
        futures = [session.submit(r) for r in reqs]
        session.drain()

        # Counter-exactness extends to the collective terms.
        assert session.stats == session.predicted
        assert session.stats.collective_bytes > 0
        # Predicted == independently HLO-measured, exactly, per kind.
        assert session.stats.all_gather_bytes == measured["all-gather"]
        assert session.stats.all_reduce_bytes == measured["all-reduce"]
        assert session.stats.reduce_scatter_bytes == measured["reduce-scatter"]
        assert session.stats.other_collective_bytes == measured_other

        # Sharding never changes results.
        for f, ref in zip(futures, solo_resp):
            resp = f.result()
            assert set(resp.outputs) == set(ref.outputs)
            for t in resp.outputs:
                np.testing.assert_allclose(
                    np.asarray(resp.outputs[t]), np.asarray(ref.outputs[t]),
                    rtol=1e-5, atol=1e-5,
                )


def test_mesh_serving_fixed_case():
    _check_roundtrip(
        [None, (0,), (1, 2), (0, 3), (2, 1), None, (1, 2), None], seed=0
    )


def test_mesh_serving_randomized_fallback():
    rng = np.random.default_rng(7)
    for trial in range(2):
        n = int(rng.integers(1, 7))
        subsets = [SUBSET_CHOICES[i]
                   for i in rng.integers(0, len(SUBSET_CHOICES), n)]
        _check_roundtrip(subsets, seed=100 + trial)


if HAVE_HYPOTHESIS:
    @settings(max_examples=3, deadline=None)
    @given(
        subsets=st.lists(
            st.sampled_from(SUBSET_CHOICES), min_size=1, max_size=6
        ),
        seed=st.integers(0, 2**16),
    )
    def test_mesh_serving_property(subsets, seed):
        _check_roundtrip(subsets, seed)


def test_single_request_on_mesh():
    eng = _mesh_engine(TP_POLICY)
    solo = MultitaskEngine(PROGRAM, hw=MSP430)
    x = jnp.asarray(np.random.default_rng(3).normal(size=(DIM,)), jnp.float32)
    a = eng.serve(MultitaskRequest(x=x))
    b = solo.serve(MultitaskRequest(x=x))
    assert set(a.outputs) == set(b.outputs)
    for t in b.outputs:
        np.testing.assert_allclose(
            np.asarray(a.outputs[t]), np.asarray(b.outputs[t]),
            rtol=1e-5, atol=1e-5,
        )
