"""Intermittent-power serving: durable journal, power-failure-atomic
checkpoint/resume, and energy-budgeted execution.

The contract under test extends tests/test_reliability.py's crash
consistency through whole-process death:

* the journal is a write-ahead log on a store that outlives the session
  (FRAM): replay is an idempotent fold, the first commit per group wins,
  and a journal containing duplicate records recovers identical state;
* segmented fused-suffix execution is invisible to results — cutting a
  suffix at checkpoint depths produces the same outputs and the same
  counters as the uncut dispatch, plus one hook firing per cut;
* a suffix interrupted at depth d resumes from d+1, not 0, via
  ``activation_checkpoint()`` / ``restore_activation()``;
* :meth:`ServingSession.recover` rebuilds a session with exactly-once
  response semantics — committed groups never re-run, the interrupted
  group resumes under its original id, outputs match the uninterrupted
  run, and ``session.stats == session.predicted`` stays exact (checkpoint
  terms included) across arbitrarily many rebooted recoveries;
* the :class:`EnergyBudget` duty-cycles the pump deterministically and
  isolates infeasible groups instead of wedging the session.
"""
import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import BlockCost, GraphCostModel, MSP430, MultitaskProgram
from repro.core.executor import TaskGraphExecutor
from repro.core.task_graph import TaskGraph
from repro.core.types import ExecutionStats
from repro.serving import (
    EnergyBudget, EnginePolicy, FileJournalStore, Journal, MemoryJournalStore,
    MultitaskEngine, MultitaskRequest, PowerFailure, PowerFailureInjector,
    RequestGroupScheduler, ServingSession,
)

DIM = 8
GRAPH = TaskGraph.from_groups([
    [[0, 1, 2, 3]], [[0, 1], [2, 3]], [[0], [1], [2, 3]],
])


def _program(seed=0, act_bytes=8.0):
    rng = np.random.default_rng(seed)
    costs = [
        BlockCost(weight_bytes=100.0 * (d + 1), flops=1e4 * (d + 1),
                  act_bytes=act_bytes)
        for d in range(GRAPH.depth)
    ]

    def block(p, x):
        return jnp.tanh(x @ p)

    node_params = {
        node: jnp.asarray(rng.normal(size=(DIM, DIM)), jnp.float32)
        for node in GRAPH.nodes()
    }
    heads = [lambda p, x: x @ p] * GRAPH.num_tasks
    head_params = [jnp.asarray(rng.normal(size=(DIM, 3)), jnp.float32)
                   for _ in range(GRAPH.num_tasks)]
    return MultitaskProgram(
        GRAPH, [block] * GRAPH.depth, node_params, heads, head_params, costs
    )


PROGRAM = _program()


def _engine(prog=PROGRAM, **kw):
    kw.setdefault("scheduler", RequestGroupScheduler(batch_shapes=(1, 2, 4)))
    return MultitaskEngine(
        prog, hw=MSP430, policy=EnginePolicy(warm_start=True), **kw
    )


def _requests(n=6, seed=1, tasks=None):
    rng = np.random.default_rng(seed)
    return [
        MultitaskRequest(
            x=jnp.asarray(rng.normal(size=(DIM,)), jnp.float32), tasks=tasks)
        for _ in range(n)
    ]


def _baseline(reqs):
    """Uninterrupted journaled serve: the reference outputs."""
    engine = _engine()
    session = ServingSession(engine, journal=Journal(MemoryJournalStore()))
    futs = [session.submit(r) for r in reqs]
    session.drain()
    assert session.stats == session.predicted
    return {f.seq: f.result().outputs for f in futs}


def _assert_outputs_match(got, ref):
    assert set(got) == set(ref)
    for t in ref:
        np.testing.assert_allclose(
            np.asarray(got[t]), np.asarray(ref[t]), rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------------
# Journal: replay idempotence, exactly-once, file-backed store
# --------------------------------------------------------------------------

def test_replay_is_idempotent_and_first_commit_wins():
    store = MemoryJournalStore()
    j = Journal(store)
    x = np.ones(DIM, np.float32)
    j.admit(0, x, None, deadline=None, priority=0, tenant=None)
    j.admit(1, x, (0, 1), deadline=1.5, priority=2, tenant="acme")
    j.admit(1, x, (0, 1), deadline=1.5, priority=2, tenant="acme")  # dup
    j.group_begin(0, [0, 1], [0, 1], 2)
    out = [{0: np.zeros(3, np.float32)}, {1: np.ones(3, np.float32)}]
    j.group_commit(0, [0, 1], out, [None] * GRAPH.depth, ExecutionStats())
    # Duplicate commit with DIFFERENT outputs: exactly-once means ignored.
    j.group_commit(0, [0, 1], [{0: np.full(3, 9.0)}, {1: np.full(3, 9.0)}],
                   [None] * GRAPH.depth, ExecutionStats())
    a, b = j.replay(), j.replay()
    assert set(a.admitted) == {0, 1}
    assert a.admitted[1]["tenant"] == "acme"
    assert a.inflight is None          # the commit closed the open group
    assert set(a.responses) == {0, 1}
    np.testing.assert_array_equal(a.responses[0]["outputs"][0],
                                  np.zeros(3, np.float32))
    assert set(b.responses) == set(a.responses)
    assert b.pending_seqs == a.pending_seqs == []


def test_replay_recovers_inflight_group_and_latest_checkpoint():
    j = Journal(MemoryJournalStore())
    x = np.ones(DIM, np.float32)
    for s in range(3):
        j.admit(s, x, None, deadline=None, priority=0, tenant=None)
    j.group_begin(7, [0, 2], [2, 0], 2)
    j.checkpoint(7, 0, 2, 0, GRAPH.path(2)[0], np.ones((2, DIM)), (2, DIM))
    j.checkpoint(7, 0, 2, 1, GRAPH.path(2)[1], np.ones((2, DIM)), (2, DIM))
    st = j.replay()
    assert st.inflight["group_id"] == 7
    assert [int(s) for s in st.inflight["seqs"]] == [0, 2]
    assert st.checkpoint["depth"] == 1          # latest wins
    assert st.checkpoint_node() == GRAPH.path(2)[1]
    assert st.pending_seqs == [0, 1, 2]
    assert st.next_group_id == 8


def test_file_journal_store_roundtrip(tmp_path):
    """The JSONL store survives process death: a fresh store over the same
    path replays to identical state, arrays included."""
    path = str(tmp_path / "journal.jsonl")
    j = Journal(FileJournalStore(path))
    x = np.arange(DIM, dtype=np.float32)
    j.admit(0, x, (0, 2), deadline=2.5, priority=1, tenant="t0")
    j.group_begin(0, [0], [0, 2], 1)
    j.checkpoint(0, 0, 0, 1, GRAPH.path(0)[1],
                 np.full((1, DIM), 0.25, np.float32), (1, DIM))
    stats = ExecutionStats(flops_executed=12.0, checkpoint_bytes=8.0,
                           checkpoint_seconds=1e-6)
    j.group_commit(0, [0], [{0: np.full(3, 2.0, np.float32)}],
                   [GRAPH.path(0)[0]] + [None] * (GRAPH.depth - 1), stats)

    st = Journal(FileJournalStore(path)).replay()
    np.testing.assert_allclose(st.admitted[0]["x"], x)
    assert st.admitted[0]["deadline"] == 2.5
    rec = st.responses[0]
    assert rec["stats"] == stats
    np.testing.assert_allclose(rec["outputs"][0], np.full(3, 2.0))
    assert st.residency[0] == GRAPH.path(0)[0]
    assert st.inflight is None


# --------------------------------------------------------------------------
# Executor: segmented suffixes, activation checkpoint/restore
# --------------------------------------------------------------------------

def test_segmented_suffix_matches_unsegmented():
    xs = jnp.stack([r.x for r in _requests(2, seed=3)])
    plain_ex = TaskGraphExecutor(PROGRAM)
    seg_ex = TaskGraphExecutor(PROGRAM)
    for task in range(GRAPH.num_tasks):
        # Fresh activations each round so every suffix starts at depth 0 —
        # a cut below the resume depth is already covered and never fires.
        plain_ex.clear_activations()
        seg_ex.clear_activations()
        s_plain, s_seg = ExecutionStats(), ExecutionStats()
        ref = plain_ex.run_task_batch(task, xs, s_plain)
        fired = []
        got = seg_ex.run_task_batch(
            task, xs, s_seg, checkpoint_depths=(0, 1),
            checkpoint_hook=fired.append,
        )
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-6, atol=1e-6)
        assert s_plain == s_seg        # cuts never change the counters
        assert fired == [0, 1]
    # Depths at/after the last block are never cut (the group commit covers
    # them) — the hook must not fire there.
    seg_ex.reset()
    fired = []
    seg_ex.run_task_batch(0, xs, ExecutionStats(),
                          checkpoint_depths=(GRAPH.depth - 1,),
                          checkpoint_hook=fired.append)
    assert fired == []


def test_activation_checkpoint_restore_resumes_mid_path():
    xs = jnp.stack([r.x for r in _requests(2, seed=4)])
    ex = TaskGraphExecutor(PROGRAM)
    ref = ex.run_task_batch(0, xs, ExecutionStats())
    ck = ex.activation_checkpoint(0)
    assert ck is not None and ck.depth == GRAPH.depth - 1
    assert ck.node == GRAPH.path(0)[ck.depth]

    # Model the reboot: SRAM gone, FRAM (residency + checkpoint) restored.
    residency = ex.residency_state()
    ex.reset()
    ex.set_residency(residency)
    ex.restore_activation(ck)
    stats = ExecutionStats()
    got = ex.run_task_batch(0, xs, stats)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)
    # Resumed from ck.depth + 1: every block at or above is skipped.
    assert stats.blocks_skipped == ck.depth + 1
    assert stats.blocks_executed == GRAPH.depth - ck.depth - 1
    assert stats.weight_bytes_loaded == 0.0     # residency survived


def test_prediction_tracks_resume_and_activation_floor():
    """``first_task_resume`` prediction matches execution exactly, including
    the activation floor: after a mid-path restore, a successor task whose
    shared prefix ends below the restore depth re-runs from 0 (the shared
    activations were never computed this boot), and prediction must not
    credit residency for depths execution never touched."""
    xs = jnp.stack([r.x for r in _requests(2, seed=5)])
    cm = GraphCostModel(GRAPH, PROGRAM.block_costs, MSP430)
    ex = TaskGraphExecutor(PROGRAM)
    order = [1, 2]      # shared_prefix_depth(1, 2) == 1 < restore depth
    ex.run_task_batch(1, xs, ExecutionStats())
    ck = ex.activation_checkpoint(1)
    residency = ex.residency_state()
    ex.reset()
    ex.set_residency(residency)
    ex.restore_activation(ck)
    stats = ExecutionStats()
    for t in order:
        ex.run_task_batch(t, xs, stats, weight=2)
    predicted = cm.predicted_stats(
        order, batch_size=2, resume=residency,
        first_task_resume=ck.depth + 1,
    )
    assert stats == predicted


# --------------------------------------------------------------------------
# Cost model: checkpoint placement
# --------------------------------------------------------------------------

def test_checkpoint_placement_follows_write_vs_reexec_rule():
    cm = GraphCostModel(GRAPH, PROGRAM.block_costs, MSP430, metric="energy")
    sites = cm.plan_checkpoints([2, 3], batch_size=2)
    assert sites, "cheap activations + expensive blocks must checkpoint"
    for s in sites:
        assert 0 <= s.depth < GRAPH.depth - 1    # never after the last block
        assert s.bytes == cm.checkpoint_bytes(s.depth, 2)
        assert s.seconds == cm.checkpoint_write_seconds(s.depth, 2)
    # Huge activations: writing durable state always costs more than any
    # replay it could save, so the planner places nothing.
    costly = GraphCostModel(
        GRAPH,
        [dataclasses.replace(bc, act_bytes=1e9) for bc in PROGRAM.block_costs],
        MSP430, metric="energy",
    )
    assert costly.plan_checkpoints([2, 3], batch_size=2) == []


def test_predicted_stats_accounts_planned_checkpoints():
    cm = GraphCostModel(GRAPH, PROGRAM.block_costs, MSP430, metric="energy")
    sites = cm.plan_checkpoints([0, 1], batch_size=2)
    base = cm.predicted_stats([0, 1], batch_size=2)
    with_ck = cm.predicted_stats([0, 1], batch_size=2, checkpoints=sites)
    assert with_ck.checkpoint_bytes == sum(s.bytes for s in sites) > 0
    assert with_ck.checkpoint_seconds == pytest.approx(
        sum(s.seconds for s in sites))
    assert base.checkpoint_bytes == 0.0
    # Checkpoints add durable writes, never compute.
    assert with_ck.flops_executed == base.flops_executed
    assert with_ck.seconds(MSP430) > base.seconds(MSP430)
    assert with_ck.energy(MSP430) > base.energy(MSP430)


# --------------------------------------------------------------------------
# Power-failure injection
# --------------------------------------------------------------------------

def test_power_injector_script_and_cap():
    inj = PowerFailureInjector(script={"group": [1]}, max_failures=1)
    inj.check("group")                       # invocation 0: survives
    with pytest.raises(PowerFailure) as e:
        inj.check("group", task=3)
    assert e.value.site == "group" and e.value.index == 1
    assert e.value.context["task"] == 3
    assert not isinstance(e.value, Exception)  # must bypass retry machinery
    inj.check("group")                       # cap reached: armed no more
    assert inj.total_injected == 1


def test_session_rejects_journal_with_mesh_or_cold_engine():
    eng_cold = MultitaskEngine(
        PROGRAM, hw=MSP430, policy=EnginePolicy(warm_start=False),
        scheduler=RequestGroupScheduler(batch_shapes=(1, 2, 4)),
    )
    with pytest.raises(ValueError):
        ServingSession(eng_cold, journal=Journal(MemoryJournalStore()))


# --------------------------------------------------------------------------
# Recovery: exactly-once, checkpoint resume, repeated reboots
# --------------------------------------------------------------------------

def test_recover_resumes_interrupted_group_exactly_once():
    reqs = _requests(6, seed=1)
    ref = _baseline(reqs)

    engine = _engine()
    store = MemoryJournalStore()
    engine.power_injector = PowerFailureInjector(script={"suffix": [1]})
    session = ServingSession(engine, journal=Journal(store))
    for r in reqs:
        session.submit(r)
    with pytest.raises(PowerFailure):
        session.drain()
    mid = Journal(store).replay()
    assert mid.inflight is not None and mid.checkpoint is not None
    committed_before = set(mid.responses)

    engine.power_injector = None
    engine.executor.reset()                       # SRAM gone
    recovered = ServingSession.recover(Journal(store), engine)
    # Committed work comes back resolved without re-running.
    for seq in committed_before:
        fut = recovered.recovered[seq]
        assert fut.done() and fut.result().recovered
    recovered.drain()
    assert recovered.stats == recovered.predicted  # incl. checkpoint terms
    # The resumed group really resumed mid-suffix: it skipped flops at a
    # depth the cold plan would have executed.
    final = Journal(store).replay()
    assert set(final.responses) == {f.seq for f in recovered.recovered.values()} \
        == set(range(len(reqs)))
    for seq, ref_out in ref.items():
        _assert_outputs_match(final.responses[seq]["outputs"], ref_out)
    # Exactly-once: one commit per group, one covering commit per seq.
    commits = [r for r in store.records() if r["kind"] == "group_commit"]
    gids = [r["group_id"] for r in commits]
    assert len(gids) == len(set(gids))
    covered = [s for r in commits for s in r["seqs"]]
    assert len(covered) == len(set(covered))
    # The open group_begin was closed *in place* by the resumed run.  If
    # anything in the resume path raises (e.g. a post-run prediction
    # rejecting the checkpoint-resumed trace), _resume_inflight silently
    # falls back to replanning the members under a fresh gid — still
    # exactly-once, but the mid-suffix checkpoint credit is thrown away
    # and the group re-executes from scratch.
    assert mid.inflight["group_id"] in gids


def test_recover_without_checkpoints_reruns_from_scratch():
    reqs = _requests(6, seed=1)
    ref = _baseline(reqs)
    engine = _engine()
    store = MemoryJournalStore()
    engine.power_injector = PowerFailureInjector(script={"suffix": [1]})
    session = ServingSession(engine, journal=Journal(store))
    for r in reqs:
        session.submit(r)
    with pytest.raises(PowerFailure):
        session.drain()
    engine.power_injector = None
    engine.executor.reset()
    recovered = ServingSession.recover(
        Journal(store), engine, use_checkpoints=False)
    assert recovered.checkpointing is False       # scratch arm writes none
    recovered.drain()
    assert recovered.stats == recovered.predicted
    assert recovered.stats.checkpoint_bytes == 0.0
    final = Journal(store).replay()
    assert set(final.responses) == set(range(len(reqs)))
    for seq, ref_out in ref.items():
        _assert_outputs_match(final.responses[seq]["outputs"], ref_out)


def _reboot_soak(reqs, injector):
    """Drive ``reqs`` to completion through ``injector``'s failure schedule,
    rebooting (reset + recover) after every death — recoveries themselves may
    die and are retried.  Returns (final session, store, reboot count)."""
    ref = _baseline(reqs)
    engine = _engine()
    engine.power_injector = injector
    store = MemoryJournalStore()
    session = ServingSession(engine, journal=Journal(store))
    for r in reqs:
        session.submit(r)
    reboots = 0
    while True:
        try:
            session.drain()
            break
        except PowerFailure:
            reboots += 1
            assert session.stats == session.predicted   # exact at death
            while True:
                engine.executor.reset()
                try:
                    session = ServingSession.recover(Journal(store), engine)
                    break
                except PowerFailure:
                    reboots += 1
    assert injector.total_injected == reboots > 0
    assert session.stats == session.predicted
    final = Journal(store).replay()
    assert set(final.responses) == set(range(len(reqs)))
    for seq, ref_out in ref.items():
        _assert_outputs_match(final.responses[seq]["outputs"], ref_out)
    commits = [r for r in store.records() if r["kind"] == "group_commit"]
    gids = [r["group_id"] for r in commits]
    assert len(gids) == len(set(gids))
    covered = [s for r in commits for s in r["seqs"]]
    assert len(covered) == len(set(covered))
    return session, store, reboots


def test_repeated_reboots_stay_exact_and_exactly_once():
    """Chaos reboots: a seeded failure schedule kills the session (and its
    recoveries) many times over; regression cover for rotating the resumed
    order by the checkpoint's *task* — a second crash inside a rotated
    resume used to mis-seed the restored activation and break exactness."""
    injector = PowerFailureInjector(
        rates={"group": 0.4, "suffix": 0.4}, seed=17, max_failures=12)
    _reboot_soak(_requests(10, seed=2), injector)


@pytest.mark.slow
def test_reboot_soak_long_trace():
    """Nightly soak (cron ``pytest -m slow``): a longer trace under a denser
    failure schedule — dozens of reboots, several of which interrupt an
    in-progress recovery, must stay exact and exactly-once end to end."""
    injector = PowerFailureInjector(
        rates={"group": 0.35, "suffix": 0.35}, seed=23, max_failures=40)
    _, _, reboots = _reboot_soak(_requests(40, seed=6), injector)
    assert reboots >= 15


# --------------------------------------------------------------------------
# Energy budget
# --------------------------------------------------------------------------

def test_energy_budget_units():
    b = EnergyBudget(capacity_joules=10.0, harvest_watts=2.0,
                     initial_joules=1.0)
    assert b.available == 1.0
    b.harvest(0.0)                     # anchors only
    b.harvest(2.0)                     # +4 J
    assert b.available == pytest.approx(5.0)
    assert b.seconds_until(5.0) == 0.0
    assert b.seconds_until(9.0) == pytest.approx(2.0)
    assert b.seconds_until(11.0) == float("inf")    # never fits
    b.advance(10.0)                    # +20 J, clamps at capacity
    assert b.available == pytest.approx(10.0)
    assert b.spilled_joules == pytest.approx(15.0)
    b.drain(4.0)
    assert b.available == pytest.approx(6.0)
    with pytest.raises(ValueError):
        b.drain(100.0)
    with pytest.raises(ValueError):
        b.advance(-1.0)


def test_energy_budget_duty_cycles_the_pump():
    reqs = _requests(6, seed=1)
    ref = _baseline(reqs)
    engine = _engine()
    budget = EnergyBudget(capacity_joules=1.0, harvest_watts=0.5,
                          initial_joules=0.0)
    session = ServingSession(
        engine, journal=Journal(MemoryJournalStore()), energy=budget,
        sleep=lambda s: None,
    )
    futs = [session.submit(r) for r in reqs]
    session.drain()
    assert session.energy_pauses > 0
    assert session.energy_paused_seconds > 0.0
    assert session.groups_failed == 0
    assert session.stats == session.predicted
    for f in futs:
        _assert_outputs_match(f.result().outputs, ref[f.seq])


def test_energy_budget_fails_infeasible_groups_isolated():
    """A group that needs more than the capacitor can ever hold fails its
    members (typed, isolated) instead of wedging the pump."""
    reqs = _requests(2, seed=1, tasks=(0,))
    engine = _engine()
    budget = EnergyBudget(capacity_joules=1e-12, harvest_watts=1.0)
    session = ServingSession(
        engine, journal=Journal(MemoryJournalStore()), energy=budget,
        sleep=lambda s: None,
    )
    futs = [session.submit(r) for r in reqs]
    session.drain()
    assert session.groups_failed >= 1
    for f in futs:
        assert f.done() and f.error() is not None
    # The session is still usable if the capacitor is upgraded.
    session.energy = EnergyBudget(capacity_joules=10.0, harvest_watts=10.0)
    ok = session.submit(_requests(1, seed=9)[0])
    session.drain()
    assert ok.done() and ok.error() is None
