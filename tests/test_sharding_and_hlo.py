"""Sharding utilities + the trip-count-aware HLO cost analyzer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.hlo_cost import analyze_hlo, collective_breakdown
from repro.sharding.policy import (
    FSDP_TP_POLICY, TP_POLICY, _ambient_mesh, shard_act,
)
from repro.sharding.utils import fit_spec, fit_specs, tree_bytes


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_fit_spec_drops_nondivisible():
    mesh = _FakeMesh({"data": 16, "model": 16})
    # 1 KV head cannot shard over 16 -> replicated on that axis
    assert fit_spec((64, 1, 128), P(None, "model", None), mesh) == P(None, None, None)
    # 48 heads shard fine
    assert fit_spec((64, 48, 128), P(None, "model", None), mesh) == P("model",) or \
        fit_spec((64, 48, 128), P(None, "model", None), mesh) == P(None, "model", None)


def test_fit_spec_tuple_prefix_fallback():
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    # batch 32 divides pod*data=32
    assert fit_spec((32, 8), P(("pod", "data"), None), mesh) == P(("pod", "data"), None)
    # batch 2 only divides the ("pod",) prefix
    assert fit_spec((2, 8), P(("pod", "data"), None), mesh) == P(("pod",), None)
    # batch 1 divides nothing
    assert fit_spec((1, 8), P(("pod", "data"), None), mesh) == P(None, None)


def test_fit_specs_tree():
    mesh = _FakeMesh({"data": 4, "model": 4})
    shapes = {"a": jax.ShapeDtypeStruct((8, 12), jnp.float32),
              "b": jax.ShapeDtypeStruct((3,), jnp.float32)}
    specs = {"a": P("data", "model"), "b": P("model")}
    out = fit_specs(shapes, specs, mesh)
    assert out["a"] == P("data", "model")
    assert out["b"] == P(None)


def test_tree_bytes():
    t = {"x": jax.ShapeDtypeStruct((10, 10), jnp.bfloat16),
         "y": jax.ShapeDtypeStruct((5,), jnp.float32)}
    assert tree_bytes(t) == 10 * 10 * 2 + 5 * 4


def test_shard_act_noop_without_mesh():
    x = jnp.ones((4, 4))
    y = shard_act(x, TP_POLICY, "batch", "model")
    assert y is x


def test_policy_axis_resolution():
    assert TP_POLICY.physical("batch") == ("pod", "data")
    assert TP_POLICY.physical("fsdp") is None
    assert FSDP_TP_POLICY.physical("fsdp") == "data"
    with pytest.raises(ValueError):
        TP_POLICY.physical("bogus")


def test_param_spec_convention():
    # matrices: first axis -> fsdp (None under TP), last -> model
    assert TP_POLICY.param_spec((8, 8)) == P(None, "model")
    assert FSDP_TP_POLICY.param_spec((8, 8)) == P("data", "model")
    assert TP_POLICY.param_spec((4, 8, 8)) == P(None, None, "model")
    # vectors and scalars replicate
    assert TP_POLICY.param_spec((8,)) == P(None)
    assert FSDP_TP_POLICY.param_spec(()) == P()


def test_data_and_weight_shard_counts():
    mesh = _FakeMesh({"data": 4, "model": 2})
    assert TP_POLICY.data_shards(mesh) == 4
    assert TP_POLICY.weight_shards(mesh) == 2
    assert FSDP_TP_POLICY.data_shards(mesh) == 4
    assert FSDP_TP_POLICY.weight_shards(mesh) == 8
    pod = _FakeMesh({"pod": 2, "data": 4, "model": 2})
    assert TP_POLICY.data_shards(pod) == 8  # batch spans ("pod", "data")
    assert TP_POLICY.data_shards(None) == 1
    assert TP_POLICY.weight_shards(None) == 1


def test_ambient_mesh_propagates_accessor_failures(monkeypatch):
    """Regression: _ambient_mesh used to swallow *every* exception, so a
    broken mesh context silently degraded all specs to replicated.  Only
    version-absence signals (ImportError/AttributeError on the private
    fallback) may be swallowed; a failing public accessor must surface."""
    def boom():
        raise RuntimeError("mesh state corrupted")

    monkeypatch.setattr(
        jax.sharding, "get_abstract_mesh", boom, raising=False
    )
    with pytest.raises(RuntimeError, match="mesh state corrupted"):
        _ambient_mesh()


def test_ambient_mesh_none_without_context():
    assert _ambient_mesh() is None


# ------------------------------------------------------------------ hlo cost

def test_hlo_cost_multiplies_scan_trip_count():
    def f_scan(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    def f_unroll(x, w):
        for _ in range(7):
            x = jnp.tanh(x @ w)
        return x

    sds = (jax.ShapeDtypeStruct((64, 64), jnp.float32),) * 2
    a = analyze_hlo(jax.jit(f_scan).lower(*sds).compile().as_text())
    b = analyze_hlo(jax.jit(f_unroll).lower(*sds).compile().as_text())
    expected = 2 * 64**3 * 7
    assert a["flops"] == expected
    assert b["flops"] == expected


def test_hlo_cost_counts_dot_flops_exactly():
    def f(x, w):
        return x @ w

    sds = (jax.ShapeDtypeStruct((32, 48), jnp.float32),
           jax.ShapeDtypeStruct((48, 16), jnp.float32))
    a = analyze_hlo(jax.jit(f).lower(*sds).compile().as_text())
    assert a["flops"] == 2 * 32 * 48 * 16


def test_hlo_cost_bytes_positive_and_bounded():
    def f(x):
        return jnp.tanh(x) * 2.0

    sds = (jax.ShapeDtypeStruct((256, 256), jnp.float32),)
    a = analyze_hlo(jax.jit(f).lower(*sds).compile().as_text())
    nbytes = 256 * 256 * 4
    assert nbytes <= a["bytes"] <= 6 * nbytes  # in + out (+ copies)
    assert a["collective_bytes"] == 0.0


def test_collective_breakdown_matches_analyze_hlo():
    if jax.device_count() < 2:
        pytest.skip("needs a multi-device host")
    from jax.sharding import Mesh, NamedSharding

    devs = np.array(jax.devices()[:2]).reshape(2)
    mesh = Mesh(devs, ("model",))

    def f(x, w):
        return x @ w

    xs = jax.ShapeDtypeStruct(
        (16, 32), jnp.float32,
        sharding=NamedSharding(mesh, P(None, None)),
    )
    ws = jax.ShapeDtypeStruct(
        (32, 64), jnp.float32,
        sharding=NamedSharding(mesh, P(None, "model")),
    )
    out_sharding = NamedSharding(mesh, P(None, None))
    hlo = (
        jax.jit(f, out_shardings=out_sharding)
        .lower(xs, ws).compile().as_text()
    )
    bd = collective_breakdown(hlo)
    acc = analyze_hlo(hlo)
    assert sum(bd.values()) == acc["collective_bytes"] > 0
    for kind, v in bd.items():
        assert acc[f"coll_{kind}"] == v
