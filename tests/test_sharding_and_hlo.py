"""Sharding utilities + the trip-count-aware HLO cost analyzer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.hlo_cost import analyze_hlo
from repro.sharding.policy import FSDP_TP_POLICY, TP_POLICY, shard_act
from repro.sharding.utils import fit_spec, fit_specs, tree_bytes


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_fit_spec_drops_nondivisible():
    mesh = _FakeMesh({"data": 16, "model": 16})
    # 1 KV head cannot shard over 16 -> replicated on that axis
    assert fit_spec((64, 1, 128), P(None, "model", None), mesh) == P(None, None, None)
    # 48 heads shard fine
    assert fit_spec((64, 48, 128), P(None, "model", None), mesh) == P("model",) or \
        fit_spec((64, 48, 128), P(None, "model", None), mesh) == P(None, "model", None)


def test_fit_spec_tuple_prefix_fallback():
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    # batch 32 divides pod*data=32
    assert fit_spec((32, 8), P(("pod", "data"), None), mesh) == P(("pod", "data"), None)
    # batch 2 only divides the ("pod",) prefix
    assert fit_spec((2, 8), P(("pod", "data"), None), mesh) == P(("pod",), None)
    # batch 1 divides nothing
    assert fit_spec((1, 8), P(("pod", "data"), None), mesh) == P(None, None)


def test_fit_specs_tree():
    mesh = _FakeMesh({"data": 4, "model": 4})
    shapes = {"a": jax.ShapeDtypeStruct((8, 12), jnp.float32),
              "b": jax.ShapeDtypeStruct((3,), jnp.float32)}
    specs = {"a": P("data", "model"), "b": P("model")}
    out = fit_specs(shapes, specs, mesh)
    assert out["a"] == P("data", "model")
    assert out["b"] == P(None)


def test_tree_bytes():
    t = {"x": jax.ShapeDtypeStruct((10, 10), jnp.bfloat16),
         "y": jax.ShapeDtypeStruct((5,), jnp.float32)}
    assert tree_bytes(t) == 10 * 10 * 2 + 5 * 4


def test_shard_act_noop_without_mesh():
    x = jnp.ones((4, 4))
    y = shard_act(x, TP_POLICY, "batch", "model")
    assert y is x


def test_policy_axis_resolution():
    assert TP_POLICY.physical("batch") == ("pod", "data")
    assert TP_POLICY.physical("fsdp") is None
    assert FSDP_TP_POLICY.physical("fsdp") == "data"
    with pytest.raises(ValueError):
        TP_POLICY.physical("bogus")


# ------------------------------------------------------------------ hlo cost

def test_hlo_cost_multiplies_scan_trip_count():
    def f_scan(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    def f_unroll(x, w):
        for _ in range(7):
            x = jnp.tanh(x @ w)
        return x

    sds = (jax.ShapeDtypeStruct((64, 64), jnp.float32),) * 2
    a = analyze_hlo(jax.jit(f_scan).lower(*sds).compile().as_text())
    b = analyze_hlo(jax.jit(f_unroll).lower(*sds).compile().as_text())
    expected = 2 * 64**3 * 7
    assert a["flops"] == expected
    assert b["flops"] == expected


def test_hlo_cost_counts_dot_flops_exactly():
    def f(x, w):
        return x @ w

    sds = (jax.ShapeDtypeStruct((32, 48), jnp.float32),
           jax.ShapeDtypeStruct((48, 16), jnp.float32))
    a = analyze_hlo(jax.jit(f).lower(*sds).compile().as_text())
    assert a["flops"] == 2 * 32 * 48 * 16


def test_hlo_cost_bytes_positive_and_bounded():
    def f(x):
        return jnp.tanh(x) * 2.0

    sds = (jax.ShapeDtypeStruct((256, 256), jnp.float32),)
    a = analyze_hlo(jax.jit(f).lower(*sds).compile().as_text())
    nbytes = 256 * 256 * 4
    assert nbytes <= a["bytes"] <= 6 * nbytes  # in + out (+ copies)
    assert a["collective_bytes"] == 0.0
