"""Warm-start pipeline: cross-group residency reuse, resume-aware cost
predictions, fused-suffix execution, and cost-aware group ordering.

The contract under test: warmth and fusion change *what gets loaded and
dispatched*, never *what gets computed* — outputs stay identical to cold
per-group serving, and every counter matches the cost model exactly
(``predicted_stats(..., resume=...)`` / ``predicted_group_stats``).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BlockCost, GraphCostModel, MSP430, MultitaskProgram, TaskGraphExecutor,
    greedy_2opt_order, held_karp_order,
)
from repro.core.task_graph import TaskGraph, enumerate_task_graphs
from repro.core.types import ExecutionStats
from repro.serving import (
    MultitaskEngine, MultitaskRequest, RequestGroupScheduler, order_groups,
)

DIM = 8


def _program(graph, dim=DIM, seed=0, heterogeneous=False):
    rng = np.random.default_rng(seed)
    costs = [BlockCost(weight_bytes=100.0 * (d + 1), flops=10.0 * (d + 1))
             for d in range(graph.depth)]

    def block(p, x):
        return jnp.tanh(x @ p)

    def block_alt(p, x):
        return jnp.sin(x @ p)

    fns = [block] * graph.depth
    if heterogeneous:
        # Distinct fn objects per depth -> the fused path must fall back to
        # the unrolled (still single-dispatch) program.
        fns = [block if d % 2 == 0 else block_alt for d in range(graph.depth)]
    node_params = {
        node: jnp.asarray(rng.normal(size=(dim, dim)), jnp.float32)
        for node in graph.nodes()
    }
    heads = [lambda p, x: x @ p] * graph.num_tasks
    head_params = [
        jnp.asarray(rng.normal(size=(dim, 3)), jnp.float32)
        for _ in range(graph.num_tasks)
    ]
    return MultitaskProgram(
        graph, fns, node_params, heads, head_params, costs
    )


def _random_cases(seed=0, n_cases=6):
    rng = np.random.default_rng(seed)
    graphs = enumerate_task_graphs(4, 2)
    idx = rng.choice(len(graphs), size=min(n_cases, len(graphs)),
                     replace=False)
    for k, gi in enumerate(idx):
        yield k, graphs[int(gi)], rng


# --------------------------------------------------------------------------
# Executor: warm resumes + resume-aware predictions
# --------------------------------------------------------------------------

def test_warm_run_batch_stats_match_resume_prediction():
    for k, graph, rng in _random_cases(seed=3):
        prog = _program(graph, seed=k)
        cm = GraphCostModel(graph, prog.block_costs, MSP430)
        ex = TaskGraphExecutor(prog)
        cumulative = ExecutionStats()
        plan = []
        for _g in range(3):
            order = list(rng.permutation(graph.num_tasks))
            b = int(rng.integers(1, 5))
            xs = jnp.asarray(rng.normal(size=(b, DIM)), jnp.float32)
            resume = ex.residency_state()
            _, stats = ex.run_batch(xs, order)  # no reset: warm
            assert stats == cm.predicted_stats(order, batch_size=b,
                                               resume=resume)
            cumulative = cumulative.merge(stats)
            plan.append((order, b))
        assert cumulative == cm.predicted_group_stats(plan)


def test_warm_outputs_identical_to_cold():
    for k, graph, rng in _random_cases(seed=4):
        prog = _program(graph, seed=k)
        ex = TaskGraphExecutor(prog)
        cold = TaskGraphExecutor(prog)
        for _g in range(3):
            order = list(rng.permutation(graph.num_tasks))
            b = int(rng.integers(1, 5))
            xs = jnp.asarray(rng.normal(size=(b, DIM)), jnp.float32)
            warm_out, _ = ex.run_batch(xs, order)   # residency carried over
            cold.reset()
            cold_out, _ = cold.run_batch(xs, order)
            for t in order:
                np.testing.assert_allclose(
                    np.asarray(warm_out[t]), np.asarray(cold_out[t]),
                    rtol=1e-5, atol=1e-6)


def test_set_residency_round_trips():
    graph = TaskGraph.from_groups([
        [[0, 1, 2, 3]], [[0, 1], [2, 3]], [[0], [1], [2, 3]],
    ])
    prog = _program(graph)
    ex = TaskGraphExecutor(prog)
    xs = jnp.ones((2, DIM))
    ex.run_batch(xs, [0, 2])
    state = ex.residency_state()
    assert state == tuple(graph.path(2))  # last task's full path resident

    other = TaskGraphExecutor(prog)
    other.set_residency(state)
    assert other.residency_state() == state
    cm = GraphCostModel(graph, prog.block_costs, MSP430)
    _, stats = other.run_batch(xs, [3, 1])
    assert stats == cm.predicted_stats([3, 1], batch_size=2, resume=state)
    with pytest.raises(ValueError):
        other.set_residency(state[:-1])


def test_predicted_stats_rejects_bad_resume_length():
    graph = TaskGraph.fully_shared(3, 2)
    prog = _program(graph, seed=1)
    cm = GraphCostModel(graph, prog.block_costs, MSP430)
    with pytest.raises(ValueError):
        cm.predicted_stats([0, 1, 2], resume=(None,))
    with pytest.raises(ValueError):
        cm.predicted_group_stats([([0], 1)], resume=(None,))


# --------------------------------------------------------------------------
# Fused-suffix execution
# --------------------------------------------------------------------------

@pytest.mark.parametrize("heterogeneous", [False, True])
def test_fused_matches_per_block_reference(heterogeneous):
    for k, graph, rng in _random_cases(seed=5):
        prog = _program(graph, seed=k, heterogeneous=heterogeneous)
        fused = TaskGraphExecutor(prog)
        ref = TaskGraphExecutor(prog, fused=False)
        for _g in range(2):  # second round runs warm
            order = list(rng.permutation(graph.num_tasks))
            b = int(rng.integers(1, 4))
            xs = jnp.asarray(rng.normal(size=(b, DIM)), jnp.float32)
            d0 = fused.dispatch_count
            out_f, stats_f = fused.run_batch(xs, order)
            # One dispatch per task: the whole suffix + head is one program.
            assert fused.dispatch_count - d0 == len(order)
            d0 = ref.dispatch_count
            out_r, stats_r = ref.run_batch(xs, order)
            assert ref.dispatch_count - d0 > len(order)
            assert stats_f == stats_r  # accounting is dispatch-mode blind
            for t in order:
                np.testing.assert_allclose(
                    np.asarray(out_f[t]), np.asarray(out_r[t]),
                    rtol=1e-5, atol=1e-6)


def test_fused_single_request_path():
    graph = TaskGraph.from_groups([
        [[0, 1, 2, 3]], [[0, 1], [2, 3]], [[0], [1], [2, 3]],
    ])
    prog = _program(graph, seed=6)
    fused = TaskGraphExecutor(prog)
    ref = TaskGraphExecutor(prog, fused=False)
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.normal(size=(DIM,)), jnp.float32)
    out_f, stats_f = fused.run(x, [2, 3, 0, 1])
    out_r, stats_r = ref.run(x, [2, 3, 0, 1])
    assert stats_f == stats_r
    for t in range(4):
        np.testing.assert_allclose(
            np.asarray(out_f[t]), np.asarray(out_r[t]),
            rtol=1e-5, atol=1e-6)


def test_fused_head_only_suffix():
    """Tasks sharing their full block path (split only at the heads) resume
    at depth == graph.depth: the fused program is just the head."""
    graph = TaskGraph.from_groups([
        [[0, 1, 2]], [[0], [1, 2]], [[0], [1, 2]],
    ])
    prog = _program(graph, seed=7)
    ex = TaskGraphExecutor(prog)
    xs = jnp.ones((3, DIM))
    d0 = ex.dispatch_count
    out, stats = ex.run_batch(xs, [1, 2])  # task 2 shares 1's entire path
    assert ex.dispatch_count - d0 == 2
    assert stats.blocks_skipped == graph.depth  # full-path activation reuse
    ref = TaskGraphExecutor(prog, fused=False)
    out_r, _ = ref.run_batch(xs, [1, 2])
    for t in (1, 2):
        np.testing.assert_allclose(
            np.asarray(out[t]), np.asarray(out_r[t]), rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------------
# Cost model: warm switching / group ordering building blocks
# --------------------------------------------------------------------------

def test_warm_switching_cost_is_load_only():
    graph = TaskGraph.from_groups([
        [[0, 1, 2, 3]], [[0, 1], [2, 3]], [[0], [1], [2, 3]],
    ])
    prog = _program(graph)
    cm = GraphCostModel(graph, prog.block_costs, MSP430)
    for i in range(4):
        for j in range(4):
            warm = cm.warm_switching_cost(i, j)
            full = cm.switching_cost(i, j)
            if graph.shared_prefix_depth(i, j) == graph.depth:
                # Full-path sharing (same task, or tasks split only at the
                # heads): everything is resident, nothing loads.
                assert warm == full == 0.0
            else:
                assert 0.0 < warm < full  # loads only, no exec component
            # Equivalent to the residency-snapshot form.
            resident = tuple(graph.path(i))
            assert warm == pytest.approx(cm.resume_load_cost(resident, j))


def test_greedy_2opt_matches_exact_on_small_instances():
    rng = np.random.default_rng(8)
    for _ in range(10):
        n = int(rng.integers(3, 8))
        # Metric-like instances (the group matrices derive from tree prefix
        # sharing, so they are near-metric): 2-opt should hit the optimum.
        pts = rng.uniform(0.0, 1.0, size=(n, 2))
        c = np.linalg.norm(pts[:, None, :] - pts[None, :, :], axis=-1)
        exact = held_karp_order(c)
        heur = greedy_2opt_order(c)
        assert sorted(heur.order) == list(range(n))
        assert heur.cost <= exact.cost + 1e-9
        # Unstructured asymmetric matrices: heuristic, but bounded.
        c = rng.uniform(0.1, 10.0, size=(n, n))
        np.fill_diagonal(c, 0.0)
        heur = greedy_2opt_order(c)
        assert sorted(heur.order) == list(range(n))
        assert heur.cost <= held_karp_order(c).cost * 1.5 + 1e-9


def test_order_groups_reduces_predicted_boundary_loads():
    graph = TaskGraph.from_groups([
        [[0, 1, 2, 3, 4, 5]],
        [[0, 1, 2], [3, 4, 5]],
        [[0, 1], [2], [3], [4, 5]],
        [[0], [1], [2], [3], [4], [5]],
    ])
    prog = _program(graph, seed=9)
    cm = GraphCostModel(graph, prog.block_costs, MSP430)
    order = tuple(range(6))
    rng = np.random.default_rng(9)
    # Alternating subtrees: the worst bucket order for warm hand-over.
    subsets = [(0, 1), (3, 4), (0, 2), (4, 5), (1, 2), (3, 5)]
    reqs = [MultitaskRequest(
        x=jnp.asarray(rng.normal(size=(DIM,)), jnp.float32), tasks=s)
        for s in subsets]
    sched = RequestGroupScheduler(batch_shapes=(1,))
    groups = sched.plan(reqs, num_tasks=6)

    def total_loads(seq):
        plan = [([t for t in order if t in g.tasks], g.valid) for g in seq]
        return cm.predicted_group_stats(plan).weight_bytes_loaded

    ordered = order_groups(groups, cm, order)
    assert sorted(g.indices for g in ordered) == sorted(
        g.indices for g in groups)  # a permutation, nothing dropped
    assert total_loads(ordered) < total_loads(groups)


def test_order_groups_keeps_empty_subset_groups_out_of_the_tsp():
    """A tasks=() group executes nothing: residency flows through it, so it
    must not act as a free waypoint between expensive neighbours — it goes
    to the back and the real groups are ordered among themselves."""
    graph = TaskGraph.from_groups([
        [[0, 1, 2, 3]], [[0, 1], [2, 3]], [[0], [1], [2], [3]],
    ])
    prog = _program(graph, seed=13)
    cm = GraphCostModel(graph, prog.block_costs, MSP430)
    order = (0, 1, 2, 3)
    rng = np.random.default_rng(13)
    subsets = [(0,), (), (2,), (1,), (3,)]
    reqs = [MultitaskRequest(
        x=jnp.asarray(rng.normal(size=(DIM,)), jnp.float32), tasks=s)
        for s in subsets]
    groups = RequestGroupScheduler(batch_shapes=(1,)).plan(reqs, num_tasks=4)
    ordered = order_groups(groups, cm, order)
    assert ordered[-1].tasks == frozenset()
    # The real groups pair up by subtree: {0},{1} adjacent and {2},{3}
    # adjacent in some rotation — never interleaved across the empty group.
    seq = [min(g.tasks) for g in ordered[:-1]]
    pairs = {tuple(sorted(seq[i:i + 2])) for i in (0, 2)}
    assert pairs == {(0, 1), (2, 3)}


def test_order_groups_uses_initial_residency():
    graph = TaskGraph.from_groups([
        [[0, 1, 2, 3]], [[0, 1], [2, 3]], [[0], [1], [2], [3]],
    ])
    prog = _program(graph, seed=10)
    cm = GraphCostModel(graph, prog.block_costs, MSP430)
    order = (0, 1, 2, 3)
    rng = np.random.default_rng(10)
    reqs = [MultitaskRequest(
        x=jnp.asarray(rng.normal(size=(DIM,)), jnp.float32), tasks=s)
        for s in [(2,), (1,)]]
    groups = RequestGroupScheduler(batch_shapes=(1,)).plan(reqs, num_tasks=4)
    # Warm from task 1's path: its neighbour subset (1,) should go first.
    resident = tuple(graph.path(1))
    ordered = order_groups(groups, cm, order, initial_resident=resident)
    assert ordered[0].tasks == frozenset({1})
    # Warm from task 2's path: the other way around.
    resident = tuple(graph.path(2))
    ordered = order_groups(groups, cm, order, initial_resident=resident)
    assert ordered[0].tasks == frozenset({2})


# --------------------------------------------------------------------------
# Engine: warm serving end to end
# --------------------------------------------------------------------------

GRAPH6 = TaskGraph.from_groups([
    [[0, 1, 2, 3, 4, 5]],
    [[0, 1, 2], [3, 4, 5]],
    [[0, 1], [2], [3], [4, 5]],
    [[0], [1], [2], [3], [4], [5]],
])


def _requests(rng, subsets):
    return [MultitaskRequest(
        x=jnp.asarray(rng.normal(size=(DIM,)), jnp.float32), tasks=s)
        for s in subsets]


def test_engine_warm_matches_cold_and_predictions():
    prog = _program(GRAPH6, seed=11)
    rng = np.random.default_rng(11)
    subsets = [(0, 1), (3, 4), (0, 1, 2), (3, 4, 5), (0, 2), (4, 5),
               None, (1,), (5,), None]
    reqs = _requests(rng, subsets)
    warm = MultitaskEngine(prog, hw=MSP430,
                           scheduler=RequestGroupScheduler(batch_shapes=(1, 2)))
    cold = MultitaskEngine(prog, hw=MSP430, warm_start=False,
                           group_ordering=False,
                           scheduler=RequestGroupScheduler(batch_shapes=(1, 2)))
    for round_idx in range(2):  # second round starts warm from the first
        groups = warm.plan_groups(reqs)
        pred = warm.predicted_group_stats(groups)
        warm_resp = warm.serve_batch(reqs)
        cold_resp = cold.serve_batch(reqs)
        assert warm.last_batch_stats == pred
        assert cold.last_batch_stats == cold.predicted_group_stats(
            cold.plan_groups(reqs))
        assert (warm.last_batch_stats.weight_bytes_loaded
                < cold.last_batch_stats.weight_bytes_loaded)
        assert any(r.warm_weight_bytes_saved > 0 for r in warm_resp)
        for rw, rc in zip(warm_resp, cold_resp):
            assert set(rw.outputs) == set(rc.outputs)
            assert rw.predicted_seconds > 0
            # Warm groups report the latency that actually ran: never more
            # than the cold estimate for the same group.
            assert rw.predicted_seconds <= rc.predicted_seconds + 1e-12
            for t in rw.outputs:
                np.testing.assert_allclose(
                    np.asarray(rw.outputs[t]), np.asarray(rc.outputs[t]),
                    rtol=1e-5, atol=1e-6)


def test_engine_warm_with_gates_stays_exact_per_request():
    prog = _program(GRAPH6, seed=12)

    def gate(outputs):
        return bool(np.asarray(outputs[0])[0] > 0)

    gates = {t: gate for t in range(1, 6)}
    order = list(range(6))
    warm = MultitaskEngine(prog, hw=MSP430, gates=gates, order=order)
    solo = MultitaskEngine(prog, hw=MSP430, gates=gates, order=order,
                           warm_start=False, group_ordering=False,
                           scheduler=RequestGroupScheduler(batch_shapes=(1,)))
    rng = np.random.default_rng(12)
    reqs = _requests(rng, [None] * 6)
    for rw, req in zip(warm.serve_batch(reqs), reqs):
        rs = solo.serve(req)
        assert set(rw.outputs) == set(rs.outputs)
        for t in rw.outputs:
            np.testing.assert_allclose(
                np.asarray(rw.outputs[t]), np.asarray(rs.outputs[t]),
                rtol=1e-5, atol=1e-6)


def test_kernel_interpret_default_resolves_from_backend(monkeypatch):
    import jax
    from repro.kernels.pearson_affinity import resolve_interpret

    # This container has no TPU: None must resolve to the interpreter.
    assert jax.default_backend() != "tpu"
    assert resolve_interpret(None) is True
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    assert resolve_interpret(None) is False
    # Explicit overrides always win.
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False
