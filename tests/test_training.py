"""Training substrate: loss decreases, chunked CE correctness, checkpoints,
optimizer behaviour."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import lm_batches
from repro.models import get_model, make_config
from repro.sharding.policy import TP_POLICY
from repro.training import (
    AdamWConfig, adamw_init, adamw_update, cross_entropy_chunked, lr_at,
    make_train_step, restore_checkpoint, save_checkpoint,
)


def _tiny_cfg():
    return make_config(
        name="tiny", family="dense", num_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=512, dtype="float32",
        param_dtype="float32", remat=False, attn_chunk=32, loss_chunk=16,
    )


def test_chunked_ce_matches_dense():
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (2, 64, 37))
    labels = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, 37)
    ce = cross_entropy_chunked(logits, labels, chunk=16)
    lp = jax.nn.log_softmax(logits, axis=-1)
    ref = -jnp.take_along_axis(lp, labels[..., None], axis=-1).mean()
    np.testing.assert_allclose(float(ce), float(ref), rtol=1e-5)


def test_loss_decreases_over_steps():
    cfg = _tiny_cfg()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(
        model, AdamWConfig(lr=2e-3, warmup_steps=5, total_steps=100), TP_POLICY
    ))
    it = lm_batches(cfg.vocab_size, batch=8, seq_len=64, seed=0)
    losses = []
    for _ in range(40):
        params, opt, m = step(params, opt, jnp.asarray(next(it)))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2
    assert np.isfinite(losses).all()


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    assert float(lr_at(cfg, jnp.asarray(0))) == 0.0
    np.testing.assert_allclose(float(lr_at(cfg, jnp.asarray(10))), 1.0, rtol=1e-5)
    assert float(lr_at(cfg, jnp.asarray(100))) <= 0.1 + 1e-6
    # monotone decay after warmup
    vals = [float(lr_at(cfg, jnp.asarray(s))) for s in range(10, 101, 10)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))


def test_adamw_moves_params_and_decays_weights():
    params = {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,))}
    grads = {"w": jnp.zeros((4, 4)), "b": jnp.zeros((4,))}
    st = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.5, warmup_steps=0,
                      total_steps=10, schedule="constant", clip_norm=None)
    new, st2, _ = adamw_update(cfg, grads, st, params)
    # zero grads: matrices shrink via decoupled decay, vectors untouched
    assert float(new["w"][0, 0]) < 1.0
    np.testing.assert_allclose(np.asarray(new["b"]), 0.0)


def test_checkpoint_roundtrip(tmp_path):
    cfg = _tiny_cfg()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    path = os.path.join(tmp_path, "ckpt_10.npz")
    save_checkpoint(path, params, step=10)
    restored, step = restore_checkpoint(path, params)
    assert step == 10
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))
