"""Model-family equivalences: chunked==dense attention, SSD==sequential,
forward == prefill+decode at every step, SWA ring-cache correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import make_config
from repro.models import transformer as T
from repro.models import ssm as S
from repro.models import hybrid as H
from repro.models import encdec as E
from repro.models.cache import EncDecCache, HybridCache, KVCache
from repro.sharding.policy import TP_POLICY

P = TP_POLICY


def _dense_cfg(**kw):
    base = dict(
        name="t", family="dense", num_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=300, dtype="float32",
        param_dtype="float32", remat=False, attn_chunk=16, loss_chunk=32,
    )
    base.update(kw)
    return make_config(**base)


def test_chunked_equals_dense_attention_model_level():
    cfg = _dense_cfg()
    params = T.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 40), 0, 300)
    a, _ = T.forward(params, toks, cfg, P, use_chunked=True)
    b, _ = T.forward(params, toks, cfg, P, use_chunked=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-4, rtol=3e-4)


@pytest.mark.parametrize("window", [None, 16])
def test_dense_decode_matches_forward(window):
    cfg = _dense_cfg(sliding_window=window)
    params = T.init(jax.random.PRNGKey(2), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 44), 0, 300)
    full, _ = T.forward(params, toks, cfg, P)
    last, cache = T.prefill(params, toks[:, :40], cfg, P)
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(full[:, 39]), atol=3e-3, rtol=3e-3
    )
    if window is None:  # grow linear cache for extra steps
        k = jnp.zeros((2, 2, 44, 2, 16))
        v = jnp.zeros_like(k)
        cache = KVCache(
            k=k.at[:, :, :40].set(cache.k), v=v.at[:, :, :40].set(cache.v)
        )
    cl = jnp.asarray(40)
    for t in range(40, 44):
        step, cache = T.decode_step(params, toks[:, t], cache, cl, cfg, P)
        np.testing.assert_allclose(
            np.asarray(step), np.asarray(full[:, t]), atol=3e-3, rtol=3e-3
        )
        cl = cl + 1


def test_moe_decode_matches_forward():
    cfg = make_config(
        name="m", family="moe", num_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=0, vocab_size=300, moe_num_experts=4, moe_top_k=2,
        moe_num_shared_experts=1, moe_d_ff=96, moe_capacity_factor=8.0,
        dtype="float32", param_dtype="float32", remat=False, attn_chunk=16,
    )
    params = T.init(jax.random.PRNGKey(4), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, 20), 0, 300)
    full, _ = T.forward(params, toks, cfg, P)
    last, cache = T.prefill(params, toks[:, :19], cfg, P)
    k = jnp.zeros((2, 2, 20, 2, 16))
    v = jnp.zeros_like(k)
    cache = KVCache(k=k.at[:, :, :19].set(cache.k), v=v.at[:, :, :19].set(cache.v))
    step, _ = T.decode_step(params, toks[:, 19], cache, jnp.asarray(19), cfg, P)
    np.testing.assert_allclose(
        np.asarray(step), np.asarray(full[:, 19]), atol=3e-3, rtol=3e-3
    )


def test_ssm_decode_matches_forward():
    cfg = make_config(
        name="s", family="ssm", num_layers=2, d_model=32, n_heads=1,
        n_kv_heads=1, d_ff=0, vocab_size=300, ssm_state=8, ssm_head_dim=8,
        ssm_chunk=8, dtype="float32", param_dtype="float32", remat=False,
    )
    params = S.init(jax.random.PRNGKey(6), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(7), (2, 21), 0, 300)
    full, _ = S.forward(params, toks, cfg, P)
    last, cache = S.prefill(params, toks[:, :18], cfg, P)
    cl = jnp.asarray(18)
    for t in range(18, 21):
        step, cache = S.decode_step(params, toks[:, t], cache, cl, cfg, P)
        np.testing.assert_allclose(
            np.asarray(step), np.asarray(full[:, t]), atol=3e-3, rtol=3e-3
        )
        cl = cl + 1


def test_hybrid_decode_matches_forward():
    cfg = make_config(
        name="h", family="hybrid", num_layers=4, d_model=32, n_heads=4,
        n_kv_heads=2, d_ff=64, vocab_size=300, ssm_state=8, ssm_head_dim=8,
        ssm_chunk=8, hybrid_attn_period=2, dtype="float32",
        param_dtype="float32", remat=False, attn_chunk=8,
    )
    params = H.init(jax.random.PRNGKey(8), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(9), (2, 20), 0, 300)
    full, _ = H.forward(params, toks, cfg, P)
    last, cache = H.prefill(params, toks[:, :19], cfg, P)
    k = jnp.zeros((2, 2, 20, 2, 8))
    v = jnp.zeros_like(k)
    cache = HybridCache(
        ssm=cache.ssm,
        kv=KVCache(k=k.at[:, :, :19].set(cache.kv.k), v=v.at[:, :, :19].set(cache.kv.v)),
    )
    step, _ = H.decode_step(params, toks[:, 19], cache, jnp.asarray(19), cfg, P)
    np.testing.assert_allclose(
        np.asarray(step), np.asarray(full[:, 19]), atol=3e-3, rtol=3e-3
    )


def test_encdec_decode_matches_forward():
    cfg = make_config(
        name="e", family="encdec", num_layers=2, d_model=32, n_heads=4,
        n_kv_heads=4, d_ff=64, vocab_size=300, enc_layers=2, enc_inputs=16,
        activation="gelu", dtype="float32", param_dtype="float32",
        remat=False, attn_chunk=8,
    )
    params = E.init(jax.random.PRNGKey(10), cfg)
    feats = jax.random.normal(jax.random.PRNGKey(11), (2, 24, 16))
    toks = jax.random.randint(jax.random.PRNGKey(12), (2, 20), 0, 300)
    full, _ = E.forward(params, feats, toks, cfg, P)
    last, cache = E.prefill(params, feats, toks[:, :19], cfg, P)
    k = jnp.zeros((2, 2, 20, 4, 8))
    v = jnp.zeros_like(k)
    cache = EncDecCache(
        self_kv=KVCache(
            k=k.at[:, :, :19].set(cache.self_kv.k),
            v=v.at[:, :, :19].set(cache.self_kv.v),
        ),
        cross_k=cache.cross_k, cross_v=cache.cross_v,
    )
    step, _ = E.decode_step(params, toks[:, 19], cache, jnp.asarray(19), cfg, P)
    np.testing.assert_allclose(
        np.asarray(step), np.asarray(full[:, 19]), atol=3e-3, rtol=3e-3
    )
