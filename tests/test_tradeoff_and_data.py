"""Tradeoff selection (paper §3.2-3.3) and the synthetic data pipelines."""
import numpy as np
import pytest

from repro.core import BlockCost, MSP430
from repro.core.tradeoff import select_task_graph, tradeoff_curve
from repro.core.task_graph import TaskGraph
from repro.data import MultitaskDataset, lm_batches, train_test_split


def _affinity(n, d, seed=0):
    rng = np.random.default_rng(seed)
    a = rng.uniform(0.2, 0.9, (d, n, n))
    a = (a + a.transpose(0, 2, 1)) / 2
    for k in range(d):
        np.fill_diagonal(a[k], 1.0)
    return a


def test_tradeoff_endpoints_and_selection():
    n, bp = 4, 2
    aff = _affinity(n, bp, seed=3)
    costs = [BlockCost(weight_bytes=1000, flops=5000) for _ in range(bp + 1)]
    res = select_task_graph(n, bp, aff, costs, MSP430)
    sizes = np.array([c.storage_bytes for c in res.candidates])
    varieties = np.array([c.variety for c in res.candidates])
    # smallest graph is the fully-shared one; it has the max variety
    smallest = res.candidates[int(np.argmin(sizes))]
    assert smallest.variety == pytest.approx(max(varieties))
    # largest graph (fully separate) has zero variety
    biggest = res.candidates[int(np.argmax(sizes))]
    assert biggest.variety == pytest.approx(0.0)
    # the selected graph is neither extreme (for generic affinities)
    assert min(sizes) <= res.selected.storage_bytes <= max(sizes)
    # trend lines are normalised to [0, 1]
    assert res.variety_trend.min() >= 0 and res.variety_trend.max() <= 1
    assert res.cost_trend.min() >= 0 and res.cost_trend.max() <= 1
    # variety trend decreases with budget; cost trend increases
    assert res.variety_trend[0] >= res.variety_trend[-1]
    assert res.cost_trend[0] <= res.cost_trend[-1]


def test_tradeoff_respects_beam():
    n, bp = 6, 3
    aff = _affinity(n, bp, seed=5)
    costs = [BlockCost(weight_bytes=100, flops=100) for _ in range(bp + 1)]
    res = select_task_graph(n, bp, aff, costs, MSP430, beam=80)
    assert len(res.candidates) <= 80


def test_lm_batches_shapes_and_structure():
    it = lm_batches(vocab_size=512, batch=4, seq_len=32, seed=0)
    a = next(it)
    b = next(it)
    assert a.shape == (4, 32) and a.dtype == np.int32
    assert (a >= 0).all() and (a < 512).all()
    assert not np.array_equal(a, b)  # stream advances
    # planted structure: repeated-context continuation entropy is limited;
    # just assert determinism across seeds
    it2 = lm_batches(vocab_size=512, batch=4, seq_len=32, seed=0)
    np.testing.assert_array_equal(a, next(it2))


def test_multitask_dataset_affinity_structure():
    ds = MultitaskDataset(num_tasks=4, num_classes=5, num_factors=2, seed=0)
    x, y = ds.sample(64)
    assert x.shape == (64, 28, 28, 1)
    assert y.shape == (4, 64)
    # tasks sharing a factor have deterministically-related labels
    f = ds.factor_of_task
    same = [(i, j) for i in range(4) for j in range(i + 1, 4) if f[i] == f[j]]
    for i, j in same:
        # label_perm[i][z] and label_perm[j][z] are both functions of the
        # same latent z -> mutual information is maximal (bijective map)
        mapping = {}
        consistent = True
        for a_, b_ in zip(y[i], y[j]):
            if a_ in mapping and mapping[a_] != b_:
                consistent = False
            mapping[a_] = b_
        assert consistent


def test_train_test_split_sizes():
    ds = MultitaskDataset(num_tasks=3, num_classes=4, seed=1)
    (xtr, ytr), (xte, yte) = train_test_split(ds, 100, 25)
    assert xtr.shape[0] == 100 and xte.shape[0] == 25
    assert ytr.shape == (3, 100) and yte.shape == (3, 25)
