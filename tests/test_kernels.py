"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import ref as R
from repro.kernels.flash_attention import flash_attention
from repro.kernels.pearson_affinity import pearson_dissimilarity
from repro.kernels.ssd_scan import ssd_scan


@pytest.mark.parametrize("s,t,d", [(32, 32, 16), (70, 70, 32), (48, 96, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (False, None), (True, 24)])
def test_flash_attention_sweep(s, t, d, dtype, causal, window):
    if not causal and s != t:
        pytest.skip("cross-attention ref only tested square here")
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    ks = jax.random.split(jax.random.PRNGKey(s * t + d), 3)
    q = jax.random.normal(ks[0], (2, s, d), dtype)
    k = jax.random.normal(ks[1], (2, t, d), dtype)
    v = jax.random.normal(ks[2], (2, t, d), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, q_blk=16, kv_blk=16)
    ref = R.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("hq,hk", [(4, 4), (8, 2), (6, 1)])
def test_flash_attention_gqa_vs_model_oracle(hq, hk):
    from repro.models.layers import attention_dense

    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, 33, hq, 16))
    k = jax.random.normal(ks[1], (2, 33, hk, 16))
    v = jax.random.normal(ks[2], (2, 33, hk, 16))
    out = ops.flash_attention_bhsd(q, k, v, q_blk=16, kv_blk=16)
    pos = jnp.arange(33)
    ref = attention_dense(q, k, v, pos, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("k,f", [(16, 64), (37, 100), (64, 300)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pearson_sweep(k, f, dtype):
    x = jax.random.normal(jax.random.PRNGKey(k), (k, f), dtype)
    out = ops.pairwise_pearson_dissimilarity(x, blk_k=16, blk_f=32)
    z = x.astype(jnp.float32)
    z = z - z.mean(-1, keepdims=True)
    z = z / jnp.maximum(jnp.linalg.norm(z, axis=-1, keepdims=True), 1e-8)
    ref = R.pearson_dissimilarity_ref(z)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=tol, rtol=tol)


@pytest.mark.parametrize("s,h,p,n,chunk", [
    (24, 2, 4, 8, 8), (50, 3, 8, 4, 16), (64, 4, 16, 16, 32),
])
def test_ssd_scan_sweep(s, h, p, n, chunk):
    ks = jax.random.split(jax.random.PRNGKey(s + h), 5)
    x = jax.random.normal(ks[0], (2, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (2, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)))
    bb = jax.random.normal(ks[3], (2, s, n))
    cc = jax.random.normal(ks[4], (2, s, n))
    y, fin = ssd_scan(x, dt, a, bb, cc, chunk=chunk)
    y_seq, fin_seq = R.ssd_sequential(x, dt, a, bb, cc)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_seq), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(fin), np.asarray(fin_seq), atol=2e-4, rtol=2e-4)


def test_ssd_chunked_ref_matches_sequential_bf16():
    ks = jax.random.split(jax.random.PRNGKey(9), 5)
    x = jax.random.normal(ks[0], (1, 32, 2, 4), jnp.bfloat16)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, 32, 2))).astype(jnp.bfloat16)
    a = -jnp.exp(jax.random.normal(ks[2], (2,)))
    bb = jax.random.normal(ks[3], (1, 32, 4), jnp.bfloat16)
    cc = jax.random.normal(ks[4], (1, 32, 4), jnp.bfloat16)
    y, _ = R.ssd_scan_ref(x, dt, a, bb, cc, chunk=8)
    y2, _ = R.ssd_sequential(x, dt, a, bb, cc)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(y2, np.float32), atol=5e-2, rtol=5e-2
    )
