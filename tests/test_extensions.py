"""Tests for the extension layer: profiler, grad accumulation, continuous
batching, and the Fig-15 deployment benchmark pieces."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import MSP430, GraphCostModel, TaskGraph, optimal_order
from repro.core.profiler import profile_program_blocks
from repro.models import get_model, make_config
from repro.models.multitask import build_cnn_program
from repro.serving.batching import ContinuousBatcher, GenRequest
from repro.sharding.policy import TP_POLICY
from repro.training import AdamWConfig, adamw_init, make_train_step


def _tiny_cfg(**kw):
    base = dict(
        name="tiny", family="dense", num_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=256, dtype="float32",
        param_dtype="float32", remat=False, attn_chunk=32, loss_chunk=16,
    )
    base.update(kw)
    return make_config(**base)


def test_profiler_produces_consistent_costs():
    graph = TaskGraph.from_groups([
        [[0, 1]], [[0, 1]], [[0], [1]], [[0], [1]],
    ])
    prog = build_cnn_program(jax.random.PRNGKey(0), graph, [4, 4])
    x = jnp.ones((4, 28, 28, 1))
    costs = profile_program_blocks(prog, x, MSP430)
    assert len(costs) == graph.depth
    for c in costs:
        assert c.weight_bytes > 0 and c.flops > 0
    # measured costs feed the same ordering machinery
    cm = GraphCostModel(graph, costs, MSP430)
    r = optimal_order(cm.cost_matrix())
    assert sorted(r.order) == [0, 1]


def test_grad_accumulation_matches_full_batch():
    cfg = _tiny_cfg()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, 256)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10,
                          schedule="constant", clip_norm=None)
    step1 = jax.jit(make_train_step(model, opt_cfg, TP_POLICY, grad_accum=1))
    step4 = jax.jit(make_train_step(model, opt_cfg, TP_POLICY, grad_accum=4))
    p1, _, m1 = step1(params, adamw_init(params), batch)
    p4, _, m4 = step4(params, adamw_init(params), batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5
        )


def test_continuous_batcher_serves_mixed_requests():
    cfg = _tiny_cfg()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    cb = ContinuousBatcher(model, params, slots=2, max_len=64)
    rng = np.random.default_rng(0)
    for uid in range(5):
        cb.submit(GenRequest(
            uid=uid,
            prompt=rng.integers(0, 256, size=4 + uid).astype(np.int32),
            max_new_tokens=3 + (uid % 3),
        ))
    results = cb.run()
    assert len(results) == 5
    assert sorted(r.uid for r in results) == list(range(5))
    for r in results:
        assert 1 <= r.steps <= 5
        assert r.tokens.shape[0] == r.steps


def test_fig15_constraints_behave():
    from benchmarks.fig15_deployment import run as fig15_run
    import io, contextlib

    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        fig15_run()
    rows = [l for l in buf.getvalue().splitlines() if l.startswith("fig15/")]
    assert len(rows) == 4
    for row in rows:
        derived = row.split(",", 2)[2]
        kv = dict(item.split("=") for item in derived.split(";"))
        # conditional constraints can only lower expected cost
        assert kv["cc_cheaper"] == "True"
        assert float(kv["reduction"].rstrip("x")) > 1.0
