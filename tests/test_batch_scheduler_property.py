"""Properties of the request-group scheduler.

Invariants checked (with hypothesis when installed, and always with a
fixed-seed randomized fallback so the suite exercises them in hermetic
environments):

* every submitted request appears in exactly one group;
* groups are homogeneous — one task subset and one input shape per group;
* group widths come from the scheduler's allowed batch shapes, and padding
  never changes served results;
* warm multi-group serving (cross-group residency reuse + cost-aware group
  ordering) returns the same outputs as the cold-per-group path, and the
  warm engine's cumulative counters equal
  ``MultitaskEngine.predicted_group_stats`` of its plan exactly.
"""
import jax.numpy as jnp
import numpy as np

from repro.core import BlockCost, MSP430, MultitaskProgram, TaskGraphExecutor
from repro.core.task_graph import TaskGraph
from repro.serving import (
    MultitaskEngine, MultitaskRequest, RequestGroupScheduler,
)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

DIM = 8
GRAPH = TaskGraph.from_groups([
    [[0, 1, 2, 3]], [[0, 1], [2, 3]], [[0], [1], [2, 3]],
])
SUBSET_CHOICES = (None, (0,), (1, 2), (0, 3), (2, 1), (0, 1, 2, 3))


def _program(seed=0):
    rng = np.random.default_rng(seed)
    costs = [BlockCost(weight_bytes=10.0, flops=1.0) for _ in range(GRAPH.depth)]

    def block(p, x):
        return jnp.tanh(x @ p)

    node_params = {
        node: jnp.asarray(rng.normal(size=(DIM, DIM)), jnp.float32)
        for node in GRAPH.nodes()
    }
    heads = [lambda p, x: x @ p] * 4
    head_params = [jnp.asarray(rng.normal(size=(DIM, 3)), jnp.float32)
                   for _ in range(4)]
    return MultitaskProgram(
        GRAPH, [block] * GRAPH.depth, node_params, heads, head_params, costs
    )


PROGRAM = _program()


def _requests_from_spec(spec, rng):
    """spec: list of (subset_index, wide_input) pairs."""
    reqs = []
    for subset_idx, wide in spec:
        shape = (2, DIM) if wide else (DIM,)
        reqs.append(MultitaskRequest(
            x=jnp.asarray(rng.normal(size=shape), jnp.float32),
            tasks=SUBSET_CHOICES[subset_idx],
        ))
    return reqs


def _norm(subset):
    return None if subset is None else frozenset(int(t) for t in subset)


def check_plan_invariants(requests, batch_shapes):
    sched = RequestGroupScheduler(batch_shapes=batch_shapes)
    groups = sched.plan(requests)

    # Exactly-one-group partition of the submitted indices.
    covered = [i for g in groups for i in g.indices]
    assert sorted(covered) == list(range(len(requests)))

    for g in groups:
        assert g.valid == len(g.indices) == len(g.requests)
        # Width is an allowed batch shape, large enough for the members.
        assert int(g.xs.shape[0]) in sched.batch_shapes
        assert int(g.xs.shape[0]) >= g.valid
        # Homogeneity: one subset, one sample shape for the whole group.
        for i, r in zip(g.indices, g.requests):
            assert requests[i] is r
            assert _norm(r.tasks) == g.tasks
            assert tuple(jnp.asarray(r.x).shape) == tuple(g.xs.shape[1:])
        # Padding rows replicate the last real row.
        for p in range(g.valid, int(g.xs.shape[0])):
            np.testing.assert_array_equal(
                np.asarray(g.xs[p]), np.asarray(g.xs[g.valid - 1]))
    return groups


def check_padding_preserves_results(requests):
    """Padded grouped serving == unbatched serving, request by request."""
    eng = MultitaskEngine(PROGRAM, hw=MSP430,
                          scheduler=RequestGroupScheduler(batch_shapes=(1, 4)))
    solo = MultitaskEngine(PROGRAM, hw=MSP430,
                           scheduler=RequestGroupScheduler(batch_shapes=(1,)))
    for rb, req in zip(eng.serve_batch(requests), requests):
        rs = solo.serve(req)
        assert set(rb.outputs) == set(rs.outputs)
        for t in rb.outputs:
            np.testing.assert_allclose(
                np.asarray(rb.outputs[t]), np.asarray(rs.outputs[t]),
                rtol=1e-5, atol=1e-6)


def check_warm_multigroup_equivalence(requests):
    """Warm multi-group serving == cold-per-group serving, and the warm
    engine's cumulative counters match ``predicted_group_stats`` exactly.

    Serves the same stream twice so the second batch starts warm from the
    first's residency (the persistent-engine case).
    """
    warm = MultitaskEngine(PROGRAM, hw=MSP430,
                           scheduler=RequestGroupScheduler(batch_shapes=(1, 4)))
    cold = MultitaskEngine(PROGRAM, hw=MSP430, warm_start=False,
                           group_ordering=False,
                           scheduler=RequestGroupScheduler(batch_shapes=(1, 4)))
    for _round in range(2):
        pred = warm.predicted_group_stats(warm.plan_groups(requests))
        warm_resp = warm.serve_batch(requests)
        cold_resp = cold.serve_batch(requests)
        assert warm.last_batch_stats == pred
        # Warmth + ordering only remove loads, never add them.
        assert (warm.last_batch_stats.weight_bytes_loaded
                <= cold.last_batch_stats.weight_bytes_loaded)
        # Per-request counters are schedule-independent.
        assert warm.last_batch_stats.flops_executed == \
            cold.last_batch_stats.flops_executed
        assert warm.last_batch_stats.tasks_run == cold.last_batch_stats.tasks_run
        for rw, rc in zip(warm_resp, cold_resp):
            assert set(rw.outputs) == set(rc.outputs)
            for t in rw.outputs:
                np.testing.assert_allclose(
                    np.asarray(rw.outputs[t]), np.asarray(rc.outputs[t]),
                    rtol=1e-5, atol=1e-6)


def test_scheduler_invariants_fixed_seeds():
    rng = np.random.default_rng(0)
    for trial in range(25):
        n = int(rng.integers(1, 12))
        spec = [(int(rng.integers(0, len(SUBSET_CHOICES))),
                 bool(rng.integers(0, 2))) for _ in range(n)]
        reqs = _requests_from_spec(spec, rng)
        check_plan_invariants(reqs, batch_shapes=(1, 2, 4))
        check_plan_invariants(reqs, batch_shapes=(1, 4, 16, 64))


def test_scheduler_chunks_oversized_buckets():
    rng = np.random.default_rng(1)
    reqs = _requests_from_spec([(0, False)] * 11, rng)  # one big bucket
    groups = check_plan_invariants(reqs, batch_shapes=(1, 2, 4))
    assert all(g.valid <= 4 for g in groups)
    assert len(groups) == 3  # 4 + 4 + 3


def test_chunk_sizes_avoid_gross_padding():
    sched = RequestGroupScheduler(batch_shapes=(1, 4, 16, 64))
    # Peel exact shapes instead of padding 17 -> 64 (3.7x wasted rows).
    assert sched.chunk_sizes(17) == [(16, 16), (1, 1)]
    assert sched.chunk_sizes(5) == [(4, 4), (1, 1)]
    # <= 50% waste pads up: one group amortises loads better than several.
    assert sched.chunk_sizes(3) == [(3, 4)]
    assert sched.chunk_sizes(2) == [(2, 4)]
    assert sched.chunk_sizes(64) == [(64, 64)]
    assert sched.chunk_sizes(80) == [(64, 64), (16, 16)]
    # Remainder below the smallest shape must pad up.
    assert RequestGroupScheduler(batch_shapes=(4,)).chunk_sizes(1) == [(1, 4)]


def test_scheduler_rejects_bad_shapes():
    import pytest
    with pytest.raises(ValueError):
        RequestGroupScheduler(batch_shapes=())
    with pytest.raises(ValueError):
        RequestGroupScheduler(batch_shapes=(0, 4))
    with pytest.raises(ValueError):
        RequestGroupScheduler(batch_shapes=(2,)).padded_size(3)


def test_padding_preserves_results_fixed_seed():
    rng = np.random.default_rng(2)
    spec = [(int(rng.integers(0, len(SUBSET_CHOICES))), False)
            for _ in range(7)]
    check_padding_preserves_results(_requests_from_spec(spec, rng))


def test_warm_multigroup_equivalence_fixed_seeds():
    rng = np.random.default_rng(3)
    for _trial in range(4):
        n = int(rng.integers(2, 10))
        spec = [(int(rng.integers(0, len(SUBSET_CHOICES))), False)
                for _ in range(n)]
        check_warm_multigroup_equivalence(_requests_from_spec(spec, rng))


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(
        spec=st.lists(
            st.tuples(st.integers(0, len(SUBSET_CHOICES) - 1), st.booleans()),
            min_size=1, max_size=12,
        ),
        data_seed=st.integers(0, 2**16),
    )
    def test_scheduler_invariants_hypothesis(spec, data_seed):
        rng = np.random.default_rng(data_seed)
        reqs = _requests_from_spec(spec, rng)
        check_plan_invariants(reqs, batch_shapes=(1, 2, 4))

    @settings(max_examples=10, deadline=None)
    @given(
        spec=st.lists(
            st.tuples(st.integers(0, len(SUBSET_CHOICES) - 1), st.just(False)),
            min_size=1, max_size=6,
        ),
        data_seed=st.integers(0, 2**16),
    )
    def test_padding_preserves_results_hypothesis(spec, data_seed):
        rng = np.random.default_rng(data_seed)
        check_padding_preserves_results(_requests_from_spec(spec, rng))

    @settings(max_examples=10, deadline=None)
    @given(
        spec=st.lists(
            st.tuples(st.integers(0, len(SUBSET_CHOICES) - 1), st.just(False)),
            min_size=1, max_size=8,
        ),
        data_seed=st.integers(0, 2**16),
    )
    def test_warm_multigroup_equivalence_hypothesis(spec, data_seed):
        rng = np.random.default_rng(data_seed)
        check_warm_multigroup_equivalence(_requests_from_spec(spec, rng))
