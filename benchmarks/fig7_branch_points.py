"""Figure 7 — effect of the number of branch points (BP in {3, 5, 7}).

More branch points let tasks branch at finer granularity: variety improves
(lower) while execution overhead worsens — we reproduce the trend with the
transformer block family (layer ranges re-split per BP) over a synthetic
affinity tensor with paired-task structure.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, random_affinity, time_call
from repro.core import BlockCost, GraphCostModel, MSP430, optimal_order
from repro.core.task_graph import enumerate_task_graphs, variety_score


def _block_costs(num_blocks: int, total_weight=1e6, total_flops=1e7):
    return [
        BlockCost(weight_bytes=total_weight / num_blocks, flops=total_flops / num_blocks)
        for _ in range(num_blocks)
    ]


def run(n_tasks: int = 5) -> None:
    for bp in (3, 5, 7):
        aff = random_affinity(n_tasks, bp, seed=7)
        costs = _block_costs(bp + 1)

        def best_graph():
            graphs = enumerate_task_graphs(n_tasks, bp)
            scored = []
            for g in graphs:
                cm = GraphCostModel(g, costs, MSP430)
                order = optimal_order(cm.cost_matrix()).order
                scored.append(
                    (variety_score(g, aff), cm.order_cost(list(order)), g)
                )
            # tradeoff pick: normalise, choose min |v_norm - c_norm|
            vs = np.array([s[0] for s in scored])
            cs = np.array([s[1] for s in scored])
            vn = (vs - vs.min()) / max(np.ptp(vs), 1e-9)
            cn = (cs - cs.min()) / max(np.ptp(cs), 1e-9)
            k = int(np.argmin(np.abs(vn - cn)))
            return scored[k][0], scored[k][1], len(graphs)

        us = time_call(best_graph, iters=1, warmup=0)
        v, c, n_graphs = best_graph()
        emit(
            f"fig7/bp{bp}", us,
            f"variety={v:.3f};exec_cost_s={c:.4f};graphs_enumerated={n_graphs}",
        )


if __name__ == "__main__":
    run()
