"""Mesh-sharded request-group serving sweep: single-device vs TP vs FSDP+TP.

A cycling-subset request trace is served three ways on a forced 8-host-device
CPU topology (one ``(data=4, model=2)`` mesh):

* **single** — the unsharded engine (the PR-5 serving path);
* **tp** — ``EnginePolicy(mesh, TP_POLICY)``: batch over ``data``, fused
  suffix weights tensor-parallel over ``model`` (weights 2-way sharded);
* **fsdp_tp** — ``FSDP_TP_POLICY``: weights additionally ZeRO-sharded over
  ``data`` (8-way), traded against per-suffix all-gather traffic.

Checks run on every configuration (dry-run included):

* sharded outputs match the single-device engine (allclose);
* every session's executed counters equal its incremental cost-model
  prediction **exactly**, including the per-kind collective-byte counters
  (nonzero on both sharded engines);
* the predicted collective bytes equal an independent ``HloCostModel``
  re-measurement over the lowered suffix programs the plan dispatches;
* the gate: the best sharded policy's modelled per-request seconds
  (``ExecutionStats.seconds(hw, weight_shards)`` on an MCU-class model with
  an attached inter-chip link) improve on single-device by **>= 1.2x** —
  each chip streams only its weight slice, and the collective traffic the
  sharding buys must not eat the saving.

Everything is modelled from exact counters (no wall-clock), so the gate is
deterministic.  Machine-readable results land in the ``mesh_sweep`` section
of ``BENCH_serving.json``.

Usage: ``PYTHONPATH=src python benchmarks/serving_mesh.py [--dry-run]``
"""
import os

if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=8"
    ).strip()

import argparse
import dataclasses
import pathlib
import sys

import jax
import jax.numpy as jnp
import numpy as np

if __package__ in (None, ""):  # `python benchmarks/serving_mesh.py`
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
from benchmarks.common import emit, update_bench_json
from benchmarks.serving_batch import build_program
from benchmarks.serving_groups import SUBSETS
from repro.core import MSP430
from repro.launch.hlo_cost import analyze_hlo
from repro.launch.mesh import make_mesh
from repro.serving import (
    EnginePolicy, MultitaskEngine, MultitaskRequest, RequestGroupScheduler,
)
from repro.sharding.policy import FSDP_TP_POLICY, TP_POLICY

SPEEDUP_GATE = 1.2   # best sharded modelled seconds vs single-device
# The MCU cost model with an inter-chip link attached (MSP430 has none):
# weight streaming stays the bottleneck, collectives ride a 50 MB/s link.
HW = dataclasses.replace(MSP430, link_bw=50e6)

COLLECTIVE_FIELDS = ("all-gather", "all-reduce", "reduce-scatter")


def trace_requests(n_requests: int, dim: int, seed: int = 5):
    rng = np.random.default_rng(seed)
    return [
        MultitaskRequest(
            x=jnp.asarray(rng.normal(size=(dim,)), jnp.float32),
            tasks=SUBSETS[i % len(SUBSETS)],
        )
        for i in range(n_requests)
    ]


def measured_collectives(engine, groups):
    """Independent per-kind re-measurement of the plan's collective bytes:
    ``analyze_hlo`` over the exact lowered suffix program of every dispatch
    (``prev`` resets per group — activations never cross groups)."""
    totals = {kind: 0.0 for kind in COLLECTIVE_FIELDS}
    other = 0.0
    for g in groups:
        prev = None
        for t in engine.group_order(g):
            shared = (
                engine.program.graph.shared_prefix_depth(prev, t)
                if prev is not None else 0
            )
            acc = analyze_hlo(engine.executor.suffix_hlo(t, shared, g.xs))
            seen = 0.0
            for kind in COLLECTIVE_FIELDS:
                v = acc.get(f"coll_{kind}", 0.0)
                totals[kind] += v
                seen += v
            other += acc["collective_bytes"] - seen
            prev = t
    return totals, other


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny sizes (the sweep is deterministic either way)")
    ap.add_argument("--dim", type=int, default=None,
                    help="block width (default 64, dry-run 16)")
    ap.add_argument("--requests", type=int, default=None,
                    help="trace length (default 48, dry-run 16)")
    ap.add_argument("--json", default="BENCH_serving.json",
                    help="machine-readable results file ('' disables)")
    args = ap.parse_args(argv)

    if jax.device_count() < 8:
        print(f"FAIL: needs 8 host devices, got {jax.device_count()} "
              "(XLA_FLAGS was locked before this script ran)",
              file=sys.stderr)
        return 1

    dim = args.dim or (16 if args.dry_run else 64)
    n_req = args.requests or (16 if args.dry_run else 48)
    shapes = (1, 4)  # the engine rounds these up to data-shard multiples

    prog = build_program(dim)
    reqs = trace_requests(n_req, dim)
    mesh = make_mesh((4, 2), ("data", "model"))
    configs = {
        "single": None,
        "tp": TP_POLICY,
        "fsdp_tp": FSDP_TP_POLICY,
    }

    print("name,us_per_call,derived")
    rows = {}
    baseline_outputs = None
    for name, sharding in configs.items():
        eng = MultitaskEngine(prog, hw=HW, policy=EnginePolicy(
            mesh=mesh if sharding is not None else None,
            sharding=sharding,
            scheduler=RequestGroupScheduler(batch_shapes=shapes),
        ))
        groups = eng.plan_groups(reqs)
        measured, measured_other = (
            measured_collectives(eng, groups) if sharding is not None
            else ({k: 0.0 for k in COLLECTIVE_FIELDS}, 0.0)
        )

        session = eng.session()
        futures = [session.submit(r) for r in reqs]
        session.drain()
        resp = [f.result() for f in futures]
        stats = session.stats

        # Counters match the incremental prediction exactly — including the
        # collective terms (no gates on these engines).
        assert stats == session.predicted, (
            f"{name}: executed counters diverge from the incremental "
            f"prediction\n  got  {stats}\n  want {session.predicted}")
        # Predicted collective bytes equal the independent HLO measurement.
        assert stats.all_gather_bytes == measured["all-gather"], name
        assert stats.all_reduce_bytes == measured["all-reduce"], name
        assert stats.reduce_scatter_bytes == measured["reduce-scatter"], name
        assert stats.other_collective_bytes == measured_other, name
        if sharding is not None:
            assert stats.collective_bytes > 0, (
                f"{name}: sharded serving must communicate")

        if baseline_outputs is None:
            baseline_outputs = resp
        else:
            for r, s in zip(resp, baseline_outputs):
                assert set(r.outputs) == set(s.outputs)
                for t in r.outputs:
                    np.testing.assert_allclose(
                        np.asarray(r.outputs[t]), np.asarray(s.outputs[t]),
                        rtol=1e-5, atol=1e-5)

        per_req = stats.seconds(HW, weight_shards=eng.weight_shards) / n_req
        emit(f"serve_mesh_{name}", per_req * 1e6,
             f"modelled_per_request;weight_shards={eng.weight_shards};"
             f"data_shards={eng.data_shards};"
             f"collective_bytes={stats.collective_bytes:.0f}")
        rows[name] = {
            "weight_shards": eng.weight_shards,
            "data_shards": eng.data_shards,
            "batch_shapes": list(eng.scheduler.batch_shapes),
            "groups": session.groups_executed,
            "weight_bytes_loaded": stats.weight_bytes_loaded,
            "all_gather_bytes": stats.all_gather_bytes,
            "all_reduce_bytes": stats.all_reduce_bytes,
            "reduce_scatter_bytes": stats.reduce_scatter_bytes,
            "other_collective_bytes": stats.other_collective_bytes,
            "modelled_per_request_seconds": per_req,
        }

    best_name, best = min(
        ((n, r) for n, r in rows.items() if n != "single"),
        key=lambda nr: nr[1]["modelled_per_request_seconds"],
    )
    speedup = (
        rows["single"]["modelled_per_request_seconds"]
        / max(best["modelled_per_request_seconds"], 1e-30)
    )
    rows["best_sharded"] = best_name
    rows["best_sharded_speedup_vs_single"] = speedup
    if args.json:
        update_bench_json(args.json, "mesh_sweep", {
            "dim": dim, "requests": n_req, "dry_run": bool(args.dry_run),
            "mesh": {"data": 4, "model": 2},
            "link_bw": HW.link_bw, "speedup_gate": SPEEDUP_GATE,
            "rows": rows,
        })
    if speedup < SPEEDUP_GATE:
        print(f"FAIL: best sharded policy ({best_name}) modelled speedup "
              f"{speedup:.2f}x < {SPEEDUP_GATE}x vs single-device",
              file=sys.stderr)
        return 1
    print(f"# best sharded policy {best_name}: {speedup:.2f}x modelled "
          f"per-request speedup vs single-device (>= {SPEEDUP_GATE}x)")
    print("# equivalence + exact-counter + HLO-measurement checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
