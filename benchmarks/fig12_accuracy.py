"""Figures 12 & 16 — inference accuracy: Antler vs individually-trained
classifiers (Vanilla).

Trains (a) the Antler task-graph multitask model (shared blocks, joint loss)
and (b) independent per-task networks, on the synthetic multitask dataset
(shared domain, factor-structured labels), and compares mean test accuracy.
The paper's claim: Antler matches Vanilla within ~±1% (deployment) / ±3%
(dataset experiments) while sharing most computation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core import TaskGraph
from repro.data import MultitaskDataset, train_test_split
from repro.models.multitask import (
    build_cnn_program, multitask_forward, multitask_loss,
    program_trainable_params,
)
from repro.training.optimizer import sgd_update


def _train(prog, flat, xtr, ytr, steps, bs, lr, key):
    loss_grad = jax.jit(jax.value_and_grad(
        lambda f, x, y: multitask_loss(prog, f, x, y)
    ))
    n = xtr.shape[0]
    rng = np.random.default_rng(0)
    for i in range(steps):
        idx = rng.integers(0, n, size=bs)
        loss, grads = loss_grad(flat, jnp.asarray(xtr[idx]), jnp.asarray(ytr[:, idx]))
        flat = sgd_update(lr, grads, flat)
    return flat, float(loss)


def _accuracy(prog, flat, xte, yte) -> float:
    outs = multitask_forward(prog, flat, jnp.asarray(xte))
    accs = []
    for t, lg in enumerate(outs):
        pred = np.asarray(jnp.argmax(lg, axis=-1))
        accs.append(float((pred == yte[t]).mean()))
    return float(np.mean(accs))


def run(steps: int = 250) -> None:
    n_tasks = 5
    ds = MultitaskDataset(num_tasks=n_tasks, num_classes=4, noise=0.5, seed=3)
    (xtr, ytr), (xte, yte) = train_test_split(ds, 1024, 256)

    # Antler: shared-prefix task graph (pairs sharing factors share blocks).
    shared_graph = TaskGraph.from_groups([
        [[0, 1, 2, 3, 4]],
        [[0, 3], [1, 4], [2]],
        [[0, 3], [1, 4], [2]],
        [[0], [1], [2], [3], [4]],
    ])
    vanilla_graph = TaskGraph.fully_separate(n_tasks, 3)

    results = {}
    for name, graph in (("antler", shared_graph), ("vanilla", vanilla_graph)):
        prog = build_cnn_program(jax.random.PRNGKey(7), graph, [4] * n_tasks)
        flat = program_trainable_params(prog)

        def job():
            f, loss = _train(prog, flat, xtr, ytr, steps, bs=64, lr=0.05,
                             key=jax.random.PRNGKey(0))
            return f, loss

        us = time_call(job, iters=1, warmup=0)
        trained, loss = job()
        acc = _accuracy(prog, trained, xte, yte)
        results[name] = (us, acc, loss)

    ua, aa, _ = results["antler"]
    uv, av, _ = results["vanilla"]
    emit(
        "fig12_16/accuracy", ua,
        (
            f"antler_acc={aa:.3f};vanilla_acc={av:.3f};"
            f"deviation_pct={100*(aa-av):+.1f};"
            f"antler_train_us={ua:.0f};vanilla_train_us={uv:.0f}"
        ),
    )


if __name__ == "__main__":
    run()
