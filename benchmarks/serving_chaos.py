"""Chaos benchmark: fault-injected multi-tenant serving on a Poisson trace.

A Poisson arrival trace of multitask requests — three tenants, cycling task
subsets, mixed priorities, and per-request deadlines — is served twice
through SLO-aware sessions on warm engines:

* **fault-free** — no injector: the goodput and output baseline;
* **chaos** — a seeded :class:`FaultInjector` armed at the engine's
  plan/load/dispatch boundaries with ~10% combined fault probability plus a
  scripted burst (the first two planning entries always fail), driving the
  session's full recovery machinery: residency rollback, bounded-backoff
  retries, and the graceful-degradation ladder.

Both runs share the identical trace, policy, and simulated clock, so the
comparison is deterministic — the chaos schedule is a pure function of the
injector seed and cannot flake the gates.

Gates (dry-run included; any failure exits 1):

* **zero stranded futures** — after the final drain, every submitted future
  in both runs is terminal (response or typed error);
* **output equivalence** — every request served successfully under chaos
  returns outputs allclose to sequential fault-free single-request serving;
* **counter exactness** — ``session.stats == session.predicted`` field for
  field in both runs: rollbacks and retries must not leak half-executed
  groups into either side;
* **goodput** — requests served successfully under chaos >= ``0.8x`` the
  fault-free count: recovery, not collapse, under a 10% fault rate.

Machine-readable results land in the ``chaos_sweep`` section of
``BENCH_serving.json``.

Usage: ``PYTHONPATH=src python benchmarks/serving_chaos.py [--dry-run]``
"""
from __future__ import annotations

import argparse
import pathlib
import sys

import jax.numpy as jnp
import numpy as np

if __package__ in (None, ""):  # `python benchmarks/serving_chaos.py`
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
from benchmarks.common import emit, update_bench_json
from benchmarks.serving_admission import SimClock
from benchmarks.serving_batch import build_program
from benchmarks.serving_groups import SUBSETS
from repro.core import MSP430
from repro.serving import (
    EnginePolicy, FaultInjector, MultitaskEngine, MultitaskRequest,
    RequestGroupScheduler, RetryPolicy, SloAwarePolicy,
)

GOODPUT_GATE = 0.8   # chaos successes >= this fraction of fault-free
FAULT_RATES = {"plan": 0.05, "load": 0.03, "dispatch": 0.02}  # ~10% combined
FAULT_SCRIPT = {"plan": (0, 1)}  # deterministic burst: first groups retry
TENANTS = ("acme", "globex", "initech")


def chaos_trace(n_requests: int, dim: int, rate: float, seed: int = 3):
    """(arrival_time, request) pairs: Poisson arrivals, cycling subsets,
    three tenants, mixed priorities, and deadlines on every third request
    (arrival + a slack drawn wide enough that only scheduling pathologies
    expire it — expiry is an SLO outcome here, not an error)."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n_requests))
    reqs = []
    for i in range(n_requests):
        deadline = (
            float(arrivals[i]) + float(rng.uniform(0.5, 2.0))
            if i % 3 == 0 else None
        )
        reqs.append(MultitaskRequest(
            x=jnp.asarray(rng.normal(size=(dim,)), jnp.float32),
            tasks=SUBSETS[i % len(SUBSETS)],
            deadline=deadline,
            priority=int(i % 3),
            tenant=TENANTS[i % len(TENANTS)],
        ))
    return list(zip(arrivals.tolist(), reqs))


def run_trace(prog, trace, shapes, injector=None, settle=0.5):
    """Serve the trace arrival-driven through an SLO-aware session."""
    eng = MultitaskEngine(
        prog, hw=MSP430,
        policy=EnginePolicy(scheduling=SloAwarePolicy(
            max_group_size=4, min_pending=8, max_wait=0.25,
            slack_threshold=0.25,
        )),
        scheduler=RequestGroupScheduler(batch_shapes=shapes),
        fault_injector=injector,
    )
    clock = SimClock()
    session = eng.session(
        clock=clock, max_pending=16, overload="shed",
        retry=RetryPolicy(max_retries=2, degrade=True),
        sleep=lambda s: None,  # simulated time: backoff is accounted, not slept
    )
    futures = []
    for t, req in trace:
        clock.t = t
        futures.append(session.submit(req))
        session.step()
    clock.t = trace[-1][0] + settle
    session.drain()
    return session, futures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny sizes (the chaos schedule is deterministic "
                         "either way)")
    ap.add_argument("--dim", type=int, default=None,
                    help="block width (default 256, dry-run 16)")
    ap.add_argument("--requests", type=int, default=None,
                    help="trace length (default 96, dry-run 30)")
    ap.add_argument("--rate", type=float, default=20.0,
                    help="Poisson arrival rate (requests per simulated second)")
    ap.add_argument("--fault-seed", type=int, default=11,
                    help="FaultInjector seed (schedule is a pure function "
                         "of it)")
    ap.add_argument("--json", default="BENCH_serving.json",
                    help="machine-readable results file ('' disables)")
    args = ap.parse_args(argv)

    dim = args.dim or (16 if args.dry_run else 256)
    n_req = args.requests or (30 if args.dry_run else 96)
    shapes = (1, 2, 4)

    prog = build_program(dim)
    trace = chaos_trace(n_req, dim, args.rate)

    # Sequential fault-free single-request serving: the output ground truth
    # (SLO metadata stripped — a one-shot serve on the simulated trace's
    # deadlines would spuriously expire them against its own clock).
    solo = MultitaskEngine(
        prog, hw=MSP430, warm_start=False, group_ordering=False,
        scheduler=RequestGroupScheduler(batch_shapes=(1,)),
    )
    solo_resp = [
        solo.serve(MultitaskRequest(x=r.x, tasks=r.tasks)) for _t, r in trace
    ]

    failures: list = []

    def check(ok, msg):
        if not ok:
            failures.append(msg)
            print(f"FAIL: {msg}", file=sys.stderr)

    runs = {}
    for name, injector in (
        ("fault_free", None),
        ("chaos", FaultInjector(
            rates=FAULT_RATES, script=FAULT_SCRIPT, seed=args.fault_seed)),
    ):
        session, futures = run_trace(prog, trace, shapes, injector=injector)
        # Gate: zero stranded futures — everything terminal after drain.
        stranded = [f.seq for f in futures if not f.done()]
        check(not stranded, f"{name}: stranded futures {stranded}")
        # Gate: counters stay exact through rollbacks and retries.
        check(session.stats == session.predicted,
              f"{name}: executed counters diverge from prediction\n"
              f"  got  {session.stats}\n  want {session.predicted}")
        # Gate: every successful response matches the fault-free reference.
        served = 0
        for f, ref in zip(futures, solo_resp):
            if f.error() is not None:
                continue
            served += 1
            resp = f.result()
            check(set(resp.outputs) == set(ref.outputs),
                  f"{name}: request {f.seq} task set mismatch")
            for t in ref.outputs:
                if not np.allclose(np.asarray(resp.outputs[t]),
                                   np.asarray(ref.outputs[t]),
                                   rtol=1e-5, atol=1e-6):
                    check(False,
                          f"{name}: request {f.seq} task {t} outputs "
                          f"diverge from fault-free serving")
        runs[name] = {
            "served": served,
            "submitted": session.requests_submitted,
            "expired": session.requests_expired,
            "shed": session.requests_shed,
            "rejected": session.requests_rejected,
            "failed": session.requests_failed,
            "groups_executed": session.groups_executed,
            "groups_failed": session.groups_failed,
            "group_retries": session.group_retries,
            "degraded_runs": session.degraded_runs,
            "backoff_seconds": session.backoff_seconds,
            "mean_admission_wait_seconds": session.mean_admission_wait,
            "max_admission_wait_seconds": session.max_admission_wait,
            "weight_bytes_loaded": session.stats.weight_bytes_loaded,
            "tenants": {
                str(t): {
                    "submitted": ts.submitted, "admitted": ts.admitted,
                    "expired": ts.expired, "shed": ts.shed,
                    "rejected": ts.rejected, "failed": ts.failed,
                    "mean_admission_wait_seconds": ts.mean_admission_wait,
                    "max_admission_wait_seconds": ts.max_admission_wait,
                }
                for t, ts in sorted(session.tenants.items(), key=lambda kv: str(kv[0]))
            },
        }
        if injector is not None:
            runs[name]["injected_faults"] = dict(injector.injected)
            runs[name]["fault_invocations"] = dict(injector.invocations)
        emit(f"serve_chaos_{name}", session.mean_admission_wait * 1e6,
             f"mean_admission_wait;served={served}/{n_req};"
             f"retries={session.group_retries};"
             f"degraded={session.degraded_runs};"
             f"groups_failed={session.groups_failed}")

    # Gate: goodput under chaos — recovery, not collapse.
    goodput = runs["chaos"]["served"] / max(runs["fault_free"]["served"], 1)
    runs["chaos_goodput_vs_fault_free"] = goodput
    check(goodput >= GOODPUT_GATE,
          f"chaos goodput {goodput:.2f}x < {GOODPUT_GATE}x fault-free "
          f"({runs['chaos']['served']} vs {runs['fault_free']['served']} served)")
    # Sanity: the chaos run must actually have injected something, or the
    # benchmark is vacuous.
    total_injected = sum(runs["chaos"]["injected_faults"].values())
    check(total_injected > 0, "chaos run injected zero faults")

    if args.json:
        update_bench_json(args.json, "chaos_sweep", {
            "dim": dim, "requests": n_req, "rate": args.rate,
            "dry_run": bool(args.dry_run), "batch_shapes": list(shapes),
            "fault_rates": FAULT_RATES,
            "fault_script": {k: list(v) for k, v in FAULT_SCRIPT.items()},
            "fault_seed": args.fault_seed,
            "goodput_gate": GOODPUT_GATE,
            "runs": runs,
        })
    if failures:
        return 1
    print(f"# chaos goodput {goodput:.2f}x fault-free "
          f"(>= {GOODPUT_GATE}x) with {total_injected} injected faults, "
          f"{runs['chaos']['group_retries']} retries, "
          f"{runs['chaos']['degraded_runs']} degraded runs, "
          f"{runs['chaos']['groups_failed']} groups lost")
    print("# zero stranded futures; outputs + exact counters verified in "
          "both runs")
    return 0


if __name__ == "__main__":
    sys.exit(main())
