"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  table3/*    — GA ordering vs exact optimum (paper Table 3)
  fig7/*      — branch-point sensitivity (paper Figure 7)
  fig8/*      — variety-vs-cost tradeoff per dataset (paper Figure 8)
  fig9_10/*   — time/energy vs Vanilla/NWV/NWS/YONO (paper Figures 9-11)
  fig15/*     — deployment variants Antler/-PC/-CC vs Vanilla (Figure 15)
  table4_5/*  — memory consumption (paper Tables 4-5)
  fig12_16/*  — accuracy parity Antler vs Vanilla (paper Figures 12/16)
  kernels/*   — Pallas kernel checks at benchmark shapes
  ablation/*  — beyond-paper ablations (GA crossover, ordering value, solver work)
  roofline/*  — per (arch x shape x mesh) roofline terms from the dry-run
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (
        ablations, fig7_branch_points, fig8_tradeoff, fig9_10_baselines,
        fig12_accuracy, fig15_deployment, kernels_bench, roofline_table,
        table3_ordering, table4_memory,
    )

    print("name,us_per_call,derived")
    sections = [
        table3_ordering, fig7_branch_points, fig8_tradeoff,
        fig9_10_baselines, fig15_deployment, table4_memory, fig12_accuracy,
        kernels_bench, ablations, roofline_table,
    ]
    failed = 0
    for mod in sections:
        try:
            mod.run()
        except Exception:
            failed += 1
            print(f"{mod.__name__},0,ERROR", file=sys.stdout)
            traceback.print_exc()
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
