"""Shared helpers for the paper-reproduction benchmarks."""
from __future__ import annotations

import json
import pathlib
import time
from typing import Any, Callable, Dict, List, Tuple

import numpy as np


def time_call(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time of ``fn(*args)`` in microseconds."""
    for _ in range(warmup):
        fn(*args)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args)
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))


def emit(name: str, us_per_call: float, derived: str) -> None:
    """One CSV row: ``name,us_per_call,derived``."""
    print(f"{name},{us_per_call:.1f},{derived}")


def update_bench_json(path: str, section: str, payload: Dict[str, Any]) -> None:
    """Merge one benchmark's machine-readable results into a JSON file.

    Each benchmark owns a top-level ``section`` key, so the batch sweep and
    the multi-group sweep can share ``BENCH_serving.json`` without clobbering
    each other; corrupt/absent files start fresh.
    """
    p = pathlib.Path(path)
    data: Dict[str, Any] = {}
    if p.exists():
        try:
            data = json.loads(p.read_text())
            if not isinstance(data, dict):
                data = {}
        except (OSError, json.JSONDecodeError):
            data = {}
    data[section] = payload
    p.write_text(json.dumps(data, indent=2, sort_keys=True) + "\n")


def random_affinity(n: int, d: int, seed: int = 0) -> np.ndarray:
    """Symmetric (D, n, n) affinity tensor with block structure.

    The paper's tasks are all classifiers on the SAME dataset, so baseline
    affinity is high (early layers learn shared low-level features), decays
    with depth, and consecutive task pairs are extra-similar — mirroring the
    synthetic multitask dataset's factor structure.
    """
    rng = np.random.default_rng(seed)
    aff = np.zeros((d, n, n))
    for k in range(d):
        depth_decay = 1.0 - 0.25 * k / max(d - 1, 1)   # deeper -> less affine
        base = rng.uniform(0.55, 0.8, size=(n, n)) * depth_decay
        for i in range(0, n - 1, 2):
            base[i, i + 1] = base[i + 1, i] = rng.uniform(0.85, 0.98) * depth_decay
        aff[k] = (base + base.T) / 2
        np.fill_diagonal(aff[k], 1.0)
    return aff
