"""§Roofline source: aggregate reports/dryrun/*.json into CSV rows.

One row per (arch x shape x mesh): the three roofline terms (seconds),
dominant bottleneck, MODEL_FLOPS/HLO_FLOPs usefulness ratio, and per-device
memory footprint from memory_analysis.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

REPORT_DIR = os.environ.get("DRYRUN_DIR", "reports/dryrun")


def rows():
    for path in sorted(glob.glob(os.path.join(REPORT_DIR, "*.json"))):
        with open(path) as f:
            yield json.load(f)


def run() -> None:
    count_ok = count_skip = count_err = 0
    for r in rows():
        name = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        if r.get("status") == "skipped":
            count_skip += 1
            emit(name, 0.0, f"skipped:{r['reason'][:60]}")
            continue
        if r.get("status") != "ok":
            count_err += 1
            emit(name, 0.0, f"ERROR:{r.get('error', '?')[:80]}")
            continue
        count_ok += 1
        emit(
            name,
            r.get("compile_s", 0.0) * 1e6,
            (
                f"t_compute={r['t_compute']:.4g};t_memory={r['t_memory']:.4g};"
                f"t_collective={r['t_collective']:.4g};dominant={r['dominant']};"
                f"useful_flops_ratio={r['useful_flops_ratio']:.3f};"
                f"policy={r.get('policy')};params_B={r.get('n_params', 0)/1e9:.1f}"
            ),
        )
    emit("roofline/summary", 0.0, f"ok={count_ok};skipped={count_skip};errors={count_err}")


if __name__ == "__main__":
    run()
