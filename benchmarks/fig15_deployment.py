"""Figure 15 — real-world deployments: Antler vs Antler-PC vs Antler-CC vs
Vanilla (paper §7.3).

Audio deployment (5 tasks, presence detector first) and image deployment
(4 tasks, presence precedence).  Three Antler variants:

* Antler     — unconstrained optimal order;
* Antler-PC  — precedence constraint (presence first); the paper observes
  it costs nothing because the optimal order already satisfies it;
* Antler-CC  — conditional constraint (dependents run at p=0.8): expected
  cost drops because gated-off tasks skip their whole suffix.

Costs are expected seconds/joules from the same cost model + executor
counters; the paper reports 2.7–3.1× vs Vanilla.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_call
from repro.core import (
    Constraints, GraphCostModel, MSP430, STM32H747, optimal_order,
    vanilla_baseline,
)
from repro.core.task_graph import TaskGraph
from repro.models.cnn import build_lenet5_blocks


def _deployment(name, n, hw, graph, p_exec=0.8):
    _i, _a, costs, _f = build_lenet5_blocks()
    cm = GraphCostModel(graph, costs, hw)
    c = cm.cost_matrix()

    # Antler: unconstrained
    plain = optimal_order(c)
    t_plain = cm.order_cost(list(plain.order))

    # Antler-PC: presence (task 0) before everything
    cons_pc = Constraints.make(n, precedence=[(0, t) for t in range(1, n)])
    pc = optimal_order(c, cons_pc)
    t_pc = cm.order_cost(list(pc.order))

    # Antler-CC: conditional at p_exec — expected cost of the order where
    # dependents only run with probability p (suffix skipped otherwise).
    cons_cc = Constraints.make(
        n, conditional=[(0, t, p_exec) for t in range(1, n)]
    )
    cc = optimal_order(c, cons_cc)
    t_cc = cm.task_cost(cc.order[0])
    for a, b in zip(cc.order[:-1], cc.order[1:]):
        t_cc += cons_cc.execution_probability(b) * cm.switching_cost(a, b)

    van = vanilla_baseline(n, costs, hw)
    emit(
        f"fig15/{name}/{hw.name}", t_plain * 1e6,
        (
            f"vanilla_s={van.seconds:.4g};antler_s={t_plain:.4g};"
            f"antler_pc_s={t_pc:.4g};antler_cc_s={t_cc:.4g};"
            f"reduction={van.seconds/t_plain:.2f}x;"
            f"pc_equals_plain={abs(t_pc-t_plain)<1e-12};"
            f"cc_cheaper={t_cc < t_plain}"
        ),
    )


def run() -> None:
    # Audio deployment (paper Fig. 14 left): presence branches immediately,
    # heavier tasks share two more blocks.
    audio_graph = TaskGraph.from_groups([
        [[0, 1, 2, 3, 4]],
        [[0], [1, 2, 3, 4]],
        [[0], [1, 2], [3, 4]],
        [[0], [1], [2], [3], [4]],
    ])
    # Image deployment (paper Fig. 14 right): 4 tasks.
    image_graph = TaskGraph.from_groups([
        [[0, 1, 2, 3]],
        [[0], [1, 2, 3]],
        [[0], [1], [2, 3]],
        [[0], [1], [2], [3]],
    ])
    for hw in (MSP430, STM32H747):
        _deployment("audio", 5, hw, audio_graph)
        _deployment("image", 4, hw, image_graph)


if __name__ == "__main__":
    run()
