"""Kernel micro-benchmarks: Pallas (interpret mode) vs jnp oracle.

Interpret-mode wall-time is NOT TPU performance — these rows exist to (a)
exercise the kernels at benchmark shapes and (b) report the oracle-relative
max error, plus the analytic VMEM working set per grid step that the
BlockSpecs claim on real hardware.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.kernels import ops
from repro.kernels import ref as R


def run() -> None:
    key = jax.random.PRNGKey(0)

    # Flash attention @ (B*H=8, S=512, d=64), blocks 128x128
    q = jax.random.normal(key, (8, 512, 64), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (8, 512, 64), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (8, 512, 64), jnp.float32)
    from repro.kernels.flash_attention import flash_attention

    us = time_call(lambda: jax.block_until_ready(
        flash_attention(q, k, v, q_blk=128, kv_blk=128)), warmup=1, iters=2)
    err = float(jnp.abs(flash_attention(q, k, v) - R.flash_attention_ref(q, k, v)).max())
    vmem_kb = (128 * 64 + 128 * 64 * 2 + 128 * 128 + 128 * 64) * 4 / 1024
    emit("kernels/flash_attention", us, f"max_err={err:.2e};vmem_per_step_kb={vmem_kb:.0f}")

    # Pearson affinity @ K=256, F=2048
    x = jax.random.normal(key, (256, 2048), jnp.float32)
    us = time_call(lambda: jax.block_until_ready(
        ops.pairwise_pearson_dissimilarity(x)), warmup=1, iters=2)
    z = x - x.mean(-1, keepdims=True)
    z = z / jnp.linalg.norm(z, axis=-1, keepdims=True)
    err = float(jnp.abs(
        ops.pairwise_pearson_dissimilarity(x) - R.pearson_dissimilarity_ref(z)
    ).max())
    vmem_kb = (128 * 512 * 2 + 128 * 128 * 2) * 4 / 1024
    emit("kernels/pearson_affinity", us, f"max_err={err:.2e};vmem_per_step_kb={vmem_kb:.0f}")

    # SSD scan @ (B=2, S=512, H=4, P=32, N=32), chunk 64
    ks = jax.random.split(key, 5)
    xx = jax.random.normal(ks[0], (2, 512, 4, 32))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (2, 512, 4)))
    a = -jnp.exp(jax.random.normal(ks[2], (4,)))
    bb = jax.random.normal(ks[3], (2, 512, 32))
    cc = jax.random.normal(ks[4], (2, 512, 32))
    us = time_call(lambda: jax.block_until_ready(
        ops.ssd_scan(xx, dt, a, bb, cc, chunk=64)[0]), warmup=1, iters=2)
    y, _ = ops.ssd_scan(xx, dt, a, bb, cc, chunk=64)
    yr, _ = R.ssd_scan_ref(xx, dt, a, bb, cc, chunk=64)
    err = float(jnp.abs(y - yr).max())
    vmem_kb = (64 * 4 * 32 + 64 * 32 * 2 + 4 * 32 * 32 + 64 * 64 * 4) * 4 / 1024
    emit("kernels/ssd_scan", us, f"max_err={err:.2e};vmem_per_step_kb={vmem_kb:.0f}")


if __name__ == "__main__":
    run()
