"""Table 3 — genetic algorithm vs. exact optimum for task ordering.

The paper repurposes TSPLIB instances (regular / precedence / conditional).
TSPLIB is not available offline, so we generate instances with the SAME
sizes and constraint counts as the paper's rows (FIVE n=5; P01 n=15;
GR17 n=17; ESC07 n=9/6 prec; ESC11 n=13/3; br17.12 n=17/12; conditional
variants add 3 probabilistic edges) from seeded symmetric cost matrices.
Optimal values come from Held-Karp / branch-and-bound (exact); the benchmark
reports GA cost vs optimal cost and the deviation, mirroring the paper's
"identical except a few conditional cases within 5%" claim.
"""
from __future__ import annotations

import zlib

import numpy as np

from benchmarks.common import emit, time_call
from repro.core import (
    Constraints, GAConfig, branch_and_bound_order, genetic_order, held_karp_order,
)


def _instance(n: int, seed: int, n_prec: int = 0, n_cond: int = 0):
    rng = np.random.default_rng(seed)
    c = rng.integers(5, 100, size=(n, n)).astype(float)
    c = (c + c.T) / 2
    np.fill_diagonal(c, 0.0)
    prec, cond = [], []
    # Sample a DAG-consistent set of precedence edges over a random order.
    hidden = rng.permutation(n)
    pairs = [
        (int(hidden[i]), int(hidden[j]))
        for i in range(n) for j in range(i + 1, n)
    ]
    rng.shuffle(pairs)
    prec = pairs[:n_prec]
    for (i, j) in pairs[n_prec:n_prec + n_cond]:
        cond.append((i, j, float(rng.uniform(0.3, 0.9))))
    cons = Constraints.make(n, precedence=prec, conditional=cond)
    return c, cons


ROWS = [
    # (variant, name, n, n_prec, n_cond)
    ("regular", "FIVE", 5, 0, 0),
    ("regular", "P01", 15, 0, 0),
    ("regular", "GR17", 17, 0, 0),
    ("precedence", "ESC07", 9, 6, 0),
    ("precedence", "ESC11", 13, 3, 0),
    ("precedence", "br17.12", 17, 12, 0),
    ("conditional", "ESC07c", 9, 6, 3),
    ("conditional", "ESC11c", 13, 3, 3),
    ("conditional", "ESC12c", 14, 7, 3),
]


def run() -> None:
    for variant, name, n, n_prec, n_cond in ROWS:
        c, cons = _instance(n, seed=zlib.crc32(name.encode()), n_prec=n_prec, n_cond=n_cond)
        exact = (
            held_karp_order(c, cons)
            if n <= 17
            else branch_and_bound_order(c, cons)
        )
        def solve_ga():
            # Multi-restart memetic GA (best of 3 seeds), paper Appendix 9.2.
            best = None
            for seed in (1, 2, 3, 4, 5):
                r = genetic_order(c, cons, GAConfig(
                    population=256, elite_pairs=64, patience=60, seed=seed))
                if best is None or r.cost < best.cost:
                    best = r
            return best

        us = time_call(solve_ga, iters=1, warmup=0)
        ga = solve_ga()
        dev = 0.0 if exact.cost == 0 else (ga.cost - exact.cost) / exact.cost * 100
        emit(
            f"table3/{variant}/{name}", us,
            f"optimal={exact.cost:.1f};antler_ga={ga.cost:.1f};deviation_pct={dev:.1f}",
        )


if __name__ == "__main__":
    run()
