"""Intermittent-power serving benchmark: journaled checkpoint/resume on the
MSP430 hardware model vs restart-from-scratch, plus energy-budgeted
duty-cycled execution.

The paper's deployment target (TI MSP430FR5994) runs batteryless: power
fails mid-inference, SRAM state evaporates, and only FRAM survives.  This
benchmark drives the serving stack's intermittent machinery end to end on
that hardware model (``get_hardware("msp430fr5994")``):

* a :class:`MemoryJournalStore` held *outside* the session plays the FRAM —
  it survives every simulated power failure while the session and the
  executor (SRAM) are rebuilt from scratch;
* a single seeded :class:`PowerFailureInjector` (also outside the session,
  like real weather) kills the whole process ~:data:`N_FAILURES` times at
  group and mid-suffix boundaries across the trace;
* every reboot calls :meth:`ServingSession.recover` over the journal, which
  resolves committed groups, resumes the interrupted group from its deepest
  durable activation checkpoint, and re-enqueues the backlog.

Three interrupted arms share the identical trace:

* **resume** — cost-placed mid-suffix checkpoints on; a reboot resumes the
  interrupted suffix from the checkpoint depth;
* **restart** — ``recover(..., use_checkpoints=False)``: the journal still
  guarantees exactly-once responses, but every reboot re-runs the
  interrupted group from depth 0 (the classic restart-from-scratch
  baseline);
* **energy** — failure-free but duty-cycled: an :class:`EnergyBudget`
  (storage capacitor + constant harvest rate) gates every group, pausing
  the pump until enough charge accrues.

Re-executed compute joules are accounted exactly: each arm's total spent
compute energy is the sum of its *committed* counters across boots plus the
partial counters each :class:`PowerFailure` carries out of the dying
process (``pf.context["stats"]``, the about-to-be-lost work); re-executed =
total spent - the uninterrupted baseline's compute energy.

Gates (dry-run included; any failure exits 1):

* **zero lost responses** — after the final drain every journaled admit has
  a committed response;
* **exactly-once** — no group commits twice, no request is covered by two
  commits, and duplicate replay of the full journal is idempotent;
* **output equivalence** — every response in every arm is allclose to the
  uninterrupted baseline's;
* **counter exactness** — ``session.stats == session.predicted`` holds for
  every boot of every arm, checkpoint terms included;
* **failures really happened** — >= :data:`MIN_FAILURES` injected power
  failures per interrupted arm (target ~:data:`N_FAILURES`);
* **checkpointing pays** — the restart arm re-executes >=
  :data:`REEXEC_GATE` x the resume arm's compute joules;
* **duty cycle works** — the energy arm pauses at least once and still
  serves everything.

Machine-readable results land in the ``intermittent_sweep`` section of
``BENCH_serving.json``.

Usage: ``PYTHONPATH=src python benchmarks/serving_intermittent.py [--dry-run]``
"""
from __future__ import annotations

import argparse
import dataclasses
import pathlib
import sys

import jax.numpy as jnp
import numpy as np

if __package__ in (None, ""):  # `python benchmarks/serving_intermittent.py`
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
from benchmarks.common import emit, update_bench_json
from benchmarks.serving_groups import SUBSETS
from repro.configs import get_hardware
from repro.core import BlockCost, MultitaskProgram
from repro.core.task_graph import TaskGraph
from repro.core.types import ExecutionStats
from repro.serving import (
    EnergyBudget, EnginePolicy, Journal, MemoryJournalStore, MultitaskEngine,
    MultitaskRequest, PowerFailure, PowerFailureInjector,
    RequestGroupScheduler, ServingSession,
)

N_FAILURES = 20      # power-failure cap per interrupted arm
MIN_FAILURES = 12    # gate: the schedule must actually exercise recovery
REEXEC_GATE = 1.5    # restart arm re-executes >= this x resume arm's joules
FAIL_RATES = {"group": 0.45, "suffix": 0.3}

HW = get_hardware("msp430fr5994")

# Deep graph with a long shared trunk — the paper's multitask networks
# share their early feature layers, which is what makes trunk checkpoints
# valuable: a durable activation on the trunk seeds the resume of *every*
# task in the group, while a post-branch checkpoint helps only its own task.
GRAPH = TaskGraph.from_groups([
    [[0, 1, 2, 3, 4, 5]],
    [[0, 1, 2, 3, 4, 5]],
    [[0, 1, 2, 3, 4, 5]],
    [[0, 1, 2], [3, 4, 5]],
    [[0, 1], [2], [3], [4, 5]],
    [[0], [1], [2], [3], [4], [5]],
])


def build_program(dim: int, seed: int = 0) -> MultitaskProgram:
    """Dense tanh blocks + linear heads with *nonzero activation bytes*.

    ``act_bytes`` (one float32 activation row per request) is what gives a
    durable checkpoint its write cost — with it at 0 the placement rule
    would checkpoint everywhere and the resume-vs-restart comparison would
    be vacuous.
    """
    rng = np.random.default_rng(seed)
    costs = [
        BlockCost(weight_bytes=4.0 * dim * dim, flops=2.0 * dim * dim,
                  act_bytes=4.0 * dim)
        for _ in range(GRAPH.depth)
    ]

    def block(p, x):
        return jnp.tanh(x @ p)

    node_params = {
        node: jnp.asarray(rng.normal(size=(dim, dim)) / np.sqrt(dim),
                          jnp.float32)
        for node in GRAPH.nodes()
    }
    head_params = [
        jnp.asarray(rng.normal(size=(dim, 8)), jnp.float32)
        for _ in range(GRAPH.num_tasks)
    ]
    return MultitaskProgram(
        graph=GRAPH,
        block_fns=[block] * GRAPH.depth,
        node_params=node_params,
        head_fns=[lambda p, x: x @ p] * GRAPH.num_tasks,
        head_params=head_params,
        block_costs=costs,
    )


def build_requests(n_requests: int, dim: int, seed: int = 2):
    rng = np.random.default_rng(seed)
    return [
        MultitaskRequest(
            x=jnp.asarray(rng.normal(size=(dim,)), jnp.float32),
            tasks=SUBSETS[i % len(SUBSETS)],
        )
        for i in range(n_requests)
    ]


def make_engine(prog, shapes):
    return MultitaskEngine(
        prog, hw=HW, policy=EnginePolicy(warm_start=True),
        scheduler=RequestGroupScheduler(batch_shapes=shapes),
    )


def run_interrupted(prog, reqs, shapes, use_checkpoints, seed):
    """One interrupted arm: serve the trace through ~N_FAILURES reboots.

    Returns the surviving journal store plus the arm's exact energy
    accounting: committed counters summed over every boot, and the partial
    counters each PowerFailure carried out of its dying process.
    """
    engine = make_engine(prog, shapes)
    injector = PowerFailureInjector(
        rates=FAIL_RATES, seed=seed, max_failures=N_FAILURES,
    )
    engine.power_injector = injector
    store = MemoryJournalStore()
    session = ServingSession(
        engine, journal=Journal(store), checkpointing=use_checkpoints,
    )
    for r in reqs:
        session.submit(r)

    committed = ExecutionStats()
    lost = ExecutionStats()
    reboots = 0
    exact = True

    def bank_lost(pf):
        """The dying process's partial counters ride out on the exception —
        the work they describe is about to evaporate with SRAM, and it is
        exactly the re-execution this benchmark measures.

        The executor charges a task's whole suffix to ``stats`` before
        dispatching it, so a mid-suffix death has over-counted the current
        task by its not-yet-executed tail — subtract it, using the depth
        and batch weight the failure context carries."""
        nonlocal lost
        part = pf.context.get("stats")
        if part is None:
            return
        part = dataclasses.replace(part)
        if pf.site == "suffix":
            w = float(pf.context.get("weight", 1))
            tail = prog.block_costs[int(pf.context["depth"]) + 1:]
            part.flops_executed -= w * sum(bc.flops for bc in tail)
        lost = lost.merge(part)

    while True:
        try:
            session.drain()
            break
        except PowerFailure as pf:
            reboots += 1
            bank_lost(pf)
            exact = exact and session.stats == session.predicted
            committed = committed.merge(session.stats)
            while True:
                engine.executor.reset()  # SRAM gone
                try:
                    session = ServingSession.recover(
                        Journal(store), engine,
                        use_checkpoints=use_checkpoints,
                    )
                    break
                except PowerFailure as pf2:
                    reboots += 1
                    bank_lost(pf2)
    exact = exact and session.stats == session.predicted
    committed = committed.merge(session.stats)
    return {
        "store": store,
        "committed": committed,
        "lost": lost,
        "reboots": reboots,
        "failures": injector.total_injected,
        "exact": exact,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny sizes (failure schedules are deterministic "
                         "either way)")
    ap.add_argument("--dim", type=int, default=None,
                    help="block width (default 128, dry-run 16)")
    ap.add_argument("--requests", type=int, default=None,
                    help="trace length (default 48, dry-run 24)")
    ap.add_argument("--fail-seed", type=int, default=17,
                    help="PowerFailureInjector seed (the failure schedule "
                         "is a pure function of it)")
    ap.add_argument("--json", default="BENCH_serving.json",
                    help="machine-readable results file ('' disables)")
    args = ap.parse_args(argv)

    dim = args.dim or (16 if args.dry_run else 128)
    n_req = args.requests or (24 if args.dry_run else 48)
    shapes = (1, 2, 4)

    prog = build_program(dim)
    reqs = build_requests(n_req, dim)

    failures: list = []

    def check(ok, msg):
        if not ok:
            failures.append(msg)
            print(f"FAIL: {msg}", file=sys.stderr)

    # ---------------------------------------------------------- baseline
    # Uninterrupted journaled run: the output + compute-energy reference.
    base_engine = make_engine(prog, shapes)
    base_store = MemoryJournalStore()
    base = ServingSession(base_engine, journal=Journal(base_store))
    base_futs = [base.submit(r) for r in reqs]
    base.drain()
    check(base.stats == base.predicted,
          "baseline: executed counters diverge from prediction")
    check(base.stats.checkpoint_bytes > 0,
          "baseline: cost model placed no checkpoints (vacuous benchmark)")
    base_outputs = {f.seq: f.result().outputs for f in base_futs}
    base_compute = base.stats.compute_energy(HW)

    def check_journal(name, store, arm=None):
        """The correctness gates every interrupted arm must pass."""
        state = Journal(store).replay()
        # Zero lost: every durable admit has a durable response.
        missing = sorted(set(state.admitted) - set(state.responses))
        check(not missing, f"{name}: requests lost {missing}")
        check(len(state.admitted) == n_req,
              f"{name}: {len(state.admitted)} admits != {n_req} requests")
        # Exactly-once: one commit per group, one covering commit per seq.
        commits = [r for r in store.records() if r["kind"] == "group_commit"]
        gids = [r["group_id"] for r in commits]
        check(len(gids) == len(set(gids)),
              f"{name}: a group committed more than once")
        seq_commits: dict = {}
        for r in commits:
            for s in r["seqs"]:
                seq_commits[s] = seq_commits.get(s, 0) + 1
        dup = sorted(s for s, k in seq_commits.items() if k > 1)
        check(not dup, f"{name}: requests {dup} covered by multiple commits")
        # Idempotent replay: folding the log twice changes nothing.
        again = Journal(store).replay()
        check(set(again.responses) == set(state.responses),
              f"{name}: replay is not idempotent")
        # Output equivalence vs the uninterrupted baseline, per request.
        for seq, ref in base_outputs.items():
            rec = state.responses.get(seq)
            if rec is None:
                continue  # already reported as lost
            got = rec["outputs"]
            check(set(got) == set(ref), f"{name}: seq {seq} task set differs")
            for t in ref:
                if not np.allclose(np.asarray(got[t]), np.asarray(ref[t]),
                                   rtol=1e-5, atol=1e-6):
                    check(False, f"{name}: seq {seq} task {t} outputs "
                                 f"diverge from the uninterrupted run")
        return state

    # ------------------------------------------------- interrupted arms
    runs = {}
    for name, use_ck in (("resume", True), ("restart", False)):
        arm = run_interrupted(prog, reqs, shapes, use_ck, args.fail_seed)
        check_journal(name, arm["store"])
        check(arm["exact"],
              f"{name}: a boot's counters diverged from its prediction")
        check(arm["failures"] >= MIN_FAILURES,
              f"{name}: only {arm['failures']} power failures injected "
              f"(< {MIN_FAILURES}; schedule too gentle to gate on)")
        spent = (arm["committed"].compute_energy(HW)
                 + arm["lost"].compute_energy(HW))
        runs[name] = {
            "reboots": arm["reboots"],
            "power_failures": arm["failures"],
            "committed_compute_joules": arm["committed"].compute_energy(HW),
            "lost_compute_joules": arm["lost"].compute_energy(HW),
            "spent_compute_joules": spent,
            "reexecuted_compute_joules": spent - base_compute,
            "checkpoint_bytes_written": arm["committed"].checkpoint_bytes,
            "checkpoint_seconds": arm["committed"].checkpoint_seconds,
            "journal_records": len(arm["store"].records()),
            "counters_exact": arm["exact"],
        }
        emit(f"serve_intermittent_{name}", spent * 1e6,
             f"spent_compute_ujoules;failures={arm['failures']};"
             f"reboots={arm['reboots']};"
             f"reexec_uJ={(spent - base_compute) * 1e6:.1f}")
    check(runs["resume"]["checkpoint_bytes_written"] > 0,
          "resume arm wrote no checkpoints")
    check(runs["restart"]["checkpoint_bytes_written"] == 0,
          "restart arm wrote checkpoints (should be disabled)")

    # Gate: checkpoints pay — restart re-executes >= REEXEC_GATE x more.
    re_resume = runs["resume"]["reexecuted_compute_joules"]
    re_restart = runs["restart"]["reexecuted_compute_joules"]
    check(re_resume > 0 and re_restart > 0,
          f"re-executed joules must be positive "
          f"(resume {re_resume:.3e}, restart {re_restart:.3e})")
    ratio = re_restart / re_resume if re_resume > 0 else float("inf")
    runs["restart_vs_resume_reexec_ratio"] = ratio
    check(ratio >= REEXEC_GATE,
          f"restart re-executes only {ratio:.2f}x the resume arm's compute "
          f"joules (< {REEXEC_GATE}x): checkpoints did not pay")

    # ------------------------------------------------- energy-budget arm
    # Duty-cycled failure-free serving: a storage capacitor sized to the
    # whole trace's energy, charged from empty by a constant harvest rate.
    # Every group waits for charge, so the pump pauses >= once per group.
    eng_e = make_engine(prog, shapes)
    store_e = MemoryJournalStore()
    budget = EnergyBudget(
        capacity_joules=base.stats.energy(HW) * 1.5,
        harvest_watts=base.stats.energy(HW),   # ~1 simulated second to fill
        initial_joules=0.0,
    )
    energy_session = ServingSession(
        eng_e, journal=Journal(store_e), energy=budget,
        sleep=lambda s: None,  # simulated time: pauses are accounted, not slept
    )
    efuts = [energy_session.submit(r) for r in reqs]
    energy_session.drain()
    check_journal("energy", store_e)
    check(energy_session.stats == energy_session.predicted,
          "energy: executed counters diverge from prediction")
    check(all(f.done() and f.error() is None for f in efuts),
          "energy: not every request served")
    check(energy_session.energy_pauses > 0, "energy: the pump never paused")
    check(energy_session.groups_failed == 0,
          "energy: groups failed under the budget")
    runs["energy"] = {
        "pauses": energy_session.energy_pauses,
        "paused_seconds": energy_session.energy_paused_seconds,
        "harvested_joules": budget.harvested_joules,
        "spilled_joules": budget.spilled_joules,
        "capacity_joules": budget.capacity_joules,
        "harvest_watts": budget.harvest_watts,
        "groups_executed": energy_session.groups_executed,
    }
    emit("serve_intermittent_energy",
         energy_session.energy_paused_seconds * 1e6,
         f"paused_useconds;pauses={energy_session.energy_pauses};"
         f"groups={energy_session.groups_executed}")

    if args.json:
        update_bench_json(args.json, "intermittent_sweep", {
            "dim": dim, "requests": n_req, "dry_run": bool(args.dry_run),
            "batch_shapes": list(shapes), "hardware": "msp430fr5994",
            "fail_rates": FAIL_RATES, "fail_seed": args.fail_seed,
            "n_failures_cap": N_FAILURES, "min_failures_gate": MIN_FAILURES,
            "reexec_gate": REEXEC_GATE,
            "baseline_compute_joules": base_compute,
            "baseline_total_joules": base.stats.energy(HW),
            "baseline_checkpoint_bytes": base.stats.checkpoint_bytes,
            "runs": runs,
        })
    if failures:
        return 1
    print(f"# intermittent: restart re-executed {ratio:.2f}x the resume "
          f"arm's compute joules (>= {REEXEC_GATE}x) across "
          f"{runs['resume']['power_failures']}+"
          f"{runs['restart']['power_failures']} power failures")
    print(f"# energy: {runs['energy']['pauses']} duty-cycle pauses, "
          f"{runs['energy']['paused_seconds']:.3f}s simulated charging, "
          f"all {n_req} requests served")
    print("# zero lost/duplicated responses; outputs + exact counters "
          "verified in every boot of every arm")
    return 0


if __name__ == "__main__":
    sys.exit(main())
