"""Figures 9 & 10 (+ the paper's headline claim) — execution time and energy
of Antler vs Vanilla / NWV / NWS / YONO.

Two measurements per dataset row:

* analytic: the cost-model seconds/joules of each system on the MCU-class
  platforms (MSP430 16-bit, STM32H747 32-bit), using the same per-block cost
  table (weights bytes + FLOPs) for every system — the paper's Figures 9/10.
* measured: REAL wall-clock of the block-cached executor vs the Vanilla
  executor on this CPU, over the paper-scale CNN programs — demonstrating
  the block-skip mechanism end to end, not just on paper.

The derived field reports Antler's speedup vs the best and worst baseline;
the paper's claim is 2.3x-4.6x vs the state of the art and 56-78% energy
saving.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, random_affinity, time_call
from repro.core import (
    MSP430, STM32H747, GraphCostModel, TaskGraphExecutor, VanillaExecutor,
    antler_report, nws_baseline, nwv_baseline, optimal_order, vanilla_baseline,
    yono_baseline,
)
from repro.core.tradeoff import select_task_graph
from repro.models.cnn import build_lenet5_blocks
from repro.models.multitask import build_cnn_program

DATASETS = {
    "mnist": (10, 1), "fmnist": (10, 2), "cifar10": (10, 3),
    "svhn": (10, 4), "gtsrb": (10, 5), "gsc": (10, 6),
    "esc": (10, 7), "us8k": (10, 8), "hhar": (6, 9),
}


def _select_graph(n: int, seed: int, costs):
    aff = random_affinity(n, 3, seed=seed)
    res = select_task_graph(
        n, 3, aff, costs, MSP430,
        beam=600 if n > 6 else None,
    )
    return res.selected


def run() -> None:
    _inits, _applies, costs, _feat = build_lenet5_blocks()
    for name, (n, seed) in DATASETS.items():
        sel = _select_graph(n, seed, costs)
        graph, order = sel.graph, list(sel.order)
        for hw in (MSP430, STM32H747):
            ant = antler_report(graph, costs, hw, order)
            rows = {
                "vanilla": vanilla_baseline(n, costs, hw),
                "nwv": nwv_baseline(n, costs, hw),
                "nws": nws_baseline(n, costs, hw),
                "yono": yono_baseline(n, costs, hw),
            }
            best = min(r.seconds for r in rows.values())
            worst = max(r.seconds for r in rows.values())
            e_best = min(r.joules for r in rows.values())
            e_worst = max(r.joules for r in rows.values())
            emit(
                f"fig9_10/{name}/{hw.name}", ant.seconds * 1e6,
                (
                    f"antler_s={ant.seconds:.4g};vanilla_s={rows['vanilla'].seconds:.4g};"
                    f"nwv_s={rows['nwv'].seconds:.4g};nws_s={rows['nws'].seconds:.4g};"
                    f"yono_s={rows['yono'].seconds:.4g};"
                    f"speedup_vs_best={best/ant.seconds:.2f}x;"
                    f"speedup_vs_worst={worst/ant.seconds:.2f}x;"
                    f"energy_saving_vs_best={100*(1-ant.joules/e_best):.0f}%;"
                    f"energy_saving_vs_worst={100*(1-ant.joules/e_worst):.0f}%"
                ),
            )

    # Measured wall-clock: block-cached vs vanilla executor on real arrays.
    n = 5
    sel = _select_graph(n, seed=1, costs=costs)
    prog = build_cnn_program(jax.random.PRNGKey(0), sel.graph, [10] * n)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(8, 28, 28, 1)), jnp.float32)
    ant_ex = TaskGraphExecutor(prog)
    van_ex = VanillaExecutor(prog)
    order = list(sel.order)

    def run_antler():
        ant_ex.reset()
        outs, _ = ant_ex.run(x, order)
        jax.block_until_ready(list(outs.values()))

    def run_vanilla():
        outs, _ = van_ex.run(x, order)
        jax.block_until_ready(list(outs.values()))

    us_a = time_call(run_antler, warmup=2, iters=5)
    us_v = time_call(run_vanilla, warmup=2, iters=5)
    _, stats_a = ant_ex.run(x, order)
    emit(
        "fig9_10/measured_executor_cpu", us_a,
        (
            f"vanilla_us={us_v:.0f};antler_us={us_a:.0f};"
            f"wallclock_speedup={us_v/us_a:.2f}x;"
            f"blocks_skipped={stats_a.blocks_skipped};blocks_executed={stats_a.blocks_executed}"
        ),
    )


if __name__ == "__main__":
    run()
