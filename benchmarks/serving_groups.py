"""Multi-group serving sweep: warm-start + group ordering vs cold per group.

A prefix-heavy workload (requests spread over many distinct task subsets, so
the scheduler emits many groups) is served three ways:

* **cold** — the PR-1 path: ``warm_start=False, group_ordering=False``; the
  executor resets before every group, so each group pays full cold weight
  loads;
* **warm** — residency kept across groups, groups in bucket order;
* **warm+ordered** — residency kept AND the inter-group sequence chosen by
  the cost-aware group-ordering pass (boundary tasks sharing the longest
  prefix become neighbours).

Checks run on every configuration (dry-run included):

* outputs of all paths match sequential single-request serving (allclose);
* the warm engine's cumulative counters equal
  ``MultitaskEngine.predicted_group_stats`` of its plan **exactly**;
* fused-suffix execution dispatches exactly one program per task execution
  (the per-block reference path dispatches ``suffix+head`` programs and
  must agree allclose);
* warm+ordered total ``weight_bytes_loaded`` is >= 1.5x lower than cold.

Machine-readable results land in the ``group_sweep`` section of
``BENCH_serving.json`` (per-request seconds, weight bytes loaded/skipped,
dispatch counts).

Usage: ``PYTHONPATH=src python benchmarks/serving_groups.py [--dry-run]``
"""
from __future__ import annotations

import argparse
import pathlib
import sys

import jax
import jax.numpy as jnp
import numpy as np

if __package__ in (None, ""):  # `python benchmarks/serving_groups.py`
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
from benchmarks.common import emit, time_call, update_bench_json
from benchmarks.serving_batch import GRAPH, build_program
from repro.core import GraphCostModel, MSP430, TaskGraphExecutor
from repro.serving import (
    MultitaskEngine, MultitaskRequest, RequestGroupScheduler,
)

# Subsets interleave the graph's two subtrees ({0,1,2} vs {3,4,5}) so bucket
# order alternates between deep-prefix-disjoint groups — the adversarial
# sequence the group-ordering pass exists to fix.
SUBSETS = (
    (0, 1), (3, 4), (0, 1, 2), (3, 4, 5),
    (0, 2), (4, 5), (1, 2), (3, 5),
)


def build_requests(n_requests: int, dim: int, seed: int = 2):
    rng = np.random.default_rng(seed)
    return [
        MultitaskRequest(
            x=jnp.asarray(rng.normal(size=(dim,)), jnp.float32),
            tasks=SUBSETS[i % len(SUBSETS)],
        )
        for i in range(n_requests)
    ]


def serve(eng: MultitaskEngine, reqs):
    resp = eng.serve_batch(reqs)
    jax.block_until_ready([list(r.outputs.values()) for r in resp])
    return resp


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny sizes, 1 iteration, no wall-clock reporting")
    ap.add_argument("--dim", type=int, default=None,
                    help="block width (default 256, dry-run 16)")
    ap.add_argument("--requests", type=int, default=None,
                    help="request count (default 48, dry-run 16)")
    ap.add_argument("--json", default="BENCH_serving.json",
                    help="machine-readable results file ('' disables)")
    args = ap.parse_args(argv)

    dim = args.dim or (16 if args.dry_run else 256)
    n_req = args.requests or (16 if args.dry_run else 48)
    iters = 1 if args.dry_run else 5
    shapes = (1, 2, 4)  # small groups -> many boundaries, the warm lever

    prog = build_program(dim)
    reqs = build_requests(n_req, dim)

    def engine(warm: bool, ordered: bool) -> MultitaskEngine:
        return MultitaskEngine(
            prog, hw=MSP430, warm_start=warm, group_ordering=ordered,
            scheduler=RequestGroupScheduler(batch_shapes=shapes),
        )

    engines = {
        "cold": engine(False, False),
        "warm": engine(True, False),
        "warm_ordered": engine(True, True),
    }
    solo = MultitaskEngine(
        prog, hw=MSP430, warm_start=False, group_ordering=False,
        scheduler=RequestGroupScheduler(batch_shapes=(1,)),
    )

    # ---------------------------------------------------------- correctness
    solo_resp = [solo.serve(r) for r in reqs]
    results = {}
    for name, eng in engines.items():
        groups = eng.plan_groups(reqs)
        pred = eng.predicted_group_stats(groups)
        d0 = eng.executor.dispatch_count
        resp = serve(eng, reqs)
        dispatches = eng.executor.dispatch_count - d0
        stats = eng.last_batch_stats
        assert stats == pred, (
            f"{name}: cumulative counters diverge from predicted_group_stats\n"
            f"  got  {stats}\n  want {pred}")
        for r, s in zip(resp, solo_resp):
            assert set(r.outputs) == set(s.outputs)
            for t in r.outputs:
                np.testing.assert_allclose(
                    np.asarray(r.outputs[t]), np.asarray(s.outputs[t]),
                    rtol=1e-5, atol=1e-6)
        # Fused-suffix execution: exactly one dispatch per task execution.
        task_execs = sum(
            len([t for t in eng.order if g.tasks is None or t in g.tasks])
            for g in groups
        )
        assert dispatches == task_execs, (
            f"{name}: {dispatches} dispatches for {task_execs} task executions")
        results[name] = {"stats": stats, "groups": len(groups),
                         "dispatches": dispatches, "task_execs": task_execs}

    # Per-block reference path agrees with the fused engine output.
    ref_eng = engine(True, True)
    ref_eng.executor = TaskGraphExecutor(prog, fused=False)
    d0 = ref_eng.executor.dispatch_count
    ref_resp = serve(ref_eng, reqs)
    perblock_dispatches = ref_eng.executor.dispatch_count - d0
    for r, s in zip(ref_resp, solo_resp):
        for t in r.outputs:
            np.testing.assert_allclose(
                np.asarray(r.outputs[t]), np.asarray(s.outputs[t]),
                rtol=1e-5, atol=1e-6)
    assert perblock_dispatches > results["warm_ordered"]["dispatches"], (
        "per-block path should dispatch more programs than the fused path")

    # -------------------------------------------------------------- summary
    cold_loads = results["cold"]["stats"].weight_bytes_loaded
    print("name,us_per_call,derived")
    rows = {}
    for name, eng in engines.items():
        stats = results[name]["stats"]
        ratio = cold_loads / max(stats.weight_bytes_loaded, 1e-9)
        per_req_us = (
            time_call(serve, eng, reqs, warmup=1, iters=iters) / n_req
        )
        emit(f"serve_groups_{name}", per_req_us,
             f"per_request;groups={results[name]['groups']};"
             f"weight_bytes_loaded={stats.weight_bytes_loaded:.0f};"
             f"load_reduction_vs_cold={ratio:.2f}x;"
             f"dispatches={results[name]['dispatches']}")
        rows[name] = {
            "groups": results[name]["groups"],
            "per_request_seconds": per_req_us * 1e-6,
            "weight_bytes_loaded": stats.weight_bytes_loaded,
            "weight_bytes_skipped": stats.weight_bytes_skipped,
            "load_reduction_vs_cold": ratio,
            "dispatches": results[name]["dispatches"],
            "task_executions": results[name]["task_execs"],
            "dispatches_per_task": (
                results[name]["dispatches"] / results[name]["task_execs"]
            ),
        }
    rows["per_block_reference_dispatches"] = perblock_dispatches

    reduction = cold_loads / results["warm_ordered"]["stats"].weight_bytes_loaded
    if args.json:
        update_bench_json(args.json, "group_sweep", {
            "dim": dim, "requests": n_req, "dry_run": bool(args.dry_run),
            "batch_shapes": list(shapes), "rows": rows,
        })
    if reduction < 1.5:
        print(f"FAIL: warm+ordered load reduction {reduction:.2f}x < 1.5x",
              file=sys.stderr)
        return 1
    print(f"# warm+ordered weight-load reduction vs cold: {reduction:.2f}x "
          f"(>= 1.5x); dispatches/task = 1 (fused), "
          f"{perblock_dispatches / results['warm_ordered']['task_execs']:.2f} "
          f"(per-block)")
    print("# equivalence + exact-counter checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
