"""Input-adaptive serving sweep: confidence gating vs the all-blocks floor.

A mixed-difficulty Poisson trace — ~70% *easy* requests (large-norm inputs
whose representation is already decisive) and ~30% *hard* ones — is served
twice through engines that differ in exactly one policy knob:

* **floor** — the ungated path: every request pays every block of its
  suffix (the all-blocks floor every previous PR optimized);
* **adaptive** — ``EnginePolicy.adaptive``: a per-row confidence gate
  (mean absolute activation) inside the fused suffixes lets a row skip a
  block once its confidence clears the threshold, with online gate-model
  calibration feeding the expected-cost predictions.

The program is genuinely input-adaptive (the regime AdaMTL/MIME target):
each block applies a *damped* residual refinement ``h + tanh(h @ W) *
relu(1 - mean|h|)`` — once a row's mean activation passes 1 the refinement
vanishes, so for easy traffic the deep blocks are identities and skipping
them is exact.  The confidence threshold sits just below the damping
cutoff, which is why adaptive execution loses (essentially) no accuracy.

Gates (dry-run included; any failure exits 1):

* **counter exactness** — ``session.stats == session.predicted`` field for
  field in both arms (the adaptive prediction replays each group's
  realized gate trace);
* **accuracy** — >= 99% per-(request, task) argmax agreement between the
  adaptive and floor arms, and *exact* (allclose) outputs on easy
  requests, whose skipped blocks are identities;
* **coverage** — the adaptive arm actually gated rows off
  (``block_rows_gated > 0``) and spent fewer flops than the floor;
* **speedup** — >= 1.3x modelled per-request seconds vs the floor on this
  easy-heavy trace;
* **calibration** — after one calibrated pass, re-serving the trace gives
  a-priori expected flops within 5% of the realized flops.

Machine-readable results land in the ``adaptive_sweep`` section of
``BENCH_serving.json``.

Usage: ``PYTHONPATH=src python benchmarks/serving_adaptive.py [--dry-run]``
"""
from __future__ import annotations

import argparse
import pathlib
import sys

import jax
import jax.numpy as jnp
import numpy as np

if __package__ in (None, ""):  # `python benchmarks/serving_adaptive.py`
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
from benchmarks.common import emit, update_bench_json
from benchmarks.serving_admission import SimClock
from benchmarks.serving_batch import GRAPH
from benchmarks.serving_groups import SUBSETS
from repro.core import BlockCost, MSP430, MultitaskProgram
from repro.serving import (
    AdaptivePolicy, EnginePolicy, MultitaskEngine, MultitaskRequest,
    RequestGroupScheduler, WindowPolicy,
)

EASY_FRACTION = 0.7    # of the trace; easy = large-norm, exits after 1 block
EASY_SCALE, HARD_SCALE = 2.0, 0.2
THRESHOLD = 0.9        # confidence gate; just under the damping cutoff (1.0)
AGREEMENT_GATE = 0.99  # adaptive-vs-floor argmax agreement
SPEEDUP_GATE = 1.3     # modelled per-request seconds: floor / adaptive
CALIBRATION_GATE = 0.05  # |expected - realized| / realized flops, 2nd pass


def build_adaptive_program(dim: int, seed: int = 0) -> MultitaskProgram:
    """Damped-residual blocks + 8-way linear heads.

    The refinement ``tanh(h @ W) * relu(1 - mean|h|)`` dies once the row's
    mean activation reaches 1: hard (small-norm) inputs keep refining while
    easy (large-norm) inputs pass through unchanged — the input-conditional
    compute profile the adaptive gate exploits.  One shared block fn object
    keeps every suffix on the fused ``lax.scan`` path.
    """
    rng = np.random.default_rng(seed)
    costs = [
        BlockCost(weight_bytes=4.0 * dim * dim, flops=2.0 * dim * dim)
        for _ in range(GRAPH.depth)
    ]

    def block(p, h):
        damp = jnp.maximum(0.0, 1.0 - jnp.mean(jnp.abs(h)))
        return h + jnp.tanh(h @ p) * damp

    node_params = {
        node: jnp.asarray(rng.normal(size=(dim, dim)) / np.sqrt(dim),
                          jnp.float32)
        for node in GRAPH.nodes()
    }
    heads = [lambda p, h: h @ p] * GRAPH.num_tasks
    head_params = [jnp.asarray(rng.normal(size=(dim, 8)), jnp.float32)
                   for _ in range(GRAPH.num_tasks)]
    return MultitaskProgram(
        GRAPH, [block] * GRAPH.depth, node_params, heads, head_params, costs
    )


def mixed_trace(n_requests: int, dim: int, rate: float = 200.0, seed: int = 3):
    """(arrival_time, request, easy?) triples: Poisson arrivals, cycling
    task subsets, and a fixed deterministic easy/hard mixture."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n_requests))
    out = []
    for i in range(n_requests):
        easy = (i % 10) < round(EASY_FRACTION * 10)
        scale = EASY_SCALE if easy else HARD_SCALE
        x = jnp.asarray(rng.normal(size=(dim,)) * scale, jnp.float32)
        req = MultitaskRequest(x=x, tasks=SUBSETS[i % len(SUBSETS)])
        out.append((float(arrivals[i]), req, easy))
    return out


def run_trace(prog, trace, shapes, adaptive):
    """Serve the trace arrival-driven; returns (session, responses)."""
    eng = MultitaskEngine(
        prog, hw=MSP430,
        # A windowed admission sized to the arrival rate, so each planning
        # batch fills the per-subset buckets to the largest batch shape —
        # large batches are where gated flops dominate the (physical,
        # ungated) weight loads.
        policy=EnginePolicy(
            adaptive=adaptive,
            scheduling=WindowPolicy(max_wait=0.4, max_group_size=128),
        ),
        scheduler=RequestGroupScheduler(batch_shapes=shapes),
    )
    session, responses = replay_trace(eng, trace)
    return eng, session, responses


def replay_trace(eng, trace):
    clock = SimClock()
    session = eng.session(clock=clock)
    futures = []
    for t, req, _easy in trace:
        clock.t = t
        futures.append(session.submit(req))
        session.step()
    session.drain()
    responses = [f.result() for f in futures]
    jax.block_until_ready([list(r.outputs.values()) for r in responses])
    return session, responses


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny sizes (gates are identical either way)")
    ap.add_argument("--dim", type=int, default=None,
                    help="block width (default 64, dry-run 16)")
    ap.add_argument("--requests", type=int, default=None,
                    help="trace length (default 96, dry-run 30)")
    ap.add_argument("--json", default="BENCH_serving.json",
                    help="machine-readable results file ('' disables)")
    args = ap.parse_args(argv)

    dim = args.dim or (16 if args.dry_run else 64)
    n_req = args.requests or (30 if args.dry_run else 96)
    shapes = (4, 8, 16)
    hw = MSP430

    prog = build_adaptive_program(dim)
    trace = mixed_trace(n_req, dim)

    failures: list = []

    def check(ok, msg):
        if not ok:
            failures.append(msg)
            print(f"FAIL: {msg}", file=sys.stderr)

    policy = AdaptivePolicy(threshold=THRESHOLD, min_blocks=1,
                            calibrate_online=True)
    arms = {}
    for name, adaptive in (("floor", None), ("adaptive", policy)):
        eng, session, responses = run_trace(prog, trace, shapes, adaptive)
        arms[name] = (eng, session, responses)
        check(session.stats == session.predicted,
              f"{name}: executed counters diverge from prediction\n"
              f"  got  {session.stats}\n  want {session.predicted}")

    (_, s_floor, r_floor) = arms["floor"]
    (eng_ad, s_ad, r_ad) = arms["adaptive"]

    # Gate: gating actually happened and bought flops.
    check(s_ad.stats.block_rows_gated > 0,
          "adaptive: no rows were gated — the sweep is vacuous")
    check(s_ad.stats.flops_executed < s_floor.stats.flops_executed,
          "adaptive: spent no fewer flops than the all-blocks floor")
    # Loads are physical (the fused scan consumes all stacked params), so
    # gating must not change the byte volume.
    check(s_ad.stats.weight_bytes_loaded == s_floor.stats.weight_bytes_loaded,
          "adaptive: loaded a different byte volume than the floor")

    # Gate: accuracy proxy — argmax agreement, exactness on easy requests.
    agree = total = 0
    for i, ((_, _, easy), ra, rf) in enumerate(zip(trace, r_ad, r_floor)):
        check(set(ra.outputs) == set(rf.outputs),
              f"request {i}: task set mismatch")
        for t in rf.outputs:
            total += 1
            agree += int(np.argmax(np.asarray(ra.outputs[t]))
                         == np.argmax(np.asarray(rf.outputs[t])))
            if easy and not np.allclose(np.asarray(ra.outputs[t]),
                                        np.asarray(rf.outputs[t]),
                                        rtol=1e-5, atol=1e-6):
                check(False, f"easy request {i} task {t}: outputs diverge "
                             f"(skipped blocks should be identities)")
    agreement = agree / max(total, 1)
    check(agreement >= AGREEMENT_GATE,
          f"argmax agreement {agreement:.4f} < {AGREEMENT_GATE}")

    # Gate: modelled per-request speedup on the easy-heavy trace.
    floor_s = s_floor.stats.seconds(hw) / n_req
    adapt_s = s_ad.stats.seconds(hw) / n_req
    speedup = floor_s / adapt_s
    check(speedup >= SPEEDUP_GATE,
          f"adaptive speedup {speedup:.2f}x < {SPEEDUP_GATE}x "
          f"({floor_s:.6f}s vs {adapt_s:.6f}s per request)")

    # Gate: a second pass over the same traffic with the online-calibrated
    # gate model predicts its realized flops a priori within 5%.
    s_ad2, _ = replay_trace(eng_ad, trace)
    check(s_ad2.stats == s_ad2.predicted,
          "adaptive 2nd pass: executed counters diverge from prediction")
    rel_err = (abs(s_ad2.expected.flops_executed
                   - s_ad2.stats.flops_executed)
               / s_ad2.stats.flops_executed)
    check(rel_err <= CALIBRATION_GATE,
          f"calibrated expected flops off by {rel_err:.4f} "
          f"(> {CALIBRATION_GATE})")

    emit("serve_adaptive_floor", floor_s * 1e6,
         f"modelled_per_request;flops={s_floor.stats.flops_executed:.0f}")
    emit("serve_adaptive_gated", adapt_s * 1e6,
         f"modelled_per_request;speedup={speedup:.2f}x;"
         f"gated_rows={s_ad.stats.block_rows_gated:.0f};"
         f"agreement={agreement:.4f};calib_err={rel_err:.4f}")

    if args.json:
        update_bench_json(args.json, "adaptive_sweep", {
            "dim": dim, "requests": n_req, "dry_run": bool(args.dry_run),
            "batch_shapes": list(shapes),
            "subsets": [list(s) for s in SUBSETS], "hw": hw.name,
            "easy_fraction": EASY_FRACTION, "threshold": THRESHOLD,
            "agreement_gate": AGREEMENT_GATE, "speedup_gate": SPEEDUP_GATE,
            "calibration_gate": CALIBRATION_GATE,
            "floor": {
                "per_request_seconds": floor_s,
                "flops_executed": s_floor.stats.flops_executed,
                "weight_bytes_loaded": s_floor.stats.weight_bytes_loaded,
            },
            "adaptive": {
                "per_request_seconds": adapt_s,
                "flops_executed": s_ad.stats.flops_executed,
                "flops_gated": s_ad.stats.flops_gated,
                "block_rows_fired": s_ad.stats.block_rows_fired,
                "block_rows_gated": s_ad.stats.block_rows_gated,
                "weight_bytes_loaded": s_ad.stats.weight_bytes_loaded,
            },
            "speedup_adaptive_vs_floor": speedup,
            "argmax_agreement": agreement,
            "calibrated_expected_flops_rel_err": rel_err,
        })
    if failures:
        return 1
    print(f"# adaptive {speedup:.2f}x faster modelled per request "
          f"({SPEEDUP_GATE}x gate); argmax agreement {agreement:.4f} "
          f"({AGREEMENT_GATE} gate)")
    print(f"# calibrated expected flops within {rel_err:.4f} of realized "
          f"({CALIBRATION_GATE} gate); counters exact in both arms")
    return 0


if __name__ == "__main__":
    sys.exit(main())
