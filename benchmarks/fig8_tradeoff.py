"""Figure 8 — variety score vs execution cost across model-size budgets.

For each synthetic "dataset" (different affinity structures standing in for
the paper's nine datasets), we compare the min-budget, max-budget and
tradeoff-budget task graphs: low budget favours execution cost, high budget
favours variety, and the tradeoff budget balances both — the paper's trend.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, random_affinity, time_call
from repro.core import BlockCost, MSP430
from repro.core.tradeoff import select_task_graph

DATASETS = {
    # name -> (n_tasks, affinity seed, weight scale)
    "mnist": (5, 1, 4e5),
    "fmnist": (5, 2, 4e5),
    "cifar10": (6, 3, 9e5),
    "svhn": (5, 4, 6e5),
    "gtsrb": (5, 5, 3e5),
    "gsc": (6, 6, 5e5),
    "esc": (5, 7, 5e5),
    "us8k": (5, 8, 7e5),
    "hhar": (6, 9, 2e5),
}


def run(num_branch_points: int = 3) -> None:
    for name, (n, seed, wscale) in DATASETS.items():
        aff = random_affinity(n, num_branch_points, seed=seed)
        costs = [
            BlockCost(weight_bytes=wscale / 4, flops=10 * wscale / 4)
            for _ in range(num_branch_points + 1)
        ]

        def select():
            return select_task_graph(n, num_branch_points, aff, costs, MSP430)

        us = time_call(select, iters=1, warmup=0)
        res = select()
        cands = res.candidates
        vmin = min(c.variety for c in cands)
        cmin = min(c.exec_cost for c in cands)
        # min budget pick = lowest-size feasible graph; max budget = best variety
        smallest = min(cands, key=lambda c: c.storage_bytes)
        best_variety = min(cands, key=lambda c: (c.variety, c.exec_cost))
        sel = res.selected
        emit(
            f"fig8/{name}", us,
            (
                f"min_budget_variety={smallest.variety:.3f};"
                f"min_budget_cost={smallest.exec_cost:.4f};"
                f"max_budget_variety={best_variety.variety:.3f};"
                f"max_budget_cost={best_variety.exec_cost:.4f};"
                f"tradeoff_variety={sel.variety:.3f};tradeoff_cost={sel.exec_cost:.4f}"
            ),
        )


if __name__ == "__main__":
    run()
