"""Batched multitask serving sweep: run_batch vs the sequential request loop.

For batch sizes {1, 4, 16, 64} this benchmark serves B identical-subset
requests two ways:

* **sequential** — the pre-batching path: ``TaskGraphExecutor.run`` once per
  request (executor reset between requests, as the engine does per serve);
* **batched** — one ``TaskGraphExecutor.run_batch`` over the stacked group:
  each depth-block is vmapped over the batch and every weight load is paid
  once per group.

Reported per batch size: per-request wall-clock latency for both paths, the
speedup, and the block loads saved by amortisation
(``(B - 1) x`` the single-request load bytes).  Every configuration also
verifies the two acceptance invariants: batched outputs ``allclose`` (rtol
1e-5) to the per-request path, and batched ``ExecutionStats`` exactly equal
to ``GraphCostModel.predicted_stats(order, batch_size=B)``.

``--dry-run`` shrinks sizes/iterations and skips the wall-clock speedup
assertion (CI boxes have noisy clocks); the equivalence checks always run.
Machine-readable results (per-request seconds, weight bytes loaded/skipped,
dispatch counts) land in the ``batch_sweep`` section of ``BENCH_serving.json``
(``--json`` to relocate/disable).

Usage: ``PYTHONPATH=src python benchmarks/serving_batch.py [--dry-run]``
"""
from __future__ import annotations

import argparse
import pathlib
import sys

import jax
import jax.numpy as jnp
import numpy as np

if __package__ in (None, ""):  # `python benchmarks/serving_batch.py`
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
from benchmarks.common import emit, time_call, update_bench_json
from repro.core import (
    BlockCost, GraphCostModel, MSP430, MultitaskProgram, TaskGraphExecutor,
    optimal_order,
)
from repro.core.task_graph import TaskGraph

GRAPH = TaskGraph.from_groups([
    [[0, 1, 2, 3, 4, 5]],
    [[0, 1, 2], [3, 4, 5]],
    [[0, 1], [2], [3], [4, 5]],
    [[0], [1], [2], [3], [4], [5]],
])


def build_program(dim: int, seed: int = 0) -> MultitaskProgram:
    """Dense tanh blocks (one matmul per block) + linear heads."""
    rng = np.random.default_rng(seed)
    costs = [
        BlockCost(weight_bytes=4.0 * dim * dim, flops=2.0 * dim * dim)
        for _ in range(GRAPH.depth)
    ]

    def block(p, x):
        return jnp.tanh(x @ p)

    node_params = {
        node: jnp.asarray(rng.normal(size=(dim, dim)) / np.sqrt(dim), jnp.float32)
        for node in GRAPH.nodes()
    }
    head_params = [
        jnp.asarray(rng.normal(size=(dim, 8)), jnp.float32)
        for _ in range(GRAPH.num_tasks)
    ]
    return MultitaskProgram(
        graph=GRAPH,
        block_fns=[block] * GRAPH.depth,
        node_params=node_params,
        head_fns=[lambda p, x: x @ p] * GRAPH.num_tasks,
        head_params=head_params,
        block_costs=costs,
    )


def run_sequential(ex: TaskGraphExecutor, xs: jnp.ndarray, order):
    outs = []
    for i in range(xs.shape[0]):
        ex.reset()
        o, s = ex.run(xs[i], order)
        outs.append((o, s))
    jax.block_until_ready([o for o, _ in outs])
    return outs


def run_batched(ex: TaskGraphExecutor, xs: jnp.ndarray, order):
    ex.reset()
    out, stats = ex.run_batch(xs, order)
    jax.block_until_ready(out)
    return out, stats


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny sizes, 1 iteration, no wall-clock assertion")
    ap.add_argument("--dim", type=int, default=None,
                    help="block width (default 256, dry-run 16)")
    ap.add_argument("--json", default="BENCH_serving.json",
                    help="machine-readable results file ('' disables)")
    args = ap.parse_args(argv)

    dim = args.dim or (16 if args.dry_run else 256)
    batches = (1, 4) if args.dry_run else (1, 4, 16, 64)
    iters = 1 if args.dry_run else 5

    prog = build_program(dim)
    cm = GraphCostModel(GRAPH, prog.block_costs, MSP430)
    order = list(optimal_order(cm.cost_matrix()).order)
    ex = TaskGraphExecutor(prog)
    rng = np.random.default_rng(1)

    print("name,us_per_call,derived")
    speedups = {}
    rows = []
    for b in batches:
        xs = jnp.asarray(rng.normal(size=(b, dim)), jnp.float32)

        # Correctness first: batched == per-request, stats == prediction.
        d0 = ex.dispatch_count
        out_b, stats_b = run_batched(ex, xs, order)
        batch_dispatches = ex.dispatch_count - d0
        seq = run_sequential(ex, xs, order)
        for t in order:
            ref = np.stack([np.asarray(seq[i][0][t]) for i in range(b)])
            np.testing.assert_allclose(
                np.asarray(out_b[t]), ref, rtol=1e-5, atol=1e-6)
        pred = cm.predicted_stats(order, batch_size=b)
        assert stats_b == pred, (
            f"batch={b}: executor stats diverge from cost model\n"
            f"  got  {stats_b}\n  want {pred}")
        # Fused-suffix execution: one dispatch per task for the whole group.
        assert batch_dispatches == len(order), (
            f"batch={b}: {batch_dispatches} dispatches for {len(order)} tasks")

        t_seq = time_call(run_sequential, ex, xs, order, warmup=1, iters=iters)
        t_bat = time_call(run_batched, ex, xs, order, warmup=1, iters=iters)
        per_req_seq = t_seq / b
        per_req_bat = t_bat / b
        speedup = per_req_seq / per_req_bat
        speedups[b] = speedup
        seq_stats = cm.predicted_stats(order)
        loads_saved = (b - 1) * seq_stats.weight_bytes_loaded
        emit(f"serve_seq_b{b}", per_req_seq, f"per_request;batch={b}")
        emit(f"serve_batch_b{b}", per_req_bat,
             f"per_request;batch={b};speedup={speedup:.2f}x;"
             f"weight_bytes_load_saved={loads_saved:.0f}")
        rows.append({
            "batch": b,
            "per_request_seconds_sequential": per_req_seq * 1e-6,
            "per_request_seconds_batched": per_req_bat * 1e-6,
            "speedup": speedup,
            "weight_bytes_loaded": stats_b.weight_bytes_loaded,
            "weight_bytes_skipped": stats_b.weight_bytes_skipped,
            "weight_bytes_load_saved_vs_sequential": loads_saved,
            "dispatches_batched": batch_dispatches,
            "dispatches_per_task": batch_dispatches / len(order),
        })
    if args.json:
        update_bench_json(args.json, "batch_sweep", {
            "dim": dim, "dry_run": bool(args.dry_run), "rows": rows,
        })

    if not args.dry_run and 16 in speedups:
        if speedups[16] < 4.0:
            print(f"FAIL: batch=16 per-request speedup {speedups[16]:.2f}x < 4x",
                  file=sys.stderr)
            return 1
        print(f"# batch=16 per-request speedup: {speedups[16]:.2f}x (>= 4x)")
    print("# equivalence + stats checks passed for batches", list(batches))
    return 0


if __name__ == "__main__":
    sys.exit(main())
