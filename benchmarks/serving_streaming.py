"""Weight-streaming sweep: double-buffered prefetch vs synchronous loads.

A load-bound multi-group trace (many distinct task subsets on MSP430-class
hardware, where streaming a block's weights costs ~2x executing it) is
served twice through warm engines that differ in exactly one policy bit:

* **synchronous** — the PR-7 path: every non-resident block is loaded
  synchronously when its group reaches it;
* **streamed** — ``EnginePolicy.streaming``: while group *k*'s fused suffix
  executes (JAX dispatch is asynchronous), the session stages group
  *k+1*'s non-resident block params through the executor's
  :class:`~repro.core.executor.WeightStreamer`.  The prefetch schedule is
  the cost model's ``plan_loads`` over the executor's actual residency, so
  the streamed bytes equal the group's loads by construction; load time
  exceeding the previous group's modelled compute window shows up as
  ``ExecutionStats.stream_stall_seconds``.

A sequential single-request serve provides the output ground truth.

Gates (dry-run included; any failure exits 1):

* **output equivalence** — streamed responses allclose to the synchronous
  session's and to sequential solo serving;
* **counter exactness** — ``session.stats == session.predicted`` field for
  field in both runs, *including* the new ``prefetched_bytes`` /
  ``stream_stall_seconds`` counters;
* **coverage** — the streamed run prefetched a nonzero byte volume (every
  group after the first, on this trace);
* **overlap** — streamed stall seconds <= ``0.5x`` the synchronous run's
  weight-load seconds: the stream hides loads, it does not rename them;
* **speedup** — >= ``1.2x`` modelled wall-clock improvement on the
  load-bound trace.

Machine-readable results land in the ``streaming_sweep`` section of
``BENCH_serving.json``.

Usage: ``PYTHONPATH=src python benchmarks/serving_streaming.py [--dry-run]``
"""
from __future__ import annotations

import argparse
import pathlib
import sys

import jax
import jax.numpy as jnp
import numpy as np

if __package__ in (None, ""):  # `python benchmarks/serving_streaming.py`
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
from benchmarks.common import emit, update_bench_json
from benchmarks.serving_batch import build_program
from benchmarks.serving_groups import SUBSETS, build_requests
from repro.core import MSP430
from repro.serving import (
    EnginePolicy, MultitaskEngine, MultitaskRequest, RequestGroupScheduler,
)

STALL_GATE = 0.5     # streamed stall <= this x synchronous load seconds
SPEEDUP_GATE = 1.2   # modelled wall-clock: sync / streamed >= this


def run_session(prog, reqs, shapes, streaming: bool):
    """One-shot warm session over ``reqs``; returns (session, responses)."""
    eng = MultitaskEngine(
        prog, hw=MSP430,
        policy=EnginePolicy(streaming=streaming),
        scheduler=RequestGroupScheduler(batch_shapes=shapes),
    )
    session = eng.session()
    futures = [session.submit(r) for r in reqs]
    session.drain()
    responses = [f.result() for f in futures]
    jax.block_until_ready([list(r.outputs.values()) for r in responses])
    return session, responses


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny sizes (gates are identical either way)")
    ap.add_argument("--dim", type=int, default=None,
                    help="block width (default 256, dry-run 16)")
    ap.add_argument("--requests", type=int, default=None,
                    help="trace length (default 64, dry-run 24)")
    ap.add_argument("--json", default="BENCH_serving.json",
                    help="machine-readable results file ('' disables)")
    args = ap.parse_args(argv)

    dim = args.dim or (16 if args.dry_run else 256)
    n_req = args.requests or (24 if args.dry_run else 64)
    shapes = (1, 2, 4)
    hw = MSP430

    prog = build_program(dim)
    reqs = build_requests(n_req, dim)

    # Sequential single-request serving: the output ground truth.
    solo = MultitaskEngine(
        prog, hw=hw, warm_start=False, group_ordering=False,
        scheduler=RequestGroupScheduler(batch_shapes=(1,)),
    )
    solo_resp = [solo.serve(MultitaskRequest(x=r.x, tasks=r.tasks))
                 for r in reqs]

    failures: list = []

    def check(ok, msg):
        if not ok:
            failures.append(msg)
            print(f"FAIL: {msg}", file=sys.stderr)

    runs = {}
    sessions = {}
    for name, streaming in (("synchronous", False), ("streamed", True)):
        session, responses = run_session(prog, reqs, shapes, streaming)
        sessions[name] = session
        # Gate: counters stay exact including the streaming fields.
        check(session.stats == session.predicted,
              f"{name}: executed counters diverge from prediction\n"
              f"  got  {session.stats}\n  want {session.predicted}")
        # Gate: every response matches sequential solo serving.
        for i, (resp, ref) in enumerate(zip(responses, solo_resp)):
            check(set(resp.outputs) == set(ref.outputs),
                  f"{name}: request {i} task set mismatch")
            for t in ref.outputs:
                if not np.allclose(np.asarray(resp.outputs[t]),
                                   np.asarray(ref.outputs[t]),
                                   rtol=1e-5, atol=1e-6):
                    check(False, f"{name}: request {i} task {t} outputs "
                                 f"diverge from solo serving")
        stats = session.stats
        runs[name] = {
            "weight_bytes_loaded": stats.weight_bytes_loaded,
            "weight_bytes_skipped": stats.weight_bytes_skipped,
            "prefetched_bytes": stats.prefetched_bytes,
            "stream_stall_seconds": stats.stream_stall_seconds,
            "compute_seconds": stats.compute_seconds(hw),
            "modelled_seconds": stats.seconds(hw),
            "groups_executed": session.groups_executed,
            "prefetches_issued": session.prefetches_issued,
            "prefetch_scheduled_bytes": session.prefetch_scheduled_bytes,
            "prefetch_failures": session.prefetch_failures,
            "streamer_cancels": session.engine.executor.streamer.cancels,
        }

    sync, strm = sessions["synchronous"], sessions["streamed"]
    # Sanity: streaming changes when bytes move, never how many.
    check(strm.stats.weight_bytes_loaded == sync.stats.weight_bytes_loaded,
          "streamed run loaded a different byte volume than synchronous")
    check(sync.stats.prefetched_bytes == 0.0
          and sync.stats.stream_stall_seconds == 0.0,
          "synchronous run carries streaming counters")
    # Gate: the stream actually ran.
    check(strm.stats.prefetched_bytes > 0.0,
          "streamed run prefetched zero bytes — the sweep is vacuous")

    # Gate: overlap — stall must be far below what the loads cost to do
    # synchronously (the whole point of hiding them behind compute).
    sync_load_seconds = hw.load_seconds(sync.stats.weight_bytes_loaded)
    stall = strm.stats.stream_stall_seconds
    check(stall <= STALL_GATE * sync_load_seconds,
          f"stream stall {stall:.6f}s > {STALL_GATE}x synchronous load "
          f"seconds ({sync_load_seconds:.6f}s)")

    # Gate: modelled wall-clock speedup on the load-bound trace.
    sync_seconds = sync.stats.seconds(hw)
    strm_seconds = strm.stats.seconds(hw)
    speedup = sync_seconds / strm_seconds
    runs["speedup_streamed_vs_synchronous"] = speedup
    runs["stall_vs_sync_load"] = stall / sync_load_seconds
    check(speedup >= SPEEDUP_GATE,
          f"streamed speedup {speedup:.2f}x < {SPEEDUP_GATE}x "
          f"({sync_seconds:.6f}s vs {strm_seconds:.6f}s)")

    emit("serve_streaming_sync", sync_seconds * 1e6,
         f"modelled_seconds;loads={sync.stats.weight_bytes_loaded:.0f}B")
    emit("serve_streaming_streamed", strm_seconds * 1e6,
         f"modelled_seconds;prefetched={strm.stats.prefetched_bytes:.0f}B;"
         f"stall={stall * 1e6:.1f}us;speedup={speedup:.2f}x")

    if args.json:
        update_bench_json(args.json, "streaming_sweep", {
            "dim": dim, "requests": n_req, "dry_run": bool(args.dry_run),
            "batch_shapes": list(shapes), "subsets": [list(s) for s in SUBSETS],
            "hw": hw.name,
            "stall_gate": STALL_GATE, "speedup_gate": SPEEDUP_GATE,
            "runs": runs,
        })
    if failures:
        return 1
    print(f"# streamed {speedup:.2f}x faster modelled ({SPEEDUP_GATE}x gate); "
          f"stall {stall * 1e6:.1f}us = "
          f"{stall / sync_load_seconds:.3f}x sync load seconds "
          f"({STALL_GATE}x gate)")
    print("# outputs + exact counters (incl. prefetched_bytes / "
          "stream_stall_seconds) verified in both runs")
    return 0


if __name__ == "__main__":
    sys.exit(main())
