"""Admission-policy sweep: window vs. greedy vs. affinity on a Poisson trace.

A Poisson arrival trace of multitask requests (task subsets cycling through
the two subtrees of the benchmark graph — the adversarial arrival order for
warm hand-over) is served through a :class:`ServingSession` under three
scheduling policies, all on warm engines:

* **greedy** — ``GreedyBatchPolicy`` driven one-shot (submit the whole
  trace, then drain): the pre-session ``serve_batch`` pipeline — one big
  planning batch with cost-aware group ordering, at the price of every
  request waiting for the end of the trace before anything is admitted;
* **window** — ``WindowPolicy``: admit by max-wait / max-group-size in
  **arrival order**, group ordering off — the classic batching-window
  baseline whose grouping follows the (adversarial) arrival sequence;
* **affinity** — ``AffinityPolicy`` + per-plan order re-solving
  (``EnginePolicy.resolve_order_per_plan``): among pending buckets, admit
  the one whose subset costs least to resume from the executor's *current*
  residency, and re-solve each group's internal task order seeded with that
  residency.

Checks run on every configuration (dry-run included):

* every policy's outputs match sequential single-request serving (allclose);
* every session's cumulative executed counters equal its incremental
  cost-model prediction **exactly** (no gates on these engines);
* the gate: affinity admission loads **>= 1.2x** fewer weight bytes than
  the arrival-order window baseline.

The trace is simulated time (a deterministic injected clock), so admission
waits and the load counters are exact and reproducible — wall-clock noise
cannot flake the gate.  Machine-readable results land in the
``admission_sweep`` section of ``BENCH_serving.json``.

Usage: ``PYTHONPATH=src python benchmarks/serving_admission.py [--dry-run]``
"""
from __future__ import annotations

import argparse
import pathlib
import sys

import jax.numpy as jnp
import numpy as np

if __package__ in (None, ""):  # `python benchmarks/serving_admission.py`
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
from benchmarks.common import emit, update_bench_json
from benchmarks.serving_batch import build_program
from benchmarks.serving_groups import SUBSETS
from repro.core import MSP430
from repro.serving import (
    AffinityPolicy, EnginePolicy, GreedyBatchPolicy, MultitaskEngine,
    MultitaskRequest, RequestGroupScheduler, WindowPolicy,
)

LOAD_GATE = 1.2  # affinity must load >= this factor fewer bytes than window


class SimClock:
    """Deterministic simulated clock driven by the arrival trace."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


def poisson_trace(n_requests: int, dim: int, rate: float, seed: int = 3):
    """(arrival_time, request) pairs: Poisson arrivals, cycling subsets."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n_requests))
    reqs = [
        MultitaskRequest(
            x=jnp.asarray(rng.normal(size=(dim,)), jnp.float32),
            tasks=SUBSETS[i % len(SUBSETS)],
        )
        for i in range(n_requests)
    ]
    return list(zip(arrivals.tolist(), reqs))


def run_policy(name, prog, trace, engine_policy, shapes, one_shot=False,
               settle=0.0):
    """Serve the trace through a session; returns (session, responses).

    Arrival-driven by default: the session pumps (``step()``) at every
    arrival, so windowed/affinity policies fire on their own thresholds.
    ``one_shot=True`` reproduces the pre-session pipeline instead: the
    whole trace is submitted, then a single end-of-trace drain plans
    everything as one batch (admission waits span to the trace end).

    ``settle`` is how far past the last arrival the clock advances before
    the final drain — the admission window for windowed policies, so tail
    requests are stamped with the wait they would really have aged out at,
    not an arbitrary end-of-benchmark jump; 0 for one-shot (the pipeline
    fires the moment the trace completes).
    """
    eng = MultitaskEngine(
        prog, hw=MSP430, policy=engine_policy,
        scheduler=RequestGroupScheduler(batch_shapes=shapes),
    )
    clock = SimClock()
    session = eng.session(clock=clock)
    futures = []
    for t, req in trace:
        clock.t = t
        futures.append(session.submit(req))
        if not one_shot:
            session.step()
    # Trace exhausted: advance to when the tail would age out, then drain.
    clock.t = trace[-1][0] + settle
    session.drain()
    assert all(f.done() for f in futures)
    return session, [f.result() for f in futures]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny sizes (the sweep is deterministic either way)")
    ap.add_argument("--dim", type=int, default=None,
                    help="block width (default 256, dry-run 16)")
    ap.add_argument("--requests", type=int, default=None,
                    help="trace length (default 64, dry-run 24)")
    ap.add_argument("--rate", type=float, default=20.0,
                    help="Poisson arrival rate (requests per simulated second)")
    ap.add_argument("--json", default="BENCH_serving.json",
                    help="machine-readable results file ('' disables)")
    args = ap.parse_args(argv)

    dim = args.dim or (16 if args.dry_run else 256)
    n_req = args.requests or (24 if args.dry_run else 64)
    shapes = (1, 2, 4)
    window = 0.25       # max admission wait, simulated seconds
    group_cap = 4

    prog = build_program(dim)
    trace = poisson_trace(n_req, dim, args.rate)

    policies = {
        # The pre-session one-shot pipeline: the whole trace submitted,
        # then one greedy planning batch with group ordering (run_policy
        # drives this entry one_shot so greedy actually sees the full
        # request list, not per-arrival singletons).
        "greedy": EnginePolicy(scheduling=GreedyBatchPolicy()),
        # Arrival-order grouping: the admission baseline the gate measures
        # against (no cost-aware sequencing anywhere).
        "window": EnginePolicy(
            scheduling=WindowPolicy(max_wait=window, max_group_size=group_cap),
            group_ordering=False,
        ),
        # Residency-aware admission + per-plan order re-solving.
        "affinity": EnginePolicy(
            scheduling=AffinityPolicy(
                max_group_size=group_cap, min_pending=2 * group_cap,
                max_wait=window,
            ),
            group_ordering=False,
            resolve_order_per_plan=True,
        ),
    }

    # Sequential single-request reference for output equivalence.
    solo = MultitaskEngine(
        prog, hw=MSP430, warm_start=False, group_ordering=False,
        scheduler=RequestGroupScheduler(batch_shapes=(1,)),
    )
    solo_resp = [solo.serve(r) for _t, r in trace]

    print("name,us_per_call,derived")
    rows = {}
    for name, engine_policy in policies.items():
        session, resp = run_policy(
            name, prog, trace, engine_policy, shapes,
            one_shot=(name == "greedy"),
            settle=(0.0 if name == "greedy" else window),
        )
        # Counters must match the incremental prediction exactly (no gates).
        assert session.stats == session.predicted, (
            f"{name}: executed counters diverge from the incremental "
            f"prediction\n  got  {session.stats}\n  want {session.predicted}")
        for r, s in zip(resp, solo_resp):
            assert set(r.outputs) == set(s.outputs)
            for t in r.outputs:
                np.testing.assert_allclose(
                    np.asarray(r.outputs[t]), np.asarray(s.outputs[t]),
                    rtol=1e-5, atol=1e-6)
        stats = session.stats
        mean_wait = session.mean_admission_wait
        max_wait = session.max_admission_wait
        per_req_modelled = stats.seconds(MSP430) / n_req
        emit(f"serve_admission_{name}", per_req_modelled * 1e6,
             f"modelled_per_request;groups={session.groups_executed};"
             f"weight_bytes_loaded={stats.weight_bytes_loaded:.0f};"
             f"mean_wait={mean_wait * 1e3:.1f}ms")
        rows[name] = {
            "weight_bytes_loaded": stats.weight_bytes_loaded,
            "weight_bytes_skipped": stats.weight_bytes_skipped,
            "groups": session.groups_executed,
            "admission_rounds": session.admission_rounds,
            "mean_admission_wait_seconds": mean_wait,
            "max_admission_wait_seconds": max_wait,
            "modelled_per_request_seconds": per_req_modelled,
            "plan_seconds": session.plan_seconds,
        }

    reduction = (
        rows["window"]["weight_bytes_loaded"]
        / max(rows["affinity"]["weight_bytes_loaded"], 1e-9)
    )
    rows["affinity_load_reduction_vs_window"] = reduction
    if args.json:
        update_bench_json(args.json, "admission_sweep", {
            "dim": dim, "requests": n_req, "rate": args.rate,
            "dry_run": bool(args.dry_run), "batch_shapes": list(shapes),
            "window_seconds": window, "max_group_size": group_cap,
            "load_gate": LOAD_GATE, "rows": rows,
        })
    if reduction < LOAD_GATE:
        print(f"FAIL: affinity load reduction {reduction:.2f}x < "
              f"{LOAD_GATE}x vs arrival-order window grouping",
              file=sys.stderr)
        return 1
    print(f"# affinity weight-load reduction vs arrival-order window: "
          f"{reduction:.2f}x (>= {LOAD_GATE}x); "
          f"mean wait window {rows['window']['mean_admission_wait_seconds'] * 1e3:.0f}ms "
          f"vs affinity {rows['affinity']['mean_admission_wait_seconds'] * 1e3:.0f}ms")
    print("# equivalence + exact-counter checks passed for all policies")
    return 0


if __name__ == "__main__":
    sys.exit(main())
