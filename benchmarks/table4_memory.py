"""Tables 4 & 5 — memory consumption of all systems.

Reports total storage (KB) of Vanilla / Antler / NWS / NWV / YONO over the
paper-scale CNN task sets.  Expected ordering (paper Table 4):
Vanilla > Antler > NWS > NWV > YONO, with Antler ~half of Vanilla in the
real-deployment rows (Table 5).
"""
from __future__ import annotations

from benchmarks.common import emit, random_affinity, time_call
from repro.core import (
    MSP430, antler_report, nws_baseline, nwv_baseline, vanilla_baseline,
    yono_baseline,
)
from repro.core.tradeoff import select_task_graph
from repro.models.cnn import build_lenet5_blocks

ROWS = {
    "dataset_driven_10task": (10, 3),
    "audio_deployment_5task": (5, 11),
    "image_deployment_4task": (4, 12),
}


def run() -> None:
    _i, _a, costs, _f = build_lenet5_blocks()
    for name, (n, seed) in ROWS.items():
        aff = random_affinity(n, 3, seed=seed)

        def pick():
            return select_task_graph(
                n, 3, aff, costs, MSP430, beam=600 if n > 6 else None
            ).selected

        us = time_call(pick, iters=1, warmup=0)
        sel = pick()
        ant = antler_report(sel.graph, costs, MSP430, list(sel.order))
        kb = lambda b: b / 1024.0
        emit(
            f"table4_5/{name}", us,
            (
                f"vanilla_kb={kb(vanilla_baseline(n, costs, MSP430).memory_bytes):.0f};"
                f"antler_kb={kb(ant.memory_bytes):.0f};"
                f"nws_kb={kb(nws_baseline(n, costs, MSP430).memory_bytes):.0f};"
                f"nwv_kb={kb(nwv_baseline(n, costs, MSP430).memory_bytes):.0f};"
                f"yono_kb={kb(yono_baseline(n, costs, MSP430).memory_bytes):.0f};"
                f"antler_vs_vanilla={ant.memory_bytes / vanilla_baseline(n, costs, MSP430).memory_bytes:.2f}"
            ),
        )


if __name__ == "__main__":
    run()
