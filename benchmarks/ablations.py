"""Beyond-paper ablations.

* GA crossover: the paper's prefix-swap (discard-invalid) crossover vs the
  repairing order-crossover (OX) — quantifies how much the faithful operator
  leans on mutation.
* Ordering value: optimal order vs mean/worst random order on a real task
  graph's cost matrix — the Figure-4 "ordering matters" claim quantified.
* Solver scaling: evaluations used by brute force / Held-Karp / B&B / GA on
  the same instance.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, random_affinity, time_call
from repro.core import (
    GAConfig, GraphCostModel, MSP430, brute_force_order, branch_and_bound_order,
    fitness, genetic_order, held_karp_order, uniform_block_costs,
)
from repro.core.tradeoff import select_task_graph
from repro.models.cnn import build_lenet5_blocks


def run() -> None:
    # --- GA crossover ablation on a 12-task instance ---
    rng = np.random.default_rng(0)
    n = 12
    c = rng.uniform(1, 100, (n, n))
    c = (c + c.T) / 2
    np.fill_diagonal(c, 0)
    opt = held_karp_order(c)
    for mode in ("paper", "ox"):
        cfg = GAConfig(crossover=mode, nn_seed=False, local_search=False,
                       reversal_mutation=False, seed=0)
        us = time_call(lambda: genetic_order(c, config=cfg), iters=1, warmup=0)
        r = genetic_order(c, config=cfg)
        gap = (r.cost - opt.cost) / opt.cost * 100
        emit(f"ablation/ga_crossover/{mode}", us,
             f"cost={r.cost:.1f};optimal={opt.cost:.1f};gap_pct={gap:.1f}")
    cfg = GAConfig(crossover="ox", seed=0)  # full memetic stack
    r = genetic_order(c, config=cfg)
    emit("ablation/ga_crossover/ox_memetic", 0.0,
         f"cost={r.cost:.1f};optimal={opt.cost:.1f};"
         f"gap_pct={(r.cost-opt.cost)/opt.cost*100:.1f}")

    # --- ordering value on a selected task graph ---
    _i, _a, costs, _f = build_lenet5_blocks()
    aff = random_affinity(8, 3, seed=2)
    sel = select_task_graph(8, 3, aff, costs, MSP430, beam=400).selected
    cm = GraphCostModel(sel.graph, costs, MSP430)
    cmat = cm.cost_matrix()
    best = held_karp_order(cmat)
    rand = [fitness(rng.permutation(8).tolist(), cmat) for _ in range(200)]
    emit("ablation/ordering_value", 0.0,
         f"optimal={best.cost:.4g};random_mean={np.mean(rand):.4g};"
         f"random_worst={np.max(rand):.4g};"
         f"gain_vs_mean={np.mean(rand)/best.cost:.2f}x;"
         f"gain_vs_worst={np.max(rand)/best.cost:.2f}x")

    # --- solver work on one instance (n=10) ---
    n = 10
    c = rng.uniform(1, 50, (n, n)); c = (c + c.T) / 2; np.fill_diagonal(c, 0)
    bf = brute_force_order(c)
    hk = held_karp_order(c)
    bb = branch_and_bound_order(c)
    ga = genetic_order(c, config=GAConfig(seed=0))
    assert abs(bf.cost - hk.cost) < 1e-9 and abs(bf.cost - bb.cost) < 1e-9
    emit("ablation/solver_work_n10", 0.0,
         f"brute_evals={bf.evaluated};heldkarp_evals={hk.evaluated};"
         f"bnb_evals={bb.evaluated};ga_evals={ga.evaluated};"
         f"all_optimal={abs(ga.cost-bf.cost)<1e-9}")


if __name__ == "__main__":
    run()
