"""Synthetic data pipelines (LM token streams + multitask classification)."""
from repro.data.synthetic import MultitaskDataset, lm_batches, train_test_split
