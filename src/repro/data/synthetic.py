"""Synthetic datasets (no external data is available in this container).

Two generators:

* :func:`lm_batches` — Zipf-distributed token streams with a planted Markov
  structure, so LM training loss decreases measurably within a few hundred
  steps (used by the end-to-end training example).
* :class:`MultitaskDataset` — the paper-style setting: one shared domain
  ``X`` and ``n`` classification tasks over it.  Samples are mixtures of
  per-factor prototypes; each task labels a different latent factor, and
  tasks sharing factors exhibit the affinity structure Antler exploits
  (tasks 2i and 2i+1 share factor groups -> high pairwise affinity).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np


# --------------------------------------------------------------------------
# Language-model streams
# --------------------------------------------------------------------------

def lm_batches(
    vocab_size: int,
    batch: int,
    seq_len: int,
    seed: int = 0,
    order: int = 2,
) -> Iterator[np.ndarray]:
    """Infinite iterator of (batch, seq_len) int32 token arrays.

    Tokens follow a sparse random ``order``-gram process over a Zipf
    unigram prior: predictable enough that a model visibly learns.
    """
    rng = np.random.default_rng(seed)
    # Zipf unigram prior over the first min(vocab, 4096) types.
    v_eff = min(vocab_size, 4096)
    ranks = np.arange(1, v_eff + 1)
    prior = 1.0 / ranks
    prior /= prior.sum()
    # Each context hashes to a small candidate set -> planted structure.
    table = rng.integers(0, v_eff, size=(8192, 4))

    while True:
        out = np.empty((batch, seq_len), dtype=np.int32)
        state = rng.choice(v_eff, size=(batch, order), p=prior)
        for t in range(seq_len):
            ctx = (state[:, 0] * 31 + state[:, 1] * 7) % 8192
            cands = table[ctx]                       # (batch, 4)
            pick = rng.integers(0, 4, size=batch)
            nxt = cands[np.arange(batch), pick]
            # 10% noise from the prior keeps entropy non-trivial.
            noise = rng.random(batch) < 0.1
            nxt = np.where(noise, rng.choice(v_eff, size=batch, p=prior), nxt)
            out[:, t] = nxt
            state = np.concatenate([state[:, 1:], nxt[:, None]], axis=1)
        yield out


# --------------------------------------------------------------------------
# Multitask classification over a shared domain (paper setting)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class MultitaskDataset:
    """n classification tasks over one image-like domain X.

    Latent factors: ``num_factors`` independent categorical factors, each
    rendered as an additive spatial prototype.  Task t labels factor
    ``factor_of_task[t]``; tasks sharing a factor (or correlated factors)
    have high affinity — giving the task-graph machinery real structure.
    """

    num_tasks: int = 5
    num_classes: int = 10
    hw: Tuple[int, int, int] = (28, 28, 1)
    num_factors: int = 3
    noise: float = 0.3
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        h, w, c = self.hw
        self.prototypes = rng.normal(
            size=(self.num_factors, self.num_classes, h, w, c)
        ).astype(np.float32)
        # Map tasks onto factors so consecutive task pairs share a factor.
        self.factor_of_task = [t % self.num_factors for t in range(self.num_tasks)]
        # Per-task random label permutation: tasks on the same factor are
        # related but not identical.
        self.label_perm = [
            rng.permutation(self.num_classes) for _ in range(self.num_tasks)
        ]
        self._rng = rng

    def sample(self, batch: int) -> Tuple[np.ndarray, np.ndarray]:
        """Returns (x (B,H,W,C) float32, labels (num_tasks, B) int32)."""
        rng = self._rng
        zs = rng.integers(0, self.num_classes, size=(self.num_factors, batch))
        h, w, c = self.hw
        x = np.zeros((batch, h, w, c), dtype=np.float32)
        for f in range(self.num_factors):
            x += self.prototypes[f, zs[f]]
        x += self.noise * rng.normal(size=x.shape).astype(np.float32)
        labels = np.stack(
            [self.label_perm[t][zs[self.factor_of_task[t]]] for t in range(self.num_tasks)]
        ).astype(np.int32)
        return x, labels

    def batches(self, batch: int) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        while True:
            yield self.sample(batch)


def train_test_split(
    ds: MultitaskDataset, n_train: int, n_test: int
) -> Tuple[Tuple[np.ndarray, np.ndarray], Tuple[np.ndarray, np.ndarray]]:
    """Paper §6.1: 80/20-style fixed train/test draws."""
    xtr, ytr = ds.sample(n_train)
    xte, yte = ds.sample(n_test)
    return (xtr, ytr), (xte, yte)
