"""Confidence-based block gating for fused-suffix execution.

The AdaMTL/MIME observation: multitask inference cost should be
input-conditional.  A :class:`BlockGater` attaches a pure confidence
function to the executor's fused suffix programs; each shape-preserving
block then runs only for the batch rows whose confidence is still *below*
the threshold (low confidence = keep refining, high confidence = the
representation is already decisive and the row can stop paying).

Two modes:

* ``"early_exit"`` — once a row's confidence clears the threshold it skips
  every remaining block of the suffix (the row has *exited*).
* ``"per_block"`` — each block re-evaluates the gate independently; a row
  can skip one block and fire a later one.

For shape-preserving passthrough gating with a pure confidence function the
two coincide on homogeneous (scan-mode) suffixes: a skipped row's activation
is unchanged, so its confidence is unchanged, so it keeps skipping.  That
equivalence is what lets checkpoint segments and crash recovery re-derive
identical gate decisions without threading an alive mask across program
boundaries.

Everything here is jit-compatible: thresholds enter the compiled program as
a runtime ``(L,)`` float32 array scanned alongside the stacked params, so
threshold-ladder changes never retrace.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Tuple

import jax.numpy as jnp

GATE_MODES = ("early_exit", "per_block")

# A threshold of +inf always fires: conf < inf for every finite confidence.
ALWAYS_FIRE = math.inf


def mean_abs_confidence(h) -> jnp.ndarray:
    """Default confidence: mean absolute activation of one row.

    Cheap (one reduction over the row's features), pure, and monotone under
    the benchmark's norm-separated easy/hard traffic.  Callers with a real
    head can pass e.g. max-softmax-probability instead.
    """
    return jnp.mean(jnp.abs(h))


@dataclasses.dataclass
class BlockGater:
    """Per-block confidence gate the executor threads into fused suffixes.

    Attributes:
      confidence_fn: pure ``row -> scalar`` confidence (vmapped over the
        batch by the executor).  Must be jit-traceable.
      mode: ``"early_exit"`` or ``"per_block"`` (see module docstring).
      threshold: fire a block for a row iff ``confidence < threshold``;
        ``math.inf`` (the default) fires everything — the all-blocks floor.
        Mutable on purpose: the serving session retunes it per group from
        the :class:`~repro.adaptive.policy.AdaptivePolicy` deadline ladder,
        and because it reaches the compiled program as a runtime array this
        never recompiles.
      min_blocks: blocks ``0 .. min_blocks-1`` of every path always fire
        (their per-depth threshold is ``inf``), bounding how early a row
        may exit regardless of threshold.
    """

    confidence_fn: Callable = mean_abs_confidence
    mode: str = "early_exit"
    threshold: float = ALWAYS_FIRE
    min_blocks: int = 1

    def __post_init__(self) -> None:
        if self.mode not in GATE_MODES:
            raise ValueError(f"unknown gate mode {self.mode!r}")
        if self.min_blocks < 0:
            raise ValueError("min_blocks must be >= 0")

    def suffix_thresholds(self, resume: int, depth: int) -> Tuple[float, ...]:
        """Per-depth thresholds for a suffix resuming at ``resume``.

        Depths below ``min_blocks`` get ``inf`` (always fire); the rest get
        the current ``threshold``.  Returned as a plain tuple — the executor
        converts it to the runtime float32 array the compiled program scans.
        """
        return tuple(
            ALWAYS_FIRE if d < self.min_blocks else float(self.threshold)
            for d in range(resume, depth)
        )
