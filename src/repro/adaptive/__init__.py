"""Input-adaptive execution: confidence gating, gate models, policy.

See README "Input-adaptive serving".  The executor consumes a
:class:`BlockGater`, the cost model a :class:`GateModel`, and the serving
stack an :class:`AdaptivePolicy` that binds the two plus the deadline
threshold ladder.
"""
from repro.adaptive.gate_model import GateModel, GateModelCalibrator
from repro.adaptive.gating import (
    ALWAYS_FIRE, GATE_MODES, BlockGater, mean_abs_confidence,
)
from repro.adaptive.policy import AdaptivePolicy

__all__ = [
    "ALWAYS_FIRE",
    "GATE_MODES",
    "AdaptivePolicy",
    "BlockGater",
    "GateModel",
    "GateModelCalibrator",
    "mean_abs_confidence",
]
