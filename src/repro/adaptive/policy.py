"""Serving-facing knob for input-adaptive execution.

``EnginePolicy.adaptive`` carries one of these; the engine builds the
executor's :class:`~repro.adaptive.gating.BlockGater` from it, seeds the
cost model's :class:`~repro.adaptive.gate_model.GateModel`, and the session
walks the deadline ladder each group to pick the confidence threshold.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Tuple

from repro.adaptive.gate_model import GateModel
from repro.adaptive.gating import GATE_MODES, mean_abs_confidence


@dataclasses.dataclass(frozen=True)
class AdaptivePolicy:
    """Input-adaptive serving configuration.

    Attributes:
      threshold: base confidence threshold (fire a block for a row iff
        ``confidence < threshold``); ``math.inf`` = all-blocks floor.
      mode: ``"early_exit"`` or ``"per_block"``.
      min_blocks: per-path block count every row always pays.
      confidence: pure per-row confidence function (jit-traceable).
      gate_model: expected fire/task probabilities for the cost model and
        order solvers; ``None`` predicts the all-blocks floor until
        calibrated.
      ladder: accuracy ladder ``((min_slack_seconds, threshold), ...)`` —
        per group the session picks the threshold of the *tightest* rung
        whose ``min_slack`` the group's worst deadline slack still clears
        (rungs sorted by ``min_slack``; more slack -> a tighter, i.e.
        lower, threshold -> more exits -> cheaper but approximate).  Groups
        with no slack (or no ladder) use the base ``threshold``.
      calibrate_online: refresh ``gate_model`` from live realized traces
        after every group, so expected-cost planning tracks traffic drift.
    """

    threshold: float = math.inf
    mode: str = "early_exit"
    min_blocks: int = 1
    confidence: Callable = mean_abs_confidence
    gate_model: Optional[GateModel] = None
    ladder: Tuple[Tuple[float, float], ...] = ()
    calibrate_online: bool = False

    def __post_init__(self) -> None:
        if self.mode not in GATE_MODES:
            raise ValueError(f"unknown gate mode {self.mode!r}")

    def threshold_for_slack(self, slack: Optional[float]) -> float:
        """Ladder lookup: the threshold earned by ``slack`` deadline room.

        ``slack`` is the group's minimum remaining deadline slack in
        seconds (``None`` = no deadlines in the group -> base threshold).
        """
        if slack is None or not self.ladder:
            return float(self.threshold)
        best = float(self.threshold)
        best_rung = -math.inf
        for min_slack, thr in self.ladder:
            if slack >= min_slack and min_slack > best_rung:
                best_rung = min_slack
                best = float(thr)
        return best
