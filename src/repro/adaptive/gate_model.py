"""Gate-probability model: the expected-cost side of input-adaptive serving.

A :class:`GateModel` gives the cost model two probability surfaces:

* ``fire_probability(task, depth)`` — of the rows a task runs for, the
  fraction expected to fire its depth-``depth`` block (adaptive confidence
  gating; 1.0 where unknown).
* ``task_probability(task)`` — the fraction of offered rows the task runs
  for at all (legacy whole-group ``gate=`` callbacks, or the conditional
  execution probabilities of Eq. 8's constraints).

``GraphCostModel.expected_stats`` weights FLOP/task counters by these, so
``solve_suborder`` / ``optimal_order`` minimize *expected* bytes/FLOPs when
fed ``expected_cost_matrix``.  Because per-row gate decisions are a
deterministic function of the row (pure confidence on deterministic
activations), the fire fractions are invariant to how rows are grouped or
where suffixes resume — which is why expected predictions converge to
measured means regardless of schedule.

A :class:`GateModelCalibrator` estimates both surfaces from realized
:class:`~repro.core.types.TaskGateRecord` traces — a profiling set offline,
or live serving traffic when ``AdaptivePolicy.calibrate_online`` is set.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.core.types import TaskGateRecord


@dataclasses.dataclass(frozen=True)
class GateModel:
    """Per-block fire probabilities and per-task execution probabilities.

    Missing entries default to 1.0 (always fires / always runs), so the
    empty model is exactly the all-blocks floor and partial calibration
    degrades gracefully toward it.
    """

    fire: Dict[Tuple[int, int], float] = dataclasses.field(default_factory=dict)
    task_fire: Dict[int, float] = dataclasses.field(default_factory=dict)

    def fire_probability(self, task: int, depth: int) -> float:
        return float(self.fire.get((task, depth), 1.0))

    def task_probability(self, task: int) -> float:
        return float(self.task_fire.get(task, 1.0))

    @classmethod
    def from_constraints(cls, constraints) -> "GateModel":
        """Task probabilities from conditional constraints (Eq. 8).

        Folds each task's conditional in-edge probabilities into
        ``task_fire`` so the expected cost matrix weights its suffix the
        way ``fitness`` weights it — letting ``solve_suborder`` (which
        rebuilds precedence-only constraints and would otherwise drop the
        probabilities) optimize the probability-weighted objective.
        """
        task_fire: Dict[int, float] = {}
        for t in range(constraints.num_tasks):
            p = constraints.execution_probability(t)
            if p != 1.0:
                task_fire[t] = float(p)
        return cls(task_fire=task_fire)


class GateModelCalibrator:
    """Running fire-fraction estimator over realized gate traces.

    ``observe`` folds one group's trace (the executor's per-task
    :class:`TaskGateRecord` list); ``model`` snapshots the current
    estimates.  Per-(task, depth) fire fractions are
    ``rows_fired / rows_offered_to_that_block``; per-task probabilities are
    ``rows_run / rows_offered``.  Depths a trace never executed (shared
    prefixes) contribute nothing — the activation-resume bookkeeping means
    those blocks' fire behaviour is observed whenever some task does
    execute them, and the fractions are grouping-invariant (see module
    docstring), so partial observation is unbiased.
    """

    def __init__(self) -> None:
        self._fired: Dict[Tuple[int, int], float] = {}
        self._live: Dict[Tuple[int, int], float] = {}
        self._ran: Dict[int, float] = {}
        self._offered: Dict[int, float] = {}

    def observe(self, trace) -> None:
        for rec in trace:
            offered = rec.offered if rec.offered is not None else rec.weight
            self._offered[rec.task] = self._offered.get(rec.task, 0.0) + offered
            self._ran[rec.task] = self._ran.get(rec.task, 0.0) + rec.weight
            if rec.fired is None or rec.weight == 0:
                continue
            resume = rec.resume if rec.resume is not None else 0
            for i, fired in enumerate(rec.fired):
                key = (rec.task, resume + i)
                self._live[key] = self._live.get(key, 0.0) + rec.weight
                self._fired[key] = self._fired.get(key, 0.0) + fired

    def model(self) -> GateModel:
        fire = {
            key: self._fired.get(key, 0.0) / live
            for key, live in self._live.items()
            if live > 0
        }
        task_fire = {
            t: self._ran.get(t, 0.0) / offered
            for t, offered in self._offered.items()
            if offered > 0 and self._ran.get(t, 0.0) != offered
        }
        return GateModel(fire=fire, task_fire=task_fire)
