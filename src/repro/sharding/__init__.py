"""Sharding policies: logical-axis -> mesh-axis mapping, activation
constraints, and parameter PartitionSpec trees."""

from repro.sharding.policy import (
    ShardingPolicy,
    TP_POLICY,
    FSDP_TP_POLICY,
    shard_act,
)

__all__ = ["ShardingPolicy", "TP_POLICY", "FSDP_TP_POLICY", "shard_act"]
