"""Logical-axis sharding policy.

Model code annotates tensors with *logical* axes (``batch``, ``model``,
``fsdp``, ``expert``, ``seq``); the policy maps those to physical mesh axes.
This keeps every model family mesh-agnostic: the same code lowers on the
single-pod ``(data, model)`` mesh, the multi-pod ``(pod, data, model)`` mesh,
and on a single CPU device (where constraints are no-ops).

Two built-in policies:

* ``TP_POLICY`` — the paper-faithful-era baseline: tensor parallelism over
  ``model``, batch over ``data`` (+ ``pod``), parameters replicated across
  data.  Sufficient for every arch except 340B-scale training.
* ``FSDP_TP_POLICY`` — beyond-paper: parameters additionally sharded over
  the data axis (ZeRO-3 style); the per-layer all-gather is amortised by
  the layer scan.  Required for nemotron-4-340b training to fit HBM
  (recorded as a §Perf iteration).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

Logical = Union[None, str, Tuple[str, ...]]


def _ambient_mesh():
    """The mesh installed by ``with mesh:`` / ``jax.sharding.use_mesh``.

    Only ``ImportError`` / ``AttributeError`` — the "this jax version does
    not have that accessor" signals — mean "try the next accessor"; anything
    else is a real failure in mesh state and must surface, not silently
    degrade every spec to replicated.
    """
    get_abstract = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract is not None:
        m = get_abstract()
        if m is not None and not m.empty:
            return m
    try:
        from jax._src import mesh as mesh_lib

        m = mesh_lib.thread_resources.env.physical_mesh
    except (ImportError, AttributeError):
        m = None
    if m is not None and not m.empty:
        return m
    return None


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """Maps logical tensor axes to physical mesh axes.

    Attributes:
      batch: mesh axes carrying the batch (``("data",)`` or
        ``("pod", "data")``).
      model: mesh axis for tensor parallelism (heads / d_ff / vocab).
      fsdp: mesh axis over which parameters are additionally sharded
        (None = replicated across data — the baseline).
      expert: mesh axis for expert parallelism of MoE stacks (None = experts
        co-located, TP inside each expert — the baseline).
    """

    name: str
    batch: Tuple[str, ...] = ("data",)
    model: Optional[str] = "model"
    fsdp: Optional[str] = None
    expert: Optional[str] = None

    def physical(self, logical: Logical):
        """Resolve one logical axis to mesh axes (or None)."""
        if logical is None:
            return None
        if logical == "batch":
            return self.batch if len(self.batch) > 1 else self.batch[0]
        if logical == "model":
            return self.model
        if logical == "fsdp":
            return self.fsdp
        if logical == "expert":
            return self.expert
        if logical == "seq":
            return None  # sequence never sharded in this framework
        raise ValueError(f"unknown logical axis {logical!r}")

    def spec(self, *logical_axes: Logical) -> P:
        """PartitionSpec from logical axes, dropping axes absent from the
        ambient mesh (lets the same model run on 1-device CPU)."""
        mesh = _ambient_mesh()
        names = set(mesh.axis_names) if mesh is not None else set()

        def keep(ax):
            if ax is None:
                return None
            if isinstance(ax, tuple):
                kept = tuple(a for a in ax if a in names)
                return kept if kept else None
            return ax if ax in names else None

        return P(*[keep(self.physical(a)) for a in logical_axes])

    def param_spec(self, shape: Sequence[int]) -> P:
        """Ideal weight layout for one parameter leaf of ``shape``.

        Convention for the task-graph serving path: matrices (and higher)
        shard their first axis over ``fsdp`` (ZeRO-style, None under TP) and
        their last axis over ``model`` (tensor parallelism); vectors and
        scalars replicate.  Callers pass the result through
        ``repro.sharding.utils.fit_spec`` so axes absent from the concrete
        mesh — or not dividing the dimension — degrade to replication.
        """
        nd = len(shape)
        if nd < 2:
            return P(*([None] * nd))
        return P(self.fsdp, *([None] * (nd - 2)), self.model)

    def data_shards(self, mesh) -> int:
        """How many ways the batch dimension splits on ``mesh`` (the
        per-shard multiple the request-group scheduler must pad to)."""
        if mesh is None:
            return 1
        names = set(mesh.axis_names)
        n = 1
        for a in self.batch:
            if a in names:
                n *= int(mesh.shape[a])
        return n

    def weight_shards(self, mesh) -> int:
        """How many ways parameters split on ``mesh`` (the divisor on the
        cost model's weight-load term: each chip streams only its slice)."""
        if mesh is None:
            return 1
        names = set(mesh.axis_names)
        n = 1
        for a in sorted({a for a in (self.model, self.fsdp) if a is not None}):
            if a in names:
                n *= int(mesh.shape[a])
        return n


TP_POLICY = ShardingPolicy(name="tp", batch=("pod", "data"))
FSDP_TP_POLICY = ShardingPolicy(
    name="fsdp_tp", batch=("pod", "data"), fsdp="data"
)
EXPERT_TP_POLICY = ShardingPolicy(
    name="expert_tp", batch=("pod", "data"), expert="model"
)
FSDP_EXPERT_POLICY = ShardingPolicy(
    name="fsdp_expert", batch=("pod", "data"), fsdp="data", expert="model"
)

POLICIES = {
    p.name: p
    for p in (TP_POLICY, FSDP_TP_POLICY, EXPERT_TP_POLICY, FSDP_EXPERT_POLICY)
}


def shard_act(x: jax.Array, policy: ShardingPolicy, *logical_axes: Logical):
    """``with_sharding_constraint`` on activations; no-op without a mesh."""
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    spec = policy.spec(*logical_axes)
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, spec)
