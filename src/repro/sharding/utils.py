"""Spec utilities: fit ideal PartitionSpecs to a concrete mesh.

``fit_specs`` walks a (shapes, specs) pytree pair and drops any spec axis
that (a) references a mesh axis absent from the mesh, or (b) does not evenly
divide the corresponding tensor dimension.  This lets model code declare the
*ideal* layout once (e.g. KV heads over the model axis) while MQA configs,
tiny smoke configs, and the 1-device CPU runtime all degrade gracefully to
replication on that axis.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axis_size(mesh: Mesh, axis: Union[str, Tuple[str, ...]]) -> int:
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return int(mesh.shape[axis])


def fit_spec(shape: Sequence[int], spec: P, mesh: Mesh) -> P:
    """Drop spec entries that don't exist in / divide over the mesh."""
    names = set(mesh.axis_names)
    out = []
    entries = tuple(spec) + (None,) * (len(shape) - len(spec))
    for dim, ax in zip(shape, entries):
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        kept = tuple(a for a in axes if a in names)
        if not kept:
            out.append(None)
            continue
        size = _axis_size(mesh, kept)
        if dim % size != 0:
            # Try progressively smaller prefixes of the axis tuple.
            while kept and dim % _axis_size(mesh, kept) != 0:
                kept = kept[:-1]
            out.append(kept if kept else None)
            continue
        out.append(kept if len(kept) > 1 else kept[0])
    return P(*out)


def fit_specs(shapes: Any, specs: Any, mesh: Mesh) -> Any:
    """Tree-map :func:`fit_spec` over matching (shape, spec) pytrees."""

    def one(shape_leaf, spec_leaf):
        shape = (
            shape_leaf.shape if hasattr(shape_leaf, "shape") else tuple(shape_leaf)
        )
        return fit_spec(shape, spec_leaf, mesh)

    return jax.tree.map(
        one, shapes, specs, is_leaf=lambda x: isinstance(x, P)
    )


def to_named_shardings(specs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def tree_bytes(tree: Any) -> int:
    """Total bytes of a pytree of arrays/ShapeDtypeStructs."""
    total = 0
    for leaf in jax.tree.leaves(tree):
        total += int(np.prod(leaf.shape)) * leaf.dtype.itemsize
    return total
