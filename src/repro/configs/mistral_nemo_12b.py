"""mistral-nemo-12b — Mistral-Nemo-Base-2407, 128k context
[hf:mistralai/Mistral-Nemo-Base-2407].

Full attention natively; ``long_context_window`` enables the beyond-paper
sliding-window variant used only for the long_500k decode shape (DESIGN §5).
"""
from repro.models.config import make_config

CONFIG = make_config(
    name="mistral-nemo-12b", family="dense",
    num_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,  # GQA kv=8
    d_ff=14336, vocab_size=131072, head_dim=160,
    activation="swiglu", rope_theta=1e6,
    long_context_window=4096,
    citation="hf:mistralai/Mistral-Nemo-Base-2407",
)

SMOKE = make_config(
    name="mistral-nemo-smoke", family="dense",
    num_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
    d_ff=512, vocab_size=1024, head_dim=32,
    activation="swiglu", dtype="float32", param_dtype="float32",
    remat=False, attn_chunk=64, loss_chunk=32,
    citation="reduced mistral-nemo",
)
