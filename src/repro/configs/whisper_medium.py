"""whisper-medium — OpenAI Whisper medium enc-dec [arXiv:2212.04356].

The mel-spectrogram + conv frontend is a STUB: input_specs delivers
(B, T, 80) frame features; the 24+24 layer transformer is fully implemented.
"""
from repro.models.config import make_config

CONFIG = make_config(
    name="whisper-medium", family="encdec",
    num_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=51865,  # padded to 51968 for the model axis
    head_dim=64, activation="gelu",
    enc_layers=24, enc_inputs=80,
    citation="arXiv:2212.04356 (Whisper)",
)

SMOKE = make_config(
    name="whisper-medium-smoke", family="encdec",
    num_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=256, vocab_size=1024, head_dim=32, activation="gelu",
    enc_layers=2, enc_inputs=80,
    dtype="float32", param_dtype="float32",
    remat=False, attn_chunk=64, loss_chunk=32,
    citation="reduced whisper-medium",
)
