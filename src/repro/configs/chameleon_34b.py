"""chameleon-34b — Meta Chameleon early-fusion VLM [arXiv:2405.09818].

Early fusion: image content arrives as VQ-VAE token ids inside the same
65536-entry vocabulary, so the backbone is a plain decoder; the VQ image
tokenizer frontend is a stub per the brief.
"""
from repro.models.config import make_config

CONFIG = make_config(
    name="chameleon-34b", family="vlm",
    num_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,  # GQA kv=8
    d_ff=22016, vocab_size=65536, head_dim=128,
    activation="swiglu", rope_theta=1e4,
    citation="arXiv:2405.09818 (Chameleon)",
)

SMOKE = make_config(
    name="chameleon-34b-smoke", family="vlm",
    num_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
    d_ff=512, vocab_size=1024, head_dim=32,
    activation="swiglu", dtype="float32", param_dtype="float32",
    remat=False, attn_chunk=64, loss_chunk=32,
    citation="reduced chameleon-34b",
)
