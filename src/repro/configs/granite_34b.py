"""granite-34b — IBM Granite 34B Code (llama-arch, MQA) [arXiv:2405.04324]."""
import dataclasses
from repro.models.config import make_config

CONFIG = make_config(
    name="granite-34b", family="dense",
    num_layers=88, d_model=6144, n_heads=48, n_kv_heads=1,  # MQA (kv=1)
    d_ff=24576, vocab_size=49152, head_dim=128,
    activation="swiglu", rope_theta=1e5,
    citation="arXiv:2405.04324 (Granite Code Models)",
)

SMOKE = make_config(
    name="granite-34b-smoke", family="dense",
    num_layers=2, d_model=256, n_heads=8, n_kv_heads=1,
    d_ff=512, vocab_size=1024, head_dim=32,
    activation="swiglu", dtype="float32", param_dtype="float32",
    remat=False, attn_chunk=64, loss_chunk=32,
    citation="reduced granite-34b",
)
