"""qwen2-moe-a2.7b — Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B].

4 always-on shared experts + 60 routed experts, top-4 routing; the shared
experts are the in-architecture mirror of Antler's shared task-graph blocks.
"""
from repro.models.config import make_config

CONFIG = make_config(
    name="qwen2-moe-a2.7b", family="moe",
    num_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=151936, head_dim=128,
    activation="swiglu",
    moe_num_experts=60, moe_top_k=4, moe_num_shared_experts=4, moe_d_ff=1408,
    citation="hf:Qwen/Qwen1.5-MoE-A2.7B",
)

# Expert-parallel variant (§Perf B5): 60 real experts padded to 64 so the
# expert axis shards over the 16-way model axis.
CONFIG_EP = make_config(
    name="qwen2-moe-a2.7b-ep", family="moe",
    num_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=151936, head_dim=128,
    activation="swiglu",
    moe_num_experts=64, moe_real_experts=60, moe_top_k=4,
    moe_num_shared_experts=4, moe_d_ff=1408,
    citation="hf:Qwen/Qwen1.5-MoE-A2.7B (padded for expert parallelism)",
)

SMOKE = make_config(
    name="qwen2-moe-smoke", family="moe",
    num_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=1024, head_dim=32,
    activation="swiglu",
    moe_num_experts=4, moe_top_k=2, moe_num_shared_experts=2, moe_d_ff=128,
    dtype="float32", param_dtype="float32",
    remat=False, attn_chunk=64, loss_chunk=32,
    citation="reduced qwen2-moe",
)
