"""zamba2-2.7b — Zyphra Zamba2 2.7B hybrid: Mamba2 backbone + globally
shared attention blocks [arXiv:2411.15242]."""
from repro.models.config import make_config

CONFIG = make_config(
    name="zamba2-2.7b", family="hybrid",
    num_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab_size=32000,  # padded to 32000->32000? see pad_vocab
    head_dim=80,
    ssm_state=64, ssm_head_dim=64, ssm_chunk=256, ssm_expand=2,
    hybrid_attn_period=6,  # shared attention every 6 Mamba2 blocks
    citation="arXiv:2411.15242 (Zamba2)",
)

SMOKE = make_config(
    name="zamba2-smoke", family="hybrid",
    num_layers=4, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=256, vocab_size=1024, head_dim=32,
    ssm_state=16, ssm_head_dim=32, ssm_chunk=32, ssm_expand=2,
    hybrid_attn_period=2,
    dtype="float32", param_dtype="float32",
    remat=False, attn_chunk=64, loss_chunk=32,
    citation="reduced zamba2",
)
