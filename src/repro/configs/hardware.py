"""Named hardware presets for the paper's MCU deployment targets.

The paper (Antler, arXiv:2302.13155) evaluates on two boards; the
benchmarks select them by name through :func:`get_hardware` so the
paper-scale sweeps and the intermittent-power benchmark state their
platform explicitly instead of importing loose constants.

* **msp430fr5994** — TI MSP430FR5994 (the batteryless/intermittent
  flagship): 16 MHz 16-bit MCU, 8 KB SRAM + 256 KB on-chip FRAM, external
  FRAM for weights.  The paper's Table 4/5 energy/latency numbers and the
  intermittent traces come from this board: ~2 MFLOP/s effective MAC
  throughput (MAC-per-8-cycles class), ~8 MB/s SRAM, ~1 MB/s external-FRAM
  weight streaming, ~250 pJ/op and ~120 pJ/byte — the FRAM write-per-byte
  cost is what makes checkpoint placement a real trade
  (``GraphCostModel.plan_checkpoints``).
* **stm32h747** — ST STM32H747 (the high-end comparison): 480 MHz
  Cortex-M7 + 240 MHz M4, ~200 MFLOP/s with DSP MACs, 640 KB SRAM,
  ~100 MB/s eFlash reads — the paper's Fig. 11 shows near-invisible
  weight-reload overhead here, which these constants reproduce.

Both presets are the canonical :data:`repro.core.types.MSP430` /
:data:`repro.core.types.STM32H747` values re-exported under the registry;
``tpu-v5e`` is included so serving benchmarks can name their default
platform the same way.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core.types import MSP430, STM32H747, TPU_V5E, HardwareModel

HARDWARE: Dict[str, HardwareModel] = {
    "msp430fr5994": MSP430,
    "stm32h747": STM32H747,
    "tpu-v5e": TPU_V5E,
}


def list_hardware() -> List[str]:
    return list(HARDWARE)


def get_hardware(name: str) -> HardwareModel:
    """Look up a named hardware preset (e.g. ``"msp430fr5994"``)."""
    key = name.strip().lower()
    if key not in HARDWARE:
        raise KeyError(
            f"unknown hardware {name!r}; known: {list_hardware()}"
        )
    return HARDWARE[key]
