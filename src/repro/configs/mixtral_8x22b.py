"""mixtral-8x22b — Mistral Mixtral 8x22B (MoE top-2, sliding window)
[arXiv:2401.04088]."""
from repro.models.config import make_config

CONFIG = make_config(
    name="mixtral-8x22b", family="moe",
    num_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,  # GQA kv=8
    d_ff=16384, vocab_size=32768, head_dim=128,
    activation="swiglu", sliding_window=4096,
    moe_num_experts=8, moe_top_k=2, moe_num_shared_experts=0, moe_d_ff=16384,
    rope_theta=1e6,
    citation="arXiv:2401.04088 (Mixtral of Experts)",
)

SMOKE = make_config(
    name="mixtral-smoke", family="moe",
    num_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    d_ff=256, vocab_size=1024, head_dim=32,
    activation="swiglu", sliding_window=32,
    moe_num_experts=4, moe_top_k=2, moe_num_shared_experts=0, moe_d_ff=256,
    dtype="float32", param_dtype="float32",
    remat=False, attn_chunk=16, loss_chunk=32,
    citation="reduced mixtral",
)
