"""mamba2-780m — Mamba2 780M, SSD state-space duality [arXiv:2405.21060]."""
from repro.models.config import make_config

CONFIG = make_config(
    name="mamba2-780m", family="ssm",
    num_layers=48, d_model=1536, n_heads=1, n_kv_heads=1,  # attention-free
    d_ff=0, vocab_size=50280,  # padded to 50432 for the model axis
    head_dim=64,
    ssm_state=128, ssm_head_dim=64, ssm_chunk=64, ssm_expand=2,  # chunk 256->64: Perf A1
    citation="arXiv:2405.21060 (Mamba2 / SSD)",
)

SMOKE = make_config(
    name="mamba2-smoke", family="ssm",
    num_layers=2, d_model=128, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab_size=1024, head_dim=32,
    ssm_state=16, ssm_head_dim=32, ssm_chunk=32, ssm_expand=2,
    dtype="float32", param_dtype="float32",
    remat=False, attn_chunk=64, loss_chunk=32,
    citation="reduced mamba2",
)
