"""nemotron-4-340b — NVIDIA Nemotron-4 340B (GQA, squared-ReLU)
[arXiv:2402.16819]."""
from repro.models.config import make_config

CONFIG = make_config(
    name="nemotron-4-340b", family="dense",
    num_layers=96, d_model=18432, n_heads=96, n_kv_heads=8,  # GQA kv=8
    d_ff=73728, vocab_size=256000, head_dim=192,
    activation="squared_relu", rope_theta=1e4,
    citation="arXiv:2402.16819 (Nemotron-4)",
)

SMOKE = make_config(
    name="nemotron-smoke", family="dense",
    num_layers=2, d_model=384, n_heads=8, n_kv_heads=2,
    d_ff=1536, vocab_size=1024, head_dim=48,
    activation="squared_relu", dtype="float32", param_dtype="float32",
    remat=False, attn_chunk=64, loss_chunk=32,
    citation="reduced nemotron-4",
)
