"""granite-20b — IBM Granite 20B Code (llama-arch, MQA) [arXiv:2405.04324]."""
from repro.models.config import make_config

CONFIG = make_config(
    name="granite-20b", family="dense",
    num_layers=52, d_model=6144, n_heads=48, n_kv_heads=1,  # MQA (kv=1)
    d_ff=24576, vocab_size=49152, head_dim=128,
    activation="swiglu", rope_theta=1e5,
    citation="arXiv:2405.04324 (Granite Code Models)",
)

SMOKE = make_config(
    name="granite-20b-smoke", family="dense",
    num_layers=2, d_model=192, n_heads=6, n_kv_heads=1,
    d_ff=384, vocab_size=1024, head_dim=32,
    activation="swiglu", dtype="float32", param_dtype="float32",
    remat=False, attn_chunk=64, loss_chunk=32,
    citation="reduced granite-20b",
)
