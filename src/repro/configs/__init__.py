"""Architecture registry: the 10 assigned architectures (each citing its
source) + the paper's own CNN-scale configurations.

``get_config(arch_id)`` returns the full production config;
``get_smoke_config(arch_id)`` returns the reduced same-family variant
(<= 2 layers, d_model <= 512, <= 4 experts) used by the CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

# canonical id -> module name
_ARCHS = {
    "granite-34b": "granite_34b",
    "whisper-medium": "whisper_medium",
    "granite-20b": "granite_20b",
    "chameleon-34b": "chameleon_34b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "nemotron-4-340b": "nemotron_4_340b",
    "mixtral-8x22b": "mixtral_8x22b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "mamba2-780m": "mamba2_780m",
    "zamba2-2.7b": "zamba2_2_7b",
}


def _norm(arch_id: str) -> str:
    mod = arch_id.replace("-", "_").replace(".", "_")
    return mod


def list_archs() -> List[str]:
    return list(_ARCHS.keys())


def _module(arch_id: str):
    name = _norm(arch_id)
    if name not in _ARCHS.values():
        raise KeyError(f"unknown arch {arch_id!r}; known: {list_archs()}")
    return importlib.import_module(f"repro.configs.{name}")


def get_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).SMOKE


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in list_archs()}


# Named hardware presets (the paper's MCU boards + the TPU default); see
# repro.configs.hardware for the documented constants.
from repro.configs.hardware import (  # noqa: E402
    HARDWARE, get_hardware, list_hardware,
)
