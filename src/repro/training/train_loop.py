"""Training step and loss for the architecture zoo.

The cross-entropy is computed in sequence chunks (``cfg.loss_chunk``) with
the softmax statistics in fp32: with the vocabulary sharded over the model
axis the live loss buffer per device is O(B * chunk * V / model_parallel),
never the full (B, S, V) fp32 tensor — required for the 256k-vocab archs to
fit HBM at 4k train sequence length (§Perf records the ablation).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.registry import ModelApi
from repro.sharding.policy import ShardingPolicy
from repro.training.optimizer import AdamWConfig, AdamWState, adamw_init, adamw_update

Params = Any


def cross_entropy_chunked(
    logits: jax.Array,   # (B, S, V) any float dtype
    labels: jax.Array,   # (B, S) int
    mask: Optional[jax.Array] = None,  # (B, S) 1/0
    chunk: int = 512,
) -> jax.Array:
    """Mean next-token NLL, computed chunk-by-chunk along the sequence."""
    b, s, v = logits.shape
    if s % chunk != 0:
        chunk = s  # fall back to a single chunk for ragged tiny inputs
    nc = s // chunk
    lg = logits.reshape(b, nc, chunk, v)
    lb = labels.reshape(b, nc, chunk)
    mk = (
        mask.reshape(b, nc, chunk)
        if mask is not None
        else jnp.ones((b, nc, chunk), jnp.float32)
    )

    def body(carry, xs):
        tot, cnt = carry
        lg_c, lb_c, mk_c = xs  # (B, chunk, V), (B, chunk), (B, chunk)
        lg32 = lg_c.astype(jnp.float32)
        m = jax.scipy.special.logsumexp(lg32, axis=-1)
        tgt = jnp.take_along_axis(lg32, lb_c[..., None], axis=-1)[..., 0]
        nll = (m - tgt) * mk_c
        return (tot + nll.sum(), cnt + mk_c.sum()), None

    (tot, cnt), _ = jax.lax.scan(
        body,
        (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (lg.transpose(1, 0, 2, 3), lb.transpose(1, 0, 2), mk.transpose(1, 0, 2)),
    )
    return tot / jnp.maximum(cnt, 1.0)


def lm_loss(
    model: ModelApi,
    params: Params,
    batch: Any,
    policy: ShardingPolicy,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token LM loss (teacher-forced).  ``batch``: tokens (B, S) or the
    encdec dict; loss predicts tokens[1:] from tokens[:-1]."""
    cfg = model.cfg
    if cfg.family == "encdec":
        tokens = batch["tokens"]
        logits, aux = model.forward(params, batch, policy)
    else:
        tokens = batch
        logits, aux = model.forward(params, tokens, policy)
    ce = cross_entropy_chunked(
        logits[:, :-1], tokens[:, 1:], chunk=cfg.loss_chunk
    )
    loss = ce + cfg.moe_aux_loss_weight * aux
    return loss, {"ce": ce, "aux": aux}


@dataclasses.dataclass
class TrainState:
    params: Params
    opt: AdamWState


def make_train_step(
    model: ModelApi,
    opt_cfg: AdamWConfig,
    policy: ShardingPolicy,
    grad_accum: int = 1,
) -> Callable:
    """Build the (jit-able) train step: grads -> clip -> AdamW -> metrics.

    With ``grad_accum > 1`` the batch's leading axis is split into that many
    microbatches and gradients accumulate under a ``lax.scan`` — the
    launcher-level knob that fits large-arch training into per-chip HBM
    (live activations scale with the microbatch, not the global batch).
    """

    def grads_of(params: Params, batch: Any):
        def loss_fn(p):
            loss, parts = lm_loss(model, p, batch, policy)
            return loss, parts

        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        return loss, parts, grads

    def train_step(params: Params, opt: AdamWState, batch: Any):
        if grad_accum == 1:
            loss, parts, grads = grads_of(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % grad_accum == 0, (
                    f"batch {b} not divisible by grad_accum {grad_accum}"
                )
                return x.reshape(grad_accum, b // grad_accum, *x.shape[1:])

            micro = jax.tree.map(split, batch)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )

            def body(acc, mb):
                g_acc, l_acc, a_acc = acc
                loss, parts, grads = grads_of(params, mb)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / grad_accum,
                    g_acc, grads,
                )
                return (g_acc, l_acc + loss / grad_accum,
                        a_acc + parts["aux"] / grad_accum), None

            (grads, loss, aux), _ = jax.lax.scan(
                body, (zero, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
                micro,
            )
            parts = {"ce": loss, "aux": aux}
        new_params, new_opt, om = adamw_update(opt_cfg, grads, opt, params)
        metrics = {"loss": loss, **parts, **om}
        return new_params, new_opt, metrics

    return train_step


def init_train_state(model: ModelApi, key: jax.Array) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt=adamw_init(params))
