"""Training substrate: optimizer, train step, checkpointing."""
from repro.training.optimizer import (
    AdamWConfig, AdamWState, adamw_init, adamw_update, lr_at, sgd_update,
    clip_by_global_norm, global_norm,
)
from repro.training.train_loop import (
    TrainState, cross_entropy_chunked, init_train_state, lm_loss, make_train_step,
)
from repro.training.checkpoint import (
    latest_checkpoint, restore_checkpoint, save_checkpoint,
)
