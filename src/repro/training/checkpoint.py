"""Checkpointing: pytree <-> .npz with path-string keys.

Simple, dependency-free, and adequate for the framework's scale of local
experiments: every leaf is saved under its joined tree path; restore
rebuilds into a reference pytree (structure must match).  Handles the
optimizer state and step counter as part of the same tree.
"""
from __future__ import annotations

import json
import os
import re
import tempfile
from typing import Any, Dict

import jax
import numpy as np


def _path_key(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_checkpoint(path: str, tree: Any, step: int = 0) -> None:
    """Atomically write ``tree`` to ``path`` (.npz)."""
    flat = {}
    for p, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[_path_key(p)] = np.asarray(jax.device_get(leaf))
    flat["__step__"] = np.asarray(step)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)))
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def restore_checkpoint(path: str, reference: Any) -> tuple[Any, int]:
    """Load into the structure of ``reference``.  Returns (tree, step)."""
    with np.load(path) as data:
        arrays = {k: data[k] for k in data.files}
    step = int(arrays.pop("__step__", np.asarray(0)))
    paths_leaves = jax.tree_util.tree_flatten_with_path(reference)
    leaves = []
    for p, ref_leaf in paths_leaves[0]:
        key = _path_key(p)
        if key not in arrays:
            raise KeyError(f"checkpoint {path} is missing leaf {key!r}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(ref_leaf.shape):
            raise ValueError(
                f"shape mismatch for {key!r}: ckpt {arr.shape} vs ref {ref_leaf.shape}"
            )
        leaves.append(jax.numpy.asarray(arr, dtype=ref_leaf.dtype))
    return jax.tree_util.tree_unflatten(paths_leaves[1], leaves), step


def latest_checkpoint(directory: str, prefix: str = "ckpt_") -> str | None:
    if not os.path.isdir(directory):
        return None
    best, best_step = None, -1
    for name in os.listdir(directory):
        m = re.match(rf"{re.escape(prefix)}(\d+)\.npz$", name)
        if m and int(m.group(1)) > best_step:
            best, best_step = os.path.join(directory, name), int(m.group(1))
    return best
