"""Optimizers and LR schedules (pure JAX; no optax in this environment).

AdamW with decoupled weight decay and global-norm clipping, plus the usual
warmup-cosine schedule.  Optimizer state is a pytree congruent with the
params, so the same PartitionSpecs apply (moments inherit the param's
sharding) — with the FSDP policy this is ZeRO-style sharded optimizer state
for free.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any      # first moment, matches params
    nu: Any      # second moment, matches params


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    schedule: str = "cosine"  # "cosine" | "constant"


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        return cfg.lr * warm
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


def adamw_init(params: Any) -> AdamWState:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros32, params),
        nu=jax.tree.map(zeros32, params),
    )


def adamw_update(
    cfg: AdamWConfig, grads: Any, state: AdamWState, params: Any
) -> Tuple[Any, AdamWState, dict]:
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    if cfg.clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        # Decoupled weight decay on matrix-like params only.
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v), metrics


def sgd_update(lr: float, grads: Any, params: Any) -> Any:
    """Plain SGD (used by the paper-scale CNN examples)."""
    return jax.tree.map(
        lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)).astype(p.dtype),
        params, grads,
    )
