"""Block-cached task-graph executor (paper §2.3).

The runtime mirrors the paper's MCU design, one level up the memory
hierarchy:

* a *static buffer* holds exactly one common-architecture's worth of blocks
  (one resident block per depth).  Before executing task ``t``, each block on
  ``t``'s path is loaded into its depth slot **unless it is already
  resident** — the "skip loading blocks already in main memory" rule;
* one *activation buffer per depth* caches the output of the most recently
  executed block at that depth, so a task sharing a prefix with the
  previously-run task resumes from the deepest shared block — the "reuse
  intermediate results" rule;
* tasks with conditional prerequisites may be *skipped at runtime* based on
  a gate over previously produced results (paper §4.3's conditional
  constraints), which skips their entire non-shared suffix.

The executor is generic over block semantics: it takes callables, so the
same engine drives the CNN-scale paper benchmarks and the transformer-scale
serving path.  Per-block work is jitted once per (depth, shape) and the
caching logic stays in Python — the task graph is static, so this is the
same "compile per suffix" structure a production serving stack would use.

``ExecutionStats`` counters must match ``GraphCostModel.predicted_stats``
exactly; a property test asserts this for random graphs and orders.

Request *groups* execute through :meth:`TaskGraphExecutor.run_batch`: the
same residency/prefix-reuse logic, but every block is vmapped over a stacked
batch of requests so one weight load (and one block invocation) serves the
whole group.  The batched counters match
``GraphCostModel.predicted_stats(order, batch_size=B)``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.constraints import Constraints
from repro.core.task_graph import TaskGraph
from repro.core.types import BlockCost, ExecutionStats

NodeId = Tuple[int, Tuple[int, ...]]  # (depth, group)

# block_fns[d](params, x) -> y  for depth-d blocks of the common architecture
BlockFn = Callable[[Any, jnp.ndarray], jnp.ndarray]
# head_fn(params, y) -> task output
HeadFn = Callable[[Any, jnp.ndarray], jnp.ndarray]


@dataclasses.dataclass
class MultitaskProgram:
    """A task graph bound to parameters and block semantics.

    Attributes:
      graph: the task graph.
      block_fns: per-depth apply function of the common architecture.
      node_params: parameters for every ``(depth, group)`` block node.
      head_fns / head_params: per-task classifier heads (the per-task leaf
        the paper attaches after the last shared block).
      block_costs: per-depth cost entries used for stats accounting.
    """

    graph: TaskGraph
    block_fns: Sequence[BlockFn]
    node_params: Dict[NodeId, Any]
    head_fns: Sequence[HeadFn]
    head_params: Sequence[Any]
    block_costs: Sequence[BlockCost]

    def __post_init__(self) -> None:
        for node in self.graph.nodes():
            if node not in self.node_params:
                raise ValueError(f"missing params for task-graph node {node}")


class TaskGraphExecutor:
    """Stateful executor with block residency + activation caching."""

    def __init__(self, program: MultitaskProgram, jit_blocks: bool = True):
        self.program = program
        self._jit = jit_blocks
        self._compiled: Dict[int, Callable] = {}
        self._compiled_heads: Dict[int, Callable] = {}
        self._compiled_batch: Dict[int, Callable] = {}
        self._compiled_heads_batch: Dict[int, Callable] = {}
        self.reset()

    # ---------------------------------------------------------------- state
    def reset(self) -> None:
        """Cold state: nothing resident, nothing cached."""
        depth = self.program.graph.depth
        self._resident: List[Optional[NodeId]] = [None] * depth
        self.clear_activations()

    def clear_activations(self) -> None:
        """Drop cached activations but keep weight residency.

        Weights are input-independent, activations are not: the whole-order
        entry points (:meth:`run` / :meth:`run_batch`) call this on entry so
        a new input can never resume from a previous input's activations,
        while the resident blocks remain loaded.  Callers driving
        :meth:`run_task` / :meth:`run_task_batch` directly own this contract
        themselves (the serving engine resets per group).
        """
        depth = self.program.graph.depth
        self._activations: List[Optional[jnp.ndarray]] = [None] * depth
        self._act_owner: List[Optional[NodeId]] = [None] * depth
        self._act_shape: Optional[Tuple[int, ...]] = None

    def _guard_act_shape(self, shape: Tuple[int, ...]) -> None:
        """Invalidate cached activations produced for a different input shape
        (e.g. switching between the single-request and batched paths)."""
        if self._act_shape is not None and self._act_shape != shape:
            self.clear_activations()
        self._act_shape = shape

    def _block_fn(self, depth: int) -> Callable:
        if depth not in self._compiled:
            fn = self.program.block_fns[depth]
            self._compiled[depth] = jax.jit(fn) if self._jit else fn
        return self._compiled[depth]

    def _head_fn(self, task: int) -> Callable:
        if task not in self._compiled_heads:
            fn = self.program.head_fns[task]
            self._compiled_heads[task] = jax.jit(fn) if self._jit else fn
        return self._compiled_heads[task]

    def _block_fn_batch(self, depth: int) -> Callable:
        # vmap over the stacked request axis; params are shared across the
        # batch.  jit's shape-keyed cache yields one compile per
        # (depth, batch-shape) — exactly the recompilation budget the
        # request-group scheduler's padded shapes bound.
        if depth not in self._compiled_batch:
            fn = jax.vmap(self.program.block_fns[depth], in_axes=(None, 0))
            self._compiled_batch[depth] = jax.jit(fn) if self._jit else fn
        return self._compiled_batch[depth]

    def _head_fn_batch(self, task: int) -> Callable:
        if task not in self._compiled_heads_batch:
            fn = jax.vmap(self.program.head_fns[task], in_axes=(None, 0))
            self._compiled_heads_batch[task] = jax.jit(fn) if self._jit else fn
        return self._compiled_heads_batch[task]

    # ------------------------------------------------------------------ run
    def _run_task_impl(
        self,
        task: int,
        x: jnp.ndarray,
        stats: ExecutionStats,
        weight: int,
        block_fn: Callable[[int], Callable],
        head_fn: Callable[[int], Callable],
    ) -> jnp.ndarray:
        """Shared body of the single-request and batched task execution.

        The residency/resume/accounting invariants live ONLY here so the two
        paths cannot drift: ``weight`` is the logical request multiplicity
        scaling the per-request counters (flops/tasks), while load counters
        stay physical (once per invocation).
        """
        graph = self.program.graph
        path = graph.path(task)
        self._guard_act_shape(tuple(x.shape))

        # Deepest prefix of this task's path whose activations are cached.
        resume = 0
        for d, node in enumerate(path):
            if self._act_owner[d] == node and self._activations[d] is not None:
                resume = d + 1
            else:
                break

        h = self._activations[resume - 1] if resume > 0 else x
        for d in range(graph.depth):
            node = path[d]
            bc = self.program.block_costs[d]
            if d < resume:
                # Shared prefix: weights resident AND activation cached ->
                # skip both the load and the execute.
                stats.blocks_skipped += 1
                stats.weight_bytes_skipped += bc.weight_bytes
                stats.flops_skipped += weight * bc.flops
                continue
            if self._resident[d] != node:
                stats.weight_bytes_loaded += bc.weight_bytes
                self._resident[d] = node
            else:
                stats.weight_bytes_skipped += bc.weight_bytes
            h = block_fn(d)(self.program.node_params[node], h)
            stats.blocks_executed += 1
            stats.flops_executed += weight * bc.flops
            self._activations[d] = h
            self._act_owner[d] = node
        stats.tasks_run += weight
        return head_fn(task)(self.program.head_params[task], h)

    def run_task(
        self, task: int, x: jnp.ndarray, stats: ExecutionStats
    ) -> jnp.ndarray:
        """Run one task, resuming from the deepest cached shared block."""
        return self._run_task_impl(
            task, x, stats, 1, self._block_fn, self._head_fn
        )

    def run(
        self,
        x: jnp.ndarray,
        order: Sequence[int],
        gate: Optional[Callable[[int, Dict[int, jnp.ndarray]], bool]] = None,
    ) -> Tuple[Dict[int, jnp.ndarray], ExecutionStats]:
        """Execute all tasks in ``order`` on input ``x``.

        Args:
          x: the shared input sample/batch (all tasks consume the same
            domain ``X`` in the paper).
          order: task permutation from the ordering solver.
          gate: optional runtime gate implementing conditional constraints —
            ``gate(task, results_so_far) -> bool``; a gated-off task is
            skipped entirely.

        Returns:
          (per-task outputs, execution stats).
        """
        self.clear_activations()  # never resume from a previous input
        results: Dict[int, jnp.ndarray] = {}
        stats = ExecutionStats()
        for t in order:
            if gate is not None and not gate(t, results):
                stats.tasks_skipped += 1
                continue
            results[t] = self.run_task(t, x, stats)
        return results, stats

    # ---------------------------------------------------------------- batch
    def run_task_batch(
        self,
        task: int,
        xs: jnp.ndarray,
        stats: ExecutionStats,
        weight: Optional[int] = None,
    ) -> jnp.ndarray:
        """Run one task for a stacked request group ``xs``: ``(B, *sample)``.

        Blocks are vmapped over the leading request axis while the Python
        residency/activation cache logic is shared across the whole group:
        every block on the path is loaded (and its batched activation cached)
        **once per group**, so weight loads amortise over ``B`` requests —
        the batch dimension the roadmap calls the main serving lever.

        Counters keep the cost model's per-request ("logical") accounting:
        ``weight`` is the number of real requests this execution serves
        (defaults to ``B``; the engine passes the gate-fired count, the
        scheduler the unpadded count).  Flop/task counters scale by
        ``weight``; load counters stay physical (once per group) — that gap
        *is* the block-loads-saved of batching.
        """
        w = int(xs.shape[0]) if weight is None else int(weight)
        return self._run_task_impl(
            task, xs, stats, w, self._block_fn_batch, self._head_fn_batch
        )

    def run_batch(
        self,
        xs: jnp.ndarray,
        order: Sequence[int],
        gate: Optional[Callable[[int, Dict[int, jnp.ndarray]], bool]] = None,
        valid: Optional[int] = None,
    ) -> Tuple[Dict[int, jnp.ndarray], ExecutionStats]:
        """Execute all tasks in ``order`` once for a stacked request group.

        Args:
          xs: ``(B, *sample_shape)`` stacked inputs, one row per request
            (rows ``valid:`` may be padding added by the scheduler).
          order: task permutation from the ordering solver.
          gate: optional group-wise gate, same signature as :meth:`run` but
            receiving *batched* results; a gated-off task is skipped for the
            whole group.  Per-request gating lives in the serving engine,
            which drives :meth:`run_task_batch` directly.
          valid: number of real (non-padding) leading rows used for logical
            per-request accounting; defaults to ``B``.

        Returns:
          (per-task batched outputs ``{task: (B, *out_shape)}``, stats).
          With a cold executor the stats equal
          ``GraphCostModel.predicted_stats(order, batch_size=valid)`` exactly.
        """
        self.clear_activations()  # never resume from a previous input
        v = int(xs.shape[0]) if valid is None else int(valid)
        results: Dict[int, jnp.ndarray] = {}
        stats = ExecutionStats()
        for t in order:
            if gate is not None and not gate(t, results):
                stats.tasks_skipped += v
                continue
            results[t] = self.run_task_batch(t, xs, stats, weight=v)
        return results, stats


class VanillaExecutor:
    """Baseline: independently-trained networks run back to back.

    No block is ever considered resident across tasks and no activation is
    reused — every task pays its full load + execute cost (the paper's
    "Vanilla" baseline).
    """

    def __init__(self, program: MultitaskProgram, jit_blocks: bool = True):
        self.program = program
        self._inner = TaskGraphExecutor(program, jit_blocks)

    def run(
        self,
        x: jnp.ndarray,
        order: Optional[Sequence[int]] = None,
        gate: Optional[Callable[[int, Dict[int, jnp.ndarray]], bool]] = None,
    ) -> Tuple[Dict[int, jnp.ndarray], ExecutionStats]:
        order = list(order) if order is not None else list(
            range(self.program.graph.num_tasks)
        )
        results: Dict[int, jnp.ndarray] = {}
        stats = ExecutionStats()
        for t in order:
            if gate is not None and not gate(t, results):
                stats.tasks_skipped += 1
                continue
            self._inner.reset()  # forget residency + caches between tasks
            results[t] = self._inner.run_task(t, x, stats)
        return results, stats


def run_in_order(
    program: MultitaskProgram,
    x: jnp.ndarray,
    order: Sequence[int],
    gate: Optional[Callable[[int, Dict[int, jnp.ndarray]], bool]] = None,
) -> Tuple[Dict[int, jnp.ndarray], ExecutionStats]:
    """One-shot convenience wrapper around :class:`TaskGraphExecutor`."""
    return TaskGraphExecutor(program).run(x, order, gate)
