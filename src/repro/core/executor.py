"""Block-cached task-graph executor (paper §2.3).

The runtime mirrors the paper's MCU design, one level up the memory
hierarchy:

* a *static buffer* holds exactly one common-architecture's worth of blocks
  (one resident block per depth).  Before executing task ``t``, each block on
  ``t``'s path is loaded into its depth slot **unless it is already
  resident** — the "skip loading blocks already in main memory" rule;
* one *activation buffer per depth* caches the output of the most recently
  executed block at that depth, so a task sharing a prefix with the
  previously-run task resumes from the deepest shared block — the "reuse
  intermediate results" rule;
* tasks with conditional prerequisites may be *skipped at runtime* based on
  a gate over previously produced results (paper §4.3's conditional
  constraints), which skips their entire non-shared suffix.

The executor is generic over block semantics: it takes callables, so the
same engine drives the CNN-scale paper benchmarks and the transformer-scale
serving path.

Dispatch strategy: by default each contiguous non-shared suffix
(resume-depth -> head) is compiled into a **single fused program** keyed by
``(task, resume_depth, batched, input shape)`` — one dispatch per task
instead of one per block.  When the suffix's blocks are homogeneous (same
apply function, same parameter shapes, shape-preserving) the fused program
stacks the suffix's parameters and drives them with ``lax.scan``; otherwise
the suffix is unrolled inside one jitted program.  ``fused=False`` keeps the
original per-block dispatch path as the reference implementation; both paths
produce identical counters and (allclose-)identical outputs, which the tests
assert.  The compile cache is bounded by the same fixed-shape discipline the
request-group scheduler enforces: tasks x (depth+1) resume points x the
scheduler's padded batch shapes.

Warm starts: :meth:`TaskGraphExecutor.residency_state` exposes the per-depth
resident blocks so callers (the serving engine, the cost model) can account
cross-group weight-load reuse; :meth:`clear_activations` is the warm-start
entry point — it invalidates input-dependent activation caches while keeping
the input-independent weight residency, so a new request group resumes with
the previous group's blocks still "in memory".

``ExecutionStats`` counters must match ``GraphCostModel.predicted_stats``
exactly (including warm starts via its ``resume`` argument); property tests
assert this for random graphs, orders, and multi-group plans.

Request *groups* execute through :meth:`TaskGraphExecutor.run_batch`: the
same residency/prefix-reuse logic, but every block is vmapped over a stacked
batch of requests so one weight load (and one fused dispatch) serves the
whole group.  The batched counters match
``GraphCostModel.predicted_stats(order, batch_size=B)``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.constraints import Constraints
from repro.core.task_graph import TaskGraph
from repro.core.types import BlockCost, ExecutionStats, NodeId

# What residency_state returns and what GraphCostModel.predicted_stats
# accepts as ``resume`` (the concrete tuple form of types.Residency).
ResidencyState = Tuple[Optional[NodeId], ...]

# block_fns[d](params, x) -> y  for depth-d blocks of the common architecture
BlockFn = Callable[[Any, jnp.ndarray], jnp.ndarray]
# head_fn(params, y) -> task output
HeadFn = Callable[[Any, jnp.ndarray], jnp.ndarray]


@dataclasses.dataclass
class MultitaskProgram:
    """A task graph bound to parameters and block semantics.

    Attributes:
      graph: the task graph.
      block_fns: per-depth apply function of the common architecture.
      node_params: parameters for every ``(depth, group)`` block node.
      head_fns / head_params: per-task classifier heads (the per-task leaf
        the paper attaches after the last shared block).
      block_costs: per-depth cost entries used for stats accounting.
    """

    graph: TaskGraph
    block_fns: Sequence[BlockFn]
    node_params: Dict[NodeId, Any]
    head_fns: Sequence[HeadFn]
    head_params: Sequence[Any]
    block_costs: Sequence[BlockCost]

    def __post_init__(self) -> None:
        for node in self.graph.nodes():
            if node not in self.node_params:
                raise ValueError(f"missing params for task-graph node {node}")


def _leaf_specs(params: Any) -> Tuple:
    """(treedef, leaf shapes/dtypes) fingerprint for stackability checks."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    return treedef, tuple((jnp.shape(l), jnp.result_type(l)) for l in leaves)


class TaskGraphExecutor:
    """Stateful executor with block residency + activation caching.

    Args:
      program: the bound multitask program.
      jit_blocks: jit-compile the dispatched programs (fused suffixes, or the
        per-block reference path).
      fused: execute each non-shared suffix as one fused program (default);
        ``False`` selects the per-block reference dispatch path.
    """

    def __init__(
        self,
        program: MultitaskProgram,
        jit_blocks: bool = True,
        fused: bool = True,
    ):
        self.program = program
        self._jit = jit_blocks
        self._fused = fused
        self._compiled: Dict[int, Callable] = {}
        self._compiled_heads: Dict[int, Callable] = {}
        self._compiled_batch: Dict[int, Callable] = {}
        self._compiled_heads_batch: Dict[int, Callable] = {}
        # (task, resume, batched, x_shape, x_dtype) -> (callable, mode); mode
        # is "scan" (stacked params + lax.scan) or "unrolled".
        self._compiled_fused: Dict[Tuple, Tuple[Callable, str]] = {}
        # (task, resume) -> stacked suffix params for the scan mode.
        self._stacked_params: Dict[Tuple[int, int], Any] = {}
        # Physical program dispatches (jitted-call invocations).  Cumulative;
        # not part of ExecutionStats (those are cost-model-predictable logical
        # counters — dispatches depend on the fused/per-block mode).
        self.dispatch_count = 0
        self.reset()

    # ---------------------------------------------------------------- state
    def reset(self) -> None:
        """Cold state: nothing resident, nothing cached."""
        depth = self.program.graph.depth
        self._resident: List[Optional[NodeId]] = [None] * depth
        self.clear_activations()

    def clear_activations(self) -> None:
        """Drop cached activations but keep weight residency (warm start).

        Weights are input-independent, activations are not: the whole-order
        entry points (:meth:`run` / :meth:`run_batch`) call this on entry so
        a new input can never resume from a previous input's activations,
        while the resident blocks remain loaded.  This is the warm-start
        boundary the serving engine uses between request groups.  Callers
        driving :meth:`run_task` / :meth:`run_task_batch` directly own this
        contract themselves.
        """
        depth = self.program.graph.depth
        self._activations: List[Optional[jnp.ndarray]] = [None] * depth
        self._act_owner: List[Optional[NodeId]] = [None] * depth
        self._act_shape: Optional[Tuple[int, ...]] = None

    def residency_state(self) -> ResidencyState:
        """Per-depth resident blocks, for warm-start cost accounting.

        Feed this to ``GraphCostModel.predicted_stats(..., resume=state)``
        (or ``predicted_group_stats``) to predict exactly what a warm
        continuation will load versus skip.
        """
        return tuple(self._resident)

    def set_residency(self, state: Sequence[Optional[NodeId]]) -> None:
        """Restore a residency snapshot (testing / replay helper).

        Only weight residency is restored; activations are always cleared —
        they belong to a specific input, which a snapshot does not carry.
        """
        depth = self.program.graph.depth
        if len(state) != depth:
            raise ValueError(
                f"residency state has {len(state)} slots, expected {depth}"
            )
        self._resident = list(state)
        self.clear_activations()

    def _guard_act_shape(self, shape: Tuple[int, ...]) -> None:
        """Invalidate cached activations produced for a different input shape
        (e.g. switching between the single-request and batched paths)."""
        if self._act_shape is not None and self._act_shape != shape:
            self.clear_activations()
        self._act_shape = shape

    # ------------------------------------------------- per-block (reference)
    def _block_fn(self, depth: int) -> Callable:
        if depth not in self._compiled:
            fn = self.program.block_fns[depth]
            self._compiled[depth] = jax.jit(fn) if self._jit else fn
        return self._compiled[depth]

    def _head_fn(self, task: int) -> Callable:
        if task not in self._compiled_heads:
            fn = self.program.head_fns[task]
            self._compiled_heads[task] = jax.jit(fn) if self._jit else fn
        return self._compiled_heads[task]

    def _block_fn_batch(self, depth: int) -> Callable:
        # vmap over the stacked request axis; params are shared across the
        # batch.  jit's shape-keyed cache yields one compile per
        # (depth, batch-shape) — exactly the recompilation budget the
        # request-group scheduler's padded shapes bound.
        if depth not in self._compiled_batch:
            fn = jax.vmap(self.program.block_fns[depth], in_axes=(None, 0))
            self._compiled_batch[depth] = jax.jit(fn) if self._jit else fn
        return self._compiled_batch[depth]

    def _head_fn_batch(self, task: int) -> Callable:
        if task not in self._compiled_heads_batch:
            fn = jax.vmap(self.program.head_fns[task], in_axes=(None, 0))
            self._compiled_heads_batch[task] = jax.jit(fn) if self._jit else fn
        return self._compiled_heads_batch[task]

    # -------------------------------------------------------- fused suffix
    def _suffix_params(self, task: int, resume: int) -> Tuple[Any, ...]:
        path = self.program.graph.path(task)
        return tuple(
            self.program.node_params[path[d]]
            for d in range(resume, self.program.graph.depth)
        )

    def _stacked_suffix_params(self, task: int, resume: int) -> Any:
        key = (task, resume)
        if key not in self._stacked_params:
            params = self._suffix_params(task, resume)
            self._stacked_params[key] = jax.tree_util.tree_map(
                lambda *ls: jnp.stack(ls), *params
            )
        return self._stacked_params[key]

    def _fused_fn(
        self, task: int, resume: int, batched: bool, x: jnp.ndarray
    ) -> Tuple[Callable, str]:
        """Build (or fetch) the fused suffix program for one resume point.

        The program runs blocks ``resume .. depth-1`` plus the task head in a
        single dispatch and returns ``(per-depth activations, head output)``
        — the intermediate activations feed the Python-level cache so later
        tasks can still resume mid-path.  Mode "scan" stacks the suffix's
        (homogeneous, shape-preserving) params and iterates with
        ``lax.scan``; mode "unrolled" traces the heterogeneous suffix block
        by block inside one program.
        """
        key = (task, resume, batched, tuple(x.shape), jnp.result_type(x))
        if key in self._compiled_fused:
            return self._compiled_fused[key]

        graph = self.program.graph
        depth = graph.depth
        suffix = list(range(resume, depth))
        base_fns = [self.program.block_fns[d] for d in suffix]
        head = self.program.head_fns[task]
        if batched:
            fns = [jax.vmap(f, in_axes=(None, 0)) for f in base_fns]
            head = jax.vmap(head, in_axes=(None, 0))
        else:
            fns = list(base_fns)

        mode = "unrolled"
        if len(suffix) >= 2 and all(f is base_fns[0] for f in base_fns):
            params = self._suffix_params(task, resume)
            specs = {_leaf_specs(p) for p in params}
            if len(specs) == 1:
                # Same fn + same param shapes; scan also needs the carry
                # shape to be invariant — verify without executing.
                try:
                    spec = jax.eval_shape(
                        fns[0],
                        params[0],
                        jax.ShapeDtypeStruct(x.shape, jnp.result_type(x)),
                    )
                    if (
                        spec.shape == tuple(x.shape)
                        and spec.dtype == jnp.result_type(x)
                    ):
                        mode = "scan"
                except Exception:
                    mode = "unrolled"

        if mode == "scan":
            step_fn = fns[0]

            def fused(stacked, head_p, h):
                def step(carry, p):
                    y = step_fn(p, carry)
                    return y, y

                h_last, acts = jax.lax.scan(step, h, stacked)
                return acts, head(head_p, h_last)

        else:

            def fused(params_tuple, head_p, h):
                acts = []
                for f, p in zip(fns, params_tuple):
                    h = f(p, h)
                    acts.append(h)
                return tuple(acts), head(head_p, h)

        compiled = jax.jit(fused) if self._jit else fused
        self._compiled_fused[key] = (compiled, mode)
        return compiled, mode

    def _run_suffix_fused(
        self, task: int, resume: int, h: jnp.ndarray, batched: bool
    ) -> jnp.ndarray:
        """One dispatch for the whole (suffix + head) of ``task``."""
        graph = self.program.graph
        fn, mode = self._fused_fn(task, resume, batched, h)
        if mode == "scan":
            acts, out = fn(
                self._stacked_suffix_params(task, resume),
                self.program.head_params[task],
                h,
            )
            acts = [acts[i] for i in range(graph.depth - resume)]
        else:
            acts, out = fn(
                self._suffix_params(task, resume),
                self.program.head_params[task],
                h,
            )
        self.dispatch_count += 1
        path = graph.path(task)
        for a, d in zip(acts, range(resume, graph.depth)):
            self._activations[d] = a
            self._act_owner[d] = path[d]
        return out

    def _run_suffix_blocks(
        self, task: int, resume: int, h: jnp.ndarray, batched: bool
    ) -> jnp.ndarray:
        """Reference path: one dispatch per block plus one for the head."""
        graph = self.program.graph
        path = graph.path(task)
        block_fn = self._block_fn_batch if batched else self._block_fn
        head_fn = self._head_fn_batch if batched else self._head_fn
        for d in range(resume, graph.depth):
            node = path[d]
            h = block_fn(d)(self.program.node_params[node], h)
            self.dispatch_count += 1
            self._activations[d] = h
            self._act_owner[d] = node
        out = head_fn(task)(self.program.head_params[task], h)
        self.dispatch_count += 1
        return out

    # ------------------------------------------------------------------ run
    def _run_task_impl(
        self,
        task: int,
        x: jnp.ndarray,
        stats: ExecutionStats,
        weight: int,
        batched: bool,
    ) -> jnp.ndarray:
        """Shared body of the single-request and batched task execution.

        The residency/resume/accounting invariants live ONLY here so the two
        paths cannot drift: ``weight`` is the logical request multiplicity
        scaling the per-request counters (flops/tasks), while load counters
        stay physical (once per invocation).  Accounting is dispatch-mode
        independent: the fused and per-block paths produce identical stats.
        """
        graph = self.program.graph
        path = graph.path(task)
        self._guard_act_shape(tuple(x.shape))

        # Deepest prefix of this task's path whose activations are cached.
        resume = 0
        for d, node in enumerate(path):
            if self._act_owner[d] == node and self._activations[d] is not None:
                resume = d + 1
            else:
                break

        for d in range(graph.depth):
            node = path[d]
            bc = self.program.block_costs[d]
            if d < resume:
                # Shared prefix: weights resident AND activation cached ->
                # skip both the load and the execute.
                stats.blocks_skipped += 1
                stats.weight_bytes_skipped += bc.weight_bytes
                stats.flops_skipped += weight * bc.flops
                continue
            if self._resident[d] != node:
                stats.weight_bytes_loaded += bc.weight_bytes
                self._resident[d] = node
            else:
                # Still resident (warm start across groups, or an intra-order
                # revisit): the load is skipped but the block must execute —
                # its input activation belongs to the current input.
                stats.weight_bytes_skipped += bc.weight_bytes
            stats.blocks_executed += 1
            stats.flops_executed += weight * bc.flops
        stats.tasks_run += weight

        h = self._activations[resume - 1] if resume > 0 else x
        if self._fused:
            return self._run_suffix_fused(task, resume, h, batched)
        return self._run_suffix_blocks(task, resume, h, batched)

    def run_task(
        self, task: int, x: jnp.ndarray, stats: ExecutionStats
    ) -> jnp.ndarray:
        """Run one task, resuming from the deepest cached shared block."""
        return self._run_task_impl(task, x, stats, 1, batched=False)

    def run(
        self,
        x: jnp.ndarray,
        order: Sequence[int],
        gate: Optional[Callable[[int, Dict[int, jnp.ndarray]], bool]] = None,
    ) -> Tuple[Dict[int, jnp.ndarray], ExecutionStats]:
        """Execute all tasks in ``order`` on input ``x``.

        Args:
          x: the shared input sample/batch (all tasks consume the same
            domain ``X`` in the paper).
          order: task permutation from the ordering solver.
          gate: optional runtime gate implementing conditional constraints —
            ``gate(task, results_so_far) -> bool``; a gated-off task is
            skipped entirely.

        Returns:
          (per-task outputs, execution stats).
        """
        self.clear_activations()  # never resume from a previous input
        results: Dict[int, jnp.ndarray] = {}
        stats = ExecutionStats()
        for t in order:
            if gate is not None and not gate(t, results):
                stats.tasks_skipped += 1
                continue
            results[t] = self.run_task(t, x, stats)
        return results, stats

    # ---------------------------------------------------------------- batch
    def run_task_batch(
        self,
        task: int,
        xs: jnp.ndarray,
        stats: ExecutionStats,
        weight: Optional[int] = None,
    ) -> jnp.ndarray:
        """Run one task for a stacked request group ``xs``: ``(B, *sample)``.

        Blocks are vmapped over the leading request axis while the Python
        residency/activation cache logic is shared across the whole group:
        every block on the path is loaded (and its batched activation cached)
        **once per group**, so weight loads amortise over ``B`` requests —
        the batch dimension the roadmap calls the main serving lever.

        Counters keep the cost model's per-request ("logical") accounting:
        ``weight`` is the number of real requests this execution serves
        (defaults to ``B``; the engine passes the gate-fired count, the
        scheduler the unpadded count).  Flop/task counters scale by
        ``weight``; load counters stay physical (once per group) — that gap
        *is* the block-loads-saved of batching.
        """
        w = int(xs.shape[0]) if weight is None else int(weight)
        return self._run_task_impl(task, xs, stats, w, batched=True)

    def run_batch(
        self,
        xs: jnp.ndarray,
        order: Sequence[int],
        gate: Optional[Callable[[int, Dict[int, jnp.ndarray]], bool]] = None,
        valid: Optional[int] = None,
    ) -> Tuple[Dict[int, jnp.ndarray], ExecutionStats]:
        """Execute all tasks in ``order`` once for a stacked request group.

        Args:
          xs: ``(B, *sample_shape)`` stacked inputs, one row per request
            (rows ``valid:`` may be padding added by the scheduler).
          order: task permutation from the ordering solver.
          gate: optional group-wise gate, same signature as :meth:`run` but
            receiving *batched* results; a gated-off task is skipped for the
            whole group.  Per-request gating lives in the serving engine,
            which drives :meth:`run_task_batch` directly.
          valid: number of real (non-padding) leading rows used for logical
            per-request accounting; defaults to ``B``.

        Returns:
          (per-task batched outputs ``{task: (B, *out_shape)}``, stats).
          With a cold executor the stats equal
          ``GraphCostModel.predicted_stats(order, batch_size=valid)``
          exactly; warm (no ``reset`` since a previous group) they equal
          ``predicted_stats(order, batch_size=valid, resume=state)`` where
          ``state`` was :meth:`residency_state` before this call.
        """
        self.clear_activations()  # never resume from a previous input
        v = int(xs.shape[0]) if valid is None else int(valid)
        results: Dict[int, jnp.ndarray] = {}
        stats = ExecutionStats()
        for t in order:
            if gate is not None and not gate(t, results):
                stats.tasks_skipped += v
                continue
            results[t] = self.run_task_batch(t, xs, stats, weight=v)
        return results, stats


class VanillaExecutor:
    """Baseline: independently-trained networks run back to back.

    No block is ever considered resident across tasks and no activation is
    reused — every task pays its full load + execute cost (the paper's
    "Vanilla" baseline).
    """

    def __init__(self, program: MultitaskProgram, jit_blocks: bool = True):
        self.program = program
        self._inner = TaskGraphExecutor(program, jit_blocks)

    def run(
        self,
        x: jnp.ndarray,
        order: Optional[Sequence[int]] = None,
        gate: Optional[Callable[[int, Dict[int, jnp.ndarray]], bool]] = None,
    ) -> Tuple[Dict[int, jnp.ndarray], ExecutionStats]:
        order = list(order) if order is not None else list(
            range(self.program.graph.num_tasks)
        )
        results: Dict[int, jnp.ndarray] = {}
        stats = ExecutionStats()
        for t in order:
            if gate is not None and not gate(t, results):
                stats.tasks_skipped += 1
                continue
            self._inner.reset()  # forget residency + caches between tasks
            results[t] = self._inner.run_task(t, x, stats)
        return results, stats


def run_in_order(
    program: MultitaskProgram,
    x: jnp.ndarray,
    order: Sequence[int],
    gate: Optional[Callable[[int, Dict[int, jnp.ndarray]], bool]] = None,
) -> Tuple[Dict[int, jnp.ndarray], ExecutionStats]:
    """One-shot convenience wrapper around :class:`TaskGraphExecutor`."""
    return TaskGraphExecutor(program).run(x, order, gate)
