"""Block-cached task-graph executor (paper §2.3).

The runtime mirrors the paper's MCU design, one level up the memory
hierarchy:

* a *static buffer* holds exactly one common-architecture's worth of blocks
  (one resident block per depth).  Before executing task ``t``, each block on
  ``t``'s path is loaded into its depth slot **unless it is already
  resident** — the "skip loading blocks already in main memory" rule;
* one *activation buffer per depth* caches the output of the most recently
  executed block at that depth, so a task sharing a prefix with the
  previously-run task resumes from the deepest shared block — the "reuse
  intermediate results" rule;
* tasks with conditional prerequisites may be *skipped at runtime* based on
  a gate over previously produced results (paper §4.3's conditional
  constraints), which skips their entire non-shared suffix.

The executor is generic over block semantics: it takes callables, so the
same engine drives the CNN-scale paper benchmarks and the transformer-scale
serving path.

Dispatch strategy: by default each contiguous non-shared suffix
(resume-depth -> head) is compiled into a **single fused program** keyed by
``(task, resume_depth, batched, input shape)`` — one dispatch per task
instead of one per block.  When the suffix's blocks are homogeneous (same
apply function, same parameter shapes, shape-preserving) the fused program
stacks the suffix's parameters and drives them with ``lax.scan``; otherwise
the suffix is unrolled inside one jitted program.  ``fused=False`` keeps the
original per-block dispatch path as the reference implementation; both paths
produce identical counters and (allclose-)identical outputs, which the tests
assert.  The compile cache is bounded by the same fixed-shape discipline the
request-group scheduler enforces: tasks x (depth+1) resume points x the
scheduler's padded batch shapes.

Warm starts: :meth:`TaskGraphExecutor.residency_state` exposes the per-depth
resident blocks so callers (the serving engine, the cost model) can account
cross-group weight-load reuse; :meth:`clear_activations` is the warm-start
entry point — it invalidates input-dependent activation caches while keeping
the input-independent weight residency, so a new request group resumes with
the previous group's blocks still "in memory".

``ExecutionStats`` counters must match ``GraphCostModel.predicted_stats``
exactly (including warm starts via its ``resume`` argument); property tests
assert this for random graphs, orders, and multi-group plans.

Request *groups* execute through :meth:`TaskGraphExecutor.run_batch`: the
same residency/prefix-reuse logic, but every block is vmapped over a stacked
batch of requests so one weight load (and one fused dispatch) serves the
whole group.  The batched counters match
``GraphCostModel.predicted_stats(order, batch_size=B)``.
"""
from __future__ import annotations

import dataclasses
from typing import (
    Any, Callable, Dict, FrozenSet, List, Optional, Sequence, Tuple,
)

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.constraints import Constraints
from repro.core.task_graph import TaskGraph
from repro.core.types import (
    BlockCost, ExecutionStats, NodeId, TaskGateRecord,
)
from repro.sharding.policy import ShardingPolicy, TP_POLICY
from repro.sharding.utils import fit_spec

# What residency_state returns and what GraphCostModel.predicted_stats
# accepts as ``resume`` (the concrete tuple form of types.Residency).
ResidencyState = Tuple[Optional[NodeId], ...]

# block_fns[d](params, x) -> y  for depth-d blocks of the common architecture
BlockFn = Callable[[Any, jnp.ndarray], jnp.ndarray]
# head_fn(params, y) -> task output
HeadFn = Callable[[Any, jnp.ndarray], jnp.ndarray]


@dataclasses.dataclass
class MultitaskProgram:
    """A task graph bound to parameters and block semantics.

    Attributes:
      graph: the task graph.
      block_fns: per-depth apply function of the common architecture.
      node_params: parameters for every ``(depth, group)`` block node.
      head_fns / head_params: per-task classifier heads (the per-task leaf
        the paper attaches after the last shared block).
      block_costs: per-depth cost entries used for stats accounting.
    """

    graph: TaskGraph
    block_fns: Sequence[BlockFn]
    node_params: Dict[NodeId, Any]
    head_fns: Sequence[HeadFn]
    head_params: Sequence[Any]
    block_costs: Sequence[BlockCost]

    def __post_init__(self) -> None:
        for node in self.graph.nodes():
            if node not in self.node_params:
                raise ValueError(f"missing params for task-graph node {node}")


def _leaf_specs(params: Any) -> Tuple:
    """(treedef, leaf shapes/dtypes) fingerprint for stackability checks."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    return treedef, tuple((jnp.shape(l), jnp.result_type(l)) for l in leaves)


def _gate_bcast(fire: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Reshape a per-row ``(B,)`` fire mask to broadcast against ``y``
    (``(B, ...)``) inside ``jnp.where``; scalar masks broadcast as-is."""
    if jnp.ndim(fire) == 0:
        return fire
    return fire.reshape(fire.shape + (1,) * (jnp.ndim(y) - jnp.ndim(fire)))


@dataclasses.dataclass
class ActivationCheckpoint:
    """A mid-suffix activation snapshot at a block-depth boundary.

    ``value`` is the cached activation of ``node`` (the block at ``depth``
    on the interrupted task's path) and ``act_shape`` the input-shape guard
    it was produced under (``TaskGraphExecutor._act_shape``).  Restoring it
    (:meth:`TaskGraphExecutor.restore_activation`) makes the next matching
    task resume from ``depth + 1`` instead of 0 — the paper's "an inference
    interrupted at block k must not restart from block 0" property.
    """

    depth: int
    node: NodeId
    value: Any
    act_shape: Optional[Tuple[int, ...]] = None


class WeightStreamer:
    """Double-buffered asynchronous host->device weight stager.

    One staging slot per executor: :meth:`stage` issues non-blocking
    ``jax.device_put`` copies for the *next* plan's non-resident block
    params (JAX dispatch is asynchronous, so the transfers overlap with
    whatever fused suffix is still executing on the device), replacing any
    previous batch — stage(k+1) while executing k is the double buffer.

    Commit-on-use: a staged copy only becomes the executor's parameter for
    its node when the executor actually loads that node
    (:meth:`commit`, called from the load branch of ``_run_task_impl``).
    Until then nothing observable changes, so cancellation —
    :meth:`cancel` on a fresh stage, :meth:`invalidate` from
    ``TaskGraphExecutor.reset`` / ``set_residency`` — simply drops the
    staged copies and composes with the serving session's residency
    snapshot/rollback: a rolled-back group retries with an empty streamer
    and loads synchronously, keeping counters exact.

    Stall accounting is modelled, not measured: the caller stages the batch
    together with the cost model's residual
    (``GraphCostModel.prefetch_stall_seconds`` — load seconds minus the
    overlap window); :meth:`finish_group` returns that stall iff the group
    consumed any staged copy, and the engine adds it to the group's
    ``ExecutionStats.stream_stall_seconds``.

    The ``lax.scan`` fused path reads its stacked per-suffix parameter
    cache (``_stacked_suffix_params``) rather than per-node params, so
    committed copies are bypassed there — values are identical either way;
    only the unrolled/per-block paths physically consume the staged
    arrays.  Accounting is dispatch-mode independent regardless.
    """

    def __init__(self, executor: "TaskGraphExecutor"):
        self._executor = executor
        self._staged: Dict[NodeId, Any] = {}
        self._committed_since_stage = False
        #: Modelled stall (seconds) of the pending staged batch: the part of
        #: its load time that did not fit in the overlap window.
        self.pending_stall_seconds = 0.0
        # Lifetime telemetry (not part of ExecutionStats: these describe the
        # streamer mechanism, not the logical execution counters).
        self.prefetches = 0
        self.staged_bytes = 0.0
        self.committed_bytes = 0.0
        self.cancels = 0

    def staged_nodes(self) -> FrozenSet[NodeId]:
        """Nodes with a staged (uncommitted) copy in flight."""
        return frozenset(self._staged)

    def stage(
        self,
        loads: Sequence[Tuple[int, NodeId]],
        stall_seconds: float = 0.0,
    ) -> None:
        """Issue async copies for ``loads`` (``GraphCostModel.plan_loads``
        entries), replacing any previously staged batch."""
        self.cancel()
        ex = self._executor
        for depth, node in loads:
            params = ex.program.node_params[node]
            if ex.mesh is not None:
                copy = jax.tree_util.tree_map(ex._place_param_leaf, params)
            else:
                copy = jax.tree_util.tree_map(jax.device_put, params)
            self._staged[node] = copy
            self.staged_bytes += ex.program.block_costs[depth].weight_bytes
        if loads:
            self.prefetches += 1
            self.pending_stall_seconds = float(stall_seconds)

    def commit(self, node: NodeId) -> bool:
        """Adopt ``node``'s staged copy as its parameters, if one exists.

        Called exactly where the executor accounts a weight load; ``True``
        means the load's bytes arrived via the prefetch stream (the caller
        counts them in ``ExecutionStats.prefetched_bytes``).
        """
        copy = self._staged.pop(node, None)
        if copy is None:
            return False
        ex = self._executor
        if ex.mesh is not None:
            ex._placed_node[node] = copy
        else:
            ex._streamed_node[node] = copy
        self._committed_since_stage = True
        self.committed_bytes += ex.program.block_costs[node[0]].weight_bytes
        return True

    def finish_group(self) -> float:
        """Close out the staged batch after its group ran.

        Returns the batch's modelled stall when the group committed any of
        it (the stream was on this group's critical path), else ``0.0``;
        uncommitted leftovers (e.g. gated-off tasks) are dropped — the next
        prefetch re-plans from actual residency.
        """
        stall = (
            self.pending_stall_seconds if self._committed_since_stage else 0.0
        )
        self._staged.clear()
        self.pending_stall_seconds = 0.0
        self._committed_since_stage = False
        return stall

    def cancel(self) -> None:
        """Drop the staged (uncommitted) batch and its pending stall."""
        if self._staged or self.pending_stall_seconds:
            self.cancels += 1
        self._staged.clear()
        self.pending_stall_seconds = 0.0
        self._committed_since_stage = False

    def invalidate(self) -> None:
        """Cancel staging *and* drop committed single-device copies.

        The residency boundary hook (``reset`` / ``set_residency``): after
        a rollback or cold reset no streamed state — staged or already
        committed — may outlive the residency it was planned against.
        """
        self.cancel()
        self._executor._streamed_node.clear()


class TaskGraphExecutor:
    """Stateful executor with block residency + activation caching.

    Args:
      program: the bound multitask program.
      jit_blocks: jit-compile the dispatched programs (fused suffixes, or the
        per-block reference path).
      fused: execute each non-shared suffix as one fused program (default);
        ``False`` selects the per-block reference dispatch path.
      mesh: optional ``jax.sharding.Mesh`` for sharded execution: the batch
        dimension shards over the policy's batch axes, parameters over the
        policy's ``model``/``fsdp`` axes (``ShardingPolicy.param_spec``),
        and activations are constrained to the batch layout inside every
        fused program — so the compiled suffix is identical to what the
        collective calibration lowers.  Requires the fused jitted path.
      sharding: logical->physical axis policy; defaults to ``TP_POLICY``
        when a mesh is given.
      gater: optional :class:`~repro.adaptive.gating.BlockGater` making
        execution input-conditional: shape-preserving blocks of every
        dispatched suffix run only for the batch rows whose confidence is
        still below the gater's threshold, skipped rows pass their
        activation through unchanged, and the realized per-(block, row)
        fire counts land in ``ExecutionStats`` (``block_rows_fired`` /
        ``flops_gated``) and :attr:`last_gate_record`.  Gating is masked
        *inside* the compiled programs (``jnp.where`` on the scan carry),
        so jit keys stay ``(task, resume, shape)`` — thresholds enter as a
        runtime array and never retrace.
    """

    def __init__(
        self,
        program: MultitaskProgram,
        jit_blocks: bool = True,
        fused: bool = True,
        mesh: Optional[Any] = None,
        sharding: Optional[ShardingPolicy] = None,
        gater: Optional[Any] = None,
    ):
        self.program = program
        self._jit = jit_blocks
        self._fused = fused
        self.gater = gater
        if mesh is not None and not (jit_blocks and fused):
            raise ValueError(
                "mesh-sharded execution requires the fused jitted dispatch "
                "path (jit_blocks=True, fused=True)"
            )
        self.mesh = mesh
        self.sharding: Optional[ShardingPolicy] = (
            sharding if sharding is not None
            else (TP_POLICY if mesh is not None else None)
        )
        self._compiled: Dict[int, Callable] = {}
        self._compiled_heads: Dict[int, Callable] = {}
        self._compiled_batch: Dict[int, Callable] = {}
        self._compiled_heads_batch: Dict[int, Callable] = {}
        # (task, resume, batched, x_shape, x_dtype) -> (callable, mode); mode
        # is "scan" (stacked params + lax.scan) or "unrolled".
        self._compiled_fused: Dict[Tuple, Tuple[Callable, str]] = {}
        # (task, start, stop, batched, x_shape, x_dtype) -> (callable, mode):
        # headless segment programs for checkpointed (intermittent) suffixes.
        self._compiled_segment: Dict[Tuple, Tuple[Callable, str]] = {}
        # (task, resume) -> stacked suffix params for the scan mode.
        self._stacked_params: Dict[Tuple[int, int], Any] = {}
        # (task, start, stop) -> stacked segment params for the scan mode.
        self._stacked_seg_params: Dict[Tuple[int, int, int], Any] = {}
        # Mesh-placed parameter copies (input-independent; survive reset).
        self._placed_node: Dict[NodeId, Any] = {}
        self._placed_head: Dict[int, Any] = {}
        # Streamed-and-committed single-device parameter copies (the mesh
        # path commits into _placed_node instead); value-identical to
        # program.node_params, dropped at every residency boundary.
        self._streamed_node: Dict[NodeId, Any] = {}
        # Double-buffered host->device weight prefetcher (serving engines
        # drive it when EnginePolicy.streaming is on; idle otherwise).
        self.streamer = WeightStreamer(self)
        # Calibration caches: suffix-input avals, lowered HLO text, and the
        # per-kind collective bytes the cost model adds per dispatch.
        self._suffix_sds: Dict[Tuple, jax.ShapeDtypeStruct] = {}
        self._suffix_hlo: Dict[Tuple, str] = {}
        self._coll_bytes: Dict[Tuple, Dict[str, float]] = {}
        # Physical program dispatches (jitted-call invocations).  Cumulative;
        # not part of ExecutionStats (those are cost-model-predictable logical
        # counters — dispatches depend on the fused/per-block mode).
        self.dispatch_count = 0
        # Adaptive-gating readback: per-dispatch realized fire masks of the
        # current task (``(start_depth, bool array)`` fragments, one per
        # dispatched segment), the finished task's TaskGateRecord, and the
        # per-task trace of the last run/run_batch call.
        self._fired_frags: List[Tuple[int, Any]] = []
        self.last_gate_record: Optional[TaskGateRecord] = None
        self.last_trace: List[TaskGateRecord] = []
        self.reset()

    def _gate_key(self) -> Optional[Tuple]:
        """Compile-cache discriminator for the active gater.

        Joins every program/calibration cache key so toggling or swapping
        the gater (different mode or confidence fn) never hits a program
        traced for other gate semantics.  Threshold changes do NOT change
        the key — thresholds are runtime inputs.
        """
        if self.gater is None:
            return None
        return (self.gater.mode, self.gater.confidence_fn)

    @property
    def fused(self) -> bool:
        """Whether suffixes dispatch as single fused programs (vs. the
        per-block reference path).  Settable at any point between tasks —
        both paths produce identical counters and (allclose-)identical
        outputs, so flipping it never changes accounting or results; the
        serving session's degradation ladder uses this to re-run a failed
        fused dispatch through the reference path.  Mesh-sharded executors
        require the fused path and reject ``False``.
        """
        return self._fused

    @fused.setter
    def fused(self, value: bool) -> None:
        if not value and self.mesh is not None:
            raise ValueError(
                "mesh-sharded execution requires the fused dispatch path; "
                "cannot set fused=False on a mesh executor"
            )
        self._fused = bool(value)

    # ---------------------------------------------------------------- state
    def reset(self) -> None:
        """Cold state: nothing resident, nothing cached, nothing streamed."""
        depth = self.program.graph.depth
        self._resident: List[Optional[NodeId]] = [None] * depth
        self.streamer.invalidate()
        self.clear_activations()

    def clear_activations(self) -> None:
        """Drop cached activations but keep weight residency (warm start).

        Weights are input-independent, activations are not: the whole-order
        entry points (:meth:`run` / :meth:`run_batch`) call this on entry so
        a new input can never resume from a previous input's activations,
        while the resident blocks remain loaded.  This is the warm-start
        boundary the serving engine uses between request groups.  Callers
        driving :meth:`run_task` / :meth:`run_task_batch` directly own this
        contract themselves.
        """
        depth = self.program.graph.depth
        self._activations: List[Optional[jnp.ndarray]] = [None] * depth
        self._act_owner: List[Optional[NodeId]] = [None] * depth
        self._act_shape: Optional[Tuple[int, ...]] = None

    def residency_state(self) -> ResidencyState:
        """Per-depth resident blocks, for warm-start cost accounting.

        Feed this to ``GraphCostModel.predicted_stats(..., resume=state)``
        (or ``predicted_group_stats``) to predict exactly what a warm
        continuation will load versus skip.
        """
        return tuple(self._resident)

    def set_residency(self, state: Sequence[Optional[NodeId]]) -> None:
        """Restore a residency snapshot (rollback / replay helper).

        Only weight residency is restored; activations are always cleared —
        they belong to a specific input, which a snapshot does not carry.
        Any in-flight prefetch is cancelled and committed streamed copies
        dropped (:meth:`WeightStreamer.invalidate`): a snapshot restore is
        the crash-recovery rollback boundary, after which no streamed state
        planned against the pre-rollback residency may survive — the next
        attempt loads synchronously and stays counter-exact.
        """
        depth = self.program.graph.depth
        if len(state) != depth:
            raise ValueError(
                f"residency state has {len(state)} slots, expected {depth}"
            )
        self._resident = list(state)
        self.streamer.invalidate()
        self.clear_activations()

    def activation_checkpoint(
        self, task: int
    ) -> Optional["ActivationCheckpoint"]:
        """Snapshot the deepest cached activation along ``task``'s path.

        This is what the serving journal persists at a segmented suffix's
        commit points: one ``(depth, node, value)`` triple is enough to
        resume the interrupted suffix, because the task graph is a tree —
        the node identity pins the whole prefix chain that produced the
        value.  Returns ``None`` when nothing on the path is cached.
        """
        path = self.program.graph.path(task)
        best: Optional[int] = None
        for d, node in enumerate(path):
            if self._act_owner[d] == node and self._activations[d] is not None:
                best = d
        if best is None:
            return None
        return ActivationCheckpoint(
            depth=best,
            node=path[best],
            value=self._activations[best],
            act_shape=self._act_shape,
        )

    def restore_activation(self, ckpt: "ActivationCheckpoint") -> None:
        """Re-seed the activation cache from a journaled crash checkpoint.

        All other activation slots are cleared (they did not survive the
        power failure); the next task sharing the checkpoint's node resumes
        from ``ckpt.depth + 1`` instead of 0.  Call *after*
        :meth:`set_residency` — restoring residency clears activations.
        """
        self.clear_activations()
        self._activations[ckpt.depth] = jnp.asarray(ckpt.value)
        self._act_owner[ckpt.depth] = ckpt.node
        self._act_shape = (
            tuple(ckpt.act_shape) if ckpt.act_shape is not None else None
        )

    def _guard_act_shape(self, shape: Tuple[int, ...]) -> None:
        """Invalidate cached activations produced for a different input shape
        (e.g. switching between the single-request and batched paths)."""
        if self._act_shape is not None and self._act_shape != shape:
            self.clear_activations()
        self._act_shape = shape

    # ------------------------------------------------- per-block (reference)
    def _block_fn(self, depth: int) -> Callable:
        if depth not in self._compiled:
            fn = self.program.block_fns[depth]
            self._compiled[depth] = jax.jit(fn) if self._jit else fn
        return self._compiled[depth]

    def _head_fn(self, task: int) -> Callable:
        if task not in self._compiled_heads:
            fn = self.program.head_fns[task]
            self._compiled_heads[task] = jax.jit(fn) if self._jit else fn
        return self._compiled_heads[task]

    def _block_fn_batch(self, depth: int) -> Callable:
        # vmap over the stacked request axis; params are shared across the
        # batch.  jit's shape-keyed cache yields one compile per
        # (depth, batch-shape) — exactly the recompilation budget the
        # request-group scheduler's padded shapes bound.
        if depth not in self._compiled_batch:
            fn = jax.vmap(self.program.block_fns[depth], in_axes=(None, 0))
            self._compiled_batch[depth] = jax.jit(fn) if self._jit else fn
        return self._compiled_batch[depth]

    def _head_fn_batch(self, task: int) -> Callable:
        if task not in self._compiled_heads_batch:
            fn = jax.vmap(self.program.head_fns[task], in_axes=(None, 0))
            self._compiled_heads_batch[task] = jax.jit(fn) if self._jit else fn
        return self._compiled_heads_batch[task]

    # ------------------------------------------------------ mesh placement
    def _place_param_leaf(self, leaf: Any, stacked: bool = False) -> Any:
        """``device_put`` one parameter leaf to its policy layout."""
        shape = tuple(jnp.shape(leaf))
        spec = self.sharding.param_spec(shape[1:] if stacked else shape)
        if stacked:
            spec = P(None, *spec)  # the scan's layer axis never shards
        spec = fit_spec(shape, spec, self.mesh)
        return jax.device_put(leaf, NamedSharding(self.mesh, spec))

    def _node_param(self, node: NodeId) -> Any:
        if self.mesh is None:
            streamed = self._streamed_node.get(node)
            if streamed is not None:
                return streamed
            return self.program.node_params[node]
        if node not in self._placed_node:
            self._placed_node[node] = jax.tree_util.tree_map(
                self._place_param_leaf, self.program.node_params[node]
            )
        return self._placed_node[node]

    def _head_param(self, task: int) -> Any:
        if self.mesh is None:
            return self.program.head_params[task]
        if task not in self._placed_head:
            self._placed_head[task] = jax.tree_util.tree_map(
                self._place_param_leaf, self.program.head_params[task]
            )
        return self._placed_head[task]

    def _batch_sharding(self, shape: Tuple[int, ...], batched: bool):
        """The NamedSharding of a batch-leading tensor (replicated when the
        tensor carries no batch axis, i.e. the single-request path)."""
        spec = P(self.sharding.physical("batch")) if batched else P()
        return NamedSharding(self.mesh, fit_spec(shape, spec, self.mesh))

    def _act_constrainer(self, batched: bool) -> Optional[Callable]:
        """Constraint pinning activations to the batch layout inside fused
        programs, so the executed program equals the calibrated one and
        cached activations never reshard on re-entry."""
        if self.mesh is None or not batched:
            return None

        def constrain(y: jnp.ndarray) -> jnp.ndarray:
            return jax.lax.with_sharding_constraint(
                y, self._batch_sharding(tuple(y.shape), batched=True)
            )

        return constrain

    # -------------------------------------------------------- fused suffix
    def _suffix_params(self, task: int, resume: int) -> Tuple[Any, ...]:
        path = self.program.graph.path(task)
        return tuple(
            self._node_param(path[d])
            for d in range(resume, self.program.graph.depth)
        )

    def _stacked_suffix_params(self, task: int, resume: int) -> Any:
        key = (task, resume)
        if key not in self._stacked_params:
            path = self.program.graph.path(task)
            params = tuple(
                self.program.node_params[path[d]]
                for d in range(resume, self.program.graph.depth)
            )
            stacked = jax.tree_util.tree_map(
                lambda *ls: jnp.stack(ls), *params
            )
            if self.mesh is not None:
                stacked = jax.tree_util.tree_map(
                    lambda l: self._place_param_leaf(l, stacked=True), stacked
                )
            self._stacked_params[key] = stacked
        return self._stacked_params[key]

    def _fused_fn(
        self,
        task: int,
        resume: int,
        batched: bool,
        shape: Tuple[int, ...],
        dtype: Any,
    ) -> Tuple[Callable, str]:
        """Build (or fetch) the fused suffix program for one resume point.

        The program runs blocks ``resume .. depth-1`` plus the task head in a
        single dispatch and returns ``(per-depth activations, head output)``
        — the intermediate activations feed the Python-level cache so later
        tasks can still resume mid-path.  Mode "scan" stacks the suffix's
        (homogeneous, shape-preserving) params and iterates with
        ``lax.scan``; mode "unrolled" traces the heterogeneous suffix block
        by block inside one program.  ``shape``/``dtype`` describe the
        suffix's input ``h``; on a mesh every activation (and the head
        output) is additionally constrained to the batch layout.

        With a gater the program takes an extra per-depth threshold array
        (runtime float32, scanned alongside the params) and returns a third
        output: the ``(L, B)`` (or ``(L,)`` unbatched) boolean fire masks.
        A gated-off row's activation passes through unchanged
        (``jnp.where`` on the carry); blocks that are not shape-preserving
        cannot pass rows through and always fire.
        """
        shape = tuple(shape)
        dtype = jnp.dtype(dtype)
        key = (task, resume, batched, shape, dtype, self._gate_key())
        if key in self._compiled_fused:
            return self._compiled_fused[key]

        graph = self.program.graph
        depth = graph.depth
        suffix = list(range(resume, depth))
        base_fns = [self.program.block_fns[d] for d in suffix]
        head = self.program.head_fns[task]
        if batched:
            fns = [jax.vmap(f, in_axes=(None, 0)) for f in base_fns]
            head = jax.vmap(head, in_axes=(None, 0))
        else:
            fns = list(base_fns)
        cst = self._act_constrainer(batched)

        mode = "unrolled"
        if len(suffix) >= 2 and all(f is base_fns[0] for f in base_fns):
            params = self._suffix_params(task, resume)
            specs = {_leaf_specs(p) for p in params}
            if len(specs) == 1:
                # Same fn + same param shapes; scan also needs the carry
                # shape to be invariant — verify without executing.  Only
                # abstract-evaluation incompatibilities mean "not
                # scannable": shape/dtype mismatches raise
                # TypeError/ValueError, and value-dependent block fns (legal
                # on the unjitted eager path) cannot trace abstractly at
                # all.  Anything else is a real bug in the block fn and must
                # surface, not silently demote the dispatch mode.
                try:
                    spec = jax.eval_shape(
                        fns[0],
                        params[0],
                        jax.ShapeDtypeStruct(shape, dtype),
                    )
                except (
                    TypeError, ValueError, jax.errors.ConcretizationTypeError
                ):
                    spec = None
                if (
                    spec is not None
                    and spec.shape == shape
                    and spec.dtype == dtype
                ):
                    mode = "scan"

        gater = self.gater
        if gater is not None:
            conf_fn = (
                jax.vmap(gater.confidence_fn) if batched
                else gater.confidence_fn
            )
            early = gater.mode == "early_exit"

        if mode == "scan":
            step_fn = fns[0]

            if gater is None:

                def fused(stacked, head_p, h):
                    def step(carry, p):
                        y = step_fn(p, carry)
                        if cst is not None:
                            y = cst(y)
                        return y, y

                    h_last, acts = jax.lax.scan(step, h, stacked)
                    out = head(head_p, h_last)
                    return acts, out if cst is None else cst(out)

            else:

                def fused(stacked, thrs, head_p, h):
                    alive0 = (
                        jnp.ones(h.shape[:1], bool) if batched
                        else jnp.asarray(True)
                    )

                    def step(carry, inp):
                        hh, alive = carry
                        p, thr = inp
                        fire = alive & (conf_fn(hh) < thr)
                        y = step_fn(p, hh)
                        y = jnp.where(_gate_bcast(fire, y), y, hh)
                        if cst is not None:
                            y = cst(y)
                        return (y, fire if early else alive), (y, fire)

                    (h_last, _), (acts, fired) = jax.lax.scan(
                        step, (h, alive0), (stacked, thrs)
                    )
                    out = head(head_p, h_last)
                    return acts, (out if cst is None else cst(out)), fired

        else:

            if gater is None:

                def fused(params_tuple, head_p, h):
                    acts = []
                    for f, p in zip(fns, params_tuple):
                        h = f(p, h)
                        if cst is not None:
                            h = cst(h)
                        acts.append(h)
                    out = head(head_p, h)
                    return tuple(acts), out if cst is None else cst(out)

            else:

                def fused(params_tuple, thrs, head_p, h):
                    alive = (
                        jnp.ones(h.shape[:1], bool) if batched
                        else jnp.asarray(True)
                    )
                    acts = []
                    fired = []
                    for i, (f, p) in enumerate(zip(fns, params_tuple)):
                        y = f(p, h)
                        if y.shape == h.shape and y.dtype == h.dtype:
                            fire = alive & (conf_fn(h) < thrs[i])
                            y = jnp.where(_gate_bcast(fire, y), y, h)
                            if early:
                                alive = fire
                        else:
                            # Shape-changing block: passthrough is
                            # impossible, so every row computes it.
                            fire = jnp.ones_like(alive)
                        if cst is not None:
                            y = cst(y)
                        acts.append(y)
                        fired.append(fire)
                        h = y
                    out = head(head_p, h)
                    stacked_fired = (
                        jnp.stack(fired) if fired
                        else jnp.zeros(
                            (0,) + (h.shape[:1] if batched else ()), bool
                        )
                    )
                    return (
                        tuple(acts),
                        out if cst is None else cst(out),
                        stacked_fired,
                    )

        compiled = jax.jit(fused) if self._jit else fused
        self._compiled_fused[key] = (compiled, mode)
        return compiled, mode

    def _suffix_thresholds(self, resume: int, stop: int) -> jnp.ndarray:
        """The gater's per-depth thresholds for blocks ``resume .. stop-1``
        as the runtime float32 array the compiled programs consume."""
        return jnp.asarray(
            self.gater.suffix_thresholds(resume, stop), jnp.float32
        )

    def _run_suffix_fused(
        self, task: int, resume: int, h: jnp.ndarray, batched: bool
    ) -> jnp.ndarray:
        """One dispatch for the whole (suffix + head) of ``task``."""
        graph = self.program.graph
        fn, mode = self._fused_fn(
            task, resume, batched, tuple(h.shape), jnp.result_type(h)
        )
        if mode == "scan":
            params = self._stacked_suffix_params(task, resume)
        else:
            params = self._suffix_params(task, resume)
        if self.gater is not None:
            acts, out, fired = fn(
                params,
                self._suffix_thresholds(resume, graph.depth),
                self._head_param(task),
                h,
            )
            self._fired_frags.append((resume, fired))
        else:
            acts, out = fn(params, self._head_param(task), h)
        if mode == "scan":
            acts = [acts[i] for i in range(graph.depth - resume)]
        self.dispatch_count += 1
        path = graph.path(task)
        for a, d in zip(acts, range(resume, graph.depth)):
            self._activations[d] = a
            self._act_owner[d] = path[d]
        return out

    # ----------------------------------------------- segmented (checkpoint)
    def _segment_params(self, task: int, start: int, stop: int) -> Tuple[Any, ...]:
        path = self.program.graph.path(task)
        return tuple(self._node_param(path[d]) for d in range(start, stop))

    def _stacked_segment_params(self, task: int, start: int, stop: int) -> Any:
        key = (task, start, stop)
        if key not in self._stacked_seg_params:
            path = self.program.graph.path(task)
            params = tuple(
                self.program.node_params[path[d]] for d in range(start, stop)
            )
            stacked = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *params)
            if self.mesh is not None:
                stacked = jax.tree_util.tree_map(
                    lambda l: self._place_param_leaf(l, stacked=True), stacked
                )
            self._stacked_seg_params[key] = stacked
        return self._stacked_seg_params[key]

    def _segment_fn(
        self,
        task: int,
        start: int,
        stop: int,
        batched: bool,
        shape: Tuple[int, ...],
        dtype: Any,
    ) -> Tuple[Callable, str]:
        """Build (or fetch) a *headless* fused program for blocks
        ``start .. stop-1`` of ``task``'s path.

        The segmented variant of :meth:`_fused_fn`: a checkpointed suffix is
        cut at its commit depths, each cut dispatching one of these segment
        programs so the Python-level journal hook can run — and a power
        failure can strike — at the block-depth boundary between them.  Same
        mode selection as the full-suffix program: ``lax.scan`` over stacked
        homogeneous shape-preserving blocks, else unrolled in one program.
        Returns the per-depth activations only (the final segment of a
        checkpointed suffix still runs through :meth:`_fused_fn`, which owns
        the head).

        With a gater the segment, like the full-suffix program, takes the
        per-depth threshold array and returns ``(acts, fired)``.  Each
        segment re-derives its alive mask from scratch (``alive = ones``):
        for shape-preserving passthrough gating a skipped row's activation
        — hence its confidence, hence its gate decision — is unchanged at
        the boundary, so the re-derived mask equals the mask an uncut
        suffix would have carried.  That is also why crash recovery replays
        identical gate decisions deterministically.
        """
        shape = tuple(shape)
        dtype = jnp.dtype(dtype)
        key = (task, start, stop, batched, shape, dtype, self._gate_key())
        if key in self._compiled_segment:
            return self._compiled_segment[key]

        segment = list(range(start, stop))
        base_fns = [self.program.block_fns[d] for d in segment]
        if batched:
            fns = [jax.vmap(f, in_axes=(None, 0)) for f in base_fns]
        else:
            fns = list(base_fns)
        cst = self._act_constrainer(batched)

        mode = "unrolled"
        if len(segment) >= 2 and all(f is base_fns[0] for f in base_fns):
            params = self._segment_params(task, start, stop)
            specs = {_leaf_specs(p) for p in params}
            if len(specs) == 1:
                try:
                    spec = jax.eval_shape(
                        fns[0], params[0], jax.ShapeDtypeStruct(shape, dtype)
                    )
                except (
                    TypeError, ValueError, jax.errors.ConcretizationTypeError
                ):
                    spec = None
                if (
                    spec is not None
                    and spec.shape == shape
                    and spec.dtype == dtype
                ):
                    mode = "scan"

        gater = self.gater
        if gater is not None:
            conf_fn = (
                jax.vmap(gater.confidence_fn) if batched
                else gater.confidence_fn
            )
            early = gater.mode == "early_exit"

        if mode == "scan":
            step_fn = fns[0]

            if gater is None:

                def seg(stacked, h):
                    def step(carry, p):
                        y = step_fn(p, carry)
                        if cst is not None:
                            y = cst(y)
                        return y, y

                    _h_last, acts = jax.lax.scan(step, h, stacked)
                    return acts

            else:

                def seg(stacked, thrs, h):
                    alive0 = (
                        jnp.ones(h.shape[:1], bool) if batched
                        else jnp.asarray(True)
                    )

                    def step(carry, inp):
                        hh, alive = carry
                        p, thr = inp
                        fire = alive & (conf_fn(hh) < thr)
                        y = step_fn(p, hh)
                        y = jnp.where(_gate_bcast(fire, y), y, hh)
                        if cst is not None:
                            y = cst(y)
                        return (y, fire if early else alive), (y, fire)

                    _last, (acts, fired) = jax.lax.scan(
                        step, (h, alive0), (stacked, thrs)
                    )
                    return acts, fired

        else:

            if gater is None:

                def seg(params_tuple, h):
                    acts = []
                    for f, p in zip(fns, params_tuple):
                        h = f(p, h)
                        if cst is not None:
                            h = cst(h)
                        acts.append(h)
                    return tuple(acts)

            else:

                def seg(params_tuple, thrs, h):
                    alive = (
                        jnp.ones(h.shape[:1], bool) if batched
                        else jnp.asarray(True)
                    )
                    acts = []
                    fired = []
                    for i, (f, p) in enumerate(zip(fns, params_tuple)):
                        y = f(p, h)
                        if y.shape == h.shape and y.dtype == h.dtype:
                            fire = alive & (conf_fn(h) < thrs[i])
                            y = jnp.where(_gate_bcast(fire, y), y, h)
                            if early:
                                alive = fire
                        else:
                            fire = jnp.ones_like(alive)
                        if cst is not None:
                            y = cst(y)
                        acts.append(y)
                        fired.append(fire)
                        h = y
                    stacked_fired = (
                        jnp.stack(fired) if fired
                        else jnp.zeros(
                            (0,) + (h.shape[:1] if batched else ()), bool
                        )
                    )
                    return tuple(acts), stacked_fired

        compiled = jax.jit(seg) if self._jit else seg
        self._compiled_segment[key] = (compiled, mode)
        return compiled, mode

    def _run_suffix_segmented(
        self,
        task: int,
        resume: int,
        h: jnp.ndarray,
        batched: bool,
        checkpoint_depths: Sequence[int],
        checkpoint_hook: Optional[Callable[[int], None]],
    ) -> jnp.ndarray:
        """Checkpointed suffix: commit points at block-depth boundaries.

        Each checkpoint depth ``d`` in ``[resume, depth-1)`` ends a segment
        dispatch after block ``d``; the hook then fires with the activation
        for depth ``d`` freshly cached — the journal write, and the point a
        :class:`~repro.serving.reliability.PowerFailureInjector` kills the
        session.  The remainder past the last cut runs through the ordinary
        fused program (:meth:`_run_suffix_fused`), so an uncut suffix is
        byte-identical to the non-intermittent path.  Counters never change
        — segmentation only adds dispatches (and the hook's own checkpoint
        accounting).
        """
        graph = self.program.graph
        path = graph.path(task)
        cur = resume
        for d in sorted(set(checkpoint_depths)):
            if d < cur or d >= graph.depth - 1:
                continue  # already covered, or past the last cut point
            fn, mode = self._segment_fn(
                task, cur, d + 1, batched, tuple(h.shape), jnp.result_type(h)
            )
            if mode == "scan":
                params = self._stacked_segment_params(task, cur, d + 1)
            else:
                params = self._segment_params(task, cur, d + 1)
            if self.gater is not None:
                acts, fired = fn(
                    params, self._suffix_thresholds(cur, d + 1), h
                )
                self._fired_frags.append((cur, fired))
            else:
                acts = fn(params, h)
            if mode == "scan":
                acts = [acts[i] for i in range(d + 1 - cur)]
            self.dispatch_count += 1
            for a, dd in zip(acts, range(cur, d + 1)):
                self._activations[dd] = a
                self._act_owner[dd] = path[dd]
            h = self._activations[d]
            if checkpoint_hook is not None:
                checkpoint_hook(d)
            cur = d + 1
        return self._run_suffix_fused(task, cur, h, batched)

    def _run_suffix_blocks(
        self,
        task: int,
        resume: int,
        h: jnp.ndarray,
        batched: bool,
        checkpoint_depths: Sequence[int] = (),
        checkpoint_hook: Optional[Callable[[int], None]] = None,
    ) -> jnp.ndarray:
        """Reference path: one dispatch per block plus one for the head.

        Checkpoint hooks fire at the same block-depth boundaries as the
        segmented fused path, so the degradation ladder's unfused rung keeps
        journaling (and checkpoint accounting) identical.
        """
        graph = self.program.graph
        path = graph.path(task)
        cuts = {
            d for d in checkpoint_depths if resume <= d < graph.depth - 1
        }
        block_fn = self._block_fn_batch if batched else self._block_fn
        head_fn = self._head_fn_batch if batched else self._head_fn
        gater = self.gater
        if gater is not None:
            conf_fn = (
                jax.vmap(gater.confidence_fn) if batched
                else gater.confidence_fn
            )
            thrs = gater.suffix_thresholds(resume, graph.depth)
            alive = (
                jnp.ones(h.shape[:1], bool) if batched else jnp.asarray(True)
            )
            fired: List[jnp.ndarray] = []
        for d in range(resume, graph.depth):
            node = path[d]
            y = block_fn(d)(self._node_param(node), h)
            if gater is not None:
                if y.shape == h.shape and y.dtype == h.dtype:
                    fire = alive & (conf_fn(h) < thrs[d - resume])
                    y = jnp.where(_gate_bcast(fire, y), y, h)
                    if gater.mode == "early_exit":
                        alive = fire
                else:
                    fire = jnp.ones_like(alive)
                fired.append(fire)
            h = y
            self.dispatch_count += 1
            self._activations[d] = h
            self._act_owner[d] = node
            if d in cuts and checkpoint_hook is not None:
                checkpoint_hook(d)
        if gater is not None:
            stacked_fired = (
                jnp.stack(fired) if fired
                else jnp.zeros((0,) + (h.shape[:1] if batched else ()), bool)
            )
            self._fired_frags.append((resume, stacked_fired))
        out = head_fn(task)(self._head_param(task), h)
        self.dispatch_count += 1
        return out

    # ------------------------------------------------------------------ run
    def _run_task_impl(
        self,
        task: int,
        x: jnp.ndarray,
        stats: ExecutionStats,
        weight: int,
        batched: bool,
        checkpoint_depths: Sequence[int] = (),
        checkpoint_hook: Optional[Callable[[int], None]] = None,
        row_mask: Optional[Any] = None,
    ) -> jnp.ndarray:
        """Shared body of the single-request and batched task execution.

        The residency/resume/accounting invariants live ONLY here so the two
        paths cannot drift: ``weight`` is the logical request multiplicity
        scaling the per-request counters (flops/tasks), while load counters
        stay physical (once per invocation).  Accounting is dispatch-mode
        independent: the fused and per-block paths produce identical stats.

        With a gater the per-block flop accounting is deferred until after
        the dispatch: the realized fire masks are read back and each
        executed block's flops split into ``flops_executed`` (rows that
        fired) and ``flops_gated`` (rows whose gate skipped it).  Loads stay
        physical and ungated — the scan program consumes every stacked
        block's params regardless of who fires, so gating saves modelled
        FLOPs, not weight traffic.  ``row_mask`` (batched only) marks which
        rows of ``x`` are logically live — exactly ``weight`` of them; rows
        outside the mask (padding, or rows a legacy per-request gate turned
        off) execute physically but never count.
        """
        graph = self.program.graph
        path = graph.path(task)
        self._guard_act_shape(tuple(x.shape))
        self._fired_frags = []

        # Deepest block of this task's path whose activation is cached.  The
        # task graph is a tree, so an owner match at depth ``d`` pins the
        # whole chain above it — contiguity below is not required, which is
        # what lets a single restored crash checkpoint
        # (:meth:`restore_activation`) seed a mid-path resume.
        resume = 0
        for d, node in enumerate(path):
            if self._act_owner[d] == node and self._activations[d] is not None:
                resume = d + 1

        gated = self.gater is not None
        executed_costs: List[BlockCost] = []
        for d in range(graph.depth):
            node = path[d]
            bc = self.program.block_costs[d]
            if d < resume:
                # Shared prefix: weights resident AND activation cached ->
                # skip both the load and the execute.
                stats.blocks_skipped += 1
                stats.weight_bytes_skipped += bc.weight_bytes
                stats.flops_skipped += weight * bc.flops
                continue
            if self._resident[d] != node:
                if self.streamer.commit(node):
                    # The bytes still count as loaded — they moved — but
                    # arrived over the prefetch stream, overlapped with the
                    # previous group's compute.
                    stats.prefetched_bytes += bc.weight_bytes
                stats.weight_bytes_loaded += bc.weight_bytes
                self._resident[d] = node
            else:
                # Still resident (warm start across groups, or an intra-order
                # revisit): the load is skipped but the block must execute —
                # its input activation belongs to the current input.
                stats.weight_bytes_skipped += bc.weight_bytes
            stats.blocks_executed += 1
            if gated:
                executed_costs.append(bc)
            else:
                stats.flops_executed += weight * bc.flops
        stats.tasks_run += weight

        h = self._activations[resume - 1] if resume > 0 else x
        if self.mesh is not None:
            # Commit the suffix input to the batch layout (a no-op for
            # cached activations, which the fused program already constrains)
            # and account this dispatch's calibrated collective traffic —
            # physical, once per dispatch, like the load counters.
            h = jax.device_put(
                h, self._batch_sharding(tuple(h.shape), batched)
            )
            stats.add_collectives(self.suffix_collective_bytes(
                task, resume, tuple(h.shape), jnp.result_type(h), batched
            ))
        if self._fused:
            if checkpoint_depths:
                out = self._run_suffix_segmented(
                    task, resume, h, batched,
                    checkpoint_depths, checkpoint_hook,
                )
            else:
                out = self._run_suffix_fused(task, resume, h, batched)
        else:
            out = self._run_suffix_blocks(
                task, resume, h, batched, checkpoint_depths, checkpoint_hook
            )

        if gated:
            fired_rows = self._collect_fired(weight, batched, row_mask)
            if len(fired_rows) != len(executed_costs):
                raise AssertionError(
                    f"gate readback covered {len(fired_rows)} blocks, "
                    f"expected {len(executed_costs)}"
                )
            for bc, f in zip(executed_costs, fired_rows):
                stats.flops_executed += f * bc.flops
                stats.flops_gated += (weight - f) * bc.flops
                stats.block_rows_fired += f
                stats.block_rows_gated += weight - f
            self.last_gate_record = TaskGateRecord(
                task=task, weight=weight, fired=tuple(fired_rows),
                resume=resume,
            )
        else:
            self.last_gate_record = TaskGateRecord(
                task=task, weight=weight, resume=resume
            )
        return out

    def _collect_fired(
        self, weight: int, batched: bool, row_mask: Optional[Any]
    ) -> List[int]:
        """Per executed block depth, how many live rows fired.

        Reads back the dispatches' boolean fire masks (a device sync — the
        price of realized-count accounting) and reduces them over the
        logically-live rows: ``row_mask`` when given, else the first
        ``weight`` rows (the scheduler pads at the tail), else the whole
        single request.
        """
        mask = None if row_mask is None else np.asarray(row_mask, bool)
        counts: List[int] = []
        for _start, frag in self._fired_frags:
            arr = np.asarray(frag)
            if arr.shape[0] == 0:
                continue
            if not batched:
                counts.extend(int(bool(v)) * weight for v in arr)
            elif mask is not None:
                counts.extend(
                    int(np.count_nonzero(row & mask)) for row in arr
                )
            else:
                counts.extend(
                    int(np.count_nonzero(row[:weight])) for row in arr
                )
        return counts

    def run_task(
        self, task: int, x: jnp.ndarray, stats: ExecutionStats
    ) -> jnp.ndarray:
        """Run one task, resuming from the deepest cached shared block."""
        return self._run_task_impl(task, x, stats, 1, batched=False)

    def run(
        self,
        x: jnp.ndarray,
        order: Sequence[int],
        gate: Optional[Callable[[int, Dict[int, jnp.ndarray]], bool]] = None,
    ) -> Tuple[Dict[int, jnp.ndarray], ExecutionStats]:
        """Execute all tasks in ``order`` on input ``x``.

        Args:
          x: the shared input sample/batch (all tasks consume the same
            domain ``X`` in the paper).
          order: task permutation from the ordering solver.
          gate: optional runtime gate implementing conditional constraints —
            ``gate(task, results_so_far) -> bool``; a gated-off task is
            skipped entirely.

        Returns:
          (per-task outputs, execution stats).
        """
        self.clear_activations()  # never resume from a previous input
        results: Dict[int, jnp.ndarray] = {}
        stats = ExecutionStats()
        self.last_trace = []
        for t in order:
            if gate is not None and not gate(t, results):
                stats.tasks_skipped += 1
                self.last_trace.append(TaskGateRecord(task=t, weight=0))
                continue
            results[t] = self.run_task(t, x, stats)
            self.last_trace.append(self.last_gate_record)
        return results, stats

    # ---------------------------------------------------------------- batch
    def run_task_batch(
        self,
        task: int,
        xs: jnp.ndarray,
        stats: ExecutionStats,
        weight: Optional[int] = None,
        checkpoint_depths: Sequence[int] = (),
        checkpoint_hook: Optional[Callable[[int], None]] = None,
        row_mask: Optional[Any] = None,
    ) -> jnp.ndarray:
        """Run one task for a stacked request group ``xs``: ``(B, *sample)``.

        Blocks are vmapped over the leading request axis while the Python
        residency/activation cache logic is shared across the whole group:
        every block on the path is loaded (and its batched activation cached)
        **once per group**, so weight loads amortise over ``B`` requests —
        the batch dimension the roadmap calls the main serving lever.

        Counters keep the cost model's per-request ("logical") accounting:
        ``weight`` is the number of real requests this execution serves
        (defaults to ``B``; the engine passes the gate-fired count, the
        scheduler the unpadded count).  Flop/task counters scale by
        ``weight``; load counters stay physical (once per group) — that gap
        *is* the block-loads-saved of batching.

        ``checkpoint_depths`` / ``checkpoint_hook`` select the segmented
        (intermittent) dispatch: the suffix is cut at those block-depth
        boundaries and the hook fires after each cut with the activation
        freshly cached — see :meth:`_run_suffix_segmented`.

        ``row_mask`` (optional ``(B,)`` bool) marks which rows are logically
        live for adaptive fire accounting — exactly ``weight`` of them; see
        :meth:`_run_task_impl`.
        """
        w = int(xs.shape[0]) if weight is None else int(weight)
        return self._run_task_impl(
            task, xs, stats, w, batched=True,
            checkpoint_depths=checkpoint_depths,
            checkpoint_hook=checkpoint_hook,
            row_mask=row_mask,
        )

    def run_batch(
        self,
        xs: jnp.ndarray,
        order: Sequence[int],
        gate: Optional[Callable[[int, Dict[int, jnp.ndarray]], bool]] = None,
        valid: Optional[int] = None,
    ) -> Tuple[Dict[int, jnp.ndarray], ExecutionStats]:
        """Execute all tasks in ``order`` once for a stacked request group.

        Args:
          xs: ``(B, *sample_shape)`` stacked inputs, one row per request
            (rows ``valid:`` may be padding added by the scheduler).
          order: task permutation from the ordering solver.
          gate: optional group-wise gate, same signature as :meth:`run` but
            receiving *batched* results; a gated-off task is skipped for the
            whole group.  Per-request gating lives in the serving engine,
            which drives :meth:`run_task_batch` directly.
          valid: number of real (non-padding) leading rows used for logical
            per-request accounting; defaults to ``B``.

        Returns:
          (per-task batched outputs ``{task: (B, *out_shape)}``, stats).
          With a cold executor the stats equal
          ``GraphCostModel.predicted_stats(order, batch_size=valid)``
          exactly; warm (no ``reset`` since a previous group) they equal
          ``predicted_stats(order, batch_size=valid, resume=state)`` where
          ``state`` was :meth:`residency_state` before this call.
        """
        self.clear_activations()  # never resume from a previous input
        v = int(xs.shape[0]) if valid is None else int(valid)
        results: Dict[int, jnp.ndarray] = {}
        stats = ExecutionStats()
        self.last_trace = []
        for t in order:
            if gate is not None and not gate(t, results):
                stats.tasks_skipped += v
                self.last_trace.append(TaskGateRecord(task=t, weight=0))
                continue
            results[t] = self.run_task_batch(t, xs, stats, weight=v)
            self.last_trace.append(self.last_gate_record)
        return results, stats

    # ------------------------------------------- collective calibration
    def _suffix_input_sds(
        self,
        task: int,
        resume: int,
        x_shape: Tuple[int, ...],
        dtype: Any,
        batched: bool,
    ) -> jax.ShapeDtypeStruct:
        """Aval of the fused suffix's input ``h`` given the group input.

        For ``resume > 0`` the suffix consumes the cached activation at
        depth ``resume - 1``; its shape is derived by abstractly evaluating
        blocks ``0 .. resume-1`` along the task's own path (a shared prefix
        runs the same depth fns, so the shapes match whichever task actually
        produced the cache).
        """
        key = (task, resume, tuple(x_shape), jnp.dtype(dtype), batched)
        if key not in self._suffix_sds:
            path = self.program.graph.path(task)
            sds = jax.ShapeDtypeStruct(tuple(x_shape), jnp.dtype(dtype))
            for d in range(resume):
                fn = self.program.block_fns[d]
                if batched:
                    fn = jax.vmap(fn, in_axes=(None, 0))
                sds = jax.eval_shape(fn, self.program.node_params[path[d]], sds)
            self._suffix_sds[key] = sds
        return self._suffix_sds[key]

    def _lowered_suffix_text(
        self,
        task: int,
        resume: int,
        shape: Tuple[int, ...],
        dtype: Any,
        batched: bool,
    ) -> str:
        """Post-optimization HLO of one fused suffix dispatch.

        Lowered from the same jitted program, the same placed parameters,
        and the same committed input layout execution uses, so the analyzed
        module is the program that runs.
        """
        if not (self._jit and self._fused):
            raise ValueError(
                "suffix HLO calibration requires the fused jitted dispatch "
                "path (jit_blocks=True, fused=True)"
            )
        shape, dtype = tuple(shape), jnp.dtype(dtype)
        key = (task, resume, batched, shape, dtype, self._gate_key())
        if key not in self._suffix_hlo:
            fn, mode = self._fused_fn(task, resume, batched, shape, dtype)
            params = (
                self._stacked_suffix_params(task, resume) if mode == "scan"
                else self._suffix_params(task, resume)
            )
            if self.mesh is not None:
                in_sds = jax.ShapeDtypeStruct(
                    shape, dtype,
                    sharding=self._batch_sharding(shape, batched),
                )
            else:
                in_sds = jax.ShapeDtypeStruct(shape, dtype)
            if self.gater is not None:
                thrs_sds = jax.ShapeDtypeStruct(
                    (self.program.graph.depth - resume,), jnp.float32
                )
                lowered = fn.lower(
                    params, thrs_sds, self._head_param(task), in_sds
                )
            else:
                lowered = fn.lower(params, self._head_param(task), in_sds)
            self._suffix_hlo[key] = lowered.compile().as_text()
        return self._suffix_hlo[key]

    def suffix_hlo(
        self, task: int, resume: int, xs: Any, batched: bool = True
    ) -> str:
        """HLO text of the dispatch running ``task`` from depth ``resume``
        for group input ``xs`` — the independent-measurement hook tests use
        to check predicted collective bytes against ``HloCostModel``."""
        sds = self._suffix_input_sds(
            task, resume, tuple(jnp.shape(xs)), jnp.result_type(xs), batched
        )
        return self._lowered_suffix_text(
            task, resume, sds.shape, sds.dtype, batched
        )

    def suffix_collective_bytes(
        self,
        task: int,
        resume: int,
        shape: Tuple[int, ...],
        dtype: Any,
        batched: bool = True,
    ) -> Dict[str, float]:
        """Calibrated per-kind collective bytes of one suffix dispatch.

        ``shape``/``dtype`` describe the suffix *input* (the activation at
        ``resume - 1``, or the group input when ``resume == 0``).  Cached per
        key, and the single source both the executor's counters and the cost
        model's predictions add from — which is what makes
        ``session.stats == session.predicted`` exact on a mesh.
        """
        shape, dtype = tuple(shape), jnp.dtype(dtype)
        key = (task, resume, batched, shape, dtype, self._gate_key())
        if key not in self._coll_bytes:
            from repro.launch.hlo_cost import collective_breakdown

            self._coll_bytes[key] = collective_breakdown(
                self._lowered_suffix_text(task, resume, shape, dtype, batched)
            )
        return self._coll_bytes[key]

    def collective_view(
        self, xs: Any, batched: bool = True
    ) -> Optional["CollectiveView"]:
        """A :class:`CollectiveView` bound to group input ``xs``, for
        ``GraphCostModel.predicted_stats(..., collectives=view)``; ``None``
        without a mesh (single-device programs have no collectives)."""
        if self.mesh is None:
            return None
        return CollectiveView(
            self, tuple(jnp.shape(xs)), jnp.result_type(xs), batched
        )


class CollectiveView:
    """Per-(task, resume) calibrated collective bytes for one batch shape.

    The ``CollectiveCosts`` implementation the cost model consumes: bound to
    a group's (padded) input aval, it resolves each ``(task, resume)`` to
    the suffix-input aval and returns the executor-cached HLO-calibrated
    breakdown — the exact dict execution adds.
    """

    def __init__(
        self,
        executor: TaskGraphExecutor,
        x_shape: Tuple[int, ...],
        dtype: Any,
        batched: bool = True,
    ):
        self._executor = executor
        self._x_shape = tuple(x_shape)
        self._dtype = jnp.dtype(dtype)
        self._batched = bool(batched)

    def breakdown(self, task: int, resume: int) -> Dict[str, float]:
        sds = self._executor._suffix_input_sds(
            task, resume, self._x_shape, self._dtype, self._batched
        )
        return self._executor.suffix_collective_bytes(
            task, resume, sds.shape, sds.dtype, self._batched
        )


class VanillaExecutor:
    """Baseline: independently-trained networks run back to back.

    No block is ever considered resident across tasks and no activation is
    reused — every task pays its full load + execute cost (the paper's
    "Vanilla" baseline).
    """

    def __init__(self, program: MultitaskProgram, jit_blocks: bool = True):
        self.program = program
        self._inner = TaskGraphExecutor(program, jit_blocks)

    def run(
        self,
        x: jnp.ndarray,
        order: Optional[Sequence[int]] = None,
        gate: Optional[Callable[[int, Dict[int, jnp.ndarray]], bool]] = None,
    ) -> Tuple[Dict[int, jnp.ndarray], ExecutionStats]:
        order = list(order) if order is not None else list(
            range(self.program.graph.num_tasks)
        )
        results: Dict[int, jnp.ndarray] = {}
        stats = ExecutionStats()
        for t in order:
            if gate is not None and not gate(t, results):
                stats.tasks_skipped += 1
                continue
            self._inner.reset()  # forget residency + caches between tasks
            results[t] = self._inner.run_task(t, x, stats)
        return results, stats


def run_in_order(
    program: MultitaskProgram,
    x: jnp.ndarray,
    order: Sequence[int],
    gate: Optional[Callable[[int, Dict[int, jnp.ndarray]], bool]] = None,
) -> Tuple[Dict[int, jnp.ndarray], ExecutionStats]:
    """One-shot convenience wrapper around :class:`TaskGraphExecutor`."""
    return TaskGraphExecutor(program).run(x, order, gate)
