"""Antler core: task affinity, task graphs, ordering, and the block-cached
multitask executor (the paper's primary contribution, in JAX)."""

from repro.core.affinity import (
    affinity_matrix,
    compute_affinity,
    pairwise_pearson_dissimilarity,
    profile_task,
    spearman,
)
from repro.core.baselines import (
    BaselineReport,
    antler_report,
    nws_baseline,
    nwv_baseline,
    vanilla_baseline,
    yono_baseline,
)
from repro.core.constraints import Constraints, no_constraints
from repro.core.cost_model import GraphCostModel, uniform_block_costs
from repro.core.executor import (
    MultitaskProgram,
    TaskGraphExecutor,
    VanillaExecutor,
    WeightStreamer,
    run_in_order,
)
from repro.core.genetic import GAConfig, genetic_order
from repro.core.profiler import profile_blocks, profile_program_blocks
from repro.core.ordering import (
    ILPFormulation,
    OrderingResult,
    branch_and_bound_order,
    brute_force_order,
    fitness,
    greedy_2opt_order,
    held_karp_order,
    optimal_order,
)
from repro.core.task_graph import (
    TaskGraph,
    enumerate_task_graphs,
    variety_score,
)
from repro.core.tradeoff import (
    GraphCandidate,
    TradeoffResult,
    select_task_graph,
    tradeoff_curve,
)
from repro.core.types import (
    MSP430,
    STM32H747,
    TPU_V5E,
    BlockCost,
    ExecutionStats,
    HardwareModel,
)

__all__ = [k for k in dir() if not k.startswith("_")]
