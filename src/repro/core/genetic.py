"""Genetic-algorithm task-ordering solver (paper Appendix 9.2).

Faithful to the paper's description:

* individuals are permutations ``pi = (pi_1 .. pi_n)``;
* fitness is Eq. 7 (Eq. 8 under conditional constraints) — lower is better;
* each round selects the best ``K`` pairs by fitness, picks a random
  crossover point ``k`` and swaps the first ``k`` elements of the pair to
  produce offspring, mutates offspring by swapping two random positions, and
  discards individuals that are not valid orderings (non-permutations or
  precedence violations);
* terminates when the best fitness stops improving.

The paper's prefix-swap crossover usually produces non-permutations (which
are then discarded), so convergence leans on mutation.  We additionally
provide an *order-crossover* (OX) repair mode — a standard TSP-GA operator —
as a beyond-paper improvement; benchmarks report both
(``crossover='paper'`` vs ``crossover='ox'``).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.constraints import Constraints, no_constraints
from repro.core.ordering import OrderingResult, fitness


@dataclasses.dataclass(frozen=True)
class GAConfig:
    population: int = 128
    elite_pairs: int = 32          # the paper's "best K pairs"
    mutation_rate: float = 0.9
    patience: int = 40             # rounds without improvement before stop
    max_rounds: int = 600
    crossover: str = "ox"          # "paper" (prefix swap) or "ox" (repairing)
    nn_seed: bool = True           # seed with nearest-neighbour tours
    reversal_mutation: bool = True # 2-opt-style segment reversal mutation
    local_search: bool = True      # memetic 2-opt polish of the GA best
    seed: int = 0


def _is_permutation(ind: np.ndarray, n: int) -> bool:
    return len(np.unique(ind)) == n


def _prefix_swap(a: np.ndarray, b: np.ndarray, k: int) -> Tuple[np.ndarray, np.ndarray]:
    """The paper's crossover: swap the first k elements of the pair."""
    ca, cb = a.copy(), b.copy()
    ca[:k], cb[:k] = b[:k].copy(), a[:k].copy()
    return ca, cb


def _order_crossover(a: np.ndarray, b: np.ndarray, k: int) -> Tuple[np.ndarray, np.ndarray]:
    """OX: child keeps a's prefix, fills the rest in b's relative order."""
    def ox(p: np.ndarray, q: np.ndarray) -> np.ndarray:
        head = p[:k]
        tail = [t for t in q if t not in set(head.tolist())]
        return np.concatenate([head, np.array(tail, dtype=p.dtype)])

    return ox(a, b), ox(b, a)


def _random_valid_order(
    rng: np.random.Generator, cons: Constraints, n: int
) -> np.ndarray:
    """Random topological order respecting precedence (seed individuals)."""
    preds = {j: set() for j in range(n)}
    for (i, j) in cons.precedence:
        preds[j].add(i)
    placed: List[int] = []
    remaining = set(range(n))
    while remaining:
        ready = [t for t in remaining if preds[t] <= set(placed)]
        t = int(rng.choice(ready))
        placed.append(t)
        remaining.remove(t)
    return np.array(placed, dtype=np.int64)


def _nearest_neighbour_order(
    cost: np.ndarray, start: int, cons: Constraints, n: int
) -> np.ndarray:
    """Greedy cheapest-next tour respecting precedence (seed individuals)."""
    preds = {j: set() for j in range(n)}
    for (i, j) in cons.precedence:
        preds[j].add(i)
    placed: List[int] = []
    remaining = set(range(n))

    def ready():
        return [t for t in remaining if preds[t] <= set(placed)]

    r = ready()
    cur = start if start in r else r[0]
    placed.append(cur)
    remaining.remove(cur)
    while remaining:
        r = ready()
        cur = min(r, key=lambda t: cost[cur, t])
        placed.append(cur)
        remaining.remove(cur)
    return np.array(placed, dtype=np.int64)


def genetic_order(
    cost: np.ndarray,
    constraints: Optional[Constraints] = None,
    config: Optional[GAConfig] = None,
) -> OrderingResult:
    """Solve the ordering problem with the Appendix-9.2 genetic algorithm."""
    cfg = config or GAConfig()
    n = cost.shape[0]
    cons = constraints or no_constraints(n)
    rng = np.random.default_rng(cfg.seed)

    if n == 1:
        return OrderingResult((0,), 0.0, "genetic", 1)

    pop = [_random_valid_order(rng, cons, n) for _ in range(cfg.population)]
    if cfg.nn_seed:
        # Seed a nearest-neighbour tour from every start task: strong,
        # diverse elites that OX recombines toward the optimum.
        pop[:n] = [
            _nearest_neighbour_order(cost, s, cons, n) for s in range(min(n, len(pop)))
        ]

    def fit(ind: np.ndarray) -> float:
        return fitness(ind.tolist(), cost, cons)

    evaluated = 0
    best = min(pop, key=fit)
    best_cost = fit(best)
    stale = 0

    for _round in range(cfg.max_rounds):
        scored = sorted(pop, key=fit)
        evaluated += len(pop)
        children: List[np.ndarray] = []
        # best K pairs by fitness: consecutive elites (1,2), (3,4), ...
        for p in range(cfg.elite_pairs):
            i, j = 2 * p, 2 * p + 1
            if j >= len(scored):
                break
            k = int(rng.integers(1, n))  # crossover point in {1..n-1}
            if cfg.crossover == "paper":
                ca, cb = _prefix_swap(scored[i], scored[j], k)
            else:
                ca, cb = _order_crossover(scored[i], scored[j], k)
            for child in (ca, cb):
                child = child.copy()
                if rng.random() < cfg.mutation_rate:
                    if cfg.reversal_mutation and rng.random() < 0.5:
                        # 2-opt-style segment reversal.
                        m1, m2 = sorted(rng.integers(0, n, size=2))
                        child[m1:m2 + 1] = child[m1:m2 + 1][::-1]
                    else:
                        m1, m2 = rng.integers(0, n, size=2)
                        child[m1], child[m2] = child[m2], child[m1]
                # Discard invalid individuals (non-permutation or precedence
                # violation) — the paper's final filtering step.
                if not _is_permutation(child, n):
                    continue
                if not cons.is_valid_order(child.tolist()):
                    continue
                children.append(child)

        # Next generation: elites survive, children compete, random refresh.
        keep = scored[: cfg.population - len(children) - 4]
        refresh = [_random_valid_order(rng, cons, n) for _ in range(4)]
        pop = keep + children + refresh

        cur = min(pop, key=fit)
        cur_cost = fit(cur)
        if cur_cost < best_cost - 1e-12:
            best, best_cost = cur.copy(), cur_cost
            stale = 0
        else:
            stale += 1
            if stale >= cfg.patience:
                break

    if cfg.local_search:
        best, best_cost, extra = _two_opt_polish(best, best_cost, cost, cons)
        evaluated += extra
    return OrderingResult(tuple(int(t) for t in best), best_cost, "genetic", evaluated)


def _two_opt_polish(
    ind: np.ndarray, cur: float, cost: np.ndarray, cons: Constraints
) -> Tuple[np.ndarray, float, int]:
    """Memetic finishing move: steepest-descent over segment reversals and
    pair swaps until a local optimum (validity-checked under precedence)."""
    n = len(ind)
    evaluated = 0
    improved = True
    best = ind.copy()
    while improved:
        improved = False
        for i in range(n - 1):
            for j in range(i + 1, n):
                for kind in ("rev", "swap", "ins"):
                    cand = best.copy()
                    if kind == "rev":
                        cand[i:j + 1] = cand[i:j + 1][::-1]
                    elif kind == "swap":
                        cand[i], cand[j] = cand[j], cand[i]
                    else:  # Or-opt: relocate element i to position j
                        seg = cand[i]
                        cand = np.delete(cand, i)
                        cand = np.insert(cand, j, seg)
                    if not cons.is_valid_order(cand.tolist()):
                        continue
                    f = fitness(cand.tolist(), cost, cons)
                    evaluated += 1
                    if f < cur - 1e-12:
                        best, cur = cand, f
                        improved = True
    return best, cur, evaluated
