"""Task graphs (paper §2.2, §3): structure, enumeration, variety score.

A *task graph* over ``n`` tasks and ``D`` branch points is a tree of depth
``D + 1`` below a virtual root:

* depth ``0 .. D`` nodes are *blocks* of the common network architecture
  (``D + 1`` blocks along every root->leaf path — the paper's deployment
  uses 4 blocks for 3 branch points);
* every task owns exactly one root->leaf path; a node is shared by the set
  of tasks whose paths pass through it;
* sharing is prefix-closed: two tasks sharing a depth-``d`` block share all
  blocks above it.

Equivalently, a task graph is a chain of nested partitions
``P_0 ⊒ P_1 ⊒ ... ⊒ P_D`` of the task set, where ``P_d`` groups tasks that
share the depth-``d`` block.  We use that canonical representation: it makes
deduplication, hashing and variety computation trivial.

The enumeration (paper §3.3 Step 2) grows graphs recursively: every graph on
``n-1`` tasks yields one new graph per *internal attach point* for the n-th
task.  In partition form, attaching at depth ``d`` means: task ``n`` joins an
existing group for all depths ``< d`` and forms singleton groups from depth
``d`` on — plus the choice of *which* existing group it joins along the way.
The count explodes combinatorially (it is the number of nested partition
chains), so for ``n`` beyond ~6 tasks the generator supports beam pruning by
variety score (an adaptation noted in DESIGN.md; the paper enumerates fully
for its 5-task example).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Tuple

import numpy as np

# A partition is a tuple of groups; each group is a sorted tuple of task ids.
Partition = Tuple[Tuple[int, ...], ...]


def _canon(groups: Iterable[Iterable[int]]) -> Partition:
    gs = [tuple(sorted(g)) for g in groups if len(tuple(g)) > 0]
    return tuple(sorted(gs))


@dataclasses.dataclass(frozen=True)
class TaskGraph:
    """Canonical nested-partition representation of a task graph.

    Attributes:
      num_tasks: ``n``.
      partitions: length ``D + 1`` tuple; ``partitions[d]`` is the partition
        of tasks into groups sharing the depth-``d`` block.  ``partitions[d+1]``
        refines ``partitions[d]``.
    """

    num_tasks: int
    partitions: Tuple[Partition, ...]

    # ------------------------------------------------------------------ api
    @property
    def depth(self) -> int:
        """Number of blocks along each path (= D + 1)."""
        return len(self.partitions)

    @property
    def num_branch_points(self) -> int:
        return self.depth - 1

    def group_of(self, depth: int, task: int) -> Tuple[int, ...]:
        for g in self.partitions[depth]:
            if task in g:
                return g
        raise KeyError(f"task {task} not in partition at depth {depth}")

    def nodes(self) -> List[Tuple[int, Tuple[int, ...]]]:
        """All blocks as ``(depth, group)`` pairs."""
        return [
            (d, g) for d, part in enumerate(self.partitions) for g in part
        ]

    def num_blocks(self) -> int:
        return len(self.nodes())

    def path(self, task: int) -> List[Tuple[int, Tuple[int, ...]]]:
        """The root->leaf chain of blocks executed by ``task``."""
        return [(d, self.group_of(d, task)) for d in range(self.depth)]

    def shared_prefix_depth(self, i: int, j: int) -> int:
        """Number of leading blocks shared by tasks ``i`` and ``j``."""
        shared = 0
        for d in range(self.depth):
            if self.group_of(d, i) == self.group_of(d, j):
                shared += 1
            else:
                break
        return shared

    def branch_nodes(self) -> List[Tuple[int, Tuple[int, ...]]]:
        """Nodes under which tasks diverge (used by the variety score).

        A ``(depth, group)`` node is a *branch point node* if the group splits
        into >= 2 child groups at ``depth + 1`` (or, at the final depth, still
        holds >= 2 tasks — they diverge into per-task heads there).
        """
        out = []
        for d, g in self.nodes():
            if len(g) < 2:
                continue
            if d == self.depth - 1:
                out.append((d, g))
            else:
                children = self.children_of(d, g)
                if len(children) >= 2:
                    out.append((d, g))
        # The virtual root is a branch node if depth-0 has >= 2 groups.
        if len(self.partitions[0]) >= 2:
            out.append((-1, tuple(sorted(range(self.num_tasks)))))
        return out

    def children_of(self, depth: int, group: Tuple[int, ...]) -> List[Tuple[int, ...]]:
        if depth == -1:
            return list(self.partitions[0])
        if depth == self.depth - 1:
            return [(t,) for t in group]
        return [g for g in self.partitions[depth + 1] if set(g) <= set(group)]

    def validate(self) -> None:
        all_tasks = set(range(self.num_tasks))
        prev: Optional[Partition] = None
        for d, part in enumerate(self.partitions):
            seen = [t for g in part for t in g]
            if sorted(seen) != sorted(all_tasks):
                raise ValueError(f"partition at depth {d} is not a partition")
            if prev is not None:
                for g in part:
                    if not any(set(g) <= set(pg) for pg in prev):
                        raise ValueError(
                            f"partition at depth {d} does not refine depth {d-1}"
                        )
            prev = part

    # ------------------------------------------------------------- factories
    @staticmethod
    def fully_shared(num_tasks: int, num_branch_points: int) -> "TaskGraph":
        """Fig. 2 left: one group everywhere (most compact, max variety)."""
        g = _canon([range(num_tasks)])
        return TaskGraph(num_tasks, tuple(g for _ in range(num_branch_points + 1)))

    @staticmethod
    def fully_separate(num_tasks: int, num_branch_points: int) -> "TaskGraph":
        """Fig. 2 right: singleton groups everywhere (no sharing)."""
        g = _canon([[t] for t in range(num_tasks)])
        return TaskGraph(num_tasks, tuple(g for _ in range(num_branch_points + 1)))

    @staticmethod
    def from_groups(groups: Sequence[Sequence[Sequence[int]]]) -> "TaskGraph":
        parts = tuple(_canon(p) for p in groups)
        n = sum(len(g) for g in parts[0])
        tg = TaskGraph(n, parts)
        tg.validate()
        return tg


# --------------------------------------------------------------------------
# Enumeration (paper §3.3 Step 2)
# --------------------------------------------------------------------------

def _attachments(graph: TaskGraph, new_task: int) -> Iterator[TaskGraph]:
    """All graphs obtained by branching ``new_task`` out of one internal node.

    Attaching under the node ``(d, g)`` means the new task shares blocks with
    group ``g`` at depths ``0..d`` and runs fresh singleton blocks below.
    Attaching at the virtual root (d = -1) shares nothing.
    """
    depth = graph.depth
    seen = set()

    def emit(parts: List[List[List[int]]]) -> Optional[TaskGraph]:
        tg = TaskGraph(graph.num_tasks + 1, tuple(_canon(p) for p in parts))
        key = tg.partitions
        if key in seen:
            return None
        seen.add(key)
        return tg

    # Virtual-root attachment: new singleton chain all the way down.
    parts = [
        [list(g) for g in graph.partitions[d]] + [[new_task]]
        for d in range(depth)
    ]
    tg = emit(parts)
    if tg is not None:
        yield tg

    # Attachment under each internal (non-leaf) node (d, g): share through d.
    for d in range(depth - 1):  # leaves (final depth) are not attach points
        for g in graph.partitions[d]:
            parts = []
            for dd in range(depth):
                layer = [list(x) for x in graph.partitions[dd]]
                if dd <= d:
                    # Join the group at depth dd that contains g (a superset
                    # of g by the nesting property) -> prefix sharing.
                    for x in layer:
                        if set(g) <= set(x):
                            x.append(new_task)
                            break
                else:
                    layer.append([new_task])
                parts.append(layer)
            tg = emit(parts)
            if tg is not None:
                yield tg


def enumerate_task_graphs(
    num_tasks: int,
    num_branch_points: int,
    beam: Optional[int] = None,
    variety_fn=None,
) -> List[TaskGraph]:
    """All task graphs on ``num_tasks`` tasks (paper §3.3 Step 2).

    Grows graphs one task at a time, deduplicating by canonical form.  With
    ``beam`` set, only the ``beam`` best graphs (by ``variety_fn``) survive
    each growth round — needed for n >= ~7 where the full set explodes.
    """
    frontier: Dict[Tuple[Partition, ...], TaskGraph] = {}
    g0 = TaskGraph.from_groups([[[0]] for _ in range(num_branch_points + 1)])
    frontier[g0.partitions] = g0
    for t in range(1, num_tasks):
        nxt: Dict[Tuple[Partition, ...], TaskGraph] = {}
        for g in frontier.values():
            for tg in _attachments(g, t):
                nxt[tg.partitions] = tg
        graphs = list(nxt.values())
        if beam is not None and len(graphs) > beam and variety_fn is not None:
            # Diversity-preserving beam: bucket by block count (a storage
            # proxy) and keep the lowest-variety graphs per bucket, so the
            # downstream tradeoff curve still spans compact <-> separate
            # graphs instead of collapsing to one end.
            buckets: Dict[int, List[TaskGraph]] = {}
            for g in graphs:
                buckets.setdefault(g.num_blocks(), []).append(g)
            per = max(beam // max(len(buckets), 1), 1)
            kept: List[TaskGraph] = []
            for bs in buckets.values():
                bs.sort(key=variety_fn)
                kept.extend(bs[:per])
            graphs = kept[:beam]
        frontier = {g.partitions: g for g in graphs}
    out = list(frontier.values())
    for g in out:
        g.validate()
    return out


# --------------------------------------------------------------------------
# Variety score (paper Eq. 1-2)
# --------------------------------------------------------------------------

def variety_at_branch_point(
    affinity: np.ndarray, depth: int, groups: Sequence[Tuple[int, ...]]
) -> float:
    """Eq. 1: ``v_rho = (1/m) sum_k max_{i,j in c_k} (1 - S[rho,i,j])``.

    ``affinity`` is the ``(D, n, n)`` Spearman tensor; ``groups`` are the
    child branches at this branch point — the groups of tasks still sharing
    a block at this depth.  This is the intra-cluster-impurity analogy the
    paper draws: a group with dissimilar tasks is a misfit.  A singleton
    group contributes 0 (a single task has no internal dissimilarity), so
    the fully-separate graph scores 0 (lowest) and the fully-shared graph
    the per-depth max (highest) — exactly Fig. 2.
    """
    d_idx = int(np.clip(depth, 0, affinity.shape[0] - 1))
    s = affinity[d_idx]
    vals = []
    for ck in groups:
        if len(ck) < 2:
            vals.append(0.0)
            continue
        worst = max(
            1.0 - float(s[i, j]) for i, j in itertools.combinations(ck, 2)
        )
        vals.append(worst)
    return float(np.mean(vals)) if vals else 0.0


def variety_score(graph: TaskGraph, affinity: np.ndarray) -> float:
    """Eq. 2: sum over branch points of the per-depth group impurity.

    The partition at depth ``d`` is what the branch point above it decided,
    so the variety score sums ``variety_at_branch_point`` over every depth's
    partition (affinity rows are clipped to the profiled branch points).
    """
    total = 0.0
    for d, part in enumerate(graph.partitions):
        total += variety_at_branch_point(affinity, d, part)
    return total
