"""Variety-vs-cost tradeoff analysis and graph selection (paper §3.2-3.3).

For a sweep of model-size budgets, pick for each budget the feasible graph
with the lowest variety score; normalise the resulting variety and execution
cost trends to [0, 1]; return the graph at the point where the two trend
lines intersect — the paper's default selection (Fig. 3), which the developer
may override (paper §5.3).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.constraints import Constraints
from repro.core.cost_model import GraphCostModel
from repro.core.ordering import optimal_order
from repro.core.task_graph import TaskGraph, enumerate_task_graphs, variety_score
from repro.core.types import BlockCost, HardwareModel


@dataclasses.dataclass(frozen=True)
class GraphCandidate:
    graph: TaskGraph
    variety: float
    exec_cost: float       # cost of the *optimal order* on this graph
    storage_bytes: float
    order: Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class TradeoffResult:
    candidates: List[GraphCandidate]
    budgets: np.ndarray
    variety_trend: np.ndarray     # normalised, one point per budget
    cost_trend: np.ndarray        # normalised, one point per budget
    selected: GraphCandidate
    selected_budget: float


def evaluate_graphs(
    graphs: Sequence[TaskGraph],
    affinity: np.ndarray,
    block_costs: Sequence[BlockCost],
    hw: Optional[HardwareModel],
    constraints: Optional[Constraints] = None,
    metric: str = "time",
    order_solver: str = "auto",
) -> List[GraphCandidate]:
    """Paper §3.3 Step 3: variety, size and (order-optimal) cost per graph."""
    out = []
    for g in graphs:
        cm = GraphCostModel(g, block_costs, hw, metric)
        res = optimal_order(cm.cost_matrix(), constraints, solver=order_solver)
        out.append(
            GraphCandidate(
                graph=g,
                variety=variety_score(g, affinity),
                exec_cost=cm.order_cost(list(res.order)),
                storage_bytes=cm.storage_bytes(),
                order=res.order,
            )
        )
    return out


def _normalise(x: np.ndarray) -> np.ndarray:
    lo, hi = float(np.min(x)), float(np.max(x))
    if hi - lo < 1e-12:
        return np.zeros_like(x)
    return (x - lo) / (hi - lo)


def tradeoff_curve(
    candidates: Sequence[GraphCandidate],
    num_budgets: int = 33,
) -> TradeoffResult:
    """Paper §3.3 Step 4: budget sweep, trend lines, intersection pick.

    For each budget: the lowest-variety feasible graph.  Variety decreases
    with budget while its execution cost increases; the selected graph sits
    where the normalised trends cross.
    """
    sizes = np.array([c.storage_bytes for c in candidates])
    budgets = np.linspace(float(sizes.min()), float(sizes.max()), num_budgets)
    picks: List[GraphCandidate] = []
    for b in budgets:
        feas = [c for c in candidates if c.storage_bytes <= b + 1e-9]
        picks.append(min(feas, key=lambda c: (c.variety, c.exec_cost)))
    variety = _normalise(np.array([p.variety for p in picks]))
    cost = _normalise(np.array([p.exec_cost for p in picks]))
    # Intersection of the two normalised trend lines: first budget index
    # where the (decreasing) variety trend falls below the (increasing) cost
    # trend; tie-break on the smallest |gap|.
    gap = variety - cost
    cross = int(np.argmin(np.abs(gap)))
    for k in range(len(budgets) - 1):
        if gap[k] >= 0.0 >= gap[k + 1]:
            cross = k + 1 if abs(gap[k + 1]) <= abs(gap[k]) else k
            break
    return TradeoffResult(
        candidates=list(candidates),
        budgets=budgets,
        variety_trend=variety,
        cost_trend=cost,
        selected=picks[cross],
        selected_budget=float(budgets[cross]),
    )


def select_task_graph(
    num_tasks: int,
    num_branch_points: int,
    affinity: np.ndarray,
    block_costs: Sequence[BlockCost],
    hw: Optional[HardwareModel] = None,
    constraints: Optional[Constraints] = None,
    metric: str = "time",
    beam: Optional[int] = None,
    order_solver: str = "auto",
) -> TradeoffResult:
    """End-to-end §3.3 pipeline: enumerate -> evaluate -> tradeoff -> select."""
    variety_fn = (lambda g: variety_score(g, affinity)) if beam else None
    graphs = enumerate_task_graphs(
        num_tasks, num_branch_points, beam=beam, variety_fn=variety_fn
    )
    cands = evaluate_graphs(
        graphs, affinity, block_costs, hw, constraints, metric, order_solver
    )
    return tradeoff_curve(cands)
