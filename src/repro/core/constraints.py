"""Inter-task dependency constraints (paper §4.3).

*Precedence* constraints are static tuples ``(i, j)``: task ``i`` must finish
before task ``j`` starts.  *Conditional* constraints are triplets
``(i, j, p)`` — a special precedence edge where ``j`` only executes with
probability ``p`` once ``i``'s result is known; the ordering objective uses
the expected switching cost (paper Eq. 8).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, Iterable, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class Constraints:
    """Precedence set ``P`` and conditional set ``R`` over ``n`` tasks."""

    num_tasks: int
    precedence: FrozenSet[Tuple[int, int]] = frozenset()
    conditional: FrozenSet[Tuple[int, int, float]] = frozenset()

    @staticmethod
    def make(
        num_tasks: int,
        precedence: Iterable[Tuple[int, int]] = (),
        conditional: Iterable[Tuple[int, int, float]] = (),
    ) -> "Constraints":
        prec = set(tuple(p) for p in precedence)
        cond = set(tuple(c) for c in conditional)
        # Conditional constraints are a special type of precedence constraint
        # (paper §4.3), so their edges are included in the precedence set.
        for (i, j, _p) in cond:
            prec.add((i, j))
        c = Constraints(num_tasks, frozenset(prec), frozenset(cond))
        c.validate()
        return c

    def validate(self) -> None:
        for (i, j) in self.precedence:
            if not (0 <= i < self.num_tasks and 0 <= j < self.num_tasks):
                raise ValueError(f"precedence edge {(i, j)} out of range")
            if i == j:
                raise ValueError("self-precedence is not allowed")
        # Reject cyclic precedence (no valid order would exist).
        if self._has_cycle():
            raise ValueError("precedence constraints contain a cycle")

    def _has_cycle(self) -> bool:
        adj: Dict[int, list] = {i: [] for i in range(self.num_tasks)}
        for (i, j) in self.precedence:
            adj[i].append(j)
        color = [0] * self.num_tasks

        def dfs(u: int) -> bool:
            color[u] = 1
            for v in adj[u]:
                if color[v] == 1 or (color[v] == 0 and dfs(v)):
                    return True
            color[u] = 2
            return False

        return any(color[u] == 0 and dfs(u) for u in range(self.num_tasks))

    # ------------------------------------------------------------------ api
    def is_valid_order(self, order: Sequence[int]) -> bool:
        """Does the permutation satisfy every precedence edge (Eq. 6)?"""
        pos = {t: k for k, t in enumerate(order)}
        return all(pos[i] < pos[j] for (i, j) in self.precedence)

    def execution_probability(self, task: int) -> float:
        """P(``task`` executes): product of its conditional in-edges' probs.

        Tasks without conditional prerequisites always run (p = 1).  This is
        the expected-execution model behind Eq. 8: the switching cost into a
        conditionally-dependent task is weighted by how often it actually
        fires (estimated offline in the paper).
        """
        p = 1.0
        for (_i, j, pj) in self.conditional:
            if j == task:
                p *= pj
        return p

    @property
    def empty(self) -> bool:
        return not self.precedence and not self.conditional


NO_CONSTRAINTS = Constraints(num_tasks=0)


def no_constraints(num_tasks: int) -> Constraints:
    return Constraints(num_tasks=num_tasks)
