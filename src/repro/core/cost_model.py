"""Switching-cost model (paper §4.1) and task-graph cost estimation.

The cost matrix ``C`` has ``c[i, j]`` = additional cost of loading and
executing task ``j`` given that task ``i`` just ran: the blocks on ``j``'s
path that are *not* shared with ``i`` must be loaded into the fast tier and
executed; shared-prefix blocks are skipped entirely because the executor
caches both their weights (already resident) and their output activations
(paper §2.3).  Because all paths run the same common architecture, block
cost depends only on depth, and the matrix is symmetric — exactly the
paper's observation.

Costs can be measured in seconds or joules through a
:class:`~repro.core.types.HardwareModel`; the unit-cost mode (``hw=None``)
reproduces the paper's Figure-4 example where every block costs 1.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Protocol, Sequence, Tuple

import numpy as np

from repro.core.task_graph import TaskGraph
from repro.core.types import (
    BlockCost, ExecutionStats, HardwareModel, NodeId, Residency,
    TaskGateRecord,
)


@dataclasses.dataclass(frozen=True)
class GraphCostModel:
    """Cost model for a task graph given per-depth block costs.

    Attributes:
      graph: the task graph.
      block_costs: length ``D + 1`` per-depth :class:`BlockCost` of the common
        architecture (the paper profiles these empirically on-device; we
        derive them from the model definition's FLOP/byte counters).
      hw: platform; ``None`` means abstract unit costs (1 load + 1 exec per
        block, as in the paper's Figure 4 walkthrough).
      metric: ``"time"`` or ``"energy"`` (paper evaluates both).
      weight_shards: how many ways block weights are sharded over a device
        mesh (``ShardingPolicy.weight_shards``): every load term divides by
        it — each chip streams only its slice — so the ordering solvers
        minimize the *sharded* schedule cost rather than the single-device
        proxy.  ``1`` (single device) reproduces the original model exactly.
      gate_model: optional :class:`~repro.adaptive.gate_model.GateModel`
        giving per-block fire probabilities and per-task execution
        probabilities — the default for the ``expected_*`` family of
        methods, which predict *expected* counters/costs under
        input-adaptive gating.  ``None`` keeps every exact method exact and
        makes the expected methods degenerate to the all-blocks floor.
    """

    graph: TaskGraph
    block_costs: Sequence[BlockCost]
    hw: Optional[HardwareModel] = None
    metric: str = "time"
    weight_shards: int = 1
    gate_model: Optional[Any] = None

    def block_cost(self, depth: int) -> float:
        """Load + execute cost of the depth-``depth`` block."""
        if self.hw is None:
            return 2.0  # 1 unit load + 1 unit exec, Figure-4 convention
        bc = self.block_costs[depth]
        if self.metric == "energy":
            return (
                self.hw.energy_joules(bc.flops, bc.act_bytes)
                + self.load_cost(depth)
            )
        return bc.exec_seconds(self.hw) + self.load_cost(depth)

    def task_cost(self, task: int) -> float:
        """Cold cost of running ``task`` with nothing cached."""
        return sum(self.block_cost(d) for d, _ in self.graph.path(task))

    def load_cost(self, depth: int) -> float:
        """Load-only component of :meth:`block_cost` (weight streaming).

        This is the part of a block's cost that warm starts can save: the
        execute part is always paid for a fresh input, but the load is
        skipped whenever the block is still resident from an earlier group.
        Sharded weights (``weight_shards > 1``) stream in parallel, one
        slice per chip, so the term divides accordingly.
        """
        if self.hw is None:
            return 1.0  # the Figure-4 unit-load convention
        bc = self.block_costs[depth]
        if self.metric == "energy":
            return (
                self.hw.energy_joules(0.0, 2.0 * bc.weight_bytes)
                / max(self.weight_shards, 1)
            )
        return bc.load_seconds(self.hw) / max(self.weight_shards, 1)

    def switching_cost(self, prev: int, nxt: int) -> float:
        """``c[prev, nxt]``: cost of the non-shared suffix of ``nxt``."""
        if prev == nxt:
            return 0.0
        shared = self.graph.shared_prefix_depth(prev, nxt)
        return sum(
            self.block_cost(d) for d in range(shared, self.graph.depth)
        )

    def warm_switching_cost(self, prev: int, nxt: int) -> float:
        """Load-only cost of starting ``nxt`` with ``prev``'s path resident.

        The inter-*group* analogue of :meth:`switching_cost`: across a group
        boundary activations never survive (they belong to the previous
        group's inputs), so every block of ``nxt`` executes — only the loads
        of the still-resident shared prefix are saved.  This is the edge
        weight of the group-ordering pass.
        """
        shared = self.graph.shared_prefix_depth(prev, nxt)
        return sum(self.load_cost(d) for d in range(shared, self.graph.depth))

    def resume_load_cost(self, resident: Residency, task: int) -> float:
        """Load cost of ``task``'s blocks not present in ``resident``.

        Generalises :meth:`warm_switching_cost` to an arbitrary residency
        snapshot (``TaskGraphExecutor.residency_state()``), e.g. the state a
        persistent engine carries between ``serve_batch`` calls.
        """
        path = self.graph.path(task)
        return sum(
            self.load_cost(d)
            for d in range(self.graph.depth)
            if resident[d] != path[d]
        )

    def cost_matrix(self) -> np.ndarray:
        """The full symmetric ``n x n`` cost matrix (Eq. 3)."""
        n = self.graph.num_tasks
        c = np.zeros((n, n), dtype=np.float64)
        for i in range(n):
            for j in range(n):
                if i != j:
                    c[i, j] = self.switching_cost(i, j)
        return c

    # ------------------------------------------------------- expected costs
    def expected_block_cost(
        self, task: int, depth: int, gate_model: Optional[Any] = None
    ) -> float:
        """Expected load + execute cost of ``task``'s depth-``depth`` block.

        Under a gate model the task runs with probability ``p`` and, given
        it runs, the block's execute cost is paid only by the fraction
        ``q`` of rows its gate fires for; the load is paid whenever the
        task dispatches (loads are physical regardless of fires):
        ``p * (load + q * exec)``.  Without a model this is exactly
        :meth:`block_cost`.
        """
        gm = gate_model if gate_model is not None else self.gate_model
        if gm is None:
            return self.block_cost(depth)
        load = self.load_cost(depth)
        return gm.task_probability(task) * (
            load
            + gm.fire_probability(task, depth)
            * (self.block_cost(depth) - load)
        )

    def expected_switching_cost(
        self, prev: int, nxt: int, gate_model: Optional[Any] = None
    ) -> float:
        """Expected ``c[prev, nxt]``: the probability-weighted non-shared
        suffix of ``nxt`` (see :meth:`expected_block_cost`)."""
        if prev == nxt:
            return 0.0
        shared = self.graph.shared_prefix_depth(prev, nxt)
        return sum(
            self.expected_block_cost(nxt, d, gate_model)
            for d in range(shared, self.graph.depth)
        )

    def expected_resume_load_cost(
        self, resident: Residency, task: int, gate_model: Optional[Any] = None
    ) -> float:
        """Expected-cost analogue of :meth:`resume_load_cost`: the load
        bytes only move if the task dispatches at all, so the warm-start
        term scales by its execution probability."""
        gm = gate_model if gate_model is not None else self.gate_model
        base = self.resume_load_cost(resident, task)
        if gm is None:
            return base
        return gm.task_probability(task) * base

    def expected_cost_matrix(
        self, gate_model: Optional[Any] = None
    ) -> np.ndarray:
        """The ``n x n`` *expected* switching-cost matrix.

        What the ordering solvers minimize for input-adaptive (or
        conditionally-constrained) engines: feeding this matrix to
        ``solve_suborder`` / ``optimal_order`` makes them optimize expected
        bytes/FLOPs without any solver changes — the probabilities are
        folded into the edge weights.  Note the matrix is generally
        asymmetric: it weights by the *destination* task's probabilities.
        """
        gm = gate_model if gate_model is not None else self.gate_model
        n = self.graph.num_tasks
        c = np.zeros((n, n), dtype=np.float64)
        for i in range(n):
            for j in range(n):
                if i != j:
                    c[i, j] = self.expected_switching_cost(i, j, gm)
        return c

    # ----------------------------------------------------------- aggregates
    def order_cost(self, order: Sequence[int], cyclic: bool = False) -> float:
        """Total cost of executing all tasks in ``order``.

        First task pays its cold cost; every subsequent task pays the
        switching cost from its predecessor.  With ``cyclic=True`` the
        wrap-around switch is added (the ILP's Hamiltonian-cycle objective);
        the paper's fitness (Eq. 7) is the path version.
        """
        total = self.task_cost(order[0])
        for a, b in zip(order[:-1], order[1:]):
            total += self.switching_cost(a, b)
        if cyclic and len(order) > 1:
            total += self.switching_cost(order[-1], order[0])
        return total

    def storage_bytes(self) -> float:
        """Total weight bytes of the task graph (Table 4/5 'memory')."""
        total = 0.0
        for d, _g in self.graph.nodes():
            total += self.block_costs[d].weight_bytes
        return total

    def vanilla_storage_bytes(self) -> float:
        """Storage if every task kept its own full network (Vanilla)."""
        per_task = sum(bc.weight_bytes for bc in self.block_costs)
        return per_task * self.graph.num_tasks

    def _predict_into(
        self,
        order: Sequence[int],
        batch_size: int,
        resident: List[Optional[NodeId]],
        stats: ExecutionStats,
        collectives: Optional["CollectiveCosts"] = None,
        first_task_resume: int = 0,
        gate_trace: Optional[Sequence[TaskGateRecord]] = None,
        gate_model: Optional[Any] = None,
    ) -> None:
        """One group's counter prediction, mutating ``resident``/``stats``.

        Mirrors ``TaskGraphExecutor._run_task_impl`` exactly: the first task
        of a group never resumes from activations (the executor clears them
        at every input/group boundary), but any block still resident in
        ``resident`` skips its load while still executing.  The one
        exception is crash recovery: ``first_task_resume`` is the resume
        depth of the order's *first* task when a journaled mid-suffix
        activation checkpoint was restored into the executor
        (``TaskGraphExecutor.restore_activation``) — blocks below it skip
        both load and execute, exactly as a shared prefix would.

        A restored checkpoint also punches a hole in the activation cache:
        depths *below* the checkpoint were never computed this boot, so a
        later task whose shared prefix with its predecessor ends below that
        floor finds no cached activation at all and resumes from 0 (the
        executor's deepest-match rule).  ``act_floor`` tracks the
        shallowest cached depth — ``first_task_resume - 1`` after a
        restore, and 0 again as soon as any task re-executes from the root.

        ``collectives`` (``TaskGraphExecutor.collective_view``) adds the
        mesh-sharded collective bytes of each task's fused-suffix dispatch:
        for an ungated engine the executor resumes task ``t`` exactly at the
        shared-prefix depth with its predecessor, so the per-``(task,
        resume)`` calibrated breakdown lands on the same counters the
        executor will report — exact by construction.

        ``gate_trace`` replays a *realized* gate outcome (one
        :class:`TaskGateRecord` per order position, the executor's
        ``last_trace``): a ``weight == 0`` record is a task a legacy
        ``gate=`` callback skipped for the whole group — it never
        dispatched, so neither residency nor the activation walk advances
        past it — while a partial-weight record scales the per-request
        counters by the rows that ran, and ``fired`` splits each executed
        block's flops into fired vs gated rows.  Records carrying a
        ``resume`` are cross-checked against this walk's resume depth, so
        any prediction/execution divergence raises instead of silently
        mis-counting.

        ``gate_model`` (mutually exclusive) predicts *expected* counters:
        flop/task/fire counters are weighted by the model's task and fire
        probabilities, while the structural counters (block invocations,
        weight bytes, residency evolution, collectives) keep the all-run
        walk — loads are physical whether or not rows fire (the scan
        program consumes every stacked block's params), and an expected
        residency walk over task-skip realizations would be ill-defined.
        For pure per-block gating (every task runs) the expected counters
        are the exact mean of the realized ones by linearity.
        """
        if gate_trace is not None and gate_model is not None:
            raise ValueError("gate_trace and gate_model are mutually exclusive")
        if gate_trace is not None and len(gate_trace) != len(order):
            raise ValueError(
                f"gate trace has {len(gate_trace)} records for "
                f"{len(order)} tasks"
            )
        prev: Optional[int] = None
        act_floor = max(int(first_task_resume) - 1, 0)
        for pos, t in enumerate(order):
            rec = gate_trace[pos] if gate_trace is not None else None
            if rec is not None and rec.task != t:
                raise ValueError(
                    f"gate trace record {pos} is for task {rec.task}, "
                    f"order has task {t}"
                )
            if rec is not None and rec.weight == 0:
                # Legacy-gated off for the whole group: never dispatched.
                stats.tasks_skipped += batch_size
                continue
            w = int(rec.weight) if rec is not None else batch_size
            p_t = (
                gate_model.task_probability(t) if gate_model is not None
                else 1.0
            )
            path = self.graph.path(t)
            if prev is None:
                shared = int(first_task_resume)
            else:
                shared = self.graph.shared_prefix_depth(prev, t)
                if 0 < shared <= act_floor:
                    # The shared activation this resume needs sits below
                    # the restored checkpoint's floor — it never existed
                    # this boot, so the executor starts the task from 0.
                    shared = 0
            act_floor = min(act_floor, shared)
            if rec is not None and rec.resume is not None:
                if int(rec.resume) != shared:
                    raise ValueError(
                        f"gate trace resume {rec.resume} for task {t} "
                        f"diverges from the predicted resume {shared}"
                    )
            if (
                rec is not None
                and rec.fired is not None
                and len(rec.fired) != self.graph.depth - shared
            ):
                raise ValueError(
                    f"gate trace for task {t} has {len(rec.fired)} fire "
                    f"counts for a {self.graph.depth - shared}-block suffix"
                )
            for d in range(self.graph.depth):
                bc = self.block_costs[d]
                if d < shared:
                    # Skipped prefix: the executor touches neither the
                    # weights nor the residency here.  With an ordinary
                    # shared prefix ``resident[d]`` already equals
                    # ``path[d]`` (the predecessor walked it); after a
                    # checkpoint restore it may not — those weights were
                    # never loaded this boot, and leaving residency as-is
                    # predicts the later reload the executor will do.
                    stats.blocks_skipped += 1
                    stats.weight_bytes_skipped += bc.weight_bytes
                    stats.flops_skipped += (
                        batch_size * p_t if gate_model is not None else w
                    ) * bc.flops
                else:
                    stats.blocks_executed += 1
                    if resident[d] == path[d]:
                        stats.weight_bytes_skipped += bc.weight_bytes
                    else:
                        stats.weight_bytes_loaded += bc.weight_bytes
                    if rec is not None and rec.fired is not None:
                        f = int(rec.fired[d - shared])
                        stats.flops_executed += f * bc.flops
                        stats.flops_gated += (w - f) * bc.flops
                        stats.block_rows_fired += f
                        stats.block_rows_gated += w - f
                    elif gate_model is not None:
                        q = gate_model.fire_probability(t, d)
                        stats.flops_executed += batch_size * p_t * q * bc.flops
                        stats.flops_gated += (
                            batch_size * p_t * (1.0 - q) * bc.flops
                        )
                        stats.block_rows_fired += batch_size * p_t * q
                        stats.block_rows_gated += batch_size * p_t * (1.0 - q)
                    else:
                        stats.flops_executed += w * bc.flops
                    resident[d] = path[d]
            if gate_model is not None:
                stats.tasks_run += batch_size * p_t
                stats.tasks_skipped += batch_size * (1.0 - p_t)
            else:
                stats.tasks_run += w
                if rec is not None:
                    stats.tasks_skipped += batch_size - w
            if collectives is not None:
                stats.add_collectives(collectives.breakdown(t, shared))
            prev = t

    def predicted_stats(
        self,
        order: Sequence[int],
        batch_size: int = 1,
        resume: Optional[Residency] = None,
        collectives: Optional["CollectiveCosts"] = None,
        first_task_resume: int = 0,
        checkpoints: Optional[Sequence["CheckpointSite"]] = None,
        gate_trace: Optional[Sequence[TaskGateRecord]] = None,
    ) -> ExecutionStats:
        """Counter-level prediction the executor must match exactly.

        With ``batch_size > 1`` this predicts the *batched* executor
        (``TaskGraphExecutor.run_batch`` serving ``batch_size`` stacked
        requests): block invocations and weight loads happen once per group
        (loads amortise across the batch), while flop and task counters
        scale per request.  ``batch_size=1`` is the original single-request
        prediction.

        ``resume`` is an initial residency snapshot
        (``TaskGraphExecutor.residency_state()``) for *warm* starts: blocks
        already resident skip their loads but still execute (activations
        never cross a group boundary).  ``resume=None`` is the cold
        prediction.

        ``collectives`` is the executor's per-dispatch collective-byte view
        for the group's (padded) batch shape; see :meth:`_predict_into`.

        ``first_task_resume`` predicts a crash-recovered group whose first
        task resumes from a restored activation checkpoint at that depth;
        ``checkpoints`` (a :meth:`plan_checkpoints` plan) adds the group's
        checkpoint-write counters, which the journaling engine accounts
        from the *same* plan — exact by construction.

        ``gate_trace`` conditions the prediction on a realized gate outcome
        (see :meth:`_predict_into`): with the executor's actual trace the
        predicted counters equal the executed ones field-for-field even
        under legacy per-request gates and adaptive block gating.
        """
        resident: List[Optional[NodeId]] = (
            list(resume) if resume is not None else [None] * self.graph.depth
        )
        if len(resident) != self.graph.depth:
            raise ValueError(
                f"resume has {len(resident)} slots, expected {self.graph.depth}"
            )
        stats = ExecutionStats()
        self._predict_into(
            order, batch_size, resident, stats, collectives,
            first_task_resume=first_task_resume,
            gate_trace=gate_trace,
        )
        for site in checkpoints or ():
            stats.checkpoint_bytes += site.bytes
            stats.checkpoint_seconds += site.seconds
        return stats

    def expected_stats(
        self,
        order: Sequence[int],
        batch_size: int = 1,
        resume: Optional[Residency] = None,
        collectives: Optional["CollectiveCosts"] = None,
        first_task_resume: int = 0,
        checkpoints: Optional[Sequence["CheckpointSite"]] = None,
        gate_model: Optional[Any] = None,
    ) -> ExecutionStats:
        """*Expected* counters under a gate model (defaults to this model's
        :attr:`gate_model`).

        The pre-execution estimate of what :meth:`predicted_stats` with the
        realized ``gate_trace`` will report: flop/task/fire counters are
        probability-weighted while structural counters keep the all-run
        walk (see :meth:`_predict_into`).  With ``gate_model=None`` and no
        model attached this is exactly :meth:`predicted_stats` — the
        all-blocks floor.
        """
        gm = gate_model if gate_model is not None else self.gate_model
        resident: List[Optional[NodeId]] = (
            list(resume) if resume is not None else [None] * self.graph.depth
        )
        if len(resident) != self.graph.depth:
            raise ValueError(
                f"resume has {len(resident)} slots, expected {self.graph.depth}"
            )
        stats = ExecutionStats()
        self._predict_into(
            order, batch_size, resident, stats, collectives,
            first_task_resume=first_task_resume,
            gate_model=gm,
        )
        for site in checkpoints or ():
            stats.checkpoint_bytes += site.bytes
            stats.checkpoint_seconds += site.seconds
        return stats

    def predicted_group_stats(
        self,
        plan: Sequence[Tuple[Sequence[int], int]],
        resume: Optional[Residency] = None,
    ) -> ExecutionStats:
        """Cumulative prediction for a warm multi-group schedule.

        ``plan`` is the executed schedule: one ``(order, batch_size)`` entry
        per group, in execution sequence, where ``order`` lists the tasks
        that group actually runs (the engine's task order filtered to the
        group's subset — or that group's re-solved per-plan order) and
        ``batch_size`` its valid (unpadded) request count.  Residency
        carries from each group into the next — activations do not — so
        this predicts exactly what the warm-start engine's cumulative
        counters will be.  ``resume`` seeds the initial residency (a
        persistent engine warm from earlier batches).

        Sessions that admit groups over time use the incremental form,
        :meth:`plan_predictor`, which this method is a one-shot wrapper
        around.
        """
        predictor = self.plan_predictor(resume=resume)
        for order, batch_size in plan:
            predictor.append(order, int(batch_size))
        return predictor.stats

    def plan_predictor(
        self,
        resume: Optional[Residency] = None,
        carry_residency: bool = True,
    ) -> "PlanPredictor":
        """An incremental predictor for incrementally-admitted plans."""
        return PlanPredictor(self, resume=resume, carry_residency=carry_residency)

    def plan_loads(
        self,
        order: Sequence[int],
        resident: Optional[Residency] = None,
        gate_trace: Optional[Sequence[TaskGateRecord]] = None,
    ) -> List[Tuple[int, NodeId]]:
        """The ``(depth, node)`` weight loads executing ``order`` will issue.

        Walks the same residency simulation as :meth:`_predict_into`, but
        instead of aggregating counters it returns the exact load sequence —
        every block that is *not* resident when its task reaches it.  This is
        the prefetch schedule the :class:`~repro.core.executor.WeightStreamer`
        stages for the next group: staging precisely this set makes the
        executor's ``prefetched_bytes`` counter equal the group's
        ``weight_bytes_loaded`` by construction, which is what keeps
        streaming prediction exact.

        ``resident`` is the residency at the start of the plan (``None`` =
        cold).  The returned list is in execution order and free of
        duplicates: an order that *revisits* an evicted block (interleaved
        subtrees, e.g. ``[0, 3, 1]``) re-loads it — and ``predicted_stats``
        counts those bytes twice — but the streamer stages one copy per
        node and the executor commits it at most once, so the schedule
        lists each node once, at its first load.  The revisit falls through
        to a synchronous load on both the predicted and executed side.

        ``gate_trace`` conditions the schedule on a realized gate outcome:
        a ``weight == 0`` record's task never dispatched, so it issues no
        loads and does not advance the walk — the load set of a gated run.
        """
        state: List[Optional[NodeId]] = (
            list(resident) if resident is not None else [None] * self.graph.depth
        )
        if len(state) != self.graph.depth:
            raise ValueError(
                f"resident has {len(state)} slots, expected {self.graph.depth}"
            )
        if gate_trace is not None and len(gate_trace) != len(order):
            raise ValueError(
                f"gate trace has {len(gate_trace)} records for "
                f"{len(order)} tasks"
            )
        loads: List[Tuple[int, NodeId]] = []
        staged: set = set()
        prev: Optional[int] = None
        for pos, t in enumerate(order):
            if gate_trace is not None and gate_trace[pos].weight == 0:
                continue  # never dispatched: no loads, walk unchanged
            path = self.graph.path(t)
            shared = (
                self.graph.shared_prefix_depth(prev, t) if prev is not None else 0
            )
            for d in range(shared, self.graph.depth):
                if state[d] != path[d] and path[d] not in staged:
                    loads.append((d, path[d]))
                    staged.add(path[d])
                state[d] = path[d]
            prev = t
        return loads

    def prefetch_stall_seconds(
        self, depths: Sequence[int], overlap_seconds: float
    ) -> float:
        """Modelled stall of streaming ``depths``' loads behind a compute
        window of ``overlap_seconds``.

        The double-buffered streamer moves the bytes while the *previous*
        group computes; whatever does not fit in that window stalls the next
        group's start.  Load terms use :meth:`load_cost`, so sharded weights
        stream one slice per chip exactly as the synchronous path models.
        """
        total = sum(self.load_cost(d) for d in depths)
        return max(total - max(overlap_seconds, 0.0), 0.0)

    # ------------------------------------------------------- checkpointing
    def checkpoint_bytes(self, depth: int, batch_size: int) -> float:
        """Durable bytes of checkpointing depth-``depth``'s activation for a
        ``batch_size``-request group (one activation row per request)."""
        return float(batch_size) * self.block_costs[depth].act_bytes

    def checkpoint_write_seconds(self, depth: int, batch_size: int) -> float:
        """Modelled seconds of writing that checkpoint to the durable tier.

        The durable tier is the same slow tier weights stream from (FRAM on
        the MSP430), so the write time uses ``hw.load_seconds`` — the unit
        convention (``hw=None``) charges 1, mirroring :meth:`load_cost`.
        """
        if self.hw is None:
            return 1.0
        return self.hw.load_seconds(self.checkpoint_bytes(depth, batch_size))

    def _checkpoint_write_cost(self, depth: int, batch_size: int) -> float:
        """Write cost in this model's metric (seconds or joules)."""
        if self.hw is None:
            return 1.0
        if self.metric == "energy":
            return self.hw.energy_joules(
                0.0, self.checkpoint_bytes(depth, batch_size)
            )
        return self.checkpoint_write_seconds(depth, batch_size)

    def _block_reexec_cost(self, depth: int, batch_size: int) -> float:
        """Metric cost of re-executing one block after a power failure.

        Compute-only: weight residency and activation checkpoints live in
        the durable tier and survive the crash, so replay pays execution
        but no loads.
        """
        if self.hw is None:
            return 1.0
        bc = self.block_costs[depth]
        if self.metric == "energy":
            return self.hw.energy_joules(batch_size * bc.flops, 0.0)
        return self.hw.exec_seconds(batch_size * bc.flops)

    def plan_checkpoints(
        self,
        order: Sequence[int],
        batch_size: int = 1,
        first_task_resume: int = 0,
    ) -> List["CheckpointSite"]:
        """Cost-chosen mid-suffix activation-checkpoint placement.

        Walks the group's execution (the same walk as :meth:`_predict_into`)
        accumulating the *re-execution* cost a power failure would incur
        since the last durable point (group start, or the previous
        checkpoint), and emits a checkpoint after a block exactly when that
        accumulated cost has reached the checkpoint's own write cost — the
        classic intermittent-computing placement rule: never spend more
        writing state than the state saves on replay.

        Sites land at block-depth boundaries strictly inside a task's
        executed suffix (never after its final block: the group commit — or
        the next task's own prefix sharing — covers everything beyond).
        Both the journaling engine (execution) and the predictor consume
        the same plan, so ``checkpoint_bytes`` / ``checkpoint_seconds``
        stay exact by construction.
        """
        sites: List[CheckpointSite] = []
        depth = self.graph.depth
        reexec = 0.0
        prev: Optional[int] = None
        # Same activation-floor rule as ``_predict_into``: a task whose
        # shared prefix ends below the restored checkpoint's floor resumes
        # from 0 — its checkpoint sites must be planned for that walk.
        act_floor = max(int(first_task_resume) - 1, 0)
        for pos, t in enumerate(order):
            if prev is None:
                shared = int(first_task_resume)
            else:
                shared = self.graph.shared_prefix_depth(prev, t)
                if 0 < shared <= act_floor:
                    shared = 0
            act_floor = min(act_floor, shared)
            for d in range(shared, depth):
                reexec += self._block_reexec_cost(d, batch_size)
                if d >= depth - 1:
                    continue  # suffix boundary: commit/prefix takes over
                if reexec >= self._checkpoint_write_cost(d, batch_size):
                    sites.append(CheckpointSite(
                        pos=pos,
                        task=t,
                        depth=d,
                        bytes=self.checkpoint_bytes(d, batch_size),
                        seconds=self.checkpoint_write_seconds(d, batch_size),
                    ))
                    reexec = 0.0
            prev = t
        return sites

    def residency_after(
        self, order: Sequence[int], resident: Optional[Residency] = None
    ) -> Tuple[Optional[NodeId], ...]:
        """Residency left behind by executing ``order``.

        Every task's path covers all depths, so after a non-empty order the
        resident block at each depth belongs to the *last* executed task;
        an empty order leaves ``resident`` untouched.  This is what planners
        (per-plan order re-solving, admission policies) use to simulate the
        executor's state between groups without touching the executor.
        """
        if order:
            return tuple(self.graph.path(order[-1]))
        if resident is None:
            return (None,) * self.graph.depth
        return tuple(resident)


@dataclasses.dataclass(frozen=True)
class CheckpointSite:
    """One planned mid-suffix activation checkpoint.

    ``pos`` indexes the group's execution order, ``task``/``depth`` name the
    block-depth boundary the checkpoint follows (the executor cuts its fused
    suffix there and fires the journal hook), and ``bytes``/``seconds`` are
    the durable write's modelled cost — the exact values both the executed
    counters and the prediction add.
    """

    pos: int
    task: int
    depth: int
    bytes: float
    seconds: float


class PlanPredictor:
    """Incremental counter prediction for incrementally-admitted plans.

    A :class:`~repro.serving.session.ServingSession` does not know its full
    group schedule up front — groups are admitted over time by a scheduling
    policy.  This object is the incremental form of
    :meth:`GraphCostModel.predicted_group_stats`: call :meth:`append` with
    each group's ``(order, batch_size)`` in execution sequence and the
    tracked residency carries group-to-group exactly as the warm engine's
    executor does.  ``carry_residency=False`` re-predicts every group from a
    cold slate (the ``warm_start=False`` engine's semantics).

    ``stats`` is the cumulative prediction so far — realized-conditional
    when groups append with their ``gate_trace``; :meth:`append` returns
    the per-group delta.  ``expected`` accumulates the parallel
    *pre-execution* prediction under the model's (or per-append) gate
    model: its residency walk is tracked separately because a trace's
    whole-group-gated tasks do not advance residency while the expected
    (structural all-run) walk does.
    """

    def __init__(
        self,
        model: GraphCostModel,
        resume: Optional[Residency] = None,
        carry_residency: bool = True,
    ):
        self.model = model
        self.carry_residency = carry_residency
        depth = model.graph.depth
        self._resident: List[Optional[NodeId]] = (
            list(resume) if resume is not None else [None] * depth
        )
        if len(self._resident) != depth:
            raise ValueError(
                f"resume has {len(self._resident)} slots, expected {depth}"
            )
        self._exp_resident: List[Optional[NodeId]] = list(self._resident)
        self.stats = ExecutionStats()
        self.expected = ExecutionStats()
        self.groups = 0

    @property
    def residency(self) -> Tuple[Optional[NodeId], ...]:
        """The tracked residency after every appended group."""
        return tuple(self._resident)

    def append(
        self,
        order: Sequence[int],
        batch_size: int = 1,
        extra_tasks_skipped: int = 0,
        collectives: Optional["CollectiveCosts"] = None,
        overlap_seconds: Optional[float] = None,
        first_task_resume: int = 0,
        checkpoints: Optional[Sequence[CheckpointSite]] = None,
        gate_trace: Optional[Sequence[TaskGateRecord]] = None,
        gate_model: Optional[Any] = None,
    ) -> ExecutionStats:
        """Account one more admitted group; returns that group's delta.

        ``extra_tasks_skipped`` lets callers fold in schedule-level skips
        (engine tasks outside the group's requested subset) so the
        cumulative prediction matches the engine's counters field-for-field.
        ``collectives`` adds the mesh-sharded collective bytes of this
        group's dispatches (see ``GraphCostModel.predicted_stats``).

        ``overlap_seconds`` (not ``None``) predicts a *streamed* group: the
        group's loads were prefetched behind a compute window of that many
        seconds, so the delta's ``prefetched_bytes`` equals its loaded bytes
        and ``stream_stall_seconds`` is whatever portion of the load time
        did not fit in the window (``GraphCostModel.prefetch_stall_seconds``).

        ``first_task_resume`` and ``checkpoints`` predict an
        intermittent-execution group: the former a crash-recovered group
        resuming its first task from a restored activation checkpoint, the
        latter the group's planned checkpoint writes
        (``GraphCostModel.plan_checkpoints``) folded into
        ``checkpoint_bytes`` / ``checkpoint_seconds``.

        ``gate_trace`` conditions this group's *realized* delta on its
        executed gate outcome (``GraphCostModel.predicted_stats`` semantics)
        while ``gate_model`` (defaults to the model's own) drives the
        parallel ``expected`` accumulator's delta — both walks run every
        append so the two residency tracks stay consistent.
        """
        if not self.carry_residency:
            self._resident = [None] * self.model.graph.depth
            self._exp_resident = [None] * self.model.graph.depth
        gm = gate_model if gate_model is not None else self.model.gate_model
        loads = (
            self.model.plan_loads(order, self._resident, gate_trace=gate_trace)
            if overlap_seconds is not None
            else []
        )
        exp_loads = (
            self.model.plan_loads(order, self._exp_resident)
            if overlap_seconds is not None
            else []
        )
        delta = ExecutionStats()
        self.model._predict_into(
            order, int(batch_size), self._resident, delta, collectives,
            first_task_resume=first_task_resume,
            gate_trace=gate_trace,
        )
        exp_delta = ExecutionStats()
        self.model._predict_into(
            order, int(batch_size), self._exp_resident, exp_delta, collectives,
            first_task_resume=first_task_resume,
            gate_model=gm,
        )
        for site in checkpoints or ():
            delta.checkpoint_bytes += site.bytes
            delta.checkpoint_seconds += site.seconds
            exp_delta.checkpoint_bytes += site.bytes
            exp_delta.checkpoint_seconds += site.seconds
        if overlap_seconds is not None and loads:
            delta.prefetched_bytes = sum(
                self.model.block_costs[d].weight_bytes for d, _node in loads
            )
            delta.stream_stall_seconds = self.model.prefetch_stall_seconds(
                [d for d, _node in loads], overlap_seconds
            )
        if overlap_seconds is not None and exp_loads:
            exp_delta.prefetched_bytes = sum(
                self.model.block_costs[d].weight_bytes
                for d, _node in exp_loads
            )
            exp_delta.stream_stall_seconds = (
                self.model.prefetch_stall_seconds(
                    [d for d, _node in exp_loads], overlap_seconds
                )
            )
        delta.tasks_skipped += int(extra_tasks_skipped)
        exp_delta.tasks_skipped += int(extra_tasks_skipped)
        self.stats = self.stats.merge(delta)
        self.expected = self.expected.merge(exp_delta)
        self.groups += 1
        return delta


class CollectiveCosts(Protocol):
    """Per-dispatch collective-byte source for counter predictions.

    ``breakdown(task, resume)`` returns the per-kind collective bytes (HLO
    kind name -> bytes) of the fused-suffix program that runs ``task``
    resuming at depth ``resume`` — ``TaskGraphExecutor.collective_view``
    is the calibrated implementation.
    """

    def breakdown(self, task: int, resume: int) -> Dict[str, float]:
        ...


def uniform_block_costs(
    depth: int, weight_bytes: float = 1.0, flops: float = 1.0
) -> List[BlockCost]:
    """Equal-cost blocks — the paper's Figure-4 illustration setting."""
    return [BlockCost(weight_bytes=weight_bytes, flops=flops) for _ in range(depth)]
