"""Switching-cost model (paper §4.1) and task-graph cost estimation.

The cost matrix ``C`` has ``c[i, j]`` = additional cost of loading and
executing task ``j`` given that task ``i`` just ran: the blocks on ``j``'s
path that are *not* shared with ``i`` must be loaded into the fast tier and
executed; shared-prefix blocks are skipped entirely because the executor
caches both their weights (already resident) and their output activations
(paper §2.3).  Because all paths run the same common architecture, block
cost depends only on depth, and the matrix is symmetric — exactly the
paper's observation.

Costs can be measured in seconds or joules through a
:class:`~repro.core.types.HardwareModel`; the unit-cost mode (``hw=None``)
reproduces the paper's Figure-4 example where every block costs 1.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Protocol, Sequence, Tuple

import numpy as np

from repro.core.task_graph import TaskGraph
from repro.core.types import (
    BlockCost, ExecutionStats, HardwareModel, NodeId, Residency,
)


@dataclasses.dataclass(frozen=True)
class GraphCostModel:
    """Cost model for a task graph given per-depth block costs.

    Attributes:
      graph: the task graph.
      block_costs: length ``D + 1`` per-depth :class:`BlockCost` of the common
        architecture (the paper profiles these empirically on-device; we
        derive them from the model definition's FLOP/byte counters).
      hw: platform; ``None`` means abstract unit costs (1 load + 1 exec per
        block, as in the paper's Figure 4 walkthrough).
      metric: ``"time"`` or ``"energy"`` (paper evaluates both).
      weight_shards: how many ways block weights are sharded over a device
        mesh (``ShardingPolicy.weight_shards``): every load term divides by
        it — each chip streams only its slice — so the ordering solvers
        minimize the *sharded* schedule cost rather than the single-device
        proxy.  ``1`` (single device) reproduces the original model exactly.
    """

    graph: TaskGraph
    block_costs: Sequence[BlockCost]
    hw: Optional[HardwareModel] = None
    metric: str = "time"
    weight_shards: int = 1

    def block_cost(self, depth: int) -> float:
        """Load + execute cost of the depth-``depth`` block."""
        if self.hw is None:
            return 2.0  # 1 unit load + 1 unit exec, Figure-4 convention
        bc = self.block_costs[depth]
        if self.metric == "energy":
            return (
                self.hw.energy_joules(bc.flops, bc.act_bytes)
                + self.load_cost(depth)
            )
        return bc.exec_seconds(self.hw) + self.load_cost(depth)

    def task_cost(self, task: int) -> float:
        """Cold cost of running ``task`` with nothing cached."""
        return sum(self.block_cost(d) for d, _ in self.graph.path(task))

    def load_cost(self, depth: int) -> float:
        """Load-only component of :meth:`block_cost` (weight streaming).

        This is the part of a block's cost that warm starts can save: the
        execute part is always paid for a fresh input, but the load is
        skipped whenever the block is still resident from an earlier group.
        Sharded weights (``weight_shards > 1``) stream in parallel, one
        slice per chip, so the term divides accordingly.
        """
        if self.hw is None:
            return 1.0  # the Figure-4 unit-load convention
        bc = self.block_costs[depth]
        if self.metric == "energy":
            return (
                self.hw.energy_joules(0.0, 2.0 * bc.weight_bytes)
                / max(self.weight_shards, 1)
            )
        return bc.load_seconds(self.hw) / max(self.weight_shards, 1)

    def switching_cost(self, prev: int, nxt: int) -> float:
        """``c[prev, nxt]``: cost of the non-shared suffix of ``nxt``."""
        if prev == nxt:
            return 0.0
        shared = self.graph.shared_prefix_depth(prev, nxt)
        return sum(
            self.block_cost(d) for d in range(shared, self.graph.depth)
        )

    def warm_switching_cost(self, prev: int, nxt: int) -> float:
        """Load-only cost of starting ``nxt`` with ``prev``'s path resident.

        The inter-*group* analogue of :meth:`switching_cost`: across a group
        boundary activations never survive (they belong to the previous
        group's inputs), so every block of ``nxt`` executes — only the loads
        of the still-resident shared prefix are saved.  This is the edge
        weight of the group-ordering pass.
        """
        shared = self.graph.shared_prefix_depth(prev, nxt)
        return sum(self.load_cost(d) for d in range(shared, self.graph.depth))

    def resume_load_cost(self, resident: Residency, task: int) -> float:
        """Load cost of ``task``'s blocks not present in ``resident``.

        Generalises :meth:`warm_switching_cost` to an arbitrary residency
        snapshot (``TaskGraphExecutor.residency_state()``), e.g. the state a
        persistent engine carries between ``serve_batch`` calls.
        """
        path = self.graph.path(task)
        return sum(
            self.load_cost(d)
            for d in range(self.graph.depth)
            if resident[d] != path[d]
        )

    def cost_matrix(self) -> np.ndarray:
        """The full symmetric ``n x n`` cost matrix (Eq. 3)."""
        n = self.graph.num_tasks
        c = np.zeros((n, n), dtype=np.float64)
        for i in range(n):
            for j in range(n):
                if i != j:
                    c[i, j] = self.switching_cost(i, j)
        return c

    # ----------------------------------------------------------- aggregates
    def order_cost(self, order: Sequence[int], cyclic: bool = False) -> float:
        """Total cost of executing all tasks in ``order``.

        First task pays its cold cost; every subsequent task pays the
        switching cost from its predecessor.  With ``cyclic=True`` the
        wrap-around switch is added (the ILP's Hamiltonian-cycle objective);
        the paper's fitness (Eq. 7) is the path version.
        """
        total = self.task_cost(order[0])
        for a, b in zip(order[:-1], order[1:]):
            total += self.switching_cost(a, b)
        if cyclic and len(order) > 1:
            total += self.switching_cost(order[-1], order[0])
        return total

    def storage_bytes(self) -> float:
        """Total weight bytes of the task graph (Table 4/5 'memory')."""
        total = 0.0
        for d, _g in self.graph.nodes():
            total += self.block_costs[d].weight_bytes
        return total

    def vanilla_storage_bytes(self) -> float:
        """Storage if every task kept its own full network (Vanilla)."""
        per_task = sum(bc.weight_bytes for bc in self.block_costs)
        return per_task * self.graph.num_tasks

    def _predict_into(
        self,
        order: Sequence[int],
        batch_size: int,
        resident: List[Optional[NodeId]],
        stats: ExecutionStats,
        collectives: Optional["CollectiveCosts"] = None,
        first_task_resume: int = 0,
    ) -> None:
        """One group's counter prediction, mutating ``resident``/``stats``.

        Mirrors ``TaskGraphExecutor._run_task_impl`` exactly: the first task
        of a group never resumes from activations (the executor clears them
        at every input/group boundary), but any block still resident in
        ``resident`` skips its load while still executing.  The one
        exception is crash recovery: ``first_task_resume`` is the resume
        depth of the order's *first* task when a journaled mid-suffix
        activation checkpoint was restored into the executor
        (``TaskGraphExecutor.restore_activation``) — blocks below it skip
        both load and execute, exactly as a shared prefix would.

        A restored checkpoint also punches a hole in the activation cache:
        depths *below* the checkpoint were never computed this boot, so a
        later task whose shared prefix with its predecessor ends below that
        floor finds no cached activation at all and resumes from 0 (the
        executor's deepest-match rule).  ``act_floor`` tracks the
        shallowest cached depth — ``first_task_resume - 1`` after a
        restore, and 0 again as soon as any task re-executes from the root.

        ``collectives`` (``TaskGraphExecutor.collective_view``) adds the
        mesh-sharded collective bytes of each task's fused-suffix dispatch:
        for an ungated engine the executor resumes task ``t`` exactly at the
        shared-prefix depth with its predecessor, so the per-``(task,
        resume)`` calibrated breakdown lands on the same counters the
        executor will report — exact by construction.
        """
        prev: Optional[int] = None
        act_floor = max(int(first_task_resume) - 1, 0)
        for t in order:
            path = self.graph.path(t)
            if prev is None:
                shared = int(first_task_resume)
            else:
                shared = self.graph.shared_prefix_depth(prev, t)
                if 0 < shared <= act_floor:
                    # The shared activation this resume needs sits below
                    # the restored checkpoint's floor — it never existed
                    # this boot, so the executor starts the task from 0.
                    shared = 0
            act_floor = min(act_floor, shared)
            for d in range(self.graph.depth):
                bc = self.block_costs[d]
                if d < shared:
                    # Skipped prefix: the executor touches neither the
                    # weights nor the residency here.  With an ordinary
                    # shared prefix ``resident[d]`` already equals
                    # ``path[d]`` (the predecessor walked it); after a
                    # checkpoint restore it may not — those weights were
                    # never loaded this boot, and leaving residency as-is
                    # predicts the later reload the executor will do.
                    stats.blocks_skipped += 1
                    stats.weight_bytes_skipped += bc.weight_bytes
                    stats.flops_skipped += batch_size * bc.flops
                else:
                    stats.blocks_executed += 1
                    if resident[d] == path[d]:
                        stats.weight_bytes_skipped += bc.weight_bytes
                    else:
                        stats.weight_bytes_loaded += bc.weight_bytes
                    stats.flops_executed += batch_size * bc.flops
                    resident[d] = path[d]
            stats.tasks_run += batch_size
            if collectives is not None:
                stats.add_collectives(collectives.breakdown(t, shared))
            prev = t

    def predicted_stats(
        self,
        order: Sequence[int],
        batch_size: int = 1,
        resume: Optional[Residency] = None,
        collectives: Optional["CollectiveCosts"] = None,
        first_task_resume: int = 0,
        checkpoints: Optional[Sequence["CheckpointSite"]] = None,
    ) -> ExecutionStats:
        """Counter-level prediction the executor must match exactly.

        With ``batch_size > 1`` this predicts the *batched* executor
        (``TaskGraphExecutor.run_batch`` serving ``batch_size`` stacked
        requests): block invocations and weight loads happen once per group
        (loads amortise across the batch), while flop and task counters
        scale per request.  ``batch_size=1`` is the original single-request
        prediction.

        ``resume`` is an initial residency snapshot
        (``TaskGraphExecutor.residency_state()``) for *warm* starts: blocks
        already resident skip their loads but still execute (activations
        never cross a group boundary).  ``resume=None`` is the cold
        prediction.

        ``collectives`` is the executor's per-dispatch collective-byte view
        for the group's (padded) batch shape; see :meth:`_predict_into`.

        ``first_task_resume`` predicts a crash-recovered group whose first
        task resumes from a restored activation checkpoint at that depth;
        ``checkpoints`` (a :meth:`plan_checkpoints` plan) adds the group's
        checkpoint-write counters, which the journaling engine accounts
        from the *same* plan — exact by construction.
        """
        resident: List[Optional[NodeId]] = (
            list(resume) if resume is not None else [None] * self.graph.depth
        )
        if len(resident) != self.graph.depth:
            raise ValueError(
                f"resume has {len(resident)} slots, expected {self.graph.depth}"
            )
        stats = ExecutionStats()
        self._predict_into(
            order, batch_size, resident, stats, collectives,
            first_task_resume=first_task_resume,
        )
        for site in checkpoints or ():
            stats.checkpoint_bytes += site.bytes
            stats.checkpoint_seconds += site.seconds
        return stats

    def predicted_group_stats(
        self,
        plan: Sequence[Tuple[Sequence[int], int]],
        resume: Optional[Residency] = None,
    ) -> ExecutionStats:
        """Cumulative prediction for a warm multi-group schedule.

        ``plan`` is the executed schedule: one ``(order, batch_size)`` entry
        per group, in execution sequence, where ``order`` lists the tasks
        that group actually runs (the engine's task order filtered to the
        group's subset — or that group's re-solved per-plan order) and
        ``batch_size`` its valid (unpadded) request count.  Residency
        carries from each group into the next — activations do not — so
        this predicts exactly what the warm-start engine's cumulative
        counters will be.  ``resume`` seeds the initial residency (a
        persistent engine warm from earlier batches).

        Sessions that admit groups over time use the incremental form,
        :meth:`plan_predictor`, which this method is a one-shot wrapper
        around.
        """
        predictor = self.plan_predictor(resume=resume)
        for order, batch_size in plan:
            predictor.append(order, int(batch_size))
        return predictor.stats

    def plan_predictor(
        self,
        resume: Optional[Residency] = None,
        carry_residency: bool = True,
    ) -> "PlanPredictor":
        """An incremental predictor for incrementally-admitted plans."""
        return PlanPredictor(self, resume=resume, carry_residency=carry_residency)

    def plan_loads(
        self,
        order: Sequence[int],
        resident: Optional[Residency] = None,
    ) -> List[Tuple[int, NodeId]]:
        """The ``(depth, node)`` weight loads executing ``order`` will issue.

        Walks the same residency simulation as :meth:`_predict_into`, but
        instead of aggregating counters it returns the exact load sequence —
        every block that is *not* resident when its task reaches it.  This is
        the prefetch schedule the :class:`~repro.core.executor.WeightStreamer`
        stages for the next group: staging precisely this set makes the
        executor's ``prefetched_bytes`` counter equal the group's
        ``weight_bytes_loaded`` by construction, which is what keeps
        streaming prediction exact.

        ``resident`` is the residency at the start of the plan (``None`` =
        cold).  The returned list is in execution order and free of
        duplicates: an order that *revisits* an evicted block (interleaved
        subtrees, e.g. ``[0, 3, 1]``) re-loads it — and ``predicted_stats``
        counts those bytes twice — but the streamer stages one copy per
        node and the executor commits it at most once, so the schedule
        lists each node once, at its first load.  The revisit falls through
        to a synchronous load on both the predicted and executed side.
        """
        state: List[Optional[NodeId]] = (
            list(resident) if resident is not None else [None] * self.graph.depth
        )
        if len(state) != self.graph.depth:
            raise ValueError(
                f"resident has {len(state)} slots, expected {self.graph.depth}"
            )
        loads: List[Tuple[int, NodeId]] = []
        staged: set = set()
        prev: Optional[int] = None
        for t in order:
            path = self.graph.path(t)
            shared = (
                self.graph.shared_prefix_depth(prev, t) if prev is not None else 0
            )
            for d in range(shared, self.graph.depth):
                if state[d] != path[d] and path[d] not in staged:
                    loads.append((d, path[d]))
                    staged.add(path[d])
                state[d] = path[d]
            prev = t
        return loads

    def prefetch_stall_seconds(
        self, depths: Sequence[int], overlap_seconds: float
    ) -> float:
        """Modelled stall of streaming ``depths``' loads behind a compute
        window of ``overlap_seconds``.

        The double-buffered streamer moves the bytes while the *previous*
        group computes; whatever does not fit in that window stalls the next
        group's start.  Load terms use :meth:`load_cost`, so sharded weights
        stream one slice per chip exactly as the synchronous path models.
        """
        total = sum(self.load_cost(d) for d in depths)
        return max(total - max(overlap_seconds, 0.0), 0.0)

    # ------------------------------------------------------- checkpointing
    def checkpoint_bytes(self, depth: int, batch_size: int) -> float:
        """Durable bytes of checkpointing depth-``depth``'s activation for a
        ``batch_size``-request group (one activation row per request)."""
        return float(batch_size) * self.block_costs[depth].act_bytes

    def checkpoint_write_seconds(self, depth: int, batch_size: int) -> float:
        """Modelled seconds of writing that checkpoint to the durable tier.

        The durable tier is the same slow tier weights stream from (FRAM on
        the MSP430), so the write time uses ``hw.load_seconds`` — the unit
        convention (``hw=None``) charges 1, mirroring :meth:`load_cost`.
        """
        if self.hw is None:
            return 1.0
        return self.hw.load_seconds(self.checkpoint_bytes(depth, batch_size))

    def _checkpoint_write_cost(self, depth: int, batch_size: int) -> float:
        """Write cost in this model's metric (seconds or joules)."""
        if self.hw is None:
            return 1.0
        if self.metric == "energy":
            return self.hw.energy_joules(
                0.0, self.checkpoint_bytes(depth, batch_size)
            )
        return self.checkpoint_write_seconds(depth, batch_size)

    def _block_reexec_cost(self, depth: int, batch_size: int) -> float:
        """Metric cost of re-executing one block after a power failure.

        Compute-only: weight residency and activation checkpoints live in
        the durable tier and survive the crash, so replay pays execution
        but no loads.
        """
        if self.hw is None:
            return 1.0
        bc = self.block_costs[depth]
        if self.metric == "energy":
            return self.hw.energy_joules(batch_size * bc.flops, 0.0)
        return self.hw.exec_seconds(batch_size * bc.flops)

    def plan_checkpoints(
        self,
        order: Sequence[int],
        batch_size: int = 1,
        first_task_resume: int = 0,
    ) -> List["CheckpointSite"]:
        """Cost-chosen mid-suffix activation-checkpoint placement.

        Walks the group's execution (the same walk as :meth:`_predict_into`)
        accumulating the *re-execution* cost a power failure would incur
        since the last durable point (group start, or the previous
        checkpoint), and emits a checkpoint after a block exactly when that
        accumulated cost has reached the checkpoint's own write cost — the
        classic intermittent-computing placement rule: never spend more
        writing state than the state saves on replay.

        Sites land at block-depth boundaries strictly inside a task's
        executed suffix (never after its final block: the group commit — or
        the next task's own prefix sharing — covers everything beyond).
        Both the journaling engine (execution) and the predictor consume
        the same plan, so ``checkpoint_bytes`` / ``checkpoint_seconds``
        stay exact by construction.
        """
        sites: List[CheckpointSite] = []
        depth = self.graph.depth
        reexec = 0.0
        prev: Optional[int] = None
        # Same activation-floor rule as ``_predict_into``: a task whose
        # shared prefix ends below the restored checkpoint's floor resumes
        # from 0 — its checkpoint sites must be planned for that walk.
        act_floor = max(int(first_task_resume) - 1, 0)
        for pos, t in enumerate(order):
            if prev is None:
                shared = int(first_task_resume)
            else:
                shared = self.graph.shared_prefix_depth(prev, t)
                if 0 < shared <= act_floor:
                    shared = 0
            act_floor = min(act_floor, shared)
            for d in range(shared, depth):
                reexec += self._block_reexec_cost(d, batch_size)
                if d >= depth - 1:
                    continue  # suffix boundary: commit/prefix takes over
                if reexec >= self._checkpoint_write_cost(d, batch_size):
                    sites.append(CheckpointSite(
                        pos=pos,
                        task=t,
                        depth=d,
                        bytes=self.checkpoint_bytes(d, batch_size),
                        seconds=self.checkpoint_write_seconds(d, batch_size),
                    ))
                    reexec = 0.0
            prev = t
        return sites

    def residency_after(
        self, order: Sequence[int], resident: Optional[Residency] = None
    ) -> Tuple[Optional[NodeId], ...]:
        """Residency left behind by executing ``order``.

        Every task's path covers all depths, so after a non-empty order the
        resident block at each depth belongs to the *last* executed task;
        an empty order leaves ``resident`` untouched.  This is what planners
        (per-plan order re-solving, admission policies) use to simulate the
        executor's state between groups without touching the executor.
        """
        if order:
            return tuple(self.graph.path(order[-1]))
        if resident is None:
            return (None,) * self.graph.depth
        return tuple(resident)


@dataclasses.dataclass(frozen=True)
class CheckpointSite:
    """One planned mid-suffix activation checkpoint.

    ``pos`` indexes the group's execution order, ``task``/``depth`` name the
    block-depth boundary the checkpoint follows (the executor cuts its fused
    suffix there and fires the journal hook), and ``bytes``/``seconds`` are
    the durable write's modelled cost — the exact values both the executed
    counters and the prediction add.
    """

    pos: int
    task: int
    depth: int
    bytes: float
    seconds: float


class PlanPredictor:
    """Incremental counter prediction for incrementally-admitted plans.

    A :class:`~repro.serving.session.ServingSession` does not know its full
    group schedule up front — groups are admitted over time by a scheduling
    policy.  This object is the incremental form of
    :meth:`GraphCostModel.predicted_group_stats`: call :meth:`append` with
    each group's ``(order, batch_size)`` in execution sequence and the
    tracked residency carries group-to-group exactly as the warm engine's
    executor does.  ``carry_residency=False`` re-predicts every group from a
    cold slate (the ``warm_start=False`` engine's semantics).

    ``stats`` is the cumulative prediction so far; :meth:`append` returns
    the per-group delta.
    """

    def __init__(
        self,
        model: GraphCostModel,
        resume: Optional[Residency] = None,
        carry_residency: bool = True,
    ):
        self.model = model
        self.carry_residency = carry_residency
        depth = model.graph.depth
        self._resident: List[Optional[NodeId]] = (
            list(resume) if resume is not None else [None] * depth
        )
        if len(self._resident) != depth:
            raise ValueError(
                f"resume has {len(self._resident)} slots, expected {depth}"
            )
        self.stats = ExecutionStats()
        self.groups = 0

    @property
    def residency(self) -> Tuple[Optional[NodeId], ...]:
        """The tracked residency after every appended group."""
        return tuple(self._resident)

    def append(
        self,
        order: Sequence[int],
        batch_size: int = 1,
        extra_tasks_skipped: int = 0,
        collectives: Optional["CollectiveCosts"] = None,
        overlap_seconds: Optional[float] = None,
        first_task_resume: int = 0,
        checkpoints: Optional[Sequence[CheckpointSite]] = None,
    ) -> ExecutionStats:
        """Account one more admitted group; returns that group's delta.

        ``extra_tasks_skipped`` lets callers fold in schedule-level skips
        (engine tasks outside the group's requested subset) so the
        cumulative prediction matches the engine's counters field-for-field.
        ``collectives`` adds the mesh-sharded collective bytes of this
        group's dispatches (see ``GraphCostModel.predicted_stats``).

        ``overlap_seconds`` (not ``None``) predicts a *streamed* group: the
        group's loads were prefetched behind a compute window of that many
        seconds, so the delta's ``prefetched_bytes`` equals its loaded bytes
        and ``stream_stall_seconds`` is whatever portion of the load time
        did not fit in the window (``GraphCostModel.prefetch_stall_seconds``).

        ``first_task_resume`` and ``checkpoints`` predict an
        intermittent-execution group: the former a crash-recovered group
        resuming its first task from a restored activation checkpoint, the
        latter the group's planned checkpoint writes
        (``GraphCostModel.plan_checkpoints``) folded into
        ``checkpoint_bytes`` / ``checkpoint_seconds``.
        """
        if not self.carry_residency:
            self._resident = [None] * self.model.graph.depth
        loads = (
            self.model.plan_loads(order, self._resident)
            if overlap_seconds is not None
            else []
        )
        delta = ExecutionStats()
        self.model._predict_into(
            order, int(batch_size), self._resident, delta, collectives,
            first_task_resume=first_task_resume,
        )
        for site in checkpoints or ():
            delta.checkpoint_bytes += site.bytes
            delta.checkpoint_seconds += site.seconds
        if overlap_seconds is not None and loads:
            delta.prefetched_bytes = sum(
                self.model.block_costs[d].weight_bytes for d, _node in loads
            )
            delta.stream_stall_seconds = self.model.prefetch_stall_seconds(
                [d for d, _node in loads], overlap_seconds
            )
        delta.tasks_skipped += int(extra_tasks_skipped)
        self.stats = self.stats.merge(delta)
        self.groups += 1
        return delta


class CollectiveCosts(Protocol):
    """Per-dispatch collective-byte source for counter predictions.

    ``breakdown(task, resume)`` returns the per-kind collective bytes (HLO
    kind name -> bytes) of the fused-suffix program that runs ``task``
    resuming at depth ``resume`` — ``TaskGraphExecutor.collective_view``
    is the calibrated implementation.
    """

    def breakdown(self, task: int, resume: int) -> Dict[str, float]:
        ...


def uniform_block_costs(
    depth: int, weight_bytes: float = 1.0, flops: float = 1.0
) -> List[BlockCost]:
    """Equal-cost blocks — the paper's Figure-4 illustration setting."""
    return [BlockCost(weight_bytes=weight_bytes, flops=flops) for _ in range(depth)]
