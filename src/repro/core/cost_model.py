"""Switching-cost model (paper §4.1) and task-graph cost estimation.

The cost matrix ``C`` has ``c[i, j]`` = additional cost of loading and
executing task ``j`` given that task ``i`` just ran: the blocks on ``j``'s
path that are *not* shared with ``i`` must be loaded into the fast tier and
executed; shared-prefix blocks are skipped entirely because the executor
caches both their weights (already resident) and their output activations
(paper §2.3).  Because all paths run the same common architecture, block
cost depends only on depth, and the matrix is symmetric — exactly the
paper's observation.

Costs can be measured in seconds or joules through a
:class:`~repro.core.types.HardwareModel`; the unit-cost mode (``hw=None``)
reproduces the paper's Figure-4 example where every block costs 1.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core.task_graph import TaskGraph
from repro.core.types import BlockCost, ExecutionStats, HardwareModel


@dataclasses.dataclass(frozen=True)
class GraphCostModel:
    """Cost model for a task graph given per-depth block costs.

    Attributes:
      graph: the task graph.
      block_costs: length ``D + 1`` per-depth :class:`BlockCost` of the common
        architecture (the paper profiles these empirically on-device; we
        derive them from the model definition's FLOP/byte counters).
      hw: platform; ``None`` means abstract unit costs (1 load + 1 exec per
        block, as in the paper's Figure 4 walkthrough).
      metric: ``"time"`` or ``"energy"`` (paper evaluates both).
    """

    graph: TaskGraph
    block_costs: Sequence[BlockCost]
    hw: Optional[HardwareModel] = None
    metric: str = "time"

    def block_cost(self, depth: int) -> float:
        """Load + execute cost of the depth-``depth`` block."""
        if self.hw is None:
            return 2.0  # 1 unit load + 1 unit exec, Figure-4 convention
        bc = self.block_costs[depth]
        if self.metric == "energy":
            return bc.energy_joules(self.hw)
        return bc.total_seconds(self.hw)

    def task_cost(self, task: int) -> float:
        """Cold cost of running ``task`` with nothing cached."""
        return sum(self.block_cost(d) for d, _ in self.graph.path(task))

    def switching_cost(self, prev: int, nxt: int) -> float:
        """``c[prev, nxt]``: cost of the non-shared suffix of ``nxt``."""
        if prev == nxt:
            return 0.0
        shared = self.graph.shared_prefix_depth(prev, nxt)
        return sum(
            self.block_cost(d) for d in range(shared, self.graph.depth)
        )

    def cost_matrix(self) -> np.ndarray:
        """The full symmetric ``n x n`` cost matrix (Eq. 3)."""
        n = self.graph.num_tasks
        c = np.zeros((n, n), dtype=np.float64)
        for i in range(n):
            for j in range(n):
                if i != j:
                    c[i, j] = self.switching_cost(i, j)
        return c

    # ----------------------------------------------------------- aggregates
    def order_cost(self, order: Sequence[int], cyclic: bool = False) -> float:
        """Total cost of executing all tasks in ``order``.

        First task pays its cold cost; every subsequent task pays the
        switching cost from its predecessor.  With ``cyclic=True`` the
        wrap-around switch is added (the ILP's Hamiltonian-cycle objective);
        the paper's fitness (Eq. 7) is the path version.
        """
        total = self.task_cost(order[0])
        for a, b in zip(order[:-1], order[1:]):
            total += self.switching_cost(a, b)
        if cyclic and len(order) > 1:
            total += self.switching_cost(order[-1], order[0])
        return total

    def storage_bytes(self) -> float:
        """Total weight bytes of the task graph (Table 4/5 'memory')."""
        total = 0.0
        for d, _g in self.graph.nodes():
            total += self.block_costs[d].weight_bytes
        return total

    def vanilla_storage_bytes(self) -> float:
        """Storage if every task kept its own full network (Vanilla)."""
        per_task = sum(bc.weight_bytes for bc in self.block_costs)
        return per_task * self.graph.num_tasks

    def predicted_stats(
        self, order: Sequence[int], batch_size: int = 1
    ) -> ExecutionStats:
        """Counter-level prediction the executor must match exactly.

        With ``batch_size > 1`` this predicts the *batched* executor
        (``TaskGraphExecutor.run_batch`` on a cold executor serving
        ``batch_size`` stacked requests): block invocations and weight loads
        happen once per group (loads amortise across the batch), while flop
        and task counters scale per request.  ``batch_size=1`` is the
        original single-request prediction.
        """
        stats = ExecutionStats()
        prev: Optional[int] = None
        for t in order:
            shared = (
                self.graph.shared_prefix_depth(prev, t) if prev is not None else 0
            )
            for d in range(self.graph.depth):
                bc = self.block_costs[d]
                if d < shared:
                    stats.blocks_skipped += 1
                    stats.weight_bytes_skipped += bc.weight_bytes
                    stats.flops_skipped += batch_size * bc.flops
                else:
                    stats.blocks_executed += 1
                    stats.weight_bytes_loaded += bc.weight_bytes
                    stats.flops_executed += batch_size * bc.flops
            stats.tasks_run += batch_size
            prev = t
        return stats


def uniform_block_costs(
    depth: int, weight_bytes: float = 1.0, flops: float = 1.0
) -> List[BlockCost]:
    """Equal-cost blocks — the paper's Figure-4 illustration setting."""
    return [BlockCost(weight_bytes=weight_bytes, flops=flops) for _ in range(depth)]
