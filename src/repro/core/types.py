"""Core datatypes shared across the Antler framework.

The paper measures cost in wall-clock time or energy on an MCU.  On the TPU
target we cannot measure wall-clock in this container, so every cost in the
framework is expressed through :class:`HardwareModel` as derived *seconds*
from three roofline terms (compute / memory / interconnect).  The same
abstraction also lets the paper-scale benchmarks use MCU-like constants so
the reproduction numbers are directly comparable with the paper's ratios.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

# A task-graph block node: (depth, group of tasks sharing it).  The executor
# and the cost model both key residency by these.
NodeId = Tuple[int, Tuple[int, ...]]
# Per-depth resident block (None = slot empty): what
# TaskGraphExecutor.residency_state() returns and what
# GraphCostModel.predicted_stats accepts as ``resume``.
Residency = Sequence[Optional[NodeId]]


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """Roofline constants of the execution platform.

    Attributes:
      name: human-readable platform name.
      peak_flops: peak FLOP/s per chip (bf16 for TPU targets).
      mem_bw: main-memory (HBM / FRAM / flash) bandwidth in bytes/s.
      link_bw: inter-chip link bandwidth in bytes/s (0 for single-chip MCUs).
      weight_load_bw: bandwidth for streaming weights from the *slow* tier
        (flash->SRAM on the MCU, host->HBM or HBM->VMEM on TPU).  This is the
        bandwidth that gives task switching its cost in the paper.
      joules_per_flop / joules_per_byte: optional energy model terms; the
        paper reports energy as well as time, so the benchmarks derive energy
        from the same counters.
    """

    name: str
    peak_flops: float
    mem_bw: float
    link_bw: float = 0.0
    weight_load_bw: Optional[float] = None
    joules_per_flop: float = 0.0
    joules_per_byte: float = 0.0

    @property
    def load_bw(self) -> float:
        return self.weight_load_bw if self.weight_load_bw is not None else self.mem_bw

    def exec_seconds(self, flops: float, bytes_touched: float = 0.0) -> float:
        """Roofline execution time of a block: max(compute, memory) term."""
        t_compute = flops / self.peak_flops if self.peak_flops else 0.0
        t_memory = bytes_touched / self.mem_bw if self.mem_bw else 0.0
        return max(t_compute, t_memory)

    def load_seconds(self, weight_bytes: float) -> float:
        """Time to bring a block's weights into the fast tier."""
        return weight_bytes / self.load_bw if self.load_bw else 0.0

    def link_seconds(self, collective_bytes: float) -> float:
        """Time the inter-chip collectives of a sharded program take."""
        return collective_bytes / self.link_bw if self.link_bw else 0.0

    def energy_joules(self, flops: float, bytes_moved: float) -> float:
        return flops * self.joules_per_flop + bytes_moved * self.joules_per_byte


# TPU v5e constants given in the brief: 197 TFLOP/s bf16, 819 GB/s HBM,
# ~50 GB/s/link ICI.
TPU_V5E = HardwareModel(
    name="tpu-v5e",
    peak_flops=197e12,
    mem_bw=819e9,
    link_bw=50e9,
    # Weight swaps for cold task-graph branches come over PCIe/host DMA; a
    # conservative 10 GB/s models the "slow tier" that drives switching cost.
    weight_load_bw=10e9,
    # Rough public numbers for deriving an energy-style metric (J/op, J/byte).
    joules_per_flop=1.0e-12,
    joules_per_byte=60e-12,
)

# MCU-like platforms used by the paper-scale benchmarks so that the
# reproduction ratios (2.3x-4.6x etc.) are measured on comparable terms.
MSP430 = HardwareModel(
    name="msp430fr5994",
    peak_flops=2e6,          # ~16 MHz 16-bit MAC-per-8-cycles class
    mem_bw=8e6,              # SRAM
    link_bw=0.0,
    weight_load_bw=1e6,      # external FRAM streaming
    joules_per_flop=250e-12,
    joules_per_byte=120e-12,
)

STM32H747 = HardwareModel(
    name="stm32h747",
    peak_flops=2e8,          # ~480 MHz M7 w/ DSP MACs
    mem_bw=6.4e8,
    link_bw=0.0,
    weight_load_bw=1e8,      # eFlash read (~100 MB/s; the paper's Fig. 11
                             # shows near-invisible reload overhead on H747)
    joules_per_flop=30e-12,
    joules_per_byte=15e-12,
)


@dataclasses.dataclass(frozen=True)
class BlockCost:
    """Cost of one task-graph block.

    ``weight_bytes`` drives switching cost (the load part); ``flops`` and
    ``act_bytes`` drive the execute part.  All are *per single input*.
    """

    weight_bytes: float
    flops: float
    act_bytes: float = 0.0

    def exec_seconds(self, hw: HardwareModel) -> float:
        return hw.exec_seconds(self.flops, self.act_bytes + self.weight_bytes)

    def load_seconds(self, hw: HardwareModel) -> float:
        return hw.load_seconds(self.weight_bytes)

    def total_seconds(self, hw: HardwareModel) -> float:
        return self.exec_seconds(hw) + self.load_seconds(hw)

    def energy_joules(self, hw: HardwareModel) -> float:
        return hw.energy_joules(self.flops, 2.0 * self.weight_bytes + self.act_bytes)


@dataclasses.dataclass
class ExecutionStats:
    """Counters produced by the task-graph executor.

    These are the executor-side ground truth that the cost model predicts;
    tests assert the two agree.

    The ``*_collective_bytes`` counters are the per-kind inter-chip traffic
    of mesh-sharded execution, calibrated per fused-suffix dispatch from the
    lowered HLO (``repro.launch.hlo_cost``); they stay zero on single-device
    engines.  Flat floats (not a dict) so ``dataclasses.replace`` copies —
    handed to every response in a group — never share mutable state.
    """

    blocks_executed: int = 0
    blocks_skipped: int = 0
    weight_bytes_loaded: float = 0.0
    weight_bytes_skipped: float = 0.0
    flops_executed: float = 0.0
    flops_skipped: float = 0.0
    tasks_run: int = 0
    tasks_skipped: int = 0
    all_gather_bytes: float = 0.0
    all_reduce_bytes: float = 0.0
    reduce_scatter_bytes: float = 0.0
    other_collective_bytes: float = 0.0
    # Weight-streaming counters (``core.executor.WeightStreamer``): bytes of
    # ``weight_bytes_loaded`` that arrived via an asynchronous prefetch
    # overlapped with the previous group's compute, and the residual stall
    # (modelled seconds) where the prefetch outran its overlap window.  Both
    # stay zero on engines without ``EnginePolicy.streaming``.
    prefetched_bytes: float = 0.0
    stream_stall_seconds: float = 0.0
    # Intermittent-execution counters: bytes of mid-suffix activation
    # checkpoints written to the durable tier (FRAM on the paper's MSP430)
    # and the modelled seconds those writes took.  Placement is chosen by
    # ``GraphCostModel.plan_checkpoints`` (checkpoint only when the expected
    # re-execution cost exceeds the write cost), so both sides of the
    # ``session.stats == session.predicted`` invariant add identical terms.
    # Zero on engines without a journal.
    checkpoint_bytes: float = 0.0
    checkpoint_seconds: float = 0.0
    # Input-adaptive gating counters (``repro.adaptive``): per-(block, row)
    # fire/skip tallies of confidence-gated fused suffixes and the modelled
    # FLOPs the gated-off rows saved.  ``flops_executed`` counts only the
    # rows that actually fired, so modelled time/energy reflect the gating;
    # ``flops_gated`` is the remainder vs the all-blocks floor.  Floats (not
    # ints) because *expected* predictions under a ``GateModel`` are
    # fractional; realized counters are whole numbers of the same fields, so
    # realized-vs-predicted equality still compares exactly.  Zero on
    # engines without an ``AdaptivePolicy``.
    block_rows_fired: float = 0.0
    block_rows_gated: float = 0.0
    flops_gated: float = 0.0

    @property
    def collective_bytes(self) -> float:
        """Total inter-chip bytes across every collective kind."""
        return (
            self.all_gather_bytes
            + self.all_reduce_bytes
            + self.reduce_scatter_bytes
            + self.other_collective_bytes
        )

    def add_collectives(self, breakdown: "dict[str, float]") -> None:
        """Fold one dispatch's per-kind collective bytes (HLO kind names,
        as produced by ``repro.launch.hlo_cost.collective_breakdown``)."""
        for kind, nbytes in breakdown.items():
            if kind == "all-gather":
                self.all_gather_bytes += nbytes
            elif kind == "all-reduce":
                self.all_reduce_bytes += nbytes
            elif kind == "reduce-scatter":
                self.reduce_scatter_bytes += nbytes
            else:
                self.other_collective_bytes += nbytes

    def compute_seconds(self, hw: HardwareModel) -> float:
        """Modelled compute + interconnect seconds (no weight-load term).

        This is the window an overlapped weight stream can hide behind: the
        prefetcher for group ``k+1`` runs while group ``k``'s fused suffix
        executes, so this group's compute window bounds how many of the next
        group's load bytes come for free.
        """
        return (
            hw.exec_seconds(self.flops_executed)
            + hw.link_seconds(self.collective_bytes)
        )

    def seconds(self, hw: HardwareModel, weight_shards: int = 1) -> float:
        """Modelled wall-clock of these counters on ``hw``.

        ``weight_shards`` is how many ways the weights are sharded over the
        mesh (``ShardingPolicy.weight_shards``): each chip streams only its
        ``1/weight_shards`` slice, so the load term divides while the
        (per-chip) collective traffic adds a link term.

        With streaming, ``prefetched_bytes`` of the loads were overlapped
        with earlier compute and drop out of the synchronous load term; what
        could not be hidden is already accounted as ``stream_stall_seconds``
        — i.e. per group the modelled time is
        ``max(compute, overlapped_load) + sync_load`` expressed as
        ``compute + stall + sync_load``.
        """
        sync_bytes = max(self.weight_bytes_loaded - self.prefetched_bytes, 0.0)
        return (
            self.compute_seconds(hw)
            + hw.load_seconds(sync_bytes / max(weight_shards, 1))
            + self.stream_stall_seconds
            + self.checkpoint_seconds
        )

    def energy(self, hw: HardwareModel) -> float:
        return hw.energy_joules(
            self.flops_executed,
            2.0 * self.weight_bytes_loaded + self.checkpoint_bytes,
        )

    def compute_energy(self, hw: HardwareModel) -> float:
        """Joules of the compute term alone (no loads, no checkpoints).

        This is the energy a power failure can waste: weight residency and
        checkpoints live in the durable tier and survive a crash, but any
        compute since the last durable point must be re-executed on the next
        charge cycle.  The intermittent benchmark's re-execution gate
        compares this term across recovery strategies.
        """
        return hw.energy_joules(self.flops_executed, 0.0)

    def merge(self, other: "ExecutionStats") -> "ExecutionStats":
        return ExecutionStats(
            blocks_executed=self.blocks_executed + other.blocks_executed,
            blocks_skipped=self.blocks_skipped + other.blocks_skipped,
            weight_bytes_loaded=self.weight_bytes_loaded + other.weight_bytes_loaded,
            weight_bytes_skipped=self.weight_bytes_skipped + other.weight_bytes_skipped,
            flops_executed=self.flops_executed + other.flops_executed,
            flops_skipped=self.flops_skipped + other.flops_skipped,
            tasks_run=self.tasks_run + other.tasks_run,
            tasks_skipped=self.tasks_skipped + other.tasks_skipped,
            all_gather_bytes=self.all_gather_bytes + other.all_gather_bytes,
            all_reduce_bytes=self.all_reduce_bytes + other.all_reduce_bytes,
            reduce_scatter_bytes=(
                self.reduce_scatter_bytes + other.reduce_scatter_bytes
            ),
            other_collective_bytes=(
                self.other_collective_bytes + other.other_collective_bytes
            ),
            prefetched_bytes=self.prefetched_bytes + other.prefetched_bytes,
            stream_stall_seconds=(
                self.stream_stall_seconds + other.stream_stall_seconds
            ),
            checkpoint_bytes=self.checkpoint_bytes + other.checkpoint_bytes,
            checkpoint_seconds=(
                self.checkpoint_seconds + other.checkpoint_seconds
            ),
            block_rows_fired=self.block_rows_fired + other.block_rows_fired,
            block_rows_gated=self.block_rows_gated + other.block_rows_gated,
            flops_gated=self.flops_gated + other.flops_gated,
        )


@dataclasses.dataclass(frozen=True)
class TaskGateRecord:
    """One task's realized gate outcome inside a group's execution.

    The executor emits one record per task in the group's effective order
    (``TaskGraphExecutor.last_trace``); the cost model replays the same
    records (``GraphCostModel.predicted_stats(..., gate_trace=...)``) so the
    realized-conditional prediction stays field-exact under gating.

    Attributes:
      task: task id.
      weight: rows of the batch this task ran for (0 = the task's legacy
        ``gate=`` callback skipped it for the whole group — the executor
        never dispatched it, so replay must not advance residency or the
        activation cache past it).
      fired: per executed block depth (``resume`` .. ``depth-1``), how many
        of the ``weight`` rows the adaptive gate let through.  ``None``
        means no adaptive gater: every executed block fired for all rows.
      resume: the activation-resume depth the executor actually used, when
        the emitter knows it (cross-checked against the replay walk).
      offered: rows of the batch the task was *offered* (the group's valid
        count) before any legacy gate — what ``GateModelCalibrator`` uses
        as the denominator of the task fire probability.
    """

    task: int
    weight: int
    fired: Optional[Tuple[int, ...]] = None
    resume: Optional[int] = None
    offered: Optional[int] = None
