"""Task-affinity computation (paper §3.1).

Two-step process, faithful to the paper:

Step 1 (per task): profile the task's network at ``D`` branch points over
``K`` samples.  At each branch point the pairwise *dissimilarity* between the
representations of every pair of samples is the **inverse Pearson
correlation** ``1 - r``; this yields a ``D x K x K`` profile tensor per task.

Step 2 (per task pair): at each branch point, the **Spearman rank
correlation** between the two tasks' flattened ``K x K`` profiles gives the
affinity ``S[d, i, j]`` -> a ``D x n x n`` affinity tensor.

The pairwise-Pearson step is the compute hot spot (O(D K^2 F)); the Pallas
kernel in :mod:`repro.kernels.pearson_affinity` implements the same
centered-Gram formulation for TPU, and :func:`pairwise_pearson_dissimilarity`
is its jnp oracle.
"""
from __future__ import annotations

from typing import Callable, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def _standardize_rows(x: jnp.ndarray, eps: float = 1e-8) -> jnp.ndarray:
    """Center each row and scale it to unit L2 norm (Pearson normalisation)."""
    x = x - jnp.mean(x, axis=-1, keepdims=True)
    norm = jnp.linalg.norm(x, axis=-1, keepdims=True)
    return x / jnp.maximum(norm, eps)


def pairwise_pearson_dissimilarity(feats: jnp.ndarray) -> jnp.ndarray:
    """``1 - Pearson(r_i, r_j)`` for all sample pairs.

    Args:
      feats: ``(K, F)`` representations of ``K`` samples at one branch point.

    Returns:
      ``(K, K)`` dissimilarity matrix in ``[0, 2]``.
    """
    z = _standardize_rows(feats.astype(jnp.float32))
    corr = z @ z.T  # centered & normalised rows -> Gram == Pearson matrix
    return 1.0 - corr


def _rankdata(x: jnp.ndarray) -> jnp.ndarray:
    """Average-tie ranks of a 1-D array (Spearman prerequisite).

    Matches ``scipy.stats.rankdata(method='average')`` for the no-ties case
    and handles ties by averaging via a double argsort on (value, index).
    """
    n = x.shape[0]
    order = jnp.argsort(x, stable=True)
    ranks = jnp.empty(n, dtype=jnp.float32).at[order].set(
        jnp.arange(1, n + 1, dtype=jnp.float32)
    )
    # Average ranks over ties: for each element, mean rank of equal values.
    sorted_x = x[order]
    # Boundaries of tie groups in sorted order.
    new_group = jnp.concatenate(
        [jnp.array([True]), sorted_x[1:] != sorted_x[:-1]]
    )
    group_id = jnp.cumsum(new_group) - 1
    group_sum = jax.ops.segment_sum(
        jnp.arange(1, n + 1, dtype=jnp.float32), group_id, num_segments=n
    )
    group_cnt = jax.ops.segment_sum(
        jnp.ones(n, dtype=jnp.float32), group_id, num_segments=n
    )
    mean_rank_per_group = group_sum / jnp.maximum(group_cnt, 1.0)
    avg_sorted = mean_rank_per_group[group_id]
    return ranks.at[order].set(avg_sorted)


def spearman(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Spearman rank correlation between two flattened profile vectors."""
    ra, rb = _rankdata(a.reshape(-1)), _rankdata(b.reshape(-1))
    ra = ra - jnp.mean(ra)
    rb = rb - jnp.mean(rb)
    denom = jnp.linalg.norm(ra) * jnp.linalg.norm(rb)
    return jnp.where(denom > 0, jnp.dot(ra, rb) / jnp.maximum(denom, 1e-12), 0.0)


def profile_task(
    reps_at_branch_points: Sequence[jnp.ndarray],
) -> jnp.ndarray:
    """Step 1 for one task: stack per-branch-point ``K x K`` dissimilarities.

    Args:
      reps_at_branch_points: length-``D`` list of ``(K, F_d)`` representation
        matrices captured at each branch point (``F_d`` may differ per depth).

    Returns:
      ``(D, K, K)`` profile tensor.
    """
    return jnp.stack(
        [pairwise_pearson_dissimilarity(r.reshape(r.shape[0], -1))
         for r in reps_at_branch_points]
    )


def affinity_matrix(profiles: Sequence[jnp.ndarray]) -> jnp.ndarray:
    """Step 2: ``(D, n, n)`` Spearman affinity between all task pairs.

    Args:
      profiles: length-``n`` list of ``(D, K, K)`` profile tensors.

    Returns:
      ``S`` with ``S[d, i, j]`` = Spearman correlation of tasks i, j's
      branch-point-``d`` profiles.  Symmetric with unit diagonal.
    """
    profs = jnp.stack(list(profiles))  # (n, D, K, K)
    n, d = profs.shape[0], profs.shape[1]
    flat = profs.reshape(n, d, -1)

    def pairwise(di: int) -> jnp.ndarray:
        def one(i, j):
            return spearman(flat[i, di], flat[j, di])

        rows = []
        for i in range(n):
            cols = [one(i, j) for j in range(n)]
            rows.append(jnp.stack(cols))
        return jnp.stack(rows)

    return jnp.stack([pairwise(di) for di in range(d)])


def compute_affinity(
    apply_with_taps: Callable[[jax.Array, int], List[jnp.ndarray]],
    num_tasks: int,
    samples: jnp.ndarray,
) -> jnp.ndarray:
    """End-to-end affinity: profile every task on ``samples`` then correlate.

    Args:
      apply_with_taps: ``f(samples, task_idx) -> [reps at D branch points]``;
        each element is ``(K, ...)``.
      num_tasks: number of tasks ``n``.
      samples: ``(K, ...)`` probe batch drawn from the shared domain ``X``.

    Returns:
      ``(D, n, n)`` affinity tensor (Spearman, in ``[-1, 1]``).
    """
    profiles = [
        profile_task(apply_with_taps(samples, t)) for t in range(num_tasks)
    ]
    return affinity_matrix(profiles)


def affinity_as_numpy(s: jnp.ndarray) -> np.ndarray:
    return np.asarray(jax.device_get(s))
