"""Baseline multitask-inference systems the paper compares against (§6.1).

* **Vanilla** — independently trained classifiers executed sequentially:
  every task loads and executes its full network (implemented as a real
  executor in :mod:`repro.core.executor`; here we expose its cost model).
* **NWV** (Neural Weight Virtualization, Lee & Nirjon 2020) — all tasks
  packed *in RAM* by virtualizing weight pages across tasks: zero switching
  (weight-load) overhead, but every task still executes its full network
  (no activation reuse), and accuracy degrades as the number of packed tasks
  grows.
* **NWS** (Weight Separation, Lee & Nirjon 2022) — like NWV but a small
  fraction (~7% in the paper) of high-significance weights lives in external
  storage and is reloaded per task switch.
* **YONO** (Kwon et al. 2022) — compressed in-memory packing (product
  quantization); zero switching cost, full re-execution, in-RAM footprint.

All four reuse the same per-depth :class:`BlockCost` table as Antler so that
time/energy/memory comparisons are apples-to-apples; the structural facts
(what is loaded, what is re-executed, what fits in RAM) come from each
paper's design.  The executor-level Vanilla baseline cross-checks the
analytic model.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.cost_model import GraphCostModel
from repro.core.task_graph import TaskGraph
from repro.core.types import BlockCost, ExecutionStats, HardwareModel


@dataclasses.dataclass(frozen=True)
class BaselineReport:
    name: str
    seconds: float
    joules: float
    memory_bytes: float        # total storage footprint of all tasks
    ram_resident_bytes: float  # portion that must sit in RAM


def _full_exec(block_costs: Sequence[BlockCost], hw: HardwareModel) -> float:
    return sum(hw.exec_seconds(bc.flops, bc.act_bytes) for bc in block_costs)


def _full_exec_energy(block_costs: Sequence[BlockCost], hw: HardwareModel) -> float:
    return sum(hw.energy_joules(bc.flops, bc.act_bytes) for bc in block_costs)


def _full_load(block_costs: Sequence[BlockCost], hw: HardwareModel) -> float:
    return sum(hw.load_seconds(bc.weight_bytes) for bc in block_costs)


def _weights(block_costs: Sequence[BlockCost]) -> float:
    return sum(bc.weight_bytes for bc in block_costs)


def vanilla_baseline(
    num_tasks: int, block_costs: Sequence[BlockCost], hw: HardwareModel
) -> BaselineReport:
    """Every task: full weight load from slow tier + full execution."""
    t = num_tasks * (_full_exec(block_costs, hw) + _full_load(block_costs, hw))
    e = num_tasks * (
        _full_exec_energy(block_costs, hw)
        + hw.energy_joules(0.0, 2.0 * _weights(block_costs))
    )
    mem = num_tasks * _weights(block_costs)
    return BaselineReport("vanilla", t, e, mem, _weights(block_costs))


def nwv_baseline(
    num_tasks: int, block_costs: Sequence[BlockCost], hw: HardwareModel
) -> BaselineReport:
    """NWV: all-in-RAM virtualized weights; zero switching, full re-exec.

    Weight pages are shared across tasks, so storage ~= one network (plus
    per-task page tables, which we fold into a 10% overhead as the paper's
    measured footprints suggest).
    """
    t = num_tasks * _full_exec(block_costs, hw)
    e = num_tasks * _full_exec_energy(block_costs, hw)
    mem = 1.10 * _weights(block_costs)
    return BaselineReport("nwv", t, e, mem, mem)


def nws_baseline(
    num_tasks: int,
    block_costs: Sequence[BlockCost],
    hw: HardwareModel,
    external_fraction: float = 0.07,
) -> BaselineReport:
    """NWS: NWV + ~7% high-significance weights streamed from storage."""
    per_switch_load = hw.load_seconds(external_fraction * _weights(block_costs))
    t = num_tasks * (_full_exec(block_costs, hw) + per_switch_load)
    e = num_tasks * (
        _full_exec_energy(block_costs, hw)
        + hw.energy_joules(0.0, 2.0 * external_fraction * _weights(block_costs))
    )
    # Shared virtualized core + per-task external residue.
    mem = 1.10 * _weights(block_costs) + num_tasks * external_fraction * _weights(
        block_costs
    )
    return BaselineReport("nws", t, e, mem, 1.10 * _weights(block_costs))


def yono_baseline(
    num_tasks: int,
    block_costs: Sequence[BlockCost],
    hw: HardwareModel,
    compression: float = 0.12,
) -> BaselineReport:
    """YONO: PQ-compressed in-memory packing; decode adds a small exec tax."""
    decode_tax = 1.05  # codebook lookup overhead on top of raw execution
    t = num_tasks * decode_tax * _full_exec(block_costs, hw)
    e = num_tasks * decode_tax * _full_exec_energy(block_costs, hw)
    mem = max(compression * num_tasks, 1.0) * _weights(block_costs) * 0.85
    return BaselineReport("yono", t, e, mem, mem)


def antler_report(
    graph: TaskGraph,
    block_costs: Sequence[BlockCost],
    hw: HardwareModel,
    order: Sequence[int],
) -> BaselineReport:
    """Antler's own numbers from the predicted executor counters."""
    cm = GraphCostModel(graph, block_costs, hw)
    stats: ExecutionStats = cm.predicted_stats(order)
    return BaselineReport(
        "antler",
        stats.seconds(hw),
        stats.energy(hw),
        cm.storage_bytes(),
        _weights(block_costs),  # static buffer = one common network
    )
