"""Optimal task execution order (paper §4).

Three exact solvers plus the fitness functions shared with the GA:

* :func:`brute_force_order` — all ``n!`` permutations, filtered by
  precedence validity (paper §4.4 "Brute-force Solver").
* :func:`held_karp_order` — O(n^2 2^n) exact DP over (visited-set, last)
  states, with precedence pruning; the "optimal" reference for Table 3.
* :class:`ILPFormulation` / :func:`branch_and_bound_order` — the paper's
  integer-linear-programming formulation (Eq. 4 objective, degree
  constraints, subtour elimination, Eq. 6 precedence timing) materialised
  explicitly, solved by depth-first branch-and-bound with an admissible
  min-out-edge bound.  No external ILP solver exists in this environment,
  so B&B plays the exact-solver role; the formulation object is still
  constructed and checked so the Eq. 4-6 structure is tested.

The fitness is the paper's Eq. 7, and Eq. 8 for conditional constraints:
``f(pi) = sum_i  p(pi_{i+1}) * c[pi_i, pi_{i+1}]`` where ``p`` is the
execution probability of the *incoming* task (1 when unconditioned).
The first task's cold cost is a permutation-independent constant under a
common architecture, so ordering by Eq. 7 and ordering by total cost agree;
``include_first_task_cost`` lets callers add it for reporting.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.constraints import Constraints, no_constraints


# --------------------------------------------------------------------------
# Fitness (Eq. 7 / Eq. 8)
# --------------------------------------------------------------------------

def fitness(
    order: Sequence[int],
    cost: np.ndarray,
    constraints: Optional[Constraints] = None,
) -> float:
    """Paper Eq. 7 (and Eq. 8 when conditional constraints exist)."""
    total = 0.0
    for a, b in zip(order[:-1], order[1:]):
        p = 1.0
        if constraints is not None and constraints.conditional:
            p = constraints.execution_probability(b)
        total += p * float(cost[a, b])
    return total


@dataclasses.dataclass(frozen=True)
class OrderingResult:
    order: Tuple[int, ...]
    cost: float
    solver: str
    evaluated: int = 0


# --------------------------------------------------------------------------
# Brute force (paper §4.4)
# --------------------------------------------------------------------------

def brute_force_order(
    cost: np.ndarray,
    constraints: Optional[Constraints] = None,
) -> OrderingResult:
    n = cost.shape[0]
    cons = constraints or no_constraints(n)
    best: Optional[Tuple[int, ...]] = None
    best_cost = float("inf")
    evaluated = 0
    for perm in itertools.permutations(range(n)):
        if not cons.is_valid_order(perm):
            continue
        evaluated += 1
        f = fitness(perm, cost, cons)
        if f < best_cost:
            best, best_cost = perm, f
    if best is None:
        raise ValueError("no permutation satisfies the precedence constraints")
    return OrderingResult(best, best_cost, "brute_force", evaluated)


# --------------------------------------------------------------------------
# Held-Karp exact DP (path version), with precedence pruning
# --------------------------------------------------------------------------

def held_karp_order(
    cost: np.ndarray,
    constraints: Optional[Constraints] = None,
) -> OrderingResult:
    n = cost.shape[0]
    cons = constraints or no_constraints(n)
    # preds[j] = bitmask of tasks that must precede j.
    preds = [0] * n
    for (i, j) in cons.precedence:
        preds[j] |= 1 << i
    prob = [
        cons.execution_probability(j) if cons.conditional else 1.0
        for j in range(n)
    ]
    full = (1 << n) - 1
    INF = float("inf")
    # dp[mask][last] = min fitness of a path visiting `mask` ending at `last`.
    dp = [[INF] * n for _ in range(1 << n)]
    parent = [[-1] * n for _ in range(1 << n)]
    for s in range(n):
        if preds[s] == 0:
            dp[1 << s][s] = 0.0
    evaluated = 0
    for mask in range(1, full + 1):
        row = dp[mask]
        for last in range(n):
            cur = row[last]
            if cur == INF:
                continue
            for nxt in range(n):
                if mask & (1 << nxt):
                    continue
                if (preds[nxt] & mask) != preds[nxt]:
                    continue  # a prerequisite of nxt is still unvisited
                cand = cur + prob[nxt] * float(cost[last, nxt])
                nmask = mask | (1 << nxt)
                evaluated += 1
                if cand < dp[nmask][nxt]:
                    dp[nmask][nxt] = cand
                    parent[nmask][nxt] = last
    best_last = min(range(n), key=lambda t: dp[full][t])
    best_cost = dp[full][best_last]
    if best_cost == INF:
        raise ValueError("no permutation satisfies the precedence constraints")
    # Reconstruct.
    order: List[int] = []
    mask, last = full, best_last
    while last != -1:
        order.append(last)
        p = parent[mask][last]
        mask ^= 1 << last
        last = p
    order.reverse()
    return OrderingResult(tuple(order), best_cost, "held_karp", evaluated)


# --------------------------------------------------------------------------
# ILP formulation (Eq. 4-6) + branch-and-bound exact solver
# --------------------------------------------------------------------------

@dataclasses.dataclass
class ILPFormulation:
    """Explicit matrix form of the paper's ILP (for inspection/testing).

    Variables are ``x[i, j]`` (Eq. 4) flattened row-major, plus the ``s[i,t]``
    start indicators (Eq. 5) implied by precedence timing (Eq. 6).  We
    materialise the objective vector and the two degree-constraint blocks; the
    exponential subtour-elimination family is represented lazily through
    :meth:`subtour_constraint` (standard row generation), which is how real
    ILP back-ends consume it too.
    """

    cost: np.ndarray

    @property
    def n(self) -> int:
        return self.cost.shape[0]

    def objective(self) -> np.ndarray:
        c = self.cost.astype(np.float64).copy()
        np.fill_diagonal(c, 0.0)
        return c.reshape(-1)

    def degree_constraints(self) -> Tuple[np.ndarray, np.ndarray]:
        """Rows ``A x = 1``: each task entered once and left once."""
        n = self.n
        a_in = np.zeros((n, n * n))
        a_out = np.zeros((n, n * n))
        for j in range(n):
            for i in range(n):
                if i != j:
                    a_in[j, i * n + j] = 1.0
                    a_out[i, i * n + j] = 1.0
        return a_in, a_out

    def subtour_constraint(self, subset: Sequence[int]) -> Tuple[np.ndarray, float]:
        """Row for ``sum_{i,j in Z} x_ij <= |Z| - 1`` (last block of Eq. 4)."""
        n = self.n
        row = np.zeros(n * n)
        for i in subset:
            for j in subset:
                if i != j:
                    row[i * n + j] = 1.0
        return row, float(len(subset) - 1)

    def check_assignment(self, x: np.ndarray) -> bool:
        """Degree feasibility of a 0/1 assignment (used by tests)."""
        a_in, a_out = self.degree_constraints()
        return bool(
            np.allclose(a_in @ x, 1.0) and np.allclose(a_out @ x, 1.0)
        )


def branch_and_bound_order(
    cost: np.ndarray,
    constraints: Optional[Constraints] = None,
) -> OrderingResult:
    """Exact DFS branch-and-bound over the ILP's feasible set.

    Bound: current path cost + sum over unvisited tasks of their cheapest
    incoming expected edge — admissible, so the result is optimal.
    """
    n = cost.shape[0]
    cons = constraints or no_constraints(n)
    preds = [0] * n
    for (i, j) in cons.precedence:
        preds[j] |= 1 << i
    prob = np.array(
        [cons.execution_probability(j) if cons.conditional else 1.0 for j in range(n)]
    )
    c = cost.astype(np.float64)
    # cheapest expected in-edge per task (excluding self).
    masked = c + np.where(np.eye(n, dtype=bool), np.inf, 0.0)
    min_in = prob * masked.min(axis=0)

    best_cost = float("inf")
    best_order: Optional[Tuple[int, ...]] = None
    evaluated = 0

    order: List[int] = []

    def dfs(mask: int, last: int, acc: float) -> None:
        nonlocal best_cost, best_order, evaluated
        if len(order) == n:
            if acc < best_cost:
                best_cost, best_order = acc, tuple(order)
            return
        remaining = [t for t in range(n) if not (mask & (1 << t))]
        bound = acc + sum(min_in[t] for t in remaining)
        if bound >= best_cost:
            return
        for nxt in remaining:
            if (preds[nxt] & mask) != preds[nxt]:
                continue
            step = prob[nxt] * c[last, nxt] if last >= 0 else 0.0
            evaluated += 1
            order.append(nxt)
            dfs(mask | (1 << nxt), nxt, acc + step)
            order.pop()

    dfs(0, -1, 0.0)
    if best_order is None:
        raise ValueError("no permutation satisfies the precedence constraints")
    return OrderingResult(best_order, best_cost, "branch_and_bound", evaluated)


# --------------------------------------------------------------------------
# Greedy + 2-opt heuristic (for instances beyond the exact solvers' reach,
# e.g. the serving engine's inter-group ordering over many request groups)
# --------------------------------------------------------------------------

def greedy_2opt_order(
    cost: np.ndarray,
    constraints: Optional[Constraints] = None,
) -> OrderingResult:
    """Nearest-neighbour seed + steepest-descent 2-opt/relocate polish.

    Heuristic path solver for the Eq. 7 objective: seeds a cheapest-next
    tour from every start task (precedence-respecting), keeps the best, then
    descends over segment reversals and single-element relocations until a
    local optimum.  O(n^2) seeding + O(n^2) moves per descent round — cheap
    enough for hundreds of nodes, where the exact solvers blow up.  The cost
    matrix may be asymmetric (the warm inter-group matrix is).
    """
    n = cost.shape[0]
    cons = constraints or no_constraints(n)
    if n == 1:
        return OrderingResult((0,), 0.0, "greedy_2opt", 1)
    preds: List[set] = [set() for _ in range(n)]
    for (i, j) in cons.precedence:
        preds[j].add(i)

    def nearest_neighbour(start: int) -> Optional[List[int]]:
        placed: List[int] = []
        placed_set: set = set()
        remaining = set(range(n))

        def ready():
            return [t for t in remaining if preds[t] <= placed_set]

        r = ready()
        if not r:
            return None
        cur = start if start in r else r[0]
        while True:
            placed.append(cur)
            placed_set.add(cur)
            remaining.remove(cur)
            if not remaining:
                return placed
            r = ready()
            if not r:
                return None  # dead end under precedence
            cur = min(r, key=lambda t: float(cost[placed[-1], t]))

    evaluated = 0
    seeds: List[Tuple[float, List[int]]] = []
    seen: set = set()
    for start in range(n):
        tour = nearest_neighbour(start)
        # Distinct starts can collapse to one tour (e.g. when precedence
        # pins the first node, as the group-ordering virtual start does) —
        # polishing duplicates is pure waste, so dedupe here.
        if tour is None or tuple(tour) in seen:
            continue
        seen.add(tuple(tour))
        evaluated += 1
        seeds.append((fitness(tour, cost, cons), tour))
    if not seeds:
        raise ValueError("no permutation satisfies the precedence constraints")
    seeds.sort(key=lambda s: s[0])

    def polish(order: np.ndarray, cur: float) -> Tuple[np.ndarray, float]:
        nonlocal evaluated
        improved = True
        while improved:
            improved = False
            for i in range(n - 1):
                for j in range(i + 1, n):
                    for kind in ("rev", "swap", "ins"):
                        cand = order.copy()
                        if kind == "rev":
                            cand[i:j + 1] = cand[i:j + 1][::-1]
                        elif kind == "swap":
                            cand[i], cand[j] = cand[j], cand[i]
                        else:  # relocate element i to position j
                            seg = cand[i]
                            cand = np.delete(cand, i)
                            cand = np.insert(cand, j, seg)
                        if not cons.is_valid_order(cand.tolist()):
                            continue
                        f = fitness(cand.tolist(), cost, cons)
                        evaluated += 1
                        if f < cur - 1e-12:
                            order, cur = cand, f
                            improved = True
        return order, cur

    # Polish a few diverse seeds, not just the cheapest: nearest-neighbour
    # ties/near-ties often descend into different local optima.
    best: Optional[np.ndarray] = None
    best_cost = float("inf")
    for f0, tour in seeds[:3]:
        order, f = polish(np.array(tour, dtype=np.int64), f0)
        if f < best_cost:
            best, best_cost = order, f
    return OrderingResult(
        tuple(int(t) for t in best), best_cost, "greedy_2opt", evaluated
    )


# --------------------------------------------------------------------------
# Subset re-solving (per-plan orders for the serving engine)
# --------------------------------------------------------------------------

def solve_suborder(
    cost: np.ndarray,
    tasks: Sequence[int],
    start_costs: Optional[Sequence[float]] = None,
    constraints: Optional[Constraints] = None,
    exact_limit: int = 9,
) -> List[int]:
    """Order a task *subset* of an existing cost matrix, warm-seeded.

    The serving engine solves one global order at startup, but a request
    group that wants only a subset of tasks — executed on an engine whose
    residency came from whatever ran before — can have a better internal
    order than the global order filtered to the subset.  This restricts
    ``cost`` to ``tasks``, keeps the precedence pairs of ``constraints``
    that fall entirely inside the subset, and (when ``start_costs`` is
    given, one entry per subset task) prepends a fixed virtual start node
    whose outgoing edges are those costs — the residency-aware "which task
    do we begin with" term, mirroring ``order_groups``'s warm start node
    one level down.

    Solved exactly (:func:`optimal_order`) up to ``exact_limit`` nodes,
    greedy + 2-opt beyond.  Returns the subset tasks in execution order
    (the virtual node stripped); a subset of one task is returned as-is.
    """
    tasks = [int(t) for t in tasks]
    m = len(tasks)
    if m <= 1:
        return list(tasks)
    if start_costs is not None and len(start_costs) != m:
        raise ValueError(
            f"{len(start_costs)} start costs for {m} subset tasks"
        )
    idx = {t: i for i, t in enumerate(tasks)}
    if len(idx) != m:
        raise ValueError(f"subset contains duplicate tasks: {tasks!r}")
    off = 1 if start_costs is not None else 0
    n = m + off
    c = np.zeros((n, n), dtype=np.float64)
    for i, a in enumerate(tasks):
        for j, b in enumerate(tasks):
            if i != j:
                c[i + off, j + off] = float(cost[a, b])
    prec: List[Tuple[int, int]] = []
    if start_costs is not None:
        for j in range(m):
            c[0, j + 1] = float(start_costs[j])
            prec.append((0, j + 1))  # the virtual start precedes everything
    if constraints is not None:
        for (a, b) in constraints.precedence:
            if a in idx and b in idx:
                prec.append((idx[a] + off, idx[b] + off))
    cons = Constraints.make(n, precedence=prec) if prec else None
    if n <= exact_limit:
        res = optimal_order(c, cons)
    else:
        res = greedy_2opt_order(c, cons)
    return [tasks[v - off] for v in res.order if v - off >= 0]


def optimal_order(
    cost: np.ndarray,
    constraints: Optional[Constraints] = None,
    solver: str = "auto",
) -> OrderingResult:
    """Dispatch: brute force for tiny n, Held-Karp DP up to ~18, B&B beyond."""
    n = cost.shape[0]
    if solver == "brute_force" or (solver == "auto" and n <= 7):
        return brute_force_order(cost, constraints)
    if solver == "held_karp" or (solver == "auto" and n <= 18):
        return held_karp_order(cost, constraints)
    if solver == "greedy_2opt":
        return greedy_2opt_order(cost, constraints)
    return branch_and_bound_order(cost, constraints)
