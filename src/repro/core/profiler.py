"""Empirical block profiling (paper §5.3, step 1).

The paper measures per-layer execution time ON DEVICE and feeds the
measurements into task-graph generation.  This module does the same for any
:class:`~repro.core.executor.MultitaskProgram`-style block family: it times
each block's jitted apply on this host, counts its weight bytes, and emits
:class:`~repro.core.types.BlockCost` entries whose ``flops`` are calibrated
so that the analytic cost model's per-block execution time on the *profiled*
hardware model matches the measurement.

This closes the loop between the analytic tables used by the benchmarks and
real execution: ``profile_blocks`` -> ``BlockCost`` -> ``GraphCostModel``
-> ordering/selection, all from measurements.
"""
from __future__ import annotations

import time
from typing import Any, Callable, List, Sequence

import jax
import numpy as np

from repro.core.types import BlockCost, HardwareModel
from repro.sharding.utils import tree_bytes


def _time_jitted(fn: Callable, params: Any, x: Any,
                 warmup: int = 2, iters: int = 5) -> float:
    jf = jax.jit(fn)
    for _ in range(warmup):
        jax.block_until_ready(jf(params, x))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(jf(params, x))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def profile_blocks(
    block_fns: Sequence[Callable],
    block_params: Sequence[Any],
    x0: Any,
    hw: HardwareModel,
    batch_divisor: int = 1,
) -> List[BlockCost]:
    """Measure each block end to end and calibrate BlockCost entries.

    Args:
      block_fns: per-depth apply functions (chained: block d feeds d+1).
      block_params: parameters for one representative node per depth.
      x0: input batch for depth 0.
      hw: the hardware model the calibrated costs should reproduce the
        measured seconds on (``hw.exec_seconds(flops) == measured``).
      batch_divisor: divide measured time by this to get per-sample cost.

    Returns:
      per-depth :class:`BlockCost` with measured-calibrated ``flops`` and
      exact ``weight_bytes``/``act_bytes``.
    """
    costs: List[BlockCost] = []
    h = x0
    for fn, params in zip(block_fns, block_params):
        seconds = _time_jitted(fn, params, h) / batch_divisor
        out = jax.jit(fn)(params, h)
        costs.append(
            BlockCost(
                weight_bytes=float(tree_bytes(params)),
                # Calibrated so hw.exec_seconds(flops) == measured seconds.
                flops=float(seconds * hw.peak_flops),
                act_bytes=float(tree_bytes(out)) / max(x0.shape[0], 1),
            )
        )
        h = out
    return costs


def profile_program_blocks(program, x0, hw: HardwareModel) -> List[BlockCost]:
    """Profile a MultitaskProgram's common architecture (one node per depth)."""
    graph = program.graph
    reps = []
    for d in range(graph.depth):
        node = graph.path(0)[d]
        reps.append(program.node_params[node])
    return profile_blocks(
        program.block_fns, reps, x0, hw, batch_divisor=x0.shape[0]
    )
