"""Shared neural-net layers for every architecture family (pure JAX).

Conventions:
* a *module* is an ``init_*(key, cfg) -> params`` / ``apply(params, ...)``
  pair of pure functions; params are plain dict pytrees;
* every ``init_*`` has a matching ``spec_*`` returning a PartitionSpec tree
  with the same structure (tested), driven by a
  :class:`~repro.sharding.policy.ShardingPolicy`;
* attention is grouped-query with optional sliding window, implemented both
  as a single dense einsum (small shapes) and as an online-softmax KV-chunk
  scan (``attention_chunked``) that keeps the score matrix O(S * chunk) —
  the jnp oracle of the Pallas flash kernel.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.sharding.policy import ShardingPolicy, shard_act

Params = Dict[str, Any]

NEG_INF = -1e30


# --------------------------------------------------------------------------
# Initialisers
# --------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_shape, dtype) -> jax.Array:
    """Truncated-normal fan-in init, matching standard transformer practice."""
    shape = (in_dim,) + tuple(out_shape)
    std = 1.0 / math.sqrt(in_dim)
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def init_rmsnorm(dim: int, dtype) -> Params:
    return {"scale": jnp.ones((dim,), dtype=dtype)}


def spec_rmsnorm() -> Params:
    return {"scale": P(None)}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    orig = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(orig)


# --------------------------------------------------------------------------
# Rotary position embeddings
# --------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotate pairs. ``x``: (..., S, H, Dh); ``positions``: (..., S)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)  # (Dh/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return rotated.astype(x.dtype)


# --------------------------------------------------------------------------
# Attention (GQA, optional sliding window; dense + chunked variants)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttentionDims:
    n_heads: int
    n_kv_heads: int
    head_dim: int


def init_attention(key, cfg: ModelConfig) -> Params:
    dtype = cfg.params_dtype()
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, hd = cfg.d_model, cfg.head_dim
    return {
        "wq": dense_init(kq, d, (cfg.n_heads, hd), dtype),
        # K and V fused on an unsharded stack axis (§Perf B2): one matmul ->
        # one dx all-reduce in backward instead of two.
        "w_kv": jnp.stack(
            [
                dense_init(kk, d, (cfg.n_kv_heads, hd), dtype),
                dense_init(kv, d, (cfg.n_kv_heads, hd), dtype),
            ],
            axis=1,
        ),  # (D, 2, Hk, hd)
        "wo": dense_init(ko, cfg.n_heads * hd, (d,), dtype).reshape(
            cfg.n_heads, hd, d
        ),
    }


def project_kv(params: Params, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    kv = jnp.einsum("bsd,dthk->bsthk", x, params["w_kv"])
    return kv[:, :, 0], kv[:, :, 1]


def spec_attention(policy: ShardingPolicy) -> Params:
    """Ideal specs; ``fit_specs`` drops axes that do not divide (e.g. MQA's
    single KV head over a 16-way model axis falls back to replicated)."""
    m, f = policy.physical("model"), policy.physical("fsdp")
    return {
        "wq": P(f, m, None),
        "w_kv": P(f, None, m, None),
        "wo": P(m, None, f),
    }


def _causal_window_mask(
    q_pos: jax.Array, k_pos: jax.Array, window: Optional[int]
) -> jax.Array:
    """(..., S, T) True where attention is allowed."""
    mask = q_pos[..., :, None] >= k_pos[..., None, :]
    if window is not None:
        mask &= (q_pos[..., :, None] - k_pos[..., None, :]) < window
    return mask


def attention_dense(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_pos: jax.Array,
    k_pos: jax.Array,
    window: Optional[int] = None,
    causal: bool = True,
    kv_valid: Optional[jax.Array] = None,
) -> jax.Array:
    """Reference attention: full score matrix.  q: (B,S,Hq,Dh); k/v: (B,T,Hk,Dh)."""
    b, s, hq, dh = q.shape
    hk = k.shape[2]
    g = hq // hk
    qg = q.reshape(b, s, hk, g, dh)
    scores = jnp.einsum("bshgd,bthd->bhgst", qg, k).astype(jnp.float32)
    scores *= 1.0 / math.sqrt(dh)
    if causal:
        mask = _causal_window_mask(q_pos, k_pos, window)  # (B?,S,T) or (S,T)
        while mask.ndim < scores.ndim:
            mask = mask[:, None] if mask.ndim > 2 else mask[None]
        scores = jnp.where(mask, scores, NEG_INF)
    if kv_valid is not None:
        kvm = kv_valid[:, None, None, None, :]
        scores = jnp.where(kvm, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgst,bthd->bshgd", w, v)
    return out.reshape(b, s, hq, dh)


def attention_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_pos: jax.Array,
    k_pos: jax.Array,
    window: Optional[int] = None,
    causal: bool = True,
    chunk: int = 1024,
    kv_valid: Optional[jax.Array] = None,
) -> jax.Array:
    """Online-softmax attention: scan over KV chunks, O(S*chunk) live scores.

    Functionally identical to :func:`attention_dense`; used for long
    sequences so the lowered HLO never materialises the (S, T) score matrix.
    This is the pure-jnp oracle of ``repro.kernels.flash_attention``.
    """
    b, s, hq, dh = q.shape
    t = k.shape[1]
    hk = k.shape[2]
    g = hq // hk
    if t % chunk != 0:
        pad = chunk - t % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, pad),), constant_values=jnp.iinfo(jnp.int32).max)
        if kv_valid is not None:
            kv_valid = jnp.pad(kv_valid, ((0, 0), (0, pad)))
        t = k.shape[1]
    n_chunks = t // chunk
    qg = (q.reshape(b, s, hk, g, dh).astype(jnp.float32)) / math.sqrt(dh)

    kc = k.reshape(b, n_chunks, chunk, hk, dh)
    vc = v.reshape(b, n_chunks, chunk, hk, dh)
    pc = k_pos.reshape(n_chunks, chunk)
    valc = (
        kv_valid.reshape(b, n_chunks, chunk) if kv_valid is not None else None
    )

    def step(carry, inputs):
        m, l, acc = carry
        if valc is None:
            k_i, v_i, p_i = inputs
            val_i = None
        else:
            k_i, v_i, p_i, val_i = inputs
        scores = jnp.einsum("bshgd,bthd->bhgst", qg, k_i.astype(jnp.float32))
        if causal:
            msk = _causal_window_mask(q_pos, p_i, window)
            while msk.ndim < scores.ndim:
                msk = msk[None]
            scores = jnp.where(msk, scores, NEG_INF)
        if val_i is not None:
            scores = jnp.where(val_i[:, None, None, None, :], scores, NEG_INF)
        m_new = jnp.maximum(m, scores.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(scores - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgst,bthd->bhgsd", p, v_i.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hk, g, s), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((b, hk, g, s), dtype=jnp.float32)
    acc0 = jnp.zeros((b, hk, g, s, dh), dtype=jnp.float32)
    xs = (
        (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4), pc)
        if valc is None
        else (
            kc.transpose(1, 0, 2, 3, 4),
            vc.transpose(1, 0, 2, 3, 4),
            pc,
            valc.transpose(1, 0, 2),
        )
    )
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0), xs)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, s, hq, dh)
    return out.astype(q.dtype)


def attention_decode(
    q: jax.Array,        # (B, 1, Hq, Dh)
    k: jax.Array,        # (B, T, Hk, Dh)  cache, stays in its storage dtype
    v: jax.Array,
    k_pos: jax.Array,    # (T,) absolute positions of cache slots
    q_pos_scalar: jax.Array,
    window: Optional[int] = None,
    kv_valid: Optional[jax.Array] = None,
) -> jax.Array:
    """Single-token decode attention: one pass over the cache, no chunking.

    The score tensor is only (B, Hk, G, T) so nothing needs the online
    softmax; K/V are read ONCE in their storage dtype with fp32 accumulation
    via ``preferred_element_type`` — no whole-cache convert/copy (the §Perf
    C1 iteration; the scan-based path cost ~20x the roofline here).
    """
    b, s, hq, dh = q.shape
    assert s == 1
    hk = k.shape[2]
    g = hq // hk
    qg = q.reshape(b, hk, g, dh)
    scores = jnp.einsum(
        "bhgd,bthd->bhgt", qg, k, preferred_element_type=jnp.float32
    )
    scores *= 1.0 / math.sqrt(dh)
    mask = k_pos[None, None, None, :] <= q_pos_scalar
    if window is not None:
        mask &= (q_pos_scalar - k_pos[None, None, None, :]) < window
    if kv_valid is not None:
        mask &= kv_valid[:, None, None, :]
    scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bhgt,bthd->bhgd", w.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, 1, hq, dh).astype(q.dtype)


def attention_block(
    params: Params,
    x: jax.Array,
    cfg: ModelConfig,
    policy: ShardingPolicy,
    q_pos: jax.Array,
    kv_cache: Optional[Tuple[jax.Array, jax.Array]] = None,
    cache_len: Optional[jax.Array] = None,
    use_chunked: bool = True,
    causal: bool = True,
) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    """Full attention sub-layer: proj -> rope -> attend -> out-proj.

    With ``kv_cache=(k, v)`` of shape (B, T, Hk, Dh) and ``cache_len``
    (current fill), performs decode: writes the new K/V at ``cache_len`` and
    attends over the filled prefix.  Returns (output, updated cache).
    """
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k, v = project_kv(params, x)
    q = shard_act(q, policy, "batch", None, "model", None)
    # K/V head sharding is left to GSPMD propagation: with few KV heads
    # (GQA/MQA) the head axis may not divide the model axis.
    q = apply_rope(q, q_pos, cfg.rope_theta)
    k = apply_rope(k, q_pos, cfg.rope_theta)

    new_cache = None
    if kv_cache is not None:
        ck, cv = kv_cache
        t = ck.shape[1]
        # Scatter this step's K/V into the ring/linear cache at cache_len.
        idx = (cache_len % t).astype(jnp.int32)
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, idx, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, idx, 0, 0))
        new_cache = (ck, cv)
        k_pos_full = jnp.arange(t, dtype=jnp.int32)
        if cfg.sliding_window is not None and t <= cfg.sliding_window:
            # Ring buffer: absolute position of slot i.
            wrapped = cache_len - ((idx - k_pos_full) % t)
            k_pos = wrapped
            kv_valid = (k_pos >= 0)[None, :].astype(bool) & jnp.ones(
                (x.shape[0], t), dtype=bool
            )
            k_pos = jnp.maximum(k_pos, 0)
        else:
            k_pos = k_pos_full
            kv_valid = (k_pos_full[None, :] <= cache_len) & jnp.ones(
                (x.shape[0], t), dtype=bool
            )
        if q.shape[1] == 1:
            # Single-token decode: one pass over the cache (§Perf C1).
            out = attention_decode(
                q, ck, cv, k_pos, cache_len,
                window=cfg.sliding_window, kv_valid=kv_valid,
            )
        else:
            attend = attention_chunked if use_chunked else attention_dense
            out = attend(
                q, ck, cv, q_pos, k_pos,
                window=cfg.sliding_window, causal=causal,
                kv_valid=kv_valid,
                **({"chunk": cfg.attn_chunk} if attend is attention_chunked else {}),
            )
    else:
        k_pos = q_pos
        attend = attention_chunked if use_chunked else attention_dense
        out = attend(
            q, k, v, q_pos, k_pos,
            window=cfg.sliding_window, causal=causal,
            **({"chunk": cfg.attn_chunk} if attend is attention_chunked else {}),
        )
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    y = shard_act(y, policy, "batch", None, None)
    return y, new_cache


# --------------------------------------------------------------------------
# MLP variants
# --------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    dtype = cfg.params_dtype()
    d_ff = d_ff or cfg.d_ff
    d = cfg.d_model
    if cfg.activation == "swiglu":
        k1, k2, k3 = jax.random.split(key, 3)
        # Gate and up fused on an unsharded stacking axis (§Perf B1): one
        # matmul -> ONE dx all-reduce in backward instead of two.
        return {
            "w_gu": jnp.stack(
                [dense_init(k1, d, (d_ff,), dtype),
                 dense_init(k2, d, (d_ff,), dtype)], axis=1,
            ),  # (D, 2, F)
            "w_down": dense_init(k3, d_ff, (d,), dtype),
        }
    k1, k2 = jax.random.split(key)
    return {
        "w_up": dense_init(k1, d, (d_ff,), dtype),
        "w_down": dense_init(k2, d_ff, (d,), dtype),
    }


def spec_mlp(cfg: ModelConfig, policy: ShardingPolicy) -> Params:
    m, f = policy.physical("model"), policy.physical("fsdp")
    if cfg.activation == "swiglu":
        return {
            "w_gu": P(f, None, m),
            "w_down": P(m, f),
        }
    return {"w_up": P(f, m), "w_down": P(m, f)}


def mlp_block(
    params: Params, x: jax.Array, cfg: ModelConfig, policy: ShardingPolicy
) -> jax.Array:
    if cfg.activation == "swiglu":
        gu = jnp.einsum("bsd,dkf->bskf", x, params["w_gu"])
        g, u = gu[:, :, 0], gu[:, :, 1]
        h = jax.nn.silu(g.astype(jnp.float32)).astype(u.dtype) * u
    else:
        h = x @ params["w_up"]
        if cfg.activation == "squared_relu":
            # Nemotron-4 (arXiv:2402.16819) uses squared ReLU.
            r = jnp.maximum(h, 0)
            h = (r * r).astype(h.dtype)
        elif cfg.activation == "gelu":
            h = jax.nn.gelu(h.astype(jnp.float32)).astype(h.dtype)
        else:
            raise ValueError(f"unknown activation {cfg.activation}")
    h = shard_act(h, policy, "batch", None, "model")
    y = h @ params["w_down"]
    return shard_act(y, policy, "batch", None, None)


# --------------------------------------------------------------------------
# Embedding / unembedding
# --------------------------------------------------------------------------

def init_embed(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    p = {"embedding": embed_init(k1, cfg.vocab_size, cfg.d_model, cfg.params_dtype())}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(k2, cfg.d_model, (cfg.vocab_size,), cfg.params_dtype())
    return p


def spec_embed(cfg: ModelConfig, policy: ShardingPolicy) -> Params:
    m, f = policy.physical("model"), policy.physical("fsdp")
    p = {"embedding": P(m, f)}
    if not cfg.tie_embeddings:
        p["unembed"] = P(f, m)
    return p


def embed_tokens(params: Params, tokens: jax.Array, cfg: ModelConfig,
                 policy: ShardingPolicy) -> jax.Array:
    x = params["embedding"].astype(cfg.activation_dtype())[tokens]
    return shard_act(x, policy, "batch", None, None)


def unembed(params: Params, x: jax.Array, cfg: ModelConfig,
            policy: ShardingPolicy) -> jax.Array:
    w = (
        params["embedding"].T if cfg.tie_embeddings else params["unembed"]
    ).astype(cfg.activation_dtype())
    logits = x @ w
    return shard_act(logits, policy, "batch", None, "model")
