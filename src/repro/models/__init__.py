"""Model zoo: dense/GQA, MoE, SSM (Mamba2), hybrid (Zamba2), enc-dec
(Whisper), VLM (Chameleon) transformer backbones + paper-scale CNNs."""

from repro.models.config import (
    INPUT_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    TRAIN_4K,
    InputShape,
    ModelConfig,
    get_shape,
    make_config,
    pad_vocab,
)
from repro.models.registry import ModelApi, get_model

__all__ = [
    "INPUT_SHAPES", "DECODE_32K", "LONG_500K", "PREFILL_32K", "TRAIN_4K",
    "InputShape", "ModelConfig", "get_shape", "make_config", "pad_vocab",
    "ModelApi", "get_model",
]
