"""Whisper-style encoder-decoder — the `encdec`/audio family
(arXiv:2212.04356).

Per the brief, the modality frontend (mel-spectrogram + conv feature
extractor) is a STUB: ``input_specs`` delivers precomputed frame features of
shape ``(B, T_enc, enc_inputs)``; a linear projection + sinusoidal positions
stand in for Whisper's two conv layers.  Everything downstream — the
bidirectional encoder, the causal decoder with cross-attention, prefill and
single-token decode with self-KV + cross-KV caches — is fully implemented.

Whisper uses absolute sinusoidal positions (no RoPE) and GELU MLPs; both are
honoured here.  We use RMSNorm instead of LayerNorm for uniformity with the
rest of the zoo (noted in DESIGN.md §8).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models.cache import EncDecCache, KVCache
from repro.models.config import ModelConfig
from repro.models.layers import dense_init
from repro.sharding.policy import ShardingPolicy, shard_act

Params = Dict[str, Any]


def sinusoids(length: int, channels: int) -> jax.Array:
    """Whisper's sinusoidal position embedding table."""
    log_timescale = math.log(10000.0) / (channels // 2 - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(channels // 2, dtype=jnp.float32))
    scaled = jnp.arange(length, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(scaled), jnp.cos(scaled)], axis=1)


# --------------------------------------------------------------------------
# Init / specs
# --------------------------------------------------------------------------

def _init_enc_layer(key, cfg: ModelConfig) -> Params:
    ka, km = jax.random.split(key)
    return {
        "attn_norm": L.init_rmsnorm(cfg.d_model, cfg.params_dtype()),
        "mlp_norm": L.init_rmsnorm(cfg.d_model, cfg.params_dtype()),
        "attn": L.init_attention(ka, cfg),
        "mlp": L.init_mlp(km, cfg),
    }


def _init_dec_layer(key, cfg: ModelConfig) -> Params:
    ka, kc, km = jax.random.split(key, 3)
    return {
        "self_norm": L.init_rmsnorm(cfg.d_model, cfg.params_dtype()),
        "cross_norm": L.init_rmsnorm(cfg.d_model, cfg.params_dtype()),
        "mlp_norm": L.init_rmsnorm(cfg.d_model, cfg.params_dtype()),
        "self_attn": L.init_attention(ka, cfg),
        "cross_attn": L.init_attention(kc, cfg),
        "mlp": L.init_mlp(km, cfg),
    }


def init(key, cfg: ModelConfig) -> Params:
    ke, kf, kenc, kdec = jax.random.split(key, 4)
    enc_keys = jax.random.split(kenc, cfg.enc_layers)
    dec_keys = jax.random.split(kdec, cfg.num_layers)
    return {
        "frontend_proj": dense_init(
            kf, cfg.enc_inputs, (cfg.d_model,), cfg.params_dtype()
        ),
        "embed": L.init_embed(ke, cfg),
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg))(enc_keys),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dec_keys),
        "enc_norm": L.init_rmsnorm(cfg.d_model, cfg.params_dtype()),
        "final_norm": L.init_rmsnorm(cfg.d_model, cfg.params_dtype()),
    }


def param_specs(cfg: ModelConfig, policy: ShardingPolicy) -> Params:
    def stack(tree):
        return jax.tree.map(
            lambda s: P(None, *tuple(s)), tree, is_leaf=lambda v: isinstance(v, P)
        )

    enc = {
        "attn_norm": L.spec_rmsnorm(),
        "mlp_norm": L.spec_rmsnorm(),
        "attn": L.spec_attention(policy),
        "mlp": L.spec_mlp(cfg, policy),
    }
    dec = {
        "self_norm": L.spec_rmsnorm(),
        "cross_norm": L.spec_rmsnorm(),
        "mlp_norm": L.spec_rmsnorm(),
        "self_attn": L.spec_attention(policy),
        "cross_attn": L.spec_attention(policy),
        "mlp": L.spec_mlp(cfg, policy),
    }
    return {
        "frontend_proj": P(None, policy.physical("model")),
        "embed": L.spec_embed(cfg, policy),
        "enc_layers": stack(enc),
        "dec_layers": stack(dec),
        "enc_norm": L.spec_rmsnorm(),
        "final_norm": L.spec_rmsnorm(),
    }


# --------------------------------------------------------------------------
# Attention without RoPE (Whisper uses absolute positions)
# --------------------------------------------------------------------------

def _attend(
    ap: Params,
    xq: jax.Array,
    xkv: jax.Array,
    cfg: ModelConfig,
    q_pos: jax.Array,
    k_pos: jax.Array,
    causal: bool,
    chunk: int,
    kv_valid: Optional[jax.Array] = None,
) -> jax.Array:
    q = jnp.einsum("bsd,dhk->bshk", xq, ap["wq"])
    k, v = L.project_kv(ap, xkv)
    out = L.attention_chunked(
        q, k, v, q_pos, k_pos, causal=causal, chunk=chunk, kv_valid=kv_valid
    )
    return jnp.einsum("bshk,hkd->bsd", out, ap["wo"])


def _proj_kv(ap: Params, xkv: jax.Array) -> Tuple[jax.Array, jax.Array]:
    return L.project_kv(ap, xkv)


def _attend_cached(
    ap: Params,
    xq: jax.Array,
    k: jax.Array,
    v: jax.Array,
    cfg: ModelConfig,
    q_pos: jax.Array,
    k_pos: jax.Array,
    causal: bool,
    chunk: int,
    kv_valid: Optional[jax.Array] = None,
) -> jax.Array:
    q = jnp.einsum("bsd,dhk->bshk", xq, ap["wq"])
    use_chunked = k.shape[1] > chunk
    attend = L.attention_chunked if use_chunked else L.attention_dense
    kw = {"chunk": chunk} if use_chunked else {}
    out = attend(q, k, v, q_pos, k_pos, causal=causal, kv_valid=kv_valid, **kw)
    return jnp.einsum("bshk,hkd->bsd", out, ap["wo"])


# --------------------------------------------------------------------------
# Encoder
# --------------------------------------------------------------------------

def encode(
    params: Params,
    features: jax.Array,  # (B, T_enc, enc_inputs) from the stubbed frontend
    cfg: ModelConfig,
    policy: ShardingPolicy,
) -> jax.Array:
    b, t, _ = features.shape
    x = features.astype(cfg.activation_dtype()) @ params["frontend_proj"]
    x = x + sinusoids(t, cfg.d_model).astype(x.dtype)[None]
    x = shard_act(x, policy, "batch", None, None)
    pos = jnp.arange(t, dtype=jnp.int32)

    def body(x, lp):
        h = L.rmsnorm(lp["attn_norm"], x, cfg.norm_eps)
        x = x + _attend(lp["attn"], h, h, cfg, pos, pos, causal=False,
                        chunk=cfg.attn_chunk)
        h = L.rmsnorm(lp["mlp_norm"], x, cfg.norm_eps)
        x = x + L.mlp_block(lp["mlp"], h, cfg, policy)
        return shard_act(x, policy, "batch", None, None), None

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


# --------------------------------------------------------------------------
# Decoder (teacher-forced / prefill / decode)
# --------------------------------------------------------------------------

def forward(
    params: Params,
    features: jax.Array,   # encoder frontend features
    tokens: jax.Array,     # (B, S_dec) decoder input ids
    cfg: ModelConfig,
    policy: ShardingPolicy,
) -> Tuple[jax.Array, jax.Array]:
    """Teacher-forced training pass -> (logits (B,S,V), aux=0)."""
    enc_out = encode(params, features, cfg, policy)
    b, s = tokens.shape
    t_enc = enc_out.shape[1]
    x = L.embed_tokens(params["embed"], tokens, cfg, policy)
    x = x + sinusoids(s, cfg.d_model).astype(x.dtype)[None]
    dpos = jnp.arange(s, dtype=jnp.int32)
    epos = jnp.arange(t_enc, dtype=jnp.int32)

    def body(x, lp):
        h = L.rmsnorm(lp["self_norm"], x, cfg.norm_eps)
        x = x + _attend(lp["self_attn"], h, h, cfg, dpos, dpos, causal=True,
                        chunk=cfg.attn_chunk)
        h = L.rmsnorm(lp["cross_norm"], x, cfg.norm_eps)
        x = x + _attend(lp["cross_attn"], h, enc_out, cfg, dpos, epos,
                        causal=False, chunk=cfg.attn_chunk)
        h = L.rmsnorm(lp["mlp_norm"], x, cfg.norm_eps)
        x = x + L.mlp_block(lp["mlp"], h, cfg, policy)
        return shard_act(x, policy, "batch", None, None), None

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return L.unembed(params["embed"], x, cfg, policy), jnp.zeros((), jnp.float32)


def prefill(
    params: Params,
    features: jax.Array,
    tokens: jax.Array,
    cfg: ModelConfig,
    policy: ShardingPolicy,
) -> Tuple[jax.Array, EncDecCache]:
    """Encode audio + consume the decoder prompt; return caches."""
    enc_out = encode(params, features, cfg, policy)
    b, s = tokens.shape
    t_enc = enc_out.shape[1]
    x = L.embed_tokens(params["embed"], tokens, cfg, policy)
    x = x + sinusoids(s, cfg.d_model).astype(x.dtype)[None]
    dpos = jnp.arange(s, dtype=jnp.int32)
    epos = jnp.arange(t_enc, dtype=jnp.int32)

    def body(x, lp):
        h = L.rmsnorm(lp["self_norm"], x, cfg.norm_eps)
        sk, sv = _proj_kv(lp["self_attn"], h)
        x = x + _attend_cached(lp["self_attn"], h, sk, sv, cfg, dpos, dpos,
                               causal=True, chunk=cfg.attn_chunk)
        h = L.rmsnorm(lp["cross_norm"], x, cfg.norm_eps)
        ck, cv = _proj_kv(lp["cross_attn"], enc_out)
        x = x + _attend_cached(lp["cross_attn"], h, ck, cv, cfg, dpos, epos,
                               causal=False, chunk=cfg.attn_chunk)
        h = L.rmsnorm(lp["mlp_norm"], x, cfg.norm_eps)
        x = x + L.mlp_block(lp["mlp"], h, cfg, policy)
        return x, (sk, sv, ck, cv)

    x, (sks, svs, cks, cvs) = jax.lax.scan(body, x, params["dec_layers"])
    x = L.rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    logits = L.unembed(params["embed"], x, cfg, policy)
    return logits[:, 0], EncDecCache(
        self_kv=KVCache(k=sks, v=svs), cross_k=cks, cross_v=cvs
    )


def decode_step(
    params: Params,
    token: jax.Array,
    cache: EncDecCache,
    cache_len: jax.Array,
    cfg: ModelConfig,
    policy: ShardingPolicy,
) -> Tuple[jax.Array, EncDecCache]:
    b = token.shape[0]
    x = L.embed_tokens(params["embed"], token[:, None], cfg, policy)
    pos_table = sinusoids(cache.self_kv.capacity, cfg.d_model)
    x = x + jax.lax.dynamic_slice_in_dim(
        pos_table, cache_len.astype(jnp.int32), 1, axis=0
    ).astype(x.dtype)[None]
    dpos = jnp.reshape(cache_len, (1,)).astype(jnp.int32)
    t_self = cache.self_kv.capacity
    t_enc = cache.cross_k.shape[2]
    epos = jnp.arange(t_enc, dtype=jnp.int32)

    def body(x, xs):
        lp, sk, sv, ck, cv = xs
        h = L.rmsnorm(lp["self_norm"], x, cfg.norm_eps)
        nk, nv = _proj_kv(lp["self_attn"], h)
        sk = jax.lax.dynamic_update_slice(
            sk, nk.astype(sk.dtype), (0, cache_len.astype(jnp.int32), 0, 0)
        )
        sv = jax.lax.dynamic_update_slice(
            sv, nv.astype(sv.dtype), (0, cache_len.astype(jnp.int32), 0, 0)
        )
        kpos = jnp.arange(t_self, dtype=jnp.int32)
        valid = (kpos[None, :] <= cache_len) & jnp.ones((b, t_self), bool)
        x = x + _attend_cached(lp["self_attn"], h, sk, sv, cfg, dpos, kpos,
                               causal=True, chunk=cfg.attn_chunk, kv_valid=valid)
        h = L.rmsnorm(lp["cross_norm"], x, cfg.norm_eps)
        x = x + _attend_cached(lp["cross_attn"], h, ck, cv, cfg, dpos, epos,
                               causal=False, chunk=cfg.attn_chunk)
        h = L.rmsnorm(lp["mlp_norm"], x, cfg.norm_eps)
        x = x + L.mlp_block(lp["mlp"], h, cfg, policy)
        return x, (sk, sv)

    x, (sks, svs) = jax.lax.scan(
        body, x,
        (params["dec_layers"], cache.self_kv.k, cache.self_kv.v,
         cache.cross_k, cache.cross_v),
    )
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], x, cfg, policy)
    return logits[:, 0], EncDecCache(
        self_kv=KVCache(k=sks, v=svs), cross_k=cache.cross_k, cross_v=cache.cross_v
    )
