"""Decoder-only transformer covering the dense, MoE, and VLM families.

* **dense**: granite-34b/20b (MQA), nemotron-4-340b (GQA + squared-ReLU),
  mistral-nemo-12b (GQA, optional SWA variant).
* **moe**: mixtral-8x22b (8e top-2 + SWA), qwen2-moe-a2.7b (4 shared + 60
  routed top-4) — MLP replaced by :mod:`repro.models.moe`.
* **vlm**: chameleon-34b — early fusion means image content arrives as VQ
  token ids inside the same vocabulary, so the backbone is exactly this
  decoder; the VQ tokenizer frontend is a stub per the brief.

All layer stacks run under ``jax.lax.scan`` with stacked parameters so the
lowered HLO is O(1) in depth (critical for compiling 40 dry-run combos), and
the per-layer body is ``jax.checkpoint``-rematerialised for training.

Entry points (all pure):
  ``init`` / ``param_specs`` — parameters and their PartitionSpec tree.
  ``forward`` — full-sequence logits (training).
  ``prefill`` — forward + populated KV cache + last-position logits.
  ``decode_step`` — one token against a KV cache.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models.cache import KVCache, kv_cache_spec
from repro.models.config import ModelConfig
from repro.models.moe import init_moe_mlp, moe_mlp, spec_moe_mlp
from repro.sharding.policy import ShardingPolicy, shard_act

Params = Dict[str, Any]


# --------------------------------------------------------------------------
# Init / specs
# --------------------------------------------------------------------------

def _init_layer(key, cfg: ModelConfig) -> Params:
    ka, km = jax.random.split(key)
    p: Params = {
        "attn_norm": L.init_rmsnorm(cfg.d_model, cfg.params_dtype()),
        "mlp_norm": L.init_rmsnorm(cfg.d_model, cfg.params_dtype()),
        "attn": L.init_attention(ka, cfg),
    }
    if cfg.family == "moe":
        p["moe"] = init_moe_mlp(km, cfg)
    else:
        p["mlp"] = L.init_mlp(km, cfg)
    return p


def _spec_layer(cfg: ModelConfig, policy: ShardingPolicy) -> Params:
    p: Params = {
        "attn_norm": L.spec_rmsnorm(),
        "mlp_norm": L.spec_rmsnorm(),
        "attn": L.spec_attention(policy),
    }
    if cfg.family == "moe":
        p["moe"] = spec_moe_mlp(cfg, policy)
    else:
        p["mlp"] = L.spec_mlp(cfg, policy)
    return p


def init(key, cfg: ModelConfig) -> Params:
    ke, kl, kn = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.num_layers)
    layers = jax.vmap(lambda k: _init_layer(k, cfg))(layer_keys)
    return {
        "embed": L.init_embed(ke, cfg),
        "layers": layers,  # every leaf stacked with leading L axis
        "final_norm": L.init_rmsnorm(cfg.d_model, cfg.params_dtype()),
    }


def param_specs(cfg: ModelConfig, policy: ShardingPolicy) -> Params:
    layer = _spec_layer(cfg, policy)
    stacked = jax.tree.map(
        lambda s: P(None, *tuple(s)), layer, is_leaf=lambda x: isinstance(x, P)
    )
    return {
        "embed": L.spec_embed(cfg, policy),
        "layers": stacked,
        "final_norm": L.spec_rmsnorm(),
    }


# --------------------------------------------------------------------------
# Layer body
# --------------------------------------------------------------------------

def _layer_apply(
    lp: Params,
    x: jax.Array,
    cfg: ModelConfig,
    policy: ShardingPolicy,
    q_pos: jax.Array,
    kv: Optional[Tuple[jax.Array, jax.Array]] = None,
    cache_len: Optional[jax.Array] = None,
    use_chunked: bool = True,
    return_kv: bool = False,
):
    h = L.rmsnorm(lp["attn_norm"], x, cfg.norm_eps)
    if return_kv:
        # Prefill: compute fresh K/V and also hand them back for the cache.
        q = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wq"])
        k, v = L.project_kv(lp["attn"], h)
        q = L.apply_rope(q, q_pos, cfg.rope_theta)
        k = L.apply_rope(k, q_pos, cfg.rope_theta)
        attend = L.attention_chunked if use_chunked else L.attention_dense
        kw = {"chunk": cfg.attn_chunk} if use_chunked else {}
        attn_out = attend(
            q, k, v, q_pos, q_pos, window=cfg.sliding_window, causal=True, **kw
        )
        attn_out = jnp.einsum("bshk,hkd->bsd", attn_out, lp["attn"]["wo"])
        new_kv = (k, v)
    else:
        attn_out, new_kv = L.attention_block(
            lp["attn"], h, cfg, policy, q_pos,
            kv_cache=kv, cache_len=cache_len, use_chunked=use_chunked,
        )
    x = x + attn_out
    h = L.rmsnorm(lp["mlp_norm"], x, cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "moe":
        mlp_out, aux = moe_mlp(lp["moe"], h, cfg, policy)
    else:
        mlp_out = L.mlp_block(lp["mlp"], h, cfg, policy)
    x = x + mlp_out
    x = shard_act(x, policy, "batch", None, None)
    return x, new_kv, aux


# --------------------------------------------------------------------------
# Entry points
# --------------------------------------------------------------------------

def forward(
    params: Params,
    tokens: jax.Array,
    cfg: ModelConfig,
    policy: ShardingPolicy,
    use_chunked: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Training forward: logits (B, S, V) and summed MoE aux loss."""
    b, s = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, cfg, policy)
    q_pos = jnp.arange(s, dtype=jnp.int32)

    def body(carry, lp):
        x, aux = carry
        x, _, a = _layer_apply(lp, x, cfg, policy, q_pos, use_chunked=use_chunked)
        return (x, aux + a), None

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), params["layers"])
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], x, cfg, policy)
    return logits, aux


def hidden_states(
    params: Params,
    tokens: jax.Array,
    cfg: ModelConfig,
    policy: ShardingPolicy,
    upto_layer: Optional[int] = None,
) -> jax.Array:
    """Hidden states after ``upto_layer`` layers (for affinity profiling)."""
    x = L.embed_tokens(params["embed"], tokens, cfg, policy)
    q_pos = jnp.arange(tokens.shape[1], dtype=jnp.int32)
    n = upto_layer if upto_layer is not None else cfg.num_layers
    sliced = jax.tree.map(lambda a: a[:n], params["layers"])

    def body(x, lp):
        x, _, _ = _layer_apply(lp, x, cfg, policy, q_pos)
        return x, None

    x, _ = jax.lax.scan(body, x, sliced)
    return x


def prefill(
    params: Params,
    tokens: jax.Array,
    cfg: ModelConfig,
    policy: ShardingPolicy,
) -> Tuple[jax.Array, KVCache]:
    """Process a full prompt; return last-position logits + KV cache."""
    b, s = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, cfg, policy)
    q_pos = jnp.arange(s, dtype=jnp.int32)

    def body(x, lp):
        x, kv, _ = _layer_apply(lp, x, cfg, policy, q_pos, return_kv=True)
        return x, kv

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    x = L.rmsnorm(params["final_norm"], x[:, -1:, :], cfg.norm_eps)
    logits = L.unembed(params["embed"], x, cfg, policy)
    # Sliding-window configs keep only the trailing window slots, laid out
    # as a ring buffer (slot = position % window) to match decode_step.
    if cfg.sliding_window is not None and s > cfg.sliding_window:
        w = cfg.sliding_window
        ks = jnp.roll(ks[:, :, -w:], shift=s % w, axis=2)
        vs = jnp.roll(vs[:, :, -w:], shift=s % w, axis=2)
    return logits[:, 0], KVCache(k=ks, v=vs)


def decode_step(
    params: Params,
    token: jax.Array,           # (B,) newest token ids
    cache: KVCache,
    cache_len: jax.Array,       # scalar: number of tokens already cached
    cfg: ModelConfig,
    policy: ShardingPolicy,
) -> Tuple[jax.Array, KVCache]:
    """One decode step: logits (B, V) for the next position + updated cache."""
    x = L.embed_tokens(params["embed"], token[:, None], cfg, policy)  # (B,1,D)
    q_pos = jnp.reshape(cache_len, (1,)).astype(jnp.int32)

    def body(x, xs):
        lp, ck, cv = xs
        x, new_kv, _ = _layer_apply(
            lp, x, cfg, policy, q_pos, kv=(ck, cv), cache_len=cache_len,
            use_chunked=ck.shape[1] > cfg.attn_chunk,
        )
        return x, new_kv

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache.k, cache.v))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], x, cfg, policy)
    return logits[:, 0], KVCache(k=ks, v=vs)


def cache_specs(cfg: ModelConfig, policy: ShardingPolicy) -> KVCache:
    return kv_cache_spec(cfg, policy)
