"""Mixture-of-experts MLP layer (Mixtral-style top-k + Qwen2-MoE shared
experts).

Dispatch is the **group-local gather** formulation: tokens are split into
groups (one group per batch row during training, so dispatch never crosses
the data-parallel axis); within each group the router's top-k choices are
sorted by expert and gathered into a capacity-padded ``(E, C, D)`` buffer per
group; expert FFNs run as one batched einsum over stacked expert weights;
results scatter-add back weighted by the router gate.  Overflowing tokens
beyond each expert's capacity are dropped (standard capacity-factor
behaviour) and counted in the aux outputs.

With ``policy.expert`` set (beyond-paper §Perf iteration), stacked expert
weights shard over the expert axis and GSPMD inserts the all_to_all
dispatch/return — the production expert-parallel layout.

Qwen2-MoE's *shared experts* (always-on, added to the routed output) are the
in-architecture mirror of Antler's shared task-graph blocks: computation
every "task" (token route) reuses unconditionally.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.layers import dense_init
from repro.sharding.policy import ShardingPolicy, shard_act

Params = Dict[str, Any]


def init_moe_mlp(key, cfg: ModelConfig) -> Params:
    dtype = cfg.params_dtype()
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.moe_num_experts
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    std = 1.0 / math.sqrt(d)
    params: Params = {
        "router": (std * jax.random.truncated_normal(kr, -2, 2, (d, e))).astype(
            jnp.float32
        ),
        # Gate/up fused on an unsharded stacking axis (Perf B1): halves the
        # big (G,E,C,D) dx all-reduces of the expert einsums in backward.
        "w_gu": jnp.stack(
            [
                (std * jax.random.truncated_normal(kg, -2, 2, (e, d, f))).astype(dtype),
                (std * jax.random.truncated_normal(ku, -2, 2, (e, d, f))).astype(dtype),
            ],
            axis=2,
        ),  # (E, D, 2, F)
        "w_down": (
            (1.0 / math.sqrt(f))
            * jax.random.truncated_normal(kd, -2, 2, (e, f, d))
        ).astype(dtype),
    }
    if cfg.moe_num_shared_experts > 0:
        fs = cfg.moe_d_ff * cfg.moe_num_shared_experts
        k1, k2, k3 = jax.random.split(ks, 3)
        params["shared"] = {
            "w_gu": jnp.stack(
                [dense_init(k1, d, (fs,), dtype), dense_init(k2, d, (fs,), dtype)],
                axis=1,
            ),  # (D, 2, Fs)
            "w_down": dense_init(k3, fs, (d,), dtype),
        }
    return params


def spec_moe_mlp(cfg: ModelConfig, policy: ShardingPolicy) -> Params:
    m, f = policy.physical("model"), policy.physical("fsdp")
    e = policy.physical("expert")
    if e is not None:
        # Expert parallelism: expert dim over the expert axis, FFN dims whole.
        expert_spec = {
            "w_gu": P(e, f, None, None),
            "w_down": P(e, None, f),
        }
    else:
        # Baseline: experts co-located, tensor-parallel inside each expert.
        expert_spec = {
            "w_gu": P(None, f, None, m),
            "w_down": P(None, m, f),
        }
    spec: Params = {"router": P(None, None), **expert_spec}
    if cfg.moe_num_shared_experts > 0:
        spec["shared"] = {
            "w_gu": P(f, None, m),
            "w_down": P(m, f),
        }
    return spec


def _route(
    router: jax.Array, x: jax.Array, cfg: ModelConfig
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Top-k routing.  x: (G, S, D) -> expert ids (G,S,k), gates (G,S,k),
    full router probs (G,S,E) for the aux loss."""
    logits = (x.astype(jnp.float32) @ router).astype(jnp.float32)  # (G,S,E)
    real = cfg.moe_real_experts or cfg.moe_num_experts
    if real < cfg.moe_num_experts:
        # Padding experts (§Perf B5): mask them out of routing entirely.
        pad_mask = jnp.arange(cfg.moe_num_experts) >= real
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(logits, cfg.moe_top_k)
    gates = jax.nn.softmax(gate_vals, axis=-1)  # renormalise over chosen k
    return expert_ids, gates, probs


def load_balance_loss(probs: jax.Array, expert_ids: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Switch-Transformer aux loss: E * sum_e f_e * P_e."""
    e = cfg.moe_num_experts
    onehot = jax.nn.one_hot(expert_ids, e, dtype=jnp.float32)  # (G,S,k,E)
    frac_tokens = onehot.sum(axis=2).mean(axis=(0, 1))  # (E,)
    mean_prob = probs.mean(axis=(0, 1))
    return e * jnp.sum(frac_tokens * mean_prob)


def moe_mlp(
    params: Params,
    x: jax.Array,
    cfg: ModelConfig,
    policy: ShardingPolicy,
) -> Tuple[jax.Array, jax.Array]:
    """Apply the MoE layer.  x: (B, S, D).  Returns (y, aux_loss).

    Grouping: one group per batch row when S is large (training/prefill) so
    dispatch stays data-local; a single global group for decode (S == 1).
    """
    b, s, d = x.shape
    if s >= 64:
        xg = x  # (G=B, S, D)
    else:
        xg = x.reshape(1, b * s, d)  # decode: one group over the batch
    g, sg, _ = xg.shape
    e, k = cfg.moe_num_experts, cfg.moe_top_k
    cap = max(int(math.ceil(sg * k / e * cfg.moe_capacity_factor)), k)

    expert_ids, gates, probs = _route(params["router"], xg, cfg)
    aux = load_balance_loss(probs, expert_ids, cfg)

    # ---- build the (G, E, C) dispatch table by sorting (token, expert) ----
    flat_e = expert_ids.reshape(g, sg * k)              # (G, S*k)
    flat_tok = jnp.repeat(jnp.arange(sg), k)[None, :].repeat(g, axis=0)
    flat_gate = gates.reshape(g, sg * k)

    order = jnp.argsort(flat_e, axis=-1, stable=True)    # group tokens by expert
    se = jnp.take_along_axis(flat_e, order, axis=-1)
    st = jnp.take_along_axis(flat_tok, order, axis=-1)
    sgate = jnp.take_along_axis(flat_gate, order, axis=-1)

    # rank of each entry within its expert run = idx - first_idx_of_expert
    idx = jnp.arange(sg * k)[None, :]
    first = jax.vmap(lambda seq: jnp.searchsorted(seq, jnp.arange(e)))(se)  # (G,E)
    rank = idx - jnp.take_along_axis(first, se, axis=-1)
    keep = rank < cap

    # (G, E, C) token-index table; empty slots hold the out-of-bounds index
    # ``sg`` so the gather fills zeros and the scatter drops them — no pad
    # row, which would make (sg+1) unevenly sharded and force GSPMD to
    # insert per-layer collective-permutes (§Perf B3).
    table = jnp.full((g, e, cap), sg, dtype=jnp.int32)
    gate_tab = jnp.zeros((g, e, cap), dtype=jnp.float32)
    gi = jnp.arange(g)[:, None]
    slot = jnp.where(keep, rank, cap)
    table = table.at[gi, se, slot].set(st.astype(jnp.int32), mode="drop")
    gate_tab = gate_tab.at[gi, se, slot].set(sgate, mode="drop")

    # Keep the E axis intact through the gather so GSPMD can propagate
    # expert sharding into the dispatch tensor (flattening E x C here blocks
    # the expert-parallel layout entirely — §Perf B5 diagnosis).
    xe = jnp.take_along_axis(
        xg[:, None, :, :], table[:, :, :, None], axis=2,
        mode="fill", fill_value=0,
    )  # (G, E, C, D)
    xe = shard_act(xe, policy, "batch", "expert", None, None)

    # ---- expert FFNs as batched einsums over fused stacked weights ----
    wgu, wd = params["w_gu"], params["w_down"]
    hgu = jnp.einsum("gecd,edkf->geckf", xe, wgu)
    hg, hu = hgu[..., 0, :], hgu[..., 1, :]
    h = jax.nn.silu(hg.astype(jnp.float32)).astype(hu.dtype) * hu
    h = shard_act(h, policy, "batch", "expert", None, "model" if policy.expert is None else None)
    ye = jnp.einsum("gecf,efd->gecd", h, wd)  # (G, E, C, D)

    # ---- combine: scatter-add back to token positions, gate-weighted ----
    ye = ye * gate_tab[..., None].astype(ye.dtype)
    out = jnp.zeros((g, sg, d), ye.dtype)
    out = out.at[gi[:, :, None], table, :].add(ye, mode="drop")

    if "shared" in params:
        sh = params["shared"]
        hgu_s = jnp.einsum("gsd,dkf->gskf", xg, sh["w_gu"])
        hs = jax.nn.silu(hgu_s[:, :, 0].astype(jnp.float32)).astype(
            xg.dtype
        ) * hgu_s[:, :, 1]
        out = out + hs @ sh["w_down"]

    y = out.reshape(b, s, d)
    return shard_act(y, policy, "batch", None, None), aux
