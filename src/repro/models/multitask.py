"""Task-graph-branched multitask models (paper §2.2 "the task graph is
retrained" + §5.3 step 3).

Binds a :class:`~repro.core.task_graph.TaskGraph` to concrete block
semantics and parameters:

* ``build_cnn_program`` — the paper-scale CNN families (benchmarks, examples,
  real-deployment reproductions);
* ``build_transformer_program`` — transformer backbones from the assigned
  architecture zoo: blocks are contiguous layer ranges, tasks are classifier
  heads on the last block's pooled hidden state (the TPU serving analogue);
* ``multitask_loss`` / joint training of all branches, which is the paper's
  "retrain the selected task graph with a multitask learning algorithm".

Both builders return a :class:`~repro.core.executor.MultitaskProgram` (for
the block-cached executor) plus a flat param pytree for training.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.executor import MultitaskProgram
from repro.core.task_graph import TaskGraph
from repro.core.types import BlockCost
from repro.models import cnn
from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.sharding.policy import ShardingPolicy, TP_POLICY

Params = Dict[str, Any]
NodeId = Tuple[int, Tuple[int, ...]]


# --------------------------------------------------------------------------
# CNN program (paper-scale)
# --------------------------------------------------------------------------

def build_cnn_program(
    key: jax.Array,
    graph: TaskGraph,
    num_classes: Sequence[int],
    input_hw: Tuple[int, int, int] = (28, 28, 1),
) -> MultitaskProgram:
    """Instantiate per-node CNN blocks + per-task heads for a task graph."""
    inits, applies, costs, feat = cnn.build_lenet5_blocks(input_hw)
    if graph.depth != len(applies):
        raise ValueError(
            f"graph depth {graph.depth} != number of CNN blocks {len(applies)}"
        )
    node_params: Dict[NodeId, Params] = {}
    for node in graph.nodes():
        d, _g = node
        key, sub = jax.random.split(key)
        node_params[node] = inits[d](sub)
    head_params = []
    for t in range(graph.num_tasks):
        key, sub = jax.random.split(key)
        head_params.append(cnn.head_init(sub, feat, num_classes[t]))
    return MultitaskProgram(
        graph=graph,
        block_fns=applies,
        node_params=node_params,
        head_fns=[cnn.head_apply] * graph.num_tasks,
        head_params=head_params,
        block_costs=costs,
    )


# --------------------------------------------------------------------------
# Transformer program (TPU-scale serving analogue)
# --------------------------------------------------------------------------

def _split_layers(num_layers: int, num_blocks: int) -> List[Tuple[int, int]]:
    """Contiguous [start, end) layer ranges, near-equal sizes."""
    base, rem = divmod(num_layers, num_blocks)
    ranges, start = [], 0
    for i in range(num_blocks):
        n = base + (1 if i < rem else 0)
        ranges.append((start, start + n))
        start += n
    return ranges


def transformer_block_costs(
    cfg: ModelConfig, ranges: Sequence[Tuple[int, int]], seq_len: int
) -> List[BlockCost]:
    """Per-block weight bytes + FLOPs for a layer-range block (per sample)."""
    bytes_per_param = jnp.dtype(cfg.param_dtype).itemsize
    d, f, hd = cfg.d_model, cfg.d_ff, cfg.head_dim
    per_layer_params = (
        d * cfg.n_heads * hd          # wq
        + 2 * d * cfg.n_kv_heads * hd # wk, wv
        + cfg.n_heads * hd * d        # wo
        + (3 if cfg.activation == "swiglu" else 2) * d * f
        + 2 * d                       # norms
    )
    per_layer_flops = 2.0 * seq_len * (
        d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd + cfg.n_heads * hd * d
        + (3 if cfg.activation == "swiglu" else 2) * d * f
    ) + 2.0 * 2.0 * seq_len * seq_len * cfg.n_heads * hd / 2.0  # causal attn
    out = []
    for (a, b) in ranges:
        n = b - a
        out.append(
            BlockCost(
                weight_bytes=float(bytes_per_param * per_layer_params * n),
                flops=float(per_layer_flops * n),
                act_bytes=float(2.0 * seq_len * d),
            )
        )
    return out


def build_transformer_program(
    key: jax.Array,
    graph: TaskGraph,
    cfg: ModelConfig,
    num_classes: Sequence[int],
    seq_len: int = 128,
    policy: ShardingPolicy = TP_POLICY,
) -> MultitaskProgram:
    """Blocks = contiguous transformer layer ranges; heads = linear probes.

    The depth-0 block also owns the embedding table (it is always the
    root-most shared computation).  Task "heads" classify the mean-pooled
    final hidden state — the multitask-serving analogue of the paper's
    per-task dense classifier.
    """
    from repro.models import transformer as T

    ranges = _split_layers(cfg.num_layers, graph.depth)
    q_pos = jnp.arange(seq_len, dtype=jnp.int32)

    def make_block_fn(depth: int):
        a, b = ranges[depth]

        def apply(p: Params, x: jax.Array) -> jax.Array:
            if depth == 0:
                x = L.embed_tokens(p["embed"], x, cfg, policy)

            def body(h, lp):
                h2, _, _ = T._layer_apply(lp, h, cfg, policy, q_pos)
                return h2, None

            x, _ = jax.lax.scan(body, x, p["layers"])
            return x

        return apply

    def init_block(key, depth: int) -> Params:
        a, b = ranges[depth]
        n = b - a
        keys = jax.random.split(key, n)
        layers = jax.vmap(lambda k: T._init_layer(k, cfg))(keys)
        p: Params = {"layers": layers}
        if depth == 0:
            p["embed"] = L.init_embed(jax.random.fold_in(key, 7), cfg)
        return p

    node_params: Dict[NodeId, Params] = {}
    for node in graph.nodes():
        d, _g = node
        key, sub = jax.random.split(key)
        node_params[node] = init_block(sub, d)

    def head_fn(p: Params, x: jax.Array) -> jax.Array:
        pooled = x[:, -1].astype(jnp.float32)  # last position sees everything
        # Parameter-free standardisation: the residual stream's scale grows
        # with depth at init; without this the head starts above-chance
        # confidently wrong and training stalls.
        pooled = (pooled - pooled.mean(-1, keepdims=True)) / (
            pooled.std(-1, keepdims=True) + 1e-6
        )
        return pooled @ p["w"] + p["b"]

    head_params = []
    for t in range(graph.num_tasks):
        key, sub = jax.random.split(key)
        std = 1.0 / math.sqrt(cfg.d_model)
        head_params.append({
            "w": (std * jax.random.truncated_normal(
                sub, -2, 2, (cfg.d_model, num_classes[t])
            )).astype(jnp.float32),
            "b": jnp.zeros((num_classes[t],), jnp.float32),
        })

    costs = transformer_block_costs(cfg, ranges, seq_len)
    return MultitaskProgram(
        graph=graph,
        block_fns=[make_block_fn(d) for d in range(graph.depth)],
        node_params=node_params,
        head_fns=[head_fn] * graph.num_tasks,
        head_params=head_params,
        block_costs=costs,
    )


# --------------------------------------------------------------------------
# Joint multitask training (the paper's retraining step, [59]-style)
# --------------------------------------------------------------------------

def program_trainable_params(program: MultitaskProgram) -> Params:
    """Flat param pytree: {"nodes": {node_key: ...}, "heads": [...]}"""
    return {
        "nodes": {repr(k): v for k, v in program.node_params.items()},
        "heads": list(program.head_params),
    }


def program_with_params(program: MultitaskProgram, flat: Params) -> MultitaskProgram:
    node_params = {k: flat["nodes"][repr(k)] for k in program.node_params}
    return MultitaskProgram(
        graph=program.graph,
        block_fns=program.block_fns,
        node_params=node_params,
        head_fns=program.head_fns,
        head_params=list(flat["heads"]),
        block_costs=program.block_costs,
    )


def multitask_forward(
    program: MultitaskProgram, flat: Params, x: jax.Array
) -> List[jax.Array]:
    """Pure forward of every task (no caching — training path).

    Shared nodes appear once in ``flat`` so gradients accumulate across all
    tasks using them: that *is* branched multitask learning.
    """
    graph = program.graph
    outs = []
    # Memoise shared-prefix activations per node within this trace: the
    # compiler sees each shared block once (same effect as the runtime cache,
    # but differentiable).
    memo: Dict[str, jax.Array] = {}
    for t in range(graph.num_tasks):
        h = x
        for d, node in enumerate(graph.path(t)):
            k = repr(node)
            if k in memo:
                h = memo[k]
                continue
            h = program.block_fns[d](flat["nodes"][k], h)
            memo[k] = h
        outs.append(program.head_fns[t](flat["heads"][t], h))
    return outs


def multitask_loss(
    program: MultitaskProgram,
    flat: Params,
    x: jax.Array,
    labels: jax.Array,  # (num_tasks, B) integer labels
    task_weights: Optional[jax.Array] = None,
) -> jax.Array:
    logits = multitask_forward(program, flat, x)
    losses = []
    for t, lg in enumerate(logits):
        logp = jax.nn.log_softmax(lg.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, labels[t][:, None], axis=-1).mean()
        losses.append(nll)
    losses = jnp.stack(losses)
    if task_weights is not None:
        return jnp.sum(losses * task_weights) / jnp.sum(task_weights)
    return losses.mean()
