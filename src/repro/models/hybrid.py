"""Zamba2-style hybrid: Mamba2 backbone + a globally-shared attention block
(arXiv:2411.15242).

Zamba2 interleaves Mamba2 blocks with a *single* shared full-attention block
invoked every ``hybrid_attn_period`` layers; invocations differ through
cheap per-invocation input norms AND low-rank (LoRA) deltas on the shared
block's q/kv projections (``hybrid_lora_rank``), matching Zamba2's design.  The shared block is the in-architecture
mirror of Antler's shared task-graph blocks: one set of weights reused at
many points of the computation (noted in DESIGN.md §5).

Structure (for ``num_layers = P * n_inv``)::

    for i in range(n_inv):            # outer scan over super-blocks
        for j in range(P):            # inner scan over Mamba2 layers
            x += mamba2(x)
        x += shared_attn(norm_i(x))   # shared weights, per-invocation norm

Decode uses :class:`~repro.models.cache.HybridCache` — SSM state for every
Mamba2 layer and a KV cache per shared-attention invocation.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models import ssm as M
from repro.models.cache import HybridCache, KVCache, SSMCache
from repro.models.config import ModelConfig
from repro.sharding.policy import ShardingPolicy, shard_act

Params = Dict[str, Any]


def _n_inv(cfg: ModelConfig) -> int:
    assert cfg.num_layers % cfg.hybrid_attn_period == 0, (
        "hybrid depth must be a multiple of hybrid_attn_period"
    )
    return cfg.num_layers // cfg.hybrid_attn_period


def init(key, cfg: ModelConfig) -> Params:
    ke, km, ka, kn = jax.random.split(key, 4)
    n_inv, period = _n_inv(cfg), cfg.hybrid_attn_period
    layer_keys = jax.random.split(km, cfg.num_layers).reshape(n_inv, period, 2)
    mamba = jax.vmap(jax.vmap(lambda k: M.init_mamba_block(k, cfg)))(layer_keys)
    inv_keys = jax.random.split(kn, n_inv)
    inv_norms = jax.vmap(
        lambda k: L.init_rmsnorm(cfg.d_model, cfg.params_dtype())
    )(inv_keys)
    params = {
        "embed": L.init_embed(ke, cfg),
        "mamba": mamba,                    # leaves: (n_inv, period, ...)
        "shared_attn": L.init_attention(ka, cfg),   # ONE set of weights
        "shared_mlp": L.init_mlp(jax.random.fold_in(ka, 1), cfg),
        "inv_norms": inv_norms,            # (n_inv, d_model)
        "final_norm": L.init_rmsnorm(cfg.d_model, cfg.params_dtype()),
    }
    if cfg.hybrid_lora_rank > 0:
        # Zamba2's per-invocation LoRA deltas on the shared block's q/kv
        # projections: A init ~ N(0, 1/sqrt(D)), B init zero (standard LoRA
        # zero-start so invocation 0 == the shared weights exactly).
        r = cfg.hybrid_lora_rank
        d, hq, hk, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        dtype = cfg.params_dtype()
        kq, kkv = jax.random.split(jax.random.fold_in(ka, 2))
        def lora_a(k, shape):
            return jax.vmap(
                lambda kk: L.dense_init(kk, shape[0], shape[1:], dtype)
            )(jax.random.split(k, n_inv))
        params["inv_lora"] = {
            "aq": lora_a(kq, (d, r)),                     # (n_inv, D, r)
            "bq": jnp.zeros((n_inv, r, hq, hd), dtype),
            "akv": lora_a(kkv, (d, r)),
            "bkv": jnp.zeros((n_inv, r, 2, hk, hd), dtype),
        }
    return params


def param_specs(cfg: ModelConfig, policy: ShardingPolicy) -> Params:
    mspec = M.spec_mamba_block(cfg, policy)
    stacked = jax.tree.map(
        lambda s: P(None, None, *tuple(s)), mspec, is_leaf=lambda v: isinstance(v, P)
    )
    return {
        "embed": L.spec_embed(cfg, policy),
        "mamba": stacked,
        "shared_attn": L.spec_attention(policy),
        "shared_mlp": L.spec_mlp(cfg, policy),
        "inv_norms": jax.tree.map(
            lambda s: P(None, *tuple(s)), L.spec_rmsnorm(),
            is_leaf=lambda v: isinstance(v, P),
        ),
        **({"inv_lora": {
            "aq": P(None, None, None),
            "bq": P(None, None, policy.physical("model"), None),
            "akv": P(None, None, None),
            "bkv": P(None, None, None, None, None),
        }} if cfg.hybrid_lora_rank > 0 else {}),
        "final_norm": L.spec_rmsnorm(),
    }


def _lora_qkv(params: Params, inv_lora: Optional[Params], h: jax.Array):
    """Shared-weight q/k/v projections + per-invocation LoRA deltas."""
    ap = params["shared_attn"]
    q = jnp.einsum("bsd,dhk->bshk", h, ap["wq"])
    k, v = L.project_kv(ap, h)
    if inv_lora is not None:
        zq = h @ inv_lora["aq"]                        # (B,S,r)
        q = q + jnp.einsum("bsr,rhk->bshk", zq, inv_lora["bq"])
        zkv = h @ inv_lora["akv"]
        dkv = jnp.einsum("bsr,rthk->bsthk", zkv, inv_lora["bkv"])
        k = k + dkv[:, :, 0]
        v = v + dkv[:, :, 1]
    return q, k, v


def _shared_attn_apply(
    params: Params,
    inv_norm: Params,
    x: jax.Array,
    cfg: ModelConfig,
    policy: ShardingPolicy,
    q_pos: jax.Array,
    kv: Optional[Tuple[jax.Array, jax.Array]] = None,
    cache_len: Optional[jax.Array] = None,
    inv_lora: Optional[Params] = None,
) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    h = L.rmsnorm(inv_norm, x, cfg.norm_eps)
    ap = params["shared_attn"]
    q, k_new, v_new = _lora_qkv(params, inv_lora, h)
    q = L.apply_rope(q, q_pos, cfg.rope_theta)
    new_kv = None
    if kv is not None:
        # decode: append this step's K/V to the invocation's cache
        ck, cv = kv
        t = ck.shape[1]
        k_new = L.apply_rope(k_new, q_pos, cfg.rope_theta)
        idx = (cache_len % t).astype(jnp.int32)
        ck = jax.lax.dynamic_update_slice(ck, k_new.astype(ck.dtype), (0, idx, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v_new.astype(cv.dtype), (0, idx, 0, 0))
        new_kv = (ck, cv)
        k_pos = jnp.arange(t, dtype=jnp.int32)
        kv_valid = (k_pos[None, :] <= cache_len) & jnp.ones(
            (x.shape[0], t), dtype=bool
        )
        attn = L.attention_decode(
            q, ck, cv, k_pos, cache_len, window=cfg.sliding_window,
            kv_valid=kv_valid,
        )
    else:
        k_new = L.apply_rope(k_new, q_pos, cfg.rope_theta)
        attn = L.attention_chunked(
            q, k_new, v_new, q_pos, q_pos, window=cfg.sliding_window,
            causal=True, chunk=cfg.attn_chunk,
        )
    x = x + jnp.einsum("bshk,hkd->bsd", attn, ap["wo"])
    x = x + L.mlp_block(params["shared_mlp"], h, cfg, policy)
    return shard_act(x, policy, "batch", None, None), new_kv


def forward(
    params: Params,
    tokens: jax.Array,
    cfg: ModelConfig,
    policy: ShardingPolicy,
    use_chunked: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    x = L.embed_tokens(params["embed"], tokens, cfg, policy)
    q_pos = jnp.arange(tokens.shape[1], dtype=jnp.int32)

    def inner(x, lp):
        y, _ = M.mamba_block(lp, x, cfg, policy)
        return x + y, None

    if cfg.remat:
        inner = jax.checkpoint(
            inner, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )

    def outer(x, xs):
        mamba_stack, inv_norm, inv_lora = xs
        x, _ = jax.lax.scan(inner, x, mamba_stack)
        x, _ = _shared_attn_apply(
            params, inv_norm, x, cfg, policy, q_pos, inv_lora=inv_lora
        )
        return x, None

    x, _ = jax.lax.scan(
        outer, x,
        (params["mamba"], params["inv_norms"], params.get("inv_lora")),
    )
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], x, cfg, policy)
    return logits, jnp.zeros((), jnp.float32)


def prefill(
    params: Params, tokens: jax.Array, cfg: ModelConfig, policy: ShardingPolicy
) -> Tuple[jax.Array, HybridCache]:
    bsz, s = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, cfg, policy)
    q_pos = jnp.arange(s, dtype=jnp.int32)
    w = cfg.ssm_conv_width
    n_inv, period = _n_inv(cfg), cfg.hybrid_attn_period

    def inner(x, lp):
        # Mamba block + cache extraction (same derivation as ssm.prefill).
        u = L.rmsnorm(lp["norm"], x, cfg.norm_eps)
        z, xin0, b0, c0, dt_raw0 = M._in_proj(lp, u)
        conv_in = jnp.concatenate([xin0, b0, c0], -1)
        tail = conv_in[:, -(w - 1):, :]
        conv_out = jax.nn.silu(
            M.causal_conv(conv_in, lp["conv"]).astype(jnp.float32)
        ).astype(conv_in.dtype)
        di, n = cfg.ssm_d_inner, cfg.ssm_state
        xin, b_in, c_in = jnp.split(conv_out, [di, di + n], axis=-1)
        dt = jax.nn.softplus(dt_raw0.astype(jnp.float32) + lp["dt_bias"])
        a = -jnp.exp(lp["a_log"])
        xh = xin.reshape(bsz, s, cfg.ssm_n_heads, cfg.ssm_head_dim)
        y, final_state = M.ssd_chunked(xh, dt, a, b_in, c_in, cfg.ssm_chunk)
        y = y + lp["d_skip"][None, None, :, None].astype(y.dtype) * xh
        gated = y.reshape(bsz, s, di) * jax.nn.silu(
            z.astype(jnp.float32)
        ).astype(y.dtype)
        gated = L.rmsnorm(lp["gated_norm"], gated, cfg.norm_eps)
        return x + gated @ lp["wo"], (tail, final_state)

    def outer(x, xs):
        mamba_stack, inv_norm, inv_lora = xs
        x, ssm_caches = jax.lax.scan(inner, x, mamba_stack)
        # Shared attention with fresh K/V for the invocation's cache.
        h = L.rmsnorm(inv_norm, x, cfg.norm_eps)
        ap = params["shared_attn"]
        q, k, v = _lora_qkv(params, inv_lora, h)
        q = L.apply_rope(q, q_pos, cfg.rope_theta)
        k = L.apply_rope(k, q_pos, cfg.rope_theta)
        attn = L.attention_chunked(
            q, k, v, q_pos, q_pos, window=cfg.sliding_window,
            causal=True, chunk=cfg.attn_chunk,
        )
        x = x + jnp.einsum("bshk,hkd->bsd", attn, ap["wo"])
        x = x + L.mlp_block(params["shared_mlp"], h, cfg, policy)
        return x, (ssm_caches, (k, v))

    x, (ssm_caches, kvs) = jax.lax.scan(
        outer, x, (params["mamba"], params["inv_norms"], params.get("inv_lora"))
    )
    conv_t, state_t = ssm_caches
    ssm = SSMCache(
        conv=conv_t.reshape(n_inv * period, *conv_t.shape[2:]),
        state=state_t.reshape(n_inv * period, *state_t.shape[2:]),
    )
    kv = KVCache(k=kvs[0], v=kvs[1])
    x = L.rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    logits = L.unembed(params["embed"], x, cfg, policy)
    return logits[:, 0], HybridCache(ssm=ssm, kv=kv)


def decode_step(
    params: Params,
    token: jax.Array,
    cache: HybridCache,
    cache_len: jax.Array,
    cfg: ModelConfig,
    policy: ShardingPolicy,
) -> Tuple[jax.Array, HybridCache]:
    x = L.embed_tokens(params["embed"], token[:, None], cfg, policy)
    q_pos = jnp.reshape(cache_len, (1,)).astype(jnp.int32)
    n_inv, period = _n_inv(cfg), cfg.hybrid_attn_period
    conv = cache.ssm.conv.reshape(n_inv, period, *cache.ssm.conv.shape[1:])
    state = cache.ssm.state.reshape(n_inv, period, *cache.ssm.state.shape[1:])

    def inner(x, xs):
        lp, cc, sc = xs
        y, new_cache = M.mamba_block(lp, x, cfg, policy, cache=(cc, sc))
        return x + y, new_cache

    def outer(x, xs):
        mamba_stack, inv_norm, inv_lora, cc, sc, ck, cv = xs
        x, ssm_new = jax.lax.scan(inner, x, (mamba_stack, cc, sc))
        x, kv_new = _shared_attn_apply(
            params, inv_norm, x, cfg, policy, q_pos,
            kv=(ck, cv), cache_len=cache_len, inv_lora=inv_lora,
        )
        return x, (ssm_new, kv_new)

    x, (ssm_new, kv_new) = jax.lax.scan(
        outer, x,
        (params["mamba"], params["inv_norms"], params.get("inv_lora"),
         conv, state, cache.kv.k, cache.kv.v),
    )
    conv_n, state_n = ssm_new
    new_cache = HybridCache(
        ssm=SSMCache(
            conv=conv_n.reshape(n_inv * period, *conv_n.shape[2:]),
            state=state_n.reshape(n_inv * period, *state_n.shape[2:]),
        ),
        kv=KVCache(k=kv_new[0], v=kv_new[1]),
    )
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], x, cfg, policy)
    return logits[:, 0], new_cache
