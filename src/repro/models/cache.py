"""Decode-time caches (KV for attention, conv+SSM state for Mamba2).

Caches are plain pytrees so they pass through ``jax.jit`` / ``lax.scan`` and
take PartitionSpecs like any other tensor.  Sliding-window attention uses a
ring buffer of ``window`` slots, which is what makes ``long_500k`` decode
feasible for SWA architectures (cache is O(window), not O(seq)).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.sharding.policy import ShardingPolicy


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class KVCache:
    """Stacked per-layer KV cache: ``k``/``v`` are (L, B, T, Hk, Dh)."""

    k: jax.Array
    v: jax.Array

    @property
    def capacity(self) -> int:
        return self.k.shape[2]


def kv_cache_shape(
    cfg: ModelConfig, batch: int, seq_len: int, layers: Optional[int] = None
):
    """ShapeDtypeStructs for a cache able to attend over ``seq_len`` tokens.

    For sliding-window configs the allocation is ``min(seq_len, window)``
    slots (ring buffer) — the long-context enabler.
    """
    t = seq_len if cfg.sliding_window is None else min(seq_len, cfg.sliding_window)
    layers = layers if layers is not None else cfg.num_layers
    shape = (layers, batch, t, cfg.n_kv_heads, cfg.head_dim)
    dt = cfg.activation_dtype()
    return KVCache(
        k=jax.ShapeDtypeStruct(shape, dt), v=jax.ShapeDtypeStruct(shape, dt)
    )


def kv_cache_zeros(cfg: ModelConfig, batch: int, seq_len: int,
                   layers: Optional[int] = None) -> KVCache:
    s = kv_cache_shape(cfg, batch, seq_len, layers)
    return KVCache(k=jnp.zeros(s.k.shape, s.k.dtype), v=jnp.zeros(s.v.shape, s.v.dtype))


def kv_cache_spec(cfg: ModelConfig, policy: ShardingPolicy) -> KVCache:
    """Batch over data axes; heads or sequence over the model axis.

    Mesh-adaptive (§Perf C2/C2b): when the KV-head count divides the model
    axis (whisper/qwen2 kv=16, zamba2 kv=32) head sharding is free and
    optimal.  When it does not (MQA kv=1, GQA kv=8 on a 16-way axis) the
    cache would be fully REPLICATED — 16x footprint, which does not even
    fit HBM for the big decode rows — so the SEQUENCE axis shards instead
    (each model shard attends over its slice; GSPMD adds only small
    softmax-stat/output all-reduces).
    """
    from repro.sharding.policy import _ambient_mesh

    b = policy.physical("batch")
    m = policy.physical("model")
    mesh = _ambient_mesh()
    model_size = 1
    if mesh is not None and isinstance(m, str) and m in mesh.shape:
        model_size = int(mesh.shape[m])
    if model_size > 1 and cfg.n_kv_heads % model_size != 0:
        spec = P(None, b, m, None, None)   # sequence-sharded ring/cache
    else:
        spec = P(None, b, None, m, None)   # head-sharded
    return KVCache(k=spec, v=spec)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SSMCache:
    """Mamba2 decode state: conv ring + SSD state, stacked over layers.

    ``conv``: (L, B, W-1, conv_dim) last inputs for the causal conv.
    ``state``: (L, B, H, P, N) SSD recurrent state.
    """

    conv: jax.Array
    state: jax.Array


def ssm_cache_shape(cfg: ModelConfig, batch: int, layers: Optional[int] = None):
    layers = layers if layers is not None else cfg.num_layers
    conv_dim = cfg.ssm_d_inner + 2 * cfg.ssm_state
    dt = cfg.activation_dtype()
    return SSMCache(
        conv=jax.ShapeDtypeStruct(
            (layers, batch, cfg.ssm_conv_width - 1, conv_dim), dt
        ),
        state=jax.ShapeDtypeStruct(
            (layers, batch, cfg.ssm_n_heads, cfg.ssm_head_dim, cfg.ssm_state),
            jnp.float32,
        ),
    )


def ssm_cache_zeros(cfg: ModelConfig, batch: int, layers: Optional[int] = None) -> SSMCache:
    s = ssm_cache_shape(cfg, batch, layers)
    return SSMCache(
        conv=jnp.zeros(s.conv.shape, s.conv.dtype),
        state=jnp.zeros(s.state.shape, s.state.dtype),
    )


def ssm_cache_spec(cfg: ModelConfig, policy: ShardingPolicy) -> SSMCache:
    b = policy.physical("batch")
    m = policy.physical("model")
    return SSMCache(
        conv=P(None, b, None, None),
        state=P(None, b, m, None, None),
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class HybridCache:
    """Zamba2 decode state: SSM caches for every Mamba2 layer + KV caches for
    each invocation of the globally-shared attention block."""

    ssm: SSMCache
    kv: KVCache


def hybrid_cache_shape(cfg: ModelConfig, batch: int, seq_len: int) -> HybridCache:
    n_inv = cfg.num_layers // cfg.hybrid_attn_period
    return HybridCache(
        ssm=ssm_cache_shape(cfg, batch, layers=cfg.num_layers),
        kv=kv_cache_shape(cfg, batch, seq_len, layers=n_inv),
    )


def hybrid_cache_zeros(cfg: ModelConfig, batch: int, seq_len: int) -> HybridCache:
    n_inv = cfg.num_layers // cfg.hybrid_attn_period
    return HybridCache(
        ssm=ssm_cache_zeros(cfg, batch, layers=cfg.num_layers),
        kv=kv_cache_zeros(cfg, batch, seq_len, layers=n_inv),
    )


def hybrid_cache_spec(cfg: ModelConfig, policy: ShardingPolicy) -> HybridCache:
    return HybridCache(
        ssm=ssm_cache_spec(cfg, policy), kv=kv_cache_spec(cfg, policy)
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class EncDecCache:
    """Whisper decode state: decoder self-attention KV + encoder cross K/V
    (computed once from the encoder output at prefill)."""

    self_kv: KVCache
    cross_k: jax.Array  # (L, B, T_enc, Hk, Dh)
    cross_v: jax.Array


def encdec_cache_shape(
    cfg: ModelConfig, batch: int, dec_len: int, enc_len: int
) -> EncDecCache:
    dt = cfg.activation_dtype()
    cross = jax.ShapeDtypeStruct(
        (cfg.num_layers, batch, enc_len, cfg.n_kv_heads, cfg.head_dim), dt
    )
    return EncDecCache(
        self_kv=kv_cache_shape(cfg, batch, dec_len), cross_k=cross, cross_v=cross
    )


def encdec_cache_zeros(cfg: ModelConfig, batch: int, dec_len: int, enc_len: int) -> EncDecCache:
    s = encdec_cache_shape(cfg, batch, dec_len, enc_len)
    return EncDecCache(
        self_kv=kv_cache_zeros(cfg, batch, dec_len),
        cross_k=jnp.zeros(s.cross_k.shape, s.cross_k.dtype),
        cross_v=jnp.zeros(s.cross_v.shape, s.cross_v.dtype),
    )


def encdec_cache_spec(cfg: ModelConfig, policy: ShardingPolicy) -> EncDecCache:
    b = policy.physical("batch")
    m = policy.physical("model")
    cross = P(None, b, None, m, None)
    return EncDecCache(self_kv=kv_cache_spec(cfg, policy), cross_k=cross, cross_v=cross)
