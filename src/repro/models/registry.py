"""Uniform model API across families.

``get_model(cfg)`` returns a :class:`ModelApi` whose members have identical
signatures regardless of family, so the trainer, server, dry-run and
benchmarks never branch on architecture:

  init(key)                         -> params
  param_specs(policy)               -> PartitionSpec tree matching params
  forward(params, batch, policy)    -> (logits, aux_loss)   [teacher-forced]
  prefill(params, batch, policy)    -> (last_logits, cache)
  decode_step(params, token, cache, cache_len, policy) -> (logits, cache)
  cache_shape(batch, seq_len)       -> ShapeDtypeStruct cache pytree
  cache_spec(policy)                -> PartitionSpec cache pytree

For `encdec`, ``batch`` is a dict with ``features`` and ``tokens``; all other
families take a token array.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import encdec, hybrid, ssm, transformer
from repro.models import cache as C
from repro.models.config import ModelConfig
from repro.sharding.policy import ShardingPolicy


@dataclasses.dataclass(frozen=True)
class ModelApi:
    cfg: ModelConfig
    init: Callable
    param_specs: Callable
    forward: Callable
    prefill: Callable
    decode_step: Callable
    cache_shape: Callable
    cache_spec: Callable


def _transformer_api(cfg: ModelConfig) -> ModelApi:
    return ModelApi(
        cfg=cfg,
        init=lambda key: transformer.init(key, cfg),
        param_specs=lambda policy: transformer.param_specs(cfg, policy),
        forward=lambda p, batch, policy: transformer.forward(p, batch, cfg, policy),
        prefill=lambda p, batch, policy: transformer.prefill(p, batch, cfg, policy),
        decode_step=lambda p, tok, cache, n, policy: transformer.decode_step(
            p, tok, cache, n, cfg, policy
        ),
        cache_shape=lambda batch, seq_len: C.kv_cache_shape(cfg, batch, seq_len),
        cache_spec=lambda policy: C.kv_cache_spec(cfg, policy),
    )


def _ssm_api(cfg: ModelConfig) -> ModelApi:
    return ModelApi(
        cfg=cfg,
        init=lambda key: ssm.init(key, cfg),
        param_specs=lambda policy: ssm.param_specs(cfg, policy),
        forward=lambda p, batch, policy: ssm.forward(p, batch, cfg, policy),
        prefill=lambda p, batch, policy: ssm.prefill(p, batch, cfg, policy),
        decode_step=lambda p, tok, cache, n, policy: ssm.decode_step(
            p, tok, cache, n, cfg, policy
        ),
        cache_shape=lambda batch, seq_len: C.ssm_cache_shape(cfg, batch),
        cache_spec=lambda policy: C.ssm_cache_spec(cfg, policy),
    )


def _hybrid_api(cfg: ModelConfig) -> ModelApi:
    return ModelApi(
        cfg=cfg,
        init=lambda key: hybrid.init(key, cfg),
        param_specs=lambda policy: hybrid.param_specs(cfg, policy),
        forward=lambda p, batch, policy: hybrid.forward(p, batch, cfg, policy),
        prefill=lambda p, batch, policy: hybrid.prefill(p, batch, cfg, policy),
        decode_step=lambda p, tok, cache, n, policy: hybrid.decode_step(
            p, tok, cache, n, cfg, policy
        ),
        cache_shape=lambda batch, seq_len: C.hybrid_cache_shape(cfg, batch, seq_len),
        cache_spec=lambda policy: C.hybrid_cache_spec(cfg, policy),
    )


# Whisper's encoder output length used by decode-shape caches: 30 s of audio
# at 50 frames/s (the model card's 1500-frame receptive field).
WHISPER_ENC_LEN = 1500


def _encdec_api(cfg: ModelConfig) -> ModelApi:
    def forward(p, batch, policy):
        return encdec.forward(p, batch["features"], batch["tokens"], cfg, policy)

    def prefill(p, batch, policy):
        return encdec.prefill(p, batch["features"], batch["tokens"], cfg, policy)

    return ModelApi(
        cfg=cfg,
        init=lambda key: encdec.init(key, cfg),
        param_specs=lambda policy: encdec.param_specs(cfg, policy),
        forward=forward,
        prefill=prefill,
        decode_step=lambda p, tok, cache, n, policy: encdec.decode_step(
            p, tok, cache, n, cfg, policy
        ),
        cache_shape=lambda batch, seq_len: C.encdec_cache_shape(
            cfg, batch, seq_len, WHISPER_ENC_LEN
        ),
        cache_spec=lambda policy: C.encdec_cache_spec(cfg, policy),
    )


def get_model(cfg: ModelConfig) -> ModelApi:
    if cfg.family in ("dense", "vlm", "moe"):
        return _transformer_api(cfg)
    if cfg.family == "ssm":
        return _ssm_api(cfg)
    if cfg.family == "hybrid":
        return _hybrid_api(cfg)
    if cfg.family == "encdec":
        return _encdec_api(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")
