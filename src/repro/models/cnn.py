"""Small CNNs for the paper-scale experiments (LeNet-class, §6 Table 2).

The paper's dataset experiments use 5-layer CNNs (2 conv + 3 dense) as the
common network architecture over MNIST-class inputs.  These blocks feed the
task-graph machinery: the common architecture is cut into ``D + 1`` blocks at
the branch points, each block is an (init, apply) pair, and
:mod:`repro.models.multitask` assembles them into a
:class:`~repro.core.executor.MultitaskProgram`.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import BlockCost

Params = Dict[str, Any]
BlockInit = Callable[[jax.Array], Params]
BlockApply = Callable[[Params, jax.Array], jax.Array]


def conv2d(params: Params, x: jax.Array) -> jax.Array:
    """3x3 SAME conv + bias.  x: (B, H, W, C)."""
    y = jax.lax.conv_general_dilated(
        x, params["w"], window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + params["b"]


def maxpool2(x: jax.Array) -> jax.Array:
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def leaky_relu(x: jax.Array) -> jax.Array:
    # The paper's C library implements leaky ReLU (§5.2).
    return jax.nn.leaky_relu(x, negative_slope=0.01)


def _conv_init(key, cin: int, cout: int) -> Params:
    kw, _ = jax.random.split(key)
    std = 1.0 / math.sqrt(9 * cin)
    return {
        "w": std * jax.random.truncated_normal(kw, -2, 2, (3, 3, cin, cout)),
        "b": jnp.zeros((cout,)),
    }


def _dense_init(key, din: int, dout: int) -> Params:
    std = 1.0 / math.sqrt(din)
    return {
        "w": std * jax.random.truncated_normal(key, -2, 2, (din, dout)),
        "b": jnp.zeros((dout,)),
    }


def dense(params: Params, x: jax.Array) -> jax.Array:
    return x @ params["w"] + params["b"]


def build_lenet5_blocks(
    input_hw: Tuple[int, int, int] = (28, 28, 1),
    channels: Sequence[int] = (8, 16),
    dense_dims: Sequence[int] = (64, 32),
    num_blocks: int = 4,
) -> Tuple[List[BlockInit], List[BlockApply], List[BlockCost], int]:
    """The paper's 5-layer CNN cut into ``num_blocks`` task-graph blocks.

    Returns (block_inits, block_applies, per-block costs, feature_dim).
    Block layout for the default 4 blocks (3 branch points, §5.3/§7):
      B0: conv1+pool, B1: conv2+pool+flatten, B2: dense1, B3: dense2.
    """
    h, w, cin = input_hw
    c1, c2 = channels
    d1, d2 = dense_dims
    h2, w2 = h // 2, w // 2
    h4, w4 = h2 // 2, w2 // 2
    flat = h4 * w4 * c2

    inits: List[BlockInit] = [
        lambda k: _conv_init(k, cin, c1),
        lambda k: _conv_init(k, c1, c2),
        lambda k: _dense_init(k, flat, d1),
        lambda k: _dense_init(k, d1, d2),
    ]

    def apply0(p, x):
        return maxpool2(leaky_relu(conv2d(p, x)))

    def apply1(p, x):
        y = maxpool2(leaky_relu(conv2d(p, x)))
        return y.reshape(y.shape[0], -1)

    def apply2(p, x):
        return leaky_relu(dense(p, x))

    def apply3(p, x):
        return leaky_relu(dense(p, x))

    applies: List[BlockApply] = [apply0, apply1, apply2, apply3]

    # Per-sample costs: weights in bytes (fp32), FLOPs = 2 * MACs.
    costs = [
        BlockCost(
            weight_bytes=4.0 * (9 * cin * c1 + c1),
            flops=2.0 * 9 * cin * c1 * h * w,
            act_bytes=4.0 * h2 * w2 * c1,
        ),
        BlockCost(
            weight_bytes=4.0 * (9 * c1 * c2 + c2),
            flops=2.0 * 9 * c1 * c2 * h2 * w2,
            act_bytes=4.0 * flat,
        ),
        BlockCost(
            weight_bytes=4.0 * (flat * d1 + d1),
            flops=2.0 * flat * d1,
            act_bytes=4.0 * d1,
        ),
        BlockCost(
            weight_bytes=4.0 * (d1 * d2 + d2),
            flops=2.0 * d1 * d2,
            act_bytes=4.0 * d2,
        ),
    ]
    assert num_blocks == 4, "the paper-scale CNN is fixed at 4 blocks (3 BPs)"
    return inits, applies, costs, d2


def head_init(key, feat_dim: int, num_classes: int) -> Params:
    return _dense_init(key, feat_dim, num_classes)


def head_apply(params: Params, x: jax.Array) -> jax.Array:
    return dense(params, x)
