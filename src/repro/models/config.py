"""Model configuration shared by every architecture family.

One dataclass covers all 10 assigned architectures (dense / MoE / SSM /
hybrid / encoder-decoder / VLM); family-specific fields are simply unused by
other families.  Every config in :mod:`repro.configs` cites its source
paper/model card.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


def pad_vocab(v: int, multiple: int = 256) -> int:
    """Pad vocab to a multiple so it shards evenly over the model axis."""
    return ((v + multiple - 1) // multiple) * multiple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters.

    Attributes:
      name: architecture id (e.g. ``granite-34b``).
      family: ``dense | moe | ssm | hybrid | encdec | vlm``.
      num_layers: decoder layers (for encdec: decoder layers).
      d_model / n_heads / n_kv_heads / d_ff / vocab_size: usual dims.
        ``vocab_size`` is already padded; ``raw_vocab_size`` records the
        source value.
      head_dim: defaults to d_model // n_heads.
      activation: ``swiglu | gelu | squared_relu`` (nemotron uses
        squared-ReLU per arXiv:2402.16819).
      sliding_window: window size for SWA layers; None = full attention.
      moe_*: MoE routing parameters (qwen2-moe: 4 shared + 60 routed top-4;
        mixtral: 8 routed top-2).  ``moe_d_ff`` is the per-expert hidden dim.
      ssm_*: Mamba2/SSD parameters (state size, head dim, chunk length).
      hybrid_attn_period: a shared attention block is applied every this
        many Mamba2 blocks (Zamba2-style globally-shared block).
      enc_layers / enc_inputs: encoder depth and frontend embedding width
        for enc-dec (whisper) — the conv/mel frontend is a stub that
        delivers ``(B, T, enc_inputs)`` frame features.
      dtype: activation/computation dtype; params kept in ``param_dtype``.
    """

    name: str
    family: str
    num_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    raw_vocab_size: int
    head_dim: int
    activation: str = "swiglu"
    sliding_window: Optional[int] = None
    # Sliding window applied ONLY for the long_500k shape (the beyond-paper
    # SWA variant that makes a full-attention arch long-context capable).
    long_context_window: Optional[int] = None
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- MoE ---
    moe_num_experts: int = 0
    # Experts beyond this index are PADDING (zero weights, never routed):
    # lets 60 real experts pad to 64 so the expert axis shards over a
    # 16-way mesh axis (expert parallelism, §Perf B5).  0 = no padding.
    moe_real_experts: int = 0
    moe_top_k: int = 0
    moe_num_shared_experts: int = 0
    moe_d_ff: int = 0
    moe_capacity_factor: float = 1.25
    moe_aux_loss_weight: float = 0.01

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_expand: int = 2
    ssm_conv_width: int = 4

    # --- hybrid (Zamba2) ---
    hybrid_attn_period: int = 6
    # Per-invocation LoRA rank on the shared attention block's projections
    # (Zamba2 uses a single shared block + cheap per-invocation LoRA deltas;
    # 0 disables).
    hybrid_lora_rank: int = 16

    # --- encoder-decoder (whisper) ---
    enc_layers: int = 0
    enc_inputs: int = 80  # mel bins delivered by the (stubbed) frontend

    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    remat: bool = True
    attn_chunk: int = 1024  # KV-block size for the online-softmax attention
    loss_chunk: int = 512   # sequence chunk for the vocab-sharded CE loss

    citation: str = ""

    # ------------------------------------------------------------- derived
    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_n_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic per-token decode: SSM, hybrid, or sliding-window."""
        return (
            self.family in ("ssm", "hybrid")
            or self.sliding_window is not None
            or self.long_context_window is not None
        )

    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    def params_dtype(self):
        return jnp.dtype(self.param_dtype)

    def validate(self) -> None:
        assert self.family in ("dense", "moe", "ssm", "hybrid", "encdec", "vlm")
        if self.family not in ("ssm",):
            assert self.n_heads % max(self.n_kv_heads, 1) == 0
        if self.family == "moe":
            assert self.moe_num_experts > 0 and self.moe_top_k > 0
        if self.family in ("ssm", "hybrid"):
            assert self.ssm_state > 0
            assert self.ssm_d_inner % self.ssm_head_dim == 0
        if self.family == "encdec":
            assert self.enc_layers > 0
        assert self.vocab_size % 256 == 0, "vocab must be padded (pad_vocab)"


def make_config(**kw) -> ModelConfig:
    """Helper that applies vocab padding + default head_dim, then validates."""
    raw_vocab = kw.pop("vocab_size")
    kw.setdefault("raw_vocab_size", raw_vocab)
    kw["vocab_size"] = pad_vocab(raw_vocab)
    if "head_dim" not in kw or kw["head_dim"] is None:
        kw["head_dim"] = kw["d_model"] // max(kw.get("n_heads", 1), 1)
    cfg = ModelConfig(vocab_size=kw.pop("vocab_size"), **kw)
    cfg.validate()
    return cfg


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One of the four assigned workload shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

INPUT_SHAPES: Tuple[InputShape, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def get_shape(name: str) -> InputShape:
    for s in INPUT_SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown input shape {name!r}; have {[s.name for s in INPUT_SHAPES]}")
