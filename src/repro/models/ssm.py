"""Mamba2 (state-space duality) blocks — the `ssm` family (arXiv:2405.21060).

The SSD layer computes, per head h with scalar decay ``A_h < 0``:

    s_t = exp(dt_t A) s_{t-1} + dt_t x_t ⊗ B_t          (state: P x N)
    y_t = C_t · s_t + D x_t

Training/prefill uses the **chunked SSD form**: within a chunk of length Q
the recurrence is a masked-decay attention-like matmul (MXU-friendly);
across chunks a short ``lax.scan`` carries the (H, P, N) state.  This is the
pure-jnp oracle of ``repro.kernels.ssd_scan``.  Decode is the one-step
recurrence against an :class:`~repro.models.cache.SSMCache`.

Layout notes for TPU: heads shard over the model axis; B/C (state dim N) are
small and replicated; the sequential inter-chunk scan has length S/Q so its
serialisation cost is negligible next to the intra-chunk matmuls.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models.cache import SSMCache
from repro.models.config import ModelConfig
from repro.models.layers import dense_init
from repro.sharding.policy import ShardingPolicy, shard_act

Params = Dict[str, Any]


# --------------------------------------------------------------------------
# SSD core (chunked) + sequential reference
# --------------------------------------------------------------------------

def ssd_chunked(
    x: jax.Array,      # (B, S, H, P)
    dt: jax.Array,     # (B, S, H)  positive step sizes
    a: jax.Array,      # (H,)       negative decay rates
    b_in: jax.Array,   # (B, S, N)
    c_in: jax.Array,   # (B, S, N)
    chunk: int,
    init_state: Optional[jax.Array] = None,  # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD: returns (y (B,S,H,P), final_state (B,H,P,N))."""
    bsz, s, h, p = x.shape
    n = b_in.shape[-1]
    if s % chunk != 0:
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0)))
        s_pad = x.shape[1]
    else:
        s_pad = s
    nc, q = s_pad // chunk, chunk

    xf = x.reshape(bsz, nc, q, h, p).astype(jnp.float32)
    dtf = dt.reshape(bsz, nc, q, h).astype(jnp.float32)
    bf = b_in.reshape(bsz, nc, q, n).astype(jnp.float32)
    cf = c_in.reshape(bsz, nc, q, n).astype(jnp.float32)

    da = dtf * a  # (B,nc,q,H), negative
    da_cum = jnp.cumsum(da, axis=2)

    # Intra-chunk: y_i += sum_{j<=i} (C_i.B_j) exp(cum_i - cum_j) dt_j x_j
    cb = jnp.einsum("bcqn,bckn->bcqk", cf, bf)                      # (B,nc,q,q)
    decay = jnp.exp(da_cum[:, :, :, None, :] - da_cum[:, :, None, :, :])
    causal = jnp.tril(jnp.ones((q, q), dtype=bool))
    lmat = jnp.where(causal[None, None, :, :, None], decay, 0.0)    # (B,nc,q,k,H)
    y_intra = jnp.einsum("bcqk,bcqkh,bckh,bckhp->bcqhp", cb, lmat, dtf, xf)

    # Chunk-final states: S_c = sum_j B_j ⊗ dt_j x_j exp(cum_Q - cum_j)
    to_end = jnp.exp(da_cum[:, :, -1:, :] - da_cum)                 # (B,nc,q,H)
    s_chunk = jnp.einsum("bckn,bckh,bckh,bckhp->bchpn", bf, to_end, dtf, xf)

    # Inter-chunk recurrence over nc chunks.
    chunk_decay = jnp.exp(da_cum[:, :, -1, :])                      # (B,nc,H)
    h0 = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((bsz, h, p, n), jnp.float32)
    )

    def step(hprev, xs):
        s_c, dec = xs  # (B,H,P,N), (B,H)
        hnew = hprev * dec[:, :, None, None] + s_c
        return hnew, hprev

    final, h_prevs = jax.lax.scan(
        step,
        h0,
        (s_chunk.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)                      # (B,nc,H,P,N)

    # Inter-chunk contribution: y_i += C_i · (h_prev) * exp(cum_i)
    state_decay = jnp.exp(da_cum)                                   # (B,nc,q,H)
    y_inter = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", cf, h_prevs, state_decay)

    y = (y_intra + y_inter).reshape(bsz, s_pad, h, p)[:, :s]
    return y.astype(x.dtype), final


def ssd_sequential_ref(x, dt, a, b_in, c_in, init_state=None):
    """Naive per-step recurrence (oracle for tests)."""
    bsz, s, h, p = x.shape
    n = b_in.shape[-1]
    st = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((bsz, h, p, n), jnp.float32)
    )
    ys = []
    for t in range(s):
        dec = jnp.exp(dt[:, t].astype(jnp.float32) * a)             # (B,H)
        upd = jnp.einsum(
            "bh,bhp,bn->bhpn", dt[:, t].astype(jnp.float32),
            x[:, t].astype(jnp.float32), b_in[:, t].astype(jnp.float32)
        )
        st = st * dec[:, :, None, None] + upd
        ys.append(jnp.einsum("bhpn,bn->bhp", st, c_in[:, t].astype(jnp.float32)))
    return jnp.stack(ys, axis=1).astype(x.dtype), st


def ssd_decode_step(state, x, dt, a, b_in, c_in):
    """One-token recurrence.  state (B,H,P,N); x (B,H,P); dt (B,H); b/c (B,N)."""
    dec = jnp.exp(dt.astype(jnp.float32) * a)
    upd = jnp.einsum(
        "bh,bhp,bn->bhpn",
        dt.astype(jnp.float32), x.astype(jnp.float32), b_in.astype(jnp.float32),
    )
    state = state * dec[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", state, c_in.astype(jnp.float32))
    return y.astype(x.dtype), state


# --------------------------------------------------------------------------
# Causal depthwise conv (width ssm_conv_width) on (x, B, C)
# --------------------------------------------------------------------------

def causal_conv(u: jax.Array, kernel: jax.Array) -> jax.Array:
    """u: (B, S, C); kernel: (W, C).  y[t] = sum_w k[w] u[t - W + 1 + w]."""
    w = kernel.shape[0]
    pad = jnp.pad(u, ((0, 0), (w - 1, 0), (0, 0)))
    s = u.shape[1]
    out = jnp.zeros_like(u, dtype=jnp.float32)
    for i in range(w):
        out = out + kernel[i].astype(jnp.float32) * pad[:, i : i + s].astype(jnp.float32)
    return out.astype(u.dtype)


def causal_conv_step(cache: jax.Array, u_t: jax.Array, kernel: jax.Array):
    """cache: (B, W-1, C) last inputs; u_t: (B, C).  Returns (y_t, new cache)."""
    window = jnp.concatenate([cache, u_t[:, None, :]], axis=1)  # (B, W, C)
    y = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32), kernel.astype(jnp.float32))
    return y.astype(u_t.dtype), window[:, 1:]


# --------------------------------------------------------------------------
# Mamba2 block
# --------------------------------------------------------------------------

def _in_proj(params: Params, u: jax.Array):
    """Input projections with wz/wx fused (§Perf A2).

    The two d_inner-sized projections are stacked on an UNSHARDED axis so
    one matmul produces both: in backward, GSPMD emits ONE (B, S, D)
    dx all-reduce for the pair instead of two.  The small B/C/dt heads stay
    separate (wb/wc are replicated — their backward has no collective).
    """
    if "w_zx" in params:
        zx = jnp.einsum("bsd,dkm->bskm", u, params["w_zx"])
        z, xin = zx[:, :, 0], zx[:, :, 1]
    else:  # legacy unfused checkpoints
        z, xin = u @ params["wz"], u @ params["wx"]
    b_in = u @ params["wb"]
    c_in = u @ params["wc"]
    dt_raw = u @ params["wdt"]
    return z, xin, b_in, c_in, dt_raw


def init_mamba_block(key, cfg: ModelConfig) -> Params:
    dtype = cfg.params_dtype()
    d, di, n, h = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_n_heads
    kz, kx, kb, kc, kdt, kconv, ko, ka = jax.random.split(key, 8)
    conv_dim = di + 2 * n
    return {
        "norm": L.init_rmsnorm(d, dtype),
        "w_zx": jnp.stack(
            [dense_init(kz, d, (di,), dtype), dense_init(kx, d, (di,), dtype)],
            axis=1,
        ),  # (D, 2, di): z and x projections fused (Perf A2)
        "wb": dense_init(kb, d, (n,), dtype),
        "wc": dense_init(kc, d, (n,), dtype),
        "wdt": dense_init(kdt, d, (h,), dtype),
        "dt_bias": jnp.zeros((h,), jnp.float32)
        + jnp.log(jnp.expm1(jnp.exp(jax.random.uniform(
            kdt, (h,), minval=math.log(1e-3), maxval=math.log(1e-1))))),
        "a_log": jnp.log(
            jax.random.uniform(ka, (h,), minval=1.0, maxval=16.0)
        ).astype(jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "conv": (jax.random.normal(kconv, (cfg.ssm_conv_width, conv_dim)) * 0.2).astype(dtype),
        "gated_norm": L.init_rmsnorm(di, dtype),
        "wo": dense_init(ko, di, (d,), dtype),
    }


def spec_mamba_block(cfg: ModelConfig, policy: ShardingPolicy) -> Params:
    m, f = policy.physical("model"), policy.physical("fsdp")
    return {
        "norm": L.spec_rmsnorm(),
        "w_zx": P(f, None, m),
        "wb": P(f, None),
        "wc": P(f, None),
        "wdt": P(f, m),
        "dt_bias": P(None),
        "a_log": P(None),
        "d_skip": P(None),
        "conv": P(None, None),
        "gated_norm": L.spec_rmsnorm(),
        "wo": P(m, f),
    }


def mamba_block(
    params: Params,
    x: jax.Array,             # (B, S, D)
    cfg: ModelConfig,
    policy: ShardingPolicy,
    cache: Optional[Tuple[jax.Array, jax.Array]] = None,  # (conv, state)
) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    """Apply one Mamba2 block (pre-norm, residual outside).

    Training/prefill: ``cache=None`` -> chunked SSD over the sequence.
    Decode: ``cache=(conv_cache, ssd_state)`` and S == 1.
    """
    bsz, s, d = x.shape
    di, n, h = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_n_heads
    p = cfg.ssm_head_dim

    u = L.rmsnorm(params["norm"], x, cfg.norm_eps)
    z, xin, b_in, c_in, dt_raw = _in_proj(params, u)

    conv_in = jnp.concatenate([xin, b_in, c_in], axis=-1)  # (B,S,di+2N)
    new_cache = None
    if cache is None:
        conv_out = causal_conv(conv_in, params["conv"])
        conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(conv_in.dtype)
        xin, b_in, c_in = jnp.split(conv_out, [di, di + n], axis=-1)
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
        a = -jnp.exp(params["a_log"])
        xh = xin.reshape(bsz, s, h, p)
        xh = shard_act(xh, policy, "batch", None, "model", None)
        y, _final = ssd_chunked(xh, dt, a, b_in, c_in, cfg.ssm_chunk)
    else:
        conv_cache, ssd_state = cache
        conv_t, conv_cache = causal_conv_step(
            conv_cache, conv_in[:, 0], params["conv"]
        )
        conv_t = jax.nn.silu(conv_t.astype(jnp.float32)).astype(conv_in.dtype)
        xin1, b1, c1 = jnp.split(conv_t, [di, di + n], axis=-1)
        dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"])
        a = -jnp.exp(params["a_log"])
        xh = xin1.reshape(bsz, h, p)
        y1, ssd_state = ssd_decode_step(ssd_state, xh, dt, a, b1, c1)
        y = y1[:, None]
        xh = xh[:, None]
        new_cache = (conv_cache, ssd_state)

    y = y + params["d_skip"][None, None, :, None].astype(y.dtype) * xh
    y = y.reshape(bsz, s, di)
    gated = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    gated = L.rmsnorm(params["gated_norm"], gated, cfg.norm_eps)
    out = gated @ params["wo"]
    return shard_act(out, policy, "batch", None, None), new_cache


# --------------------------------------------------------------------------
# Full SSM model (mamba2-780m)
# --------------------------------------------------------------------------

def init(key, cfg: ModelConfig) -> Params:
    ke, kl = jax.random.split(key)
    layer_keys = jax.random.split(kl, cfg.num_layers)
    layers = jax.vmap(lambda k: init_mamba_block(k, cfg))(layer_keys)
    return {
        "embed": L.init_embed(ke, cfg),
        "layers": layers,
        "final_norm": L.init_rmsnorm(cfg.d_model, cfg.params_dtype()),
    }


def param_specs(cfg: ModelConfig, policy: ShardingPolicy) -> Params:
    layer = spec_mamba_block(cfg, policy)
    stacked = jax.tree.map(
        lambda sp: P(None, *tuple(sp)), layer, is_leaf=lambda v: isinstance(v, P)
    )
    return {
        "embed": L.spec_embed(cfg, policy),
        "layers": stacked,
        "final_norm": L.spec_rmsnorm(),
    }


def forward(
    params: Params,
    tokens: jax.Array,
    cfg: ModelConfig,
    policy: ShardingPolicy,
    use_chunked: bool = True,  # accepted for interface parity
) -> Tuple[jax.Array, jax.Array]:
    x = L.embed_tokens(params["embed"], tokens, cfg, policy)

    def body(x, lp):
        y, _ = mamba_block(lp, x, cfg, policy)
        return x + y, None

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    x, _ = jax.lax.scan(body, x, params["layers"])
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], x, cfg, policy)
    return logits, jnp.zeros((), jnp.float32)


def prefill(
    params: Params, tokens: jax.Array, cfg: ModelConfig, policy: ShardingPolicy
) -> Tuple[jax.Array, SSMCache]:
    """Prompt pass returning final logits + SSM state caches per layer."""
    bsz, s = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, cfg, policy)
    w = cfg.ssm_conv_width

    def body(x, lp):
        # Re-derive the block's conv tail + final SSD state for the cache.
        u = L.rmsnorm(lp["norm"], x, cfg.norm_eps)
        z, xin0, b0, c0, dt_raw0 = _in_proj(lp, u)
        conv_in = jnp.concatenate([xin0, b0, c0], axis=-1)
        tail = conv_in[:, -(w - 1):, :]
        conv_out = jax.nn.silu(
            causal_conv(conv_in, lp["conv"]).astype(jnp.float32)
        ).astype(conv_in.dtype)
        di, n = cfg.ssm_d_inner, cfg.ssm_state
        xin, b_in, c_in = jnp.split(conv_out, [di, di + n], axis=-1)
        dt = jax.nn.softplus(dt_raw0.astype(jnp.float32) + lp["dt_bias"])
        a = -jnp.exp(lp["a_log"])
        xh = xin.reshape(bsz, s, cfg.ssm_n_heads, cfg.ssm_head_dim)
        y, final_state = ssd_chunked(xh, dt, a, b_in, c_in, cfg.ssm_chunk)
        y = y + lp["d_skip"][None, None, :, None].astype(y.dtype) * xh
        y = y.reshape(bsz, s, di)
        gated = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
        gated = L.rmsnorm(lp["gated_norm"], gated, cfg.norm_eps)
        out = x + gated @ lp["wo"]
        return out, (tail, final_state)

    x, (tails, states) = jax.lax.scan(body, x, params["layers"])
    x = L.rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    logits = L.unembed(params["embed"], x, cfg, policy)
    return logits[:, 0], SSMCache(conv=tails, state=states)


def decode_step(
    params: Params,
    token: jax.Array,
    cache: SSMCache,
    cache_len: jax.Array,  # unused (state is summary); kept for interface parity
    cfg: ModelConfig,
    policy: ShardingPolicy,
) -> Tuple[jax.Array, SSMCache]:
    x = L.embed_tokens(params["embed"], token[:, None], cfg, policy)

    def body(x, xs):
        lp, conv_c, state_c = xs
        y, new_cache = mamba_block(lp, x, cfg, policy, cache=(conv_c, state_c))
        return x + y, new_cache

    x, (convs, states) = jax.lax.scan(body, x, (params["layers"], cache.conv, cache.state))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], x, cfg, policy)
    return logits[:, 0], SSMCache(conv=convs, state=states)
