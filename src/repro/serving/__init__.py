"""Serving: the Antler multitask engine + batched LM prefill/decode."""
from repro.serving.engine import (
    LMServer, MultitaskEngine, MultitaskRequest, MultitaskResponse,
)
