"""Serving: the Antler multitask engine, session-based admission, and the
batched LM prefill/decode path.

The task-graph surface is session-first: open a ``ServingSession`` on a
``MultitaskEngine`` (``engine.session()``), ``submit()`` requests over time
under a pluggable ``SchedulingPolicy``, and resolve ``MultitaskFuture``s.
``serve`` / ``serve_batch`` remain as one-shot wrappers over the same
machinery; ``serve_many`` is deprecated.

Reliability lives in ``repro.serving.reliability``: typed per-request
errors (``RequestError`` / ``DeadlineExceeded`` / ``QueueFull``), the
group-recovery ``RetryPolicy`` (rollback + bounded backoff + degradation
ladder), per-tenant ``TenantStats``, and the deterministic
``FaultInjector`` the chaos benchmark drives.

Intermittent-power serving lives in ``repro.serving.journal`` (the durable
write-ahead ``Journal`` + ``ServingSession.recover``) and
``repro.serving.reliability`` (``PowerFailure`` / ``PowerFailureInjector``
for whole-session power loss, ``EnergyBudget`` for duty-cycled
energy-harvesting execution).

Input-adaptive serving lives in ``repro.adaptive`` (``AdaptivePolicy`` /
``BlockGater`` / ``GateModel``; re-exported here for convenience): set
``EnginePolicy.adaptive`` and the engine gates per-row block execution on
confidence inside the fused suffixes, predicts and plans with *expected*
counters, and walks the policy's deadline ladder per group.
"""
from repro.adaptive import AdaptivePolicy, BlockGater, GateModel
from repro.serving.batching import (
    ContinuousBatcher, GenRequest, GenResult, RequestGroup,
    RequestGroupScheduler, effective_order, normalize_subset, order_groups,
)
from repro.serving.engine import (
    GroupExecution, IntermittentContext, LMServer, MultitaskEngine,
    MultitaskRequest, MultitaskResponse,
)
from repro.serving.journal import (
    FileJournalStore, Journal, JournalState, JournalStore, MemoryJournalStore,
)
from repro.serving.policies import (
    AffinityPolicy, EnginePolicy, GreedyBatchPolicy, SchedulingPolicy,
    SloAwarePolicy, WindowPolicy,
)
from repro.serving.reliability import (
    FAULT_SITES, POWER_SITES, DeadlineExceeded, EnergyBudget, FaultInjector,
    InjectedFault, PowerFailure, PowerFailureInjector, QueueFull,
    RequestError, RetryPolicy, TenantStats,
)
from repro.serving.session import (
    AdmissionQueue, MultitaskFuture, PendingRequest, ServingSession,
)

__all__ = [
    # engine + request/response surface
    "MultitaskEngine",
    "MultitaskRequest",
    "MultitaskResponse",
    "GroupExecution",
    # sessions
    "ServingSession",
    "MultitaskFuture",
    "AdmissionQueue",
    "PendingRequest",
    # input-adaptive serving (re-exported from repro.adaptive)
    "AdaptivePolicy",
    "BlockGater",
    "GateModel",
    # policies
    "EnginePolicy",
    "SchedulingPolicy",
    "GreedyBatchPolicy",
    "WindowPolicy",
    "AffinityPolicy",
    "SloAwarePolicy",
    # reliability
    "RequestError",
    "DeadlineExceeded",
    "QueueFull",
    "InjectedFault",
    "RetryPolicy",
    "FaultInjector",
    "TenantStats",
    "FAULT_SITES",
    # intermittent power
    "Journal",
    "JournalState",
    "JournalStore",
    "MemoryJournalStore",
    "FileJournalStore",
    "IntermittentContext",
    "PowerFailure",
    "PowerFailureInjector",
    "POWER_SITES",
    "EnergyBudget",
    # request grouping
    "RequestGroup",
    "RequestGroupScheduler",
    "effective_order",
    "normalize_subset",
    "order_groups",
    # LM serving path
    "LMServer",
    "ContinuousBatcher",
    "GenRequest",
    "GenResult",
]
