"""Serving: the Antler multitask engine + batched LM prefill/decode."""
from repro.serving.batching import (
    ContinuousBatcher, GenRequest, GenResult, RequestGroup,
    RequestGroupScheduler, effective_order, order_groups,
)
from repro.serving.engine import (
    LMServer, MultitaskEngine, MultitaskRequest, MultitaskResponse,
)
