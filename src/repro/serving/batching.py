"""Continuous batching for the LM server.

A minimal production-shaped scheduler: requests arrive with different prompt
lengths and generation budgets; slots in a fixed-size batch are recycled the
moment a sequence finishes, new prompts are prefilled into free slots (with
right-aligned padding so cache positions line up), and every engine step
decodes all active slots together.

This is the decode-shape economics the dry-run's ``serve_step`` lowers:
batch = concurrent slots, cache_len grows per step.  For simplicity the
scheduler keeps a single shared ``cache_len`` high-water mark per batch
(slot-level masks handle shorter sequences) — the standard static-shape
compromise without ragged support.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import ModelApi
from repro.sharding.policy import ShardingPolicy, TP_POLICY


@dataclasses.dataclass
class GenRequest:
    uid: int
    prompt: np.ndarray          # (S0,) int32
    max_new_tokens: int


@dataclasses.dataclass
class GenResult:
    uid: int
    tokens: np.ndarray          # generated ids
    steps: int


class ContinuousBatcher:
    """Fixed-slot continuous batching over a ModelApi.

    The engine re-prefills the WHOLE batch whenever slot membership changes
    (simple and correct; a production engine would insert into the live
    cache).  Between membership changes, decode steps are batched.
    """

    def __init__(
        self,
        model: ModelApi,
        params: Any,
        slots: int = 4,
        max_len: int = 256,
        policy: ShardingPolicy = TP_POLICY,
        eos_token: Optional[int] = None,
    ):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.policy = policy
        self.eos = eos_token
        self.queue: Deque[GenRequest] = deque()
        self.results: List[GenResult] = []
        self._prefill = jax.jit(lambda p, b: model.prefill(p, b, policy))
        self._step = jax.jit(
            lambda p, t, c, n: model.decode_step(p, t, c, n, policy)
        )

    def submit(self, req: GenRequest) -> None:
        if len(req.prompt) + req.max_new_tokens > self.max_len:
            raise ValueError("request exceeds max_len")
        self.queue.append(req)

    # ------------------------------------------------------------------ run
    def run(self) -> List[GenResult]:
        """Serve until the queue drains.  Returns completed results."""
        while self.queue:
            active = [
                self.queue.popleft()
                for _ in range(min(self.slots, len(self.queue)))
            ]
            self._serve_wave(active)
        return self.results

    def _serve_wave(self, active: List[GenRequest]) -> None:
        """Prefill a wave of requests together, decode until all finish."""
        b = len(active)
        s0 = max(len(r.prompt) for r in active)
        # Right-align prompts so the last prompt token sits at position s0-1
        # for every slot; left padding repeats the first token (masked by
        # causality for generation purposes at this scale).
        toks = np.stack([
            np.pad(r.prompt, (s0 - len(r.prompt), 0), mode="edge")
            for r in active
        ]).astype(np.int32)
        logits, cache = self._prefill(self.params, jnp.asarray(toks))
        from repro.serving.engine import _grow_cache

        total = s0 + max(r.max_new_tokens for r in active)
        cache = _grow_cache(self.model, cache, total, s0)

        out: Dict[int, List[int]] = {r.uid: [] for r in active}
        done = [False] * b
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        cache_len = jnp.asarray(s0, jnp.int32)
        for step in range(max(r.max_new_tokens for r in active)):
            ids = np.asarray(jax.device_get(tok))
            for i, r in enumerate(active):
                if done[i]:
                    continue
                out[r.uid].append(int(ids[i]))
                if (
                    len(out[r.uid]) >= r.max_new_tokens
                    or (self.eos is not None and ids[i] == self.eos)
                ):
                    done[i] = True
            if all(done):
                break
            logits, cache = self._step(self.params, tok, cache, cache_len)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            cache_len = cache_len + 1
        for r in active:
            self.results.append(
                GenResult(uid=r.uid, tokens=np.array(out[r.uid]), steps=len(out[r.uid]))
            )
