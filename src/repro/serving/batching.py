"""Batching schedulers for both serving paths.

* :class:`RequestGroupScheduler` — groups :class:`MultitaskRequest`s for the
  *task-graph* engine: requests are bucketed by requested task subset (and
  input shape/dtype) so every group runs one homogeneous schedule through
  ``TaskGraphExecutor.run_batch``, and each group is padded up to a small
  fixed set of batch shapes so jit recompilation stays bounded at
  ``len(batch_shapes)`` batch dims per sample shape.  Per-request gate
  outcomes are resolved by the engine while a group executes (a task's
  output depends only on the input row, so running a gated-off row and
  dropping its output is exact) — the dynamic analogue of bucketing by gate
  outcome without re-stacking mid-flight.

* :class:`ContinuousBatcher` — continuous batching for the LM server: a
  minimal production-shaped scheduler where slots in a fixed-size batch are
  recycled the moment a sequence finishes, new prompts are prefilled into
  free slots (with right-aligned padding so cache positions line up), and
  every engine step decodes all active slots together.  This is the
  decode-shape economics the dry-run's ``serve_step`` lowers: batch =
  concurrent slots, cache_len grows per step.  For simplicity the scheduler
  keeps a single shared ``cache_len`` high-water mark per batch (slot-level
  masks handle shorter sequences) — the standard static-shape compromise
  without ragged support.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import (
    TYPE_CHECKING, Any, Deque, Dict, FrozenSet, List, Optional, Sequence,
    Tuple,
)

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import ModelApi
from repro.sharding.policy import ShardingPolicy, TP_POLICY

if TYPE_CHECKING:  # avoid a module cycle with repro.serving.engine
    from repro.serving.engine import MultitaskRequest


# --------------------------------------------------------------------------
# Task-graph request grouping
# --------------------------------------------------------------------------

DEFAULT_BATCH_SHAPES = (1, 4, 16, 64)


@dataclasses.dataclass
class RequestGroup:
    """One homogeneous, padded execution group for ``run_batch``.

    Attributes:
      indices: positions of the member requests in the submitted sequence.
      requests: the member requests themselves (no padding entries).
      tasks: the shared requested task subset (``None`` = all tasks).
      xs: ``(P, *sample_shape)`` stacked inputs where ``P`` is one of the
        scheduler's padded batch shapes; rows ``valid:`` repeat the last real
        row and are dropped from outputs and logical accounting.
      valid: number of real leading rows (``len(requests)``).
    """

    indices: Tuple[int, ...]
    requests: Tuple["MultitaskRequest", ...]
    tasks: Optional[FrozenSet[int]]
    xs: jnp.ndarray
    valid: int

    @property
    def padding(self) -> int:
        return int(self.xs.shape[0]) - self.valid


class RequestGroupScheduler:
    """Bucket + chunk + pad pending multitask requests into groups.

    Invariants (property-tested):
      * every submitted request lands in exactly one group;
      * groups are homogeneous: all members share the same task subset and
        the same input shape/dtype;
      * every group's padded width is one of ``batch_shapes`` (requests
        beyond the largest shape are chunked into multiple groups);
      * padding never changes results — padded rows are replicas of the last
        real row, executed vmapped and then sliced away.

    Arrival order is preserved within a bucket so latency-sensitive callers
    get deterministic group membership.
    """

    def __init__(self, batch_shapes: Sequence[int] = DEFAULT_BATCH_SHAPES):
        shapes = tuple(sorted({int(s) for s in batch_shapes}))
        if not shapes or shapes[0] < 1:
            raise ValueError(f"invalid batch shapes: {batch_shapes!r}")
        self.batch_shapes = shapes

    def padded_size(self, n: int) -> int:
        """Smallest allowed batch shape >= ``n`` (callers chunk to the max)."""
        if n > self.batch_shapes[-1]:
            raise ValueError(
                f"group of {n} exceeds the largest batch shape "
                f"{self.batch_shapes[-1]}; chunk before padding"
            )
        for s in self.batch_shapes:
            if s >= n:
                return s
        raise AssertionError("unreachable")

    def chunk_sizes(self, n: int) -> List[Tuple[int, int]]:
        """Split a bucket of ``n`` requests into ``(take, padded_to)`` chunks.

        Greedy: peel off the largest allowed shape while it fits, and pad
        the remainder up to the next shape only when the padding does not
        exceed the remainder itself (<= 50% waste — one padded group beats
        splitting into more groups that each re-pay the weight loads).  A
        remainder below the smallest allowed shape must pad up.  E.g. with
        shapes (1, 4, 16, 64): 17 -> 16 + 1, 5 -> 4 + 1, 3 -> one chunk
        padded to 4.
        """
        out: List[Tuple[int, int]] = []
        while n > 0:
            up = next((s for s in self.batch_shapes if s >= n), None)
            down = max((s for s in self.batch_shapes if s <= n), default=None)
            if up is not None and (down is None or up - n <= n):
                out.append((n, up))
                break
            out.append((down, down))
            n -= down
        return out

    def plan(
        self,
        requests: Sequence["MultitaskRequest"],
        num_tasks: Optional[int] = None,
    ) -> List[RequestGroup]:
        """Partition ``requests`` into padded homogeneous groups.

        With ``num_tasks`` given, an explicit all-tasks subset is normalised
        to ``None`` so it shares a group (and its weight loads) with
        ``tasks=None`` requests.
        """
        all_tasks = None if num_tasks is None else frozenset(range(num_tasks))
        buckets: Dict[Tuple, List[Tuple[int, Any, jnp.ndarray]]] = {}
        for i, req in enumerate(requests):
            x = jnp.asarray(req.x)
            subset = (
                None if req.tasks is None
                else frozenset(int(t) for t in req.tasks)
            )
            if subset is not None and subset == all_tasks:
                subset = None
            key = (subset, tuple(x.shape), str(x.dtype))
            buckets.setdefault(key, []).append((i, req, x))

        groups: List[RequestGroup] = []
        for (subset, _shape, _dtype), members in buckets.items():
            start = 0
            for take, p in self.chunk_sizes(len(members)):
                chunk = members[start:start + take]
                start += take
                rows = [x for (_i, _r, x) in chunk]
                rows.extend([rows[-1]] * (p - take))
                groups.append(RequestGroup(
                    indices=tuple(i for (i, _r, _x) in chunk),
                    requests=tuple(r for (_i, r, _x) in chunk),
                    tasks=subset,
                    xs=jnp.stack(rows),
                    valid=take,
                ))
        return groups


@dataclasses.dataclass
class GenRequest:
    uid: int
    prompt: np.ndarray          # (S0,) int32
    max_new_tokens: int


@dataclasses.dataclass
class GenResult:
    uid: int
    tokens: np.ndarray          # generated ids
    steps: int


class ContinuousBatcher:
    """Fixed-slot continuous batching over a ModelApi.

    The engine re-prefills the WHOLE batch whenever slot membership changes
    (simple and correct; a production engine would insert into the live
    cache).  Between membership changes, decode steps are batched.
    """

    def __init__(
        self,
        model: ModelApi,
        params: Any,
        slots: int = 4,
        max_len: int = 256,
        policy: ShardingPolicy = TP_POLICY,
        eos_token: Optional[int] = None,
    ):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.policy = policy
        self.eos = eos_token
        self.queue: Deque[GenRequest] = deque()
        self.results: List[GenResult] = []
        self._prefill = jax.jit(lambda p, b: model.prefill(p, b, policy))
        self._step = jax.jit(
            lambda p, t, c, n: model.decode_step(p, t, c, n, policy)
        )

    def submit(self, req: GenRequest) -> None:
        if len(req.prompt) + req.max_new_tokens > self.max_len:
            raise ValueError("request exceeds max_len")
        self.queue.append(req)

    # ------------------------------------------------------------------ run
    def run(self) -> List[GenResult]:
        """Serve until the queue drains.  Returns completed results."""
        while self.queue:
            active = [
                self.queue.popleft()
                for _ in range(min(self.slots, len(self.queue)))
            ]
            self._serve_wave(active)
        return self.results

    def _serve_wave(self, active: List[GenRequest]) -> None:
        """Prefill a wave of requests together, decode until all finish."""
        b = len(active)
        s0 = max(len(r.prompt) for r in active)
        # Right-align prompts so the last prompt token sits at position s0-1
        # for every slot; left padding repeats the first token (masked by
        # causality for generation purposes at this scale).
        toks = np.stack([
            np.pad(r.prompt, (s0 - len(r.prompt), 0), mode="edge")
            for r in active
        ]).astype(np.int32)
        logits, cache = self._prefill(self.params, jnp.asarray(toks))
        from repro.serving.engine import _grow_cache

        total = s0 + max(r.max_new_tokens for r in active)
        cache = _grow_cache(self.model, cache, total, s0)

        out: Dict[int, List[int]] = {r.uid: [] for r in active}
        done = [False] * b
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        cache_len = jnp.asarray(s0, jnp.int32)
        for step in range(max(r.max_new_tokens for r in active)):
            ids = np.asarray(jax.device_get(tok))
            for i, r in enumerate(active):
                if done[i]:
                    continue
                out[r.uid].append(int(ids[i]))
                if (
                    len(out[r.uid]) >= r.max_new_tokens
                    or (self.eos is not None and ids[i] == self.eos)
                ):
                    done[i] = True
            if all(done):
                break
            logits, cache = self._step(self.params, tok, cache, cache_len)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            cache_len = cache_len + 1
        for r in active:
            self.results.append(
                GenResult(uid=r.uid, tokens=np.array(out[r.uid]), steps=len(out[r.uid]))
            )
