"""Batching schedulers for both serving paths.

* :class:`RequestGroupScheduler` — groups :class:`MultitaskRequest`s for the
  *task-graph* engine: requests are bucketed by requested task subset (and
  input shape/dtype) so every group runs one homogeneous schedule through
  ``TaskGraphExecutor.run_batch``, and each group is padded up to a small
  fixed set of batch shapes so jit recompilation stays bounded at
  ``len(batch_shapes)`` batch dims per sample shape.  Per-request gate
  outcomes are resolved by the engine while a group executes (a task's
  output depends only on the input row, so running a gated-off row and
  dropping its output is exact) — the dynamic analogue of bucketing by gate
  outcome without re-stacking mid-flight.  With a cost model supplied, the
  emitted groups are additionally *sequenced* by :func:`order_groups` so
  consecutive groups hand residency over cheaply — the paper's task-ordering
  idea lifted one level up, feeding the engine's warm-start pipeline.

* :class:`ContinuousBatcher` — continuous batching for the LM server: a
  minimal production-shaped scheduler where slots in a fixed-size batch are
  recycled the moment a sequence finishes, new prompts are prefilled into
  free slots (with right-aligned padding so cache positions line up), and
  every engine step decodes all active slots together.  This is the
  decode-shape economics the dry-run's ``serve_step`` lowers: batch =
  concurrent slots, cache_len grows per step.  For simplicity the scheduler
  keeps a single shared ``cache_len`` high-water mark per batch (slot-level
  masks handle shorter sequences) — the standard static-shape compromise
  without ragged support.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import (
    TYPE_CHECKING, Any, Deque, Dict, FrozenSet, List, Optional, Sequence,
    Tuple,
)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.constraints import Constraints
from repro.core.cost_model import GraphCostModel, Residency
from repro.core.ordering import greedy_2opt_order, optimal_order
from repro.models.registry import ModelApi
from repro.sharding.policy import ShardingPolicy, TP_POLICY

if TYPE_CHECKING:  # avoid a module cycle with repro.serving.engine
    from repro.serving.engine import MultitaskRequest


# --------------------------------------------------------------------------
# Task-graph request grouping
# --------------------------------------------------------------------------

DEFAULT_BATCH_SHAPES = (1, 4, 16, 64)


def normalize_subset(
    tasks: Optional[Sequence[int]], num_tasks: Optional[int] = None
) -> Optional[FrozenSet[int]]:
    """A request's task subset in bucket-key form.

    ``None`` for all-tasks — implicit, or explicit when ``num_tasks`` is
    known — so full requests share a group (and its weight loads) however
    they were spelled; a frozenset otherwise.  The single normalization
    both the scheduler's bucketing and admission policies key on: they must
    agree, or a policy would score buckets that never actually form.
    """
    if tasks is None:
        return None
    subset = frozenset(int(t) for t in tasks)
    if num_tasks is not None and subset == frozenset(range(num_tasks)):
        return None
    return subset


@dataclasses.dataclass
class RequestGroup:
    """One homogeneous, padded execution group for ``run_batch``.

    Attributes:
      indices: positions of the member requests in the submitted sequence.
      requests: the member requests themselves (no padding entries).
      tasks: the shared requested task subset (``None`` = all tasks).
      xs: ``(P, *sample_shape)`` stacked inputs where ``P`` is one of the
        scheduler's padded batch shapes; rows ``valid:`` repeat the last real
        row and are dropped from outputs and logical accounting.
      valid: number of real leading rows (``len(requests)``).
      order: the group's resolved execution order, set by the engine's
        per-plan order re-solving pass (``EnginePolicy.resolve_order_per_plan``);
        ``None`` means "the engine's global order filtered to ``tasks``" —
        the default semantics every pre-session caller gets.
    """

    indices: Tuple[int, ...]
    requests: Tuple["MultitaskRequest", ...]
    tasks: Optional[FrozenSet[int]]
    xs: jnp.ndarray
    valid: int
    order: Optional[Tuple[int, ...]] = None

    @property
    def padding(self) -> int:
        return int(self.xs.shape[0]) - self.valid


class RequestGroupScheduler:
    """Bucket + chunk + pad pending multitask requests into groups.

    Invariants (property-tested):
      * every submitted request lands in exactly one group;
      * groups are homogeneous: all members share the same task subset and
        the same input shape/dtype;
      * every group's padded width is one of ``batch_shapes`` (requests
        beyond the largest shape are chunked into multiple groups);
      * padding never changes results — padded rows are replicas of the last
        real row, executed vmapped and then sliced away.

    Arrival order is preserved within a bucket so latency-sensitive callers
    get deterministic group membership.

    ``shard_multiple`` rounds every allowed batch shape up to a multiple of
    the mesh's data-shard count (``ShardingPolicy.data_shards``) so a padded
    group always splits evenly over the batch axes — the engine folds this
    in automatically when given a mesh.
    """

    def __init__(
        self,
        batch_shapes: Sequence[int] = DEFAULT_BATCH_SHAPES,
        shard_multiple: int = 1,
    ):
        m = int(shard_multiple)
        if m < 1:
            raise ValueError(f"invalid shard multiple: {shard_multiple!r}")
        shapes = tuple(sorted({-(-int(s) // m) * m for s in batch_shapes}))
        if not shapes or shapes[0] < 1:
            raise ValueError(f"invalid batch shapes: {batch_shapes!r}")
        self.batch_shapes = shapes
        self.shard_multiple = m

    def padded_size(self, n: int) -> int:
        """Smallest allowed batch shape >= ``n`` (callers chunk to the max)."""
        if n > self.batch_shapes[-1]:
            raise ValueError(
                f"group of {n} exceeds the largest batch shape "
                f"{self.batch_shapes[-1]}; chunk before padding"
            )
        for s in self.batch_shapes:
            if s >= n:
                return s
        raise AssertionError("unreachable")

    def chunk_sizes(self, n: int) -> List[Tuple[int, int]]:
        """Split a bucket of ``n`` requests into ``(take, padded_to)`` chunks.

        Greedy: peel off the largest allowed shape while it fits, and pad
        the remainder up to the next shape only when the padding does not
        exceed the remainder itself (<= 50% waste — one padded group beats
        splitting into more groups that each re-pay the weight loads).  A
        remainder below the smallest allowed shape must pad up.  E.g. with
        shapes (1, 4, 16, 64): 17 -> 16 + 1, 5 -> 4 + 1, 3 -> one chunk
        padded to 4.
        """
        out: List[Tuple[int, int]] = []
        while n > 0:
            up = next((s for s in self.batch_shapes if s >= n), None)
            down = max((s for s in self.batch_shapes if s <= n), default=None)
            if up is not None and (down is None or up - n <= n):
                out.append((n, up))
                break
            out.append((down, down))
            n -= down
        return out

    def plan(
        self,
        requests: Sequence["MultitaskRequest"],
        num_tasks: Optional[int] = None,
        cost_model: Optional[GraphCostModel] = None,
        task_order: Optional[Sequence[int]] = None,
        initial_resident: Optional[Residency] = None,
    ) -> List[RequestGroup]:
        """Partition ``requests`` into padded homogeneous groups.

        With ``num_tasks`` given, an explicit all-tasks subset is normalised
        to ``None`` so it shares a group (and its weight loads) with
        ``tasks=None`` requests.

        With ``cost_model`` and ``task_order`` given, the groups come back
        in the cost-aware inter-group sequence (:func:`order_groups`) that
        minimises the warm-start boundary loads between consecutive groups;
        otherwise bucket order is kept.  ``initial_resident`` feeds the
        engine's current residency in so a warm engine also picks the
        cheapest first group.
        """
        buckets: Dict[Tuple, List[Tuple[int, Any, jnp.ndarray]]] = {}
        for i, req in enumerate(requests):
            x = jnp.asarray(req.x)
            subset = normalize_subset(req.tasks, num_tasks)
            key = (subset, tuple(x.shape), str(x.dtype))
            buckets.setdefault(key, []).append((i, req, x))

        groups: List[RequestGroup] = []
        for (subset, _shape, _dtype), members in buckets.items():
            start = 0
            for take, p in self.chunk_sizes(len(members)):
                chunk = members[start:start + take]
                start += take
                rows = [x for (_i, _r, x) in chunk]
                rows.extend([rows[-1]] * (p - take))
                groups.append(RequestGroup(
                    indices=tuple(i for (i, _r, _x) in chunk),
                    requests=tuple(r for (_i, r, _x) in chunk),
                    tasks=subset,
                    xs=jnp.stack(rows),
                    valid=take,
                ))
        if cost_model is not None and task_order is not None:
            groups = order_groups(
                groups, cost_model, task_order, initial_resident
            )
        return groups


# Above this many groups the exact path solvers get expensive; fall back to
# the greedy + 2-opt heuristic (the matrix is asymmetric either way).
EXACT_GROUP_ORDERING_LIMIT = 9


def effective_order(
    task_order: Sequence[int], tasks: Optional[FrozenSet[int]]
) -> List[int]:
    """The engine's task order filtered to one group's requested subset."""
    if tasks is None:
        return list(task_order)
    return [t for t in task_order if t in tasks]


def order_groups(
    groups: Sequence[RequestGroup],
    cost_model: GraphCostModel,
    task_order: Sequence[int],
    initial_resident: Optional[Residency] = None,
) -> List[RequestGroup]:
    """Cost-aware inter-group sequencing for the warm-start pipeline.

    The paper orders *tasks* so consecutive tasks share the longest prefix;
    this generalises the same idea one level up: consecutive *groups* should
    hand over residency cheaply.  The boundary cost of running group ``j``
    right after group ``i`` is the load-only switching cost from ``i``'s
    last executed task to ``j``'s first (activations never cross groups, so
    only loads are at stake), weighted by ``j``'s request count — a group of
    many requests stalling on a cold boundary costs more request-seconds
    than a singleton.  Each group's internal cost is sequence-independent,
    so minimising the boundary sum minimises the whole schedule's modelled
    cost; the matrix goes through the existing ordering machinery (exact
    Held-Karp for few groups, greedy + 2-opt beyond
    ``EXACT_GROUP_ORDERING_LIMIT``).

    ``initial_resident`` (the executor's residency before this batch) adds a
    fixed virtual start node so a warm engine also picks the cheapest *first*
    group; cold, the first group's cost is group-independent (block costs
    depend only on depth) and no virtual node is needed.
    """
    # Groups executing no tasks (empty requested subset) are residency
    # no-ops: residency flows through them untouched, so they must not sit
    # in the cost matrix as free waypoints hiding their neighbours' real
    # boundary cost.  Order the real groups, append the no-ops at the end.
    def group_eff(g: RequestGroup) -> List[int]:
        # A pre-resolved per-plan order wins over the filtered global order.
        if g.order is not None:
            return list(g.order)
        return effective_order(task_order, g.tasks)

    active = [i for i, g in enumerate(groups) if group_eff(g)]
    inert = [i for i in range(len(groups)) if i not in set(active)]
    m = len(active)
    if m <= 1:
        return [groups[i] for i in active + inert]
    firsts: List[int] = []
    lasts: List[int] = []
    for i in active:
        eff = group_eff(groups[i])
        firsts.append(eff[0])
        lasts.append(eff[-1])

    warm = initial_resident is not None and any(
        r is not None for r in initial_resident
    )
    n = m + 1 if warm else m
    off = 1 if warm else 0
    c = np.zeros((n, n), dtype=np.float64)
    for i in range(m):
        for j in range(m):
            if i == j:
                continue
            c[i + off, j + off] = (
                groups[active[j]].valid
                * cost_model.warm_switching_cost(lasts[i], firsts[j])
            )
    cons = None
    if warm:
        for j in range(m):
            c[0, j + 1] = groups[active[j]].valid * cost_model.resume_load_cost(
                initial_resident, firsts[j]
            )
        # The virtual start must come first: it precedes every group.
        cons = Constraints.make(n, precedence=[(0, j + 1) for j in range(m)])

    if n <= EXACT_GROUP_ORDERING_LIMIT:
        res = optimal_order(c, cons)
    else:
        res = greedy_2opt_order(c, cons)
    seq = [active[g - off] for g in res.order if g - off >= 0]
    return [groups[i] for i in seq + inert]


@dataclasses.dataclass
class GenRequest:
    uid: int
    prompt: np.ndarray          # (S0,) int32
    max_new_tokens: int


@dataclasses.dataclass
class GenResult:
    uid: int
    tokens: np.ndarray          # generated ids
    steps: int


class ContinuousBatcher:
    """Fixed-slot continuous batching over a ModelApi.

    The engine re-prefills the WHOLE batch whenever slot membership changes
    (simple and correct; a production engine would insert into the live
    cache).  Between membership changes, decode steps are batched.
    """

    def __init__(
        self,
        model: ModelApi,
        params: Any,
        slots: int = 4,
        max_len: int = 256,
        policy: ShardingPolicy = TP_POLICY,
        eos_token: Optional[int] = None,
    ):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.policy = policy
        self.eos = eos_token
        self.queue: Deque[GenRequest] = deque()
        self.results: List[GenResult] = []
        self._prefill = jax.jit(lambda p, b: model.prefill(p, b, policy))
        self._step = jax.jit(
            lambda p, t, c, n: model.decode_step(p, t, c, n, policy)
        )

    def submit(self, req: GenRequest) -> None:
        if len(req.prompt) + req.max_new_tokens > self.max_len:
            raise ValueError("request exceeds max_len")
        self.queue.append(req)

    # ------------------------------------------------------------------ run
    def run(self) -> List[GenResult]:
        """Serve until the queue drains.  Returns completed results."""
        while self.queue:
            active = [
                self.queue.popleft()
                for _ in range(min(self.slots, len(self.queue)))
            ]
            self._serve_wave(active)
        return self.results

    def _serve_wave(self, active: List[GenRequest]) -> None:
        """Prefill a wave of requests together, decode until all finish."""
        b = len(active)
        s0 = max(len(r.prompt) for r in active)
        # Right-align prompts so the last prompt token sits at position s0-1
        # for every slot; left padding repeats the first token (masked by
        # causality for generation purposes at this scale).
        toks = np.stack([
            np.pad(r.prompt, (s0 - len(r.prompt), 0), mode="edge")
            for r in active
        ]).astype(np.int32)
        logits, cache = self._prefill(self.params, jnp.asarray(toks))
        from repro.serving.engine import _grow_cache

        total = s0 + max(r.max_new_tokens for r in active)
        cache = _grow_cache(self.model, cache, total, s0)

        out: Dict[int, List[int]] = {r.uid: [] for r in active}
        done = [False] * b
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        cache_len = jnp.asarray(s0, jnp.int32)
        for step in range(max(r.max_new_tokens for r in active)):
            ids = np.asarray(jax.device_get(tok))
            for i, r in enumerate(active):
                if done[i]:
                    continue
                out[r.uid].append(int(ids[i]))
                if (
                    len(out[r.uid]) >= r.max_new_tokens
                    or (self.eos is not None and ids[i] == self.eos)
                ):
                    done[i] = True
            if all(done):
                break
            logits, cache = self._step(self.params, tok, cache, cache_len)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            cache_len = cache_len + 1
        for r in active:
            self.results.append(
                GenResult(uid=r.uid, tokens=np.array(out[r.uid]), steps=len(out[r.uid]))
            )
