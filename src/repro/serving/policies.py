"""Pluggable scheduling policies and the engine-level policy config.

Batching-aware multitask serving treats *which requests run together, and
when* as a first-class, swappable decision rather than a hard-coded engine
flag.  A :class:`SchedulingPolicy` owns exactly that decision for a
:class:`~repro.serving.session.ServingSession`: each pump of the session it
inspects the admission queue (and, through the engine, the cost model and
the executor's current weight residency) and returns the pending requests to
admit as the next planning batch — or nothing, to keep accumulating.

Four policies ship:

* :class:`GreedyBatchPolicy` — admit everything pending at once.  This is
  the pre-session ``serve_batch`` semantics: one plan over the whole
  request list, and the policy one-shot wrappers use so existing entry
  points reproduce their old outputs exactly.
* :class:`WindowPolicy` — admit by max-wait / max-group-size, in arrival
  order.  The classic batching window: requests accumulate until the window
  fills or the oldest request has waited long enough.
* :class:`AffinityPolicy` — residency-aware admission: among the pending
  task-subset buckets, admit the one whose cheapest entry task costs the
  least to resume from the executor's *current* residency (deepest shared
  prefix with whatever just ran).  The paper's switching-cost idea applied
  at admission time, before grouping or ordering ever see the requests.
* :class:`SloAwarePolicy` — affinity admission with SLO overrides: a
  request whose deadline slack has run out (or a tenant starving behind a
  residency-friendly stream) pre-empts the cheapest-resume choice, and
  oversubscribed buckets admit priority-first.

:class:`EnginePolicy` folds everything schedule-shaped about the engine —
the old ``warm_start`` / ``group_ordering`` constructor flags, the request
grouping scheduler, per-plan order re-solving, and the session scheduling
policy — into one config object, so "how this engine schedules" is a single
value that can be swapped, logged, or swept.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Any, Dict, List, Optional, Protocol, Tuple

from repro.serving.batching import RequestGroupScheduler, effective_order
from repro.sharding.policy import ShardingPolicy

if TYPE_CHECKING:  # session/engine import this module; keep runtime acyclic
    from repro.serving.engine import MultitaskEngine
    from repro.serving.session import AdmissionQueue, PendingRequest


class SchedulingPolicy(Protocol):
    """Admission control for a :class:`~repro.serving.session.ServingSession`.

    ``admit`` is called repeatedly during each session pump until it returns
    an empty list: inspect ``queue`` (arrival times, task subsets) and
    ``engine`` (cost model, current residency), pop the entries to admit as
    one planning batch via the queue's ``pop_*`` methods, and return them.
    ``now`` is the session clock reading for this pump.  ``flush=True``
    means the caller intends to empty the queue (``drain()`` or a one-shot
    serve): size/wait thresholds must be ignored, but *selection order*
    is still the policy's to choose — an affinity policy still empties the
    queue residency-nearest-first.
    """

    def admit(
        self,
        queue: "AdmissionQueue",
        engine: "MultitaskEngine",
        now: float,
        flush: bool,
    ) -> List["PendingRequest"]:
        ...


@dataclasses.dataclass(frozen=True)
class GreedyBatchPolicy:
    """Admit everything pending immediately (classic ``serve_batch``).

    One admission round covers the whole queue, so the downstream planner
    sees the full request list at once — exactly what the one-shot entry
    points did before sessions existed, which is why the ``serve`` /
    ``serve_batch`` wrappers run under this policy by default.
    """

    def admit(self, queue, engine, now, flush):
        return queue.pop_all()


@dataclasses.dataclass(frozen=True)
class WindowPolicy:
    """Admit by max-wait / max-group-size, in arrival order.

    Requests accumulate until either ``max_group_size`` are pending (admit
    the first ``max_group_size``) or the oldest pending request has waited
    ``max_wait`` seconds (admit what's there, bounded by the same size cap
    so a long-idle queue still produces bounded groups).  This is the
    arrival-order baseline the residency-aware policies are measured
    against.
    """

    max_wait: float = 0.05
    max_group_size: int = 16

    def __post_init__(self):
        if self.max_group_size < 1:
            raise ValueError(f"max_group_size must be >= 1, got {self.max_group_size}")
        if self.max_wait < 0:
            raise ValueError(f"max_wait must be >= 0, got {self.max_wait}")

    def admit(self, queue, engine, now, flush):
        if not queue:
            return []
        full = len(queue) >= self.max_group_size
        aged = now - queue.oldest_arrival() >= self.max_wait
        if flush or full or aged:
            return queue.pop_first(self.max_group_size)
        return []


@dataclasses.dataclass(frozen=True)
class AffinityPolicy:
    """Residency-aware admission: group requests whose task subsets share
    deep prefixes with what is already resident.

    Pending requests are bucketed by (normalised) requested task subset.
    When an admission fires, the policy scores every bucket by the cheapest
    ``resume_load_cost`` from the executor's *current* residency to any task
    in the bucket's subset — i.e. how little it would cost to start serving
    that bucket right now, given the blocks the previous group left in
    memory — and admits up to ``max_group_size`` requests (FIFO within the
    bucket) from the best one.  Repeated admission rounds therefore empty
    the queue in a residency-chained sequence, the admission-time analogue
    of ``order_groups``'s boundary-cost TSP, without ever waiting for the
    full request list.

    Thresholds mirror :class:`WindowPolicy`: admissions fire when
    ``min_pending`` (default ``max_group_size``) requests are queued, when
    the oldest has waited ``max_wait`` (``None`` = no ageing trigger), or on
    flush.
    """

    max_group_size: int = 16
    min_pending: Optional[int] = None
    max_wait: Optional[float] = None

    def __post_init__(self):
        if self.max_group_size < 1:
            raise ValueError(f"max_group_size must be >= 1, got {self.max_group_size}")

    def admit(self, queue, engine, now, flush):
        if not queue:
            return []
        aged = (
            self.max_wait is not None
            and now - queue.oldest_arrival() >= self.max_wait
        )
        threshold = (
            self.min_pending if self.min_pending is not None
            else self.max_group_size
        )
        if not (flush or aged or len(queue) >= threshold):
            return []
        buckets: Dict[object, List["PendingRequest"]] = {}
        for p in queue.pending:
            # Normalized once at submit time; pumping stays O(pending).
            buckets.setdefault(p.subset, []).append(p)
        resident = engine.executor.residency_state()

        def resume_cost(subset) -> float:
            tasks = effective_order(engine.order, subset)
            if not tasks:  # empty subset executes nothing: free
                return 0.0
            return min(
                engine.cost_model.resume_load_cost(resident, t) for t in tasks
            )

        _key, best = min(
            buckets.items(),
            key=lambda kv: (
                resume_cost(kv[0]),
                kv[1][0].seq,  # deterministic tie-break: oldest bucket
            ),
        )
        return queue.pop_seqs(p.seq for p in best[: self.max_group_size])


@dataclasses.dataclass(frozen=True)
class SloAwarePolicy:
    """Deadline- and tenant-aware admission layered over residency affinity.

    :class:`AffinityPolicy` minimises switching cost but is SLO-blind: a
    bucket whose requests are about to miss their deadlines waits exactly as
    long as one with no deadline at all, and a tenant whose subsets never
    match the resident prefix can starve indefinitely behind a tenant whose
    subsets always do.  This policy keeps affinity as the *default* choice
    and overrides it only when an SLO is actually at risk — trading
    residency affinity against deadline slack, per the roadmap's
    multi-tenant item:

    1. **urgency** — if any pending request's slack (``deadline - now``)
       is at most ``slack_threshold``, admission fires immediately and the
       bucket containing the most urgent request (minimum slack) is chosen,
       regardless of resume cost.  A near-deadline request never waits for
       a cheaper bucket to finish warming.
    2. **anti-starvation** — otherwise, if some tenant's oldest pending
       request has waited at least ``starvation_wait`` seconds, the bucket
       holding the longest-waiting such request is chosen.  One tenant's
       residency-friendly stream cannot lock out another's forever.
    3. **affinity** — otherwise the bucket with the cheapest
       ``resume_load_cost`` from the executor's current residency wins,
       exactly as :class:`AffinityPolicy` scores it.

    Within the chosen bucket, admission is priority-descending (then
    arrival order), up to ``max_group_size`` — so when a bucket is
    oversubscribed, high-priority requests ride the earlier group.

    Firing thresholds mirror :class:`AffinityPolicy` (``min_pending`` /
    ``max_wait`` / flush), with the urgency rule as an additional trigger:
    a pump that finds an at-risk request admits even below the thresholds.
    """

    max_group_size: int = 16
    min_pending: Optional[int] = None
    max_wait: Optional[float] = None
    slack_threshold: float = 0.0
    starvation_wait: Optional[float] = None

    def __post_init__(self):
        if self.max_group_size < 1:
            raise ValueError(f"max_group_size must be >= 1, got {self.max_group_size}")
        if self.slack_threshold < 0:
            raise ValueError(
                f"slack_threshold must be >= 0, got {self.slack_threshold}"
            )
        if self.starvation_wait is not None and self.starvation_wait < 0:
            raise ValueError(
                f"starvation_wait must be >= 0, got {self.starvation_wait}"
            )

    def admit(self, queue, engine, now, flush):
        if not queue:
            return []
        pending = queue.pending
        urgent = [
            p for p in pending if p.slack(now) <= self.slack_threshold
        ]
        aged = (
            self.max_wait is not None
            and now - queue.oldest_arrival() >= self.max_wait
        )
        threshold = (
            self.min_pending if self.min_pending is not None
            else self.max_group_size
        )
        if not (flush or urgent or aged or len(queue) >= threshold):
            return []
        buckets: Dict[object, List["PendingRequest"]] = {}
        for p in pending:
            buckets.setdefault(p.subset, []).append(p)

        if urgent:
            # Rule 1: serve the most at-risk request's bucket now.
            pick = min(urgent, key=lambda p: (p.slack(now), p.seq))
            chosen = buckets[pick.subset]
        else:
            starving = (
                [
                    p for p in pending
                    if now - p.arrival >= self.starvation_wait
                ]
                if self.starvation_wait is not None else []
            )
            if starving:
                # Rule 2: longest-waiting request breaks the affinity lock.
                pick = min(starving, key=lambda p: (p.arrival, p.seq))
                chosen = buckets[pick.subset]
            else:
                # Rule 3: residency affinity, as AffinityPolicy scores it.
                resident = engine.executor.residency_state()

                def resume_cost(subset) -> float:
                    tasks = effective_order(engine.order, subset)
                    if not tasks:
                        return 0.0
                    return min(
                        engine.cost_model.resume_load_cost(resident, t)
                        for t in tasks
                    )

                _key, chosen = min(
                    buckets.items(),
                    key=lambda kv: (resume_cost(kv[0]), kv[1][0].seq),
                )
        take = sorted(chosen, key=lambda p: (-p.priority, p.seq))
        return queue.pop_seqs(
            p.seq for p in take[: self.max_group_size]
        )


def _default_scheduling() -> SchedulingPolicy:
    return GreedyBatchPolicy()


@dataclasses.dataclass(frozen=True)
class EnginePolicy:
    """Everything schedule-shaped about a :class:`MultitaskEngine`.

    Attributes:
      warm_start: keep executor weight residency across request groups
        (activations are always dropped at group boundaries); ``False``
        resets the executor cold before every group.
      group_ordering: sequence planned groups by the cost model's warm
        boundary costs (``order_groups``) instead of bucket order.
      resolve_order_per_plan: re-solve each planned group's internal task
        order (``ordering.solve_suborder``) seeded with the residency the
        engine will actually have when the group runs, instead of using the
        cold global order filtered to the group's subset.  Runtime gates
        are order-sensitive (a gate reads the outputs produced so far), so
        the re-solve only runs when every gated task's inputs are declared
        — via ``MultitaskEngine(gate_deps=...)`` or derived from the
        conditional constraint edges — in which case those inputs become
        precedence edges of the re-solve and gating semantics are
        preserved.  Conditional-probability constraints no longer disable
        the re-solve: the engine re-solves over the *expected* cost matrix
        (``GraphCostModel.expected_cost_matrix`` with the constraints'
        Eq.-8 execution probabilities folded into a
        :class:`~repro.adaptive.gate_model.GateModel`), so per-plan orders
        optimize the same probability-weighted objective as the global
        solve instead of a p-blind proxy.
      scheduling: the session admission policy; the one-shot entry points
        (``serve`` / ``serve_batch``) run their internal session under it.
      scheduler: the request-group scheduler (bucketing / padding shapes);
        ``None`` means a default :class:`RequestGroupScheduler`, which the
        engine folds back into its ``policy`` at construction so
        ``engine.policy`` alone describes the engine's full scheduling
        behavior.
      mesh: optional ``jax.sharding.Mesh`` to shard group execution over:
        each group's batch dimension splits across the ``sharding`` policy's
        batch axes and the fused-suffix weights across its ``model`` /
        ``fsdp`` axes.  The engine rounds the scheduler's batch shapes up to
        per-shard multiples and extends cost prediction with HLO-calibrated
        per-collective byte terms so ``session.stats == session.predicted``
        stays exact on the mesh.
      sharding: logical->physical axis mapping used with ``mesh``
        (``TP_POLICY`` when unset; ``FSDP_TP_POLICY`` additionally shards
        weights over the data axis).
      streaming: double-buffered asynchronous weight streaming: while each
        group's fused suffix executes, the session prefetches the *next*
        group's non-resident block params (``MultitaskEngine.prefetch_group``
        -> ``WeightStreamer``), hiding load latency behind compute.  Prefetched
        bytes drop out of the modelled synchronous load term and any
        residue appears as ``ExecutionStats.stream_stall_seconds``; outputs
        and byte counters are unchanged, and ``session.stats ==
        session.predicted`` stays exact.  Requires ``warm_start`` (a cold
        reset before every group would cancel every prefetch).
      adaptive: optional :class:`~repro.adaptive.policy.AdaptivePolicy`
        turning on input-adaptive execution: the engine builds a
        per-row confidence :class:`~repro.adaptive.gating.BlockGater` for
        the executor (early exit / per-block gating inside fused
        suffixes), seeds the cost model's expected-counter
        :class:`~repro.adaptive.gate_model.GateModel`, solves task orders
        against *expected* switching costs, and lets sessions walk the
        policy's deadline ladder to pick each group's confidence
        threshold.  ``session.stats == session.predicted`` stays exact
        (prediction replays the realized gate trace);
        ``session.expected`` carries the a-priori expected prediction.

    The defaults reproduce the pre-session engine exactly: greedy one-shot
    admission, warm starts, cost-aware group ordering, global task order,
    single-device execution.
    """

    warm_start: bool = True
    group_ordering: bool = True
    resolve_order_per_plan: bool = False
    scheduling: SchedulingPolicy = dataclasses.field(
        default_factory=_default_scheduling
    )
    scheduler: Optional[RequestGroupScheduler] = None
    mesh: Optional[Any] = None
    sharding: Optional[ShardingPolicy] = None
    streaming: bool = False
    adaptive: Optional[Any] = None
