"""Session-based serving: async admission over the multitask engine.

The one-shot entry points (``serve`` / ``serve_batch``) plan a fixed request
list all at once.  A :class:`ServingSession` decouples the three phases so
they can overlap and be controlled independently:

* **admission** — :meth:`ServingSession.submit` enqueues a request at any
  time and returns a lightweight :class:`MultitaskFuture` immediately; an
  :class:`AdmissionQueue` accumulates pending requests under a pluggable
  :class:`~repro.serving.policies.SchedulingPolicy` that decides *when* a
  batch fires and *which* requests ride in it (greedy, windowed, or
  residency-affine);
* **planning** — each admitted batch goes through the engine's full
  planning stack (subset bucketing, padding, cost-aware group ordering,
  optional per-plan order re-solving).  Planning is pure host work: because
  JAX dispatch is asynchronous, the session plans admission batch *k+1*
  while batch *k*'s dispatched programs are still executing on the device
  — the planning-overlaps-execution pipeline the roadmap names;
* **execution** — groups run through the engine's batched executor exactly
  as ``serve_batch`` runs them; responses land in their futures as soon as
  their group has been dispatched (resolution is non-blocking: outputs are
  unsynced JAX arrays, reading them blocks as usual).

``session.stats`` accumulates the executed counters and
``session.predicted`` the cost model's incremental prediction (each group
predicted from the executor's actual residency right before it runs — the
incremental form of ``predicted_group_stats``).  With no gates the two are
equal, field for field, which the property tests assert.  On a mesh-sharded
engine (``EnginePolicy.mesh``) both sides include the per-kind collective
bytes of every fused-suffix dispatch — calibrated once from the lowered
HLO, added identically to counters and prediction — so the equality extends
to ``all_gather_bytes`` / ``all_reduce_bytes`` / ``reduce_scatter_bytes``.

Driving the loop: callers either poll :meth:`step` on their own cadence
(arrival-driven serving — the admission benchmark does this on a simulated
Poisson trace), call :meth:`flush` to force one admit-everything pass, or
call :meth:`drain` to serve until the queue is empty.  ``Future.result()``
drains the session if its response is not ready, so ``submit`` + ``result``
alone is a complete (if fully synchronous) usage.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import (
    TYPE_CHECKING, Callable, Deque, Iterable, List, Optional, Tuple,
)

from repro.core.types import ExecutionStats

if TYPE_CHECKING:
    from repro.serving.engine import (
        GroupExecution, MultitaskEngine, MultitaskRequest, MultitaskResponse,
    )
    from repro.serving.policies import SchedulingPolicy


class MultitaskFuture:
    """Handle for one submitted request's eventual response.

    ``done()`` is non-blocking; ``result()`` drives the owning session's
    :meth:`~ServingSession.drain` when the response is not yet available, so
    a future can always be resolved synchronously.  (Outputs inside the
    response are JAX arrays and may still be materialising on-device;
    reading them blocks as usual.)

    A future whose admitted batch failed mid-pump (planning or execution
    raised after its request left the queue) is *failed*, not stranded:
    ``done()`` reports True and ``result()`` re-raises the original error.
    """

    __slots__ = ("_session", "seq", "_response", "_error")

    def __init__(self, session: "ServingSession", seq: int):
        self._session = session
        self.seq = seq
        self._response: Optional["MultitaskResponse"] = None
        self._error: Optional[BaseException] = None

    def done(self) -> bool:
        return self._response is not None or self._error is not None

    def result(self) -> "MultitaskResponse":
        if not self.done():
            self._session.drain()
        if self._error is not None:
            raise self._error
        if self._response is None:  # pragma: no cover - drain() guarantees
            raise RuntimeError(f"request {self.seq} unresolved after drain")
        return self._response

    def _set(self, response: "MultitaskResponse") -> None:
        self._response = response

    def _fail(self, error: BaseException) -> None:
        self._error = error

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        state = (
            "failed" if self._error is not None
            else "done" if self._response is not None else "pending"
        )
        return f"MultitaskFuture(seq={self.seq}, {state})"


@dataclasses.dataclass
class PendingRequest:
    """One queued request awaiting admission.

    ``subset`` is the request's normalized task subset (the scheduler's
    bucket key), computed once at submit time so admission policies can
    bucket/score pending requests without re-normalizing the queue on
    every pump.
    """

    seq: int
    request: "MultitaskRequest"
    arrival: float
    future: MultitaskFuture
    subset: object = None


class AdmissionQueue:
    """FIFO of pending requests with policy-directed selective removal.

    Policies read :attr:`pending` (an arrival-ordered snapshot) to score
    candidates, then remove what they admit with :meth:`pop_all`,
    :meth:`pop_first`, or :meth:`pop_seqs` — removal is explicit so a
    request can never be admitted twice or dropped silently.
    """

    def __init__(self) -> None:
        self._entries: List[PendingRequest] = []

    def push(self, entry: PendingRequest) -> None:
        self._entries.append(entry)

    def __len__(self) -> int:
        return len(self._entries)

    def __bool__(self) -> bool:
        return bool(self._entries)

    @property
    def pending(self) -> Tuple[PendingRequest, ...]:
        """Arrival-ordered snapshot of everything awaiting admission."""
        return tuple(self._entries)

    def oldest_arrival(self) -> float:
        if not self._entries:
            raise ValueError("queue is empty")
        return self._entries[0].arrival

    def pop_all(self) -> List[PendingRequest]:
        out, self._entries = self._entries, []
        return out

    def pop_first(self, n: int) -> List[PendingRequest]:
        out, self._entries = self._entries[:n], self._entries[n:]
        return out

    def pop_seqs(self, seqs: Iterable[int]) -> List[PendingRequest]:
        """Remove and return the entries with these seqs, arrival-ordered."""
        want = set(seqs)
        out = [e for e in self._entries if e.seq in want]
        missing = want - {e.seq for e in out}
        if missing:
            raise KeyError(f"seqs not pending: {sorted(missing)}")
        self._entries = [e for e in self._entries if e.seq not in want]
        return out


class ServingSession:
    """Async admission + pipelined planning/execution over one engine.

    Args:
      engine: the :class:`MultitaskEngine` to serve through.  A session
        assumes exclusive use of the engine's executor while it has work in
        flight (interleaving one-shot ``serve`` calls shifts residency and
        breaks the incremental prediction's exactness, though never
        correctness).
      policy: the admission :class:`SchedulingPolicy`; defaults to the
        engine's configured ``EnginePolicy.scheduling``.
      clock: time source for arrival stamps and wait/window decisions
        (``time.monotonic`` by default; benchmarks inject simulated clocks,
        and every public method also accepts an explicit ``now``).
    """

    def __init__(
        self,
        engine: "MultitaskEngine",
        policy: Optional["SchedulingPolicy"] = None,
        clock: Optional[Callable[[], float]] = None,
    ):
        self.engine = engine
        self.policy = policy if policy is not None else engine.policy.scheduling
        self._clock = clock if clock is not None else time.monotonic
        self.queue = AdmissionQueue()
        self._seq = 0
        # ------------------------------------------------- running counters
        self.stats = ExecutionStats()       # executed, cumulative
        self.predicted = ExecutionStats()   # all-gates-fire prediction
        self.requests_submitted = 0
        self.requests_admitted = 0
        self.admission_rounds = 0
        self.groups_executed = 0
        self.plan_seconds = 0.0
        # Admission-latency tracking: running aggregates over every admitted
        # request (exact for the session's whole lifetime) plus a bounded
        # window of recent samples — a long-lived session must not grow a
        # per-request list forever.
        self.waits: Deque[float] = collections.deque(maxlen=self.WAITS_WINDOW)
        self.wait_sum = 0.0
        self.wait_max = 0.0

    #: recent admission-latency samples kept in ``waits`` (aggregates in
    #: ``wait_sum`` / ``wait_max`` / ``mean_admission_wait`` cover all).
    WAITS_WINDOW = 4096

    @property
    def mean_admission_wait(self) -> float:
        """Mean admission latency over every request ever admitted."""
        if not self.requests_admitted:
            return 0.0
        return self.wait_sum / self.requests_admitted

    @property
    def max_admission_wait(self) -> float:
        """Max admission latency over every request ever admitted."""
        return self.wait_max

    # ------------------------------------------------------------ admission
    def _now(self, now: Optional[float]) -> float:
        return self._clock() if now is None else float(now)

    def submit(
        self, request: "MultitaskRequest", now: Optional[float] = None
    ) -> MultitaskFuture:
        """Enqueue one request; returns its future immediately.

        Nothing executes until a pump (:meth:`step` / :meth:`flush` /
        :meth:`drain`) lets the scheduling policy admit it — that is what
        makes one-shot ``serve_batch`` (submit all, then drain) plan the
        whole list as a single batch.
        """
        fut = MultitaskFuture(self, self._seq)
        self.queue.push(PendingRequest(
            seq=self._seq, request=request, arrival=self._now(now), future=fut,
            subset=self.engine.normalized_subset(request.tasks),
        ))
        self._seq += 1
        self.requests_submitted += 1
        return fut

    # ------------------------------------------------------------- pumping
    def step(self, now: Optional[float] = None) -> List["MultitaskResponse"]:
        """One scheduling pump: admit/plan/execute whatever the policy says
        is ready at ``now``.  Returns the responses resolved by this pump
        (execution order, possibly including groups dispatched earlier)."""
        return self._pump(self._now(now), flush=False)

    def flush(self, now: Optional[float] = None) -> List["MultitaskResponse"]:
        """Pump with flush semantics: thresholds off, queue emptied, and the
        last in-flight group resolved."""
        return self._pump(self._now(now), flush=True)

    def drain(self) -> List["MultitaskResponse"]:
        """Serve until nothing is pending."""
        out = self.flush()
        if self.queue:
            raise RuntimeError(
                f"drain incomplete: scheduling policy {self.policy!r} "
                f"returned no admissions on flush with "
                f"{len(self.queue)} request(s) still pending — flush=True "
                f"must empty the queue (see SchedulingPolicy.admit)"
            )
        return out

    def pending_count(self) -> int:
        return len(self.queue)

    def _pump(self, now: float, flush: bool) -> List["MultitaskResponse"]:
        completed: List["MultitaskResponse"] = []
        while True:
            admitted = self.policy.admit(self.queue, self.engine, now, flush)
            if not admitted:
                break
            self.admission_rounds += 1
            self.requests_admitted += len(admitted)
            for p in admitted:
                wait = now - p.arrival
                self.waits.append(wait)
                self.wait_sum += wait
                self.wait_max = max(self.wait_max, wait)
            try:
                # Planning (bucketing, group-ordering TSP, per-plan
                # re-solve) is host-only work; any previously dispatched
                # group is still executing asynchronously on the device
                # underneath it.
                t0 = time.perf_counter()
                groups = self.engine.plan_groups(
                    [p.request for p in admitted])
                self.plan_seconds += time.perf_counter() - t0
                for group in groups:
                    members = tuple(admitted[slot] for slot in group.indices)
                    execution = self.engine._execute_group(group)
                    self.groups_executed += 1
                    self.stats = self.stats.merge(execution.stats)
                    self.predicted = self.predicted.merge(execution.predicted)
                    # Resolve immediately: building responses is
                    # non-blocking host work (outputs are unsynced JAX
                    # arrays, the modelled seconds come from counters), so
                    # deferring resolution would buy no extra overlap —
                    # and an exception in a later group must not strand
                    # futures whose group already ran.
                    completed.extend(self._resolve(execution, members))
            except BaseException as err:
                # The admitted entries already left the queue; anything not
                # yet resolved would otherwise be stranded forever.  Fail
                # those futures so result() re-raises the cause instead of
                # reporting an inexplicable unresolved request.
                for p in admitted:
                    if not p.future.done():
                        p.future._fail(err)
                raise
        return completed

    def _resolve(
        self,
        execution: "GroupExecution",
        members: Tuple[PendingRequest, ...],
    ) -> List["MultitaskResponse"]:
        """Build responses for one executed group and fill its futures."""
        responses = self.engine._group_responses(execution)
        for entry, response in zip(members, responses):
            entry.future._set(response)
        return responses
