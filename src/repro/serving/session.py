"""Session-based serving: async admission over the multitask engine.

The one-shot entry points (``serve`` / ``serve_batch``) plan a fixed request
list all at once.  A :class:`ServingSession` decouples the three phases so
they can overlap and be controlled independently:

* **admission** — :meth:`ServingSession.submit` enqueues a request at any
  time and returns a lightweight :class:`MultitaskFuture` immediately; an
  :class:`AdmissionQueue` accumulates pending requests under a pluggable
  :class:`~repro.serving.policies.SchedulingPolicy` that decides *when* a
  batch fires and *which* requests ride in it (greedy, windowed,
  residency-affine, or SLO-aware);
* **planning** — each admitted batch goes through the engine's full
  planning stack (subset bucketing, padding, cost-aware group ordering,
  optional per-plan order re-solving).  Planning is pure host work: because
  JAX dispatch is asynchronous, the session plans admission batch *k+1*
  while batch *k*'s dispatched programs are still executing on the device
  — the planning-overlaps-execution pipeline the roadmap names;
* **execution** — groups run through the engine's batched executor exactly
  as ``serve_batch`` runs them; responses land in their futures as soon as
  their group has been dispatched (resolution is non-blocking: outputs are
  unsynced JAX arrays, reading them blocks as usual).

``session.stats`` accumulates the executed counters and
``session.predicted`` the cost model's incremental prediction (each group
predicted from the executor's actual residency right before it runs — the
incremental form of ``predicted_group_stats``).  The prediction is
conditioned on each group's *realized gate trace* (legacy ``gate=`` skips
and adaptive per-block fire counts, replayed over the pre-execution
residency), so the two are equal field for field — for ungated, gated, and
input-adaptive engines alike — which the property tests assert.
``session.expected`` accumulates the *a-priori* expected prediction
instead: counters weighted by the engine's
:class:`~repro.adaptive.gate_model.GateModel` probabilities, computed
before each group runs; on a non-adaptive engine it simply equals
``session.predicted``.  An adaptive engine whose policy carries a deadline
``ladder`` additionally picks each group's confidence threshold from the
group's worst remaining deadline slack (more slack -> a tighter threshold
-> more early exits), trading accuracy headroom for energy exactly where
the SLOs allow it.  On a mesh-sharded
engine (``EnginePolicy.mesh``) both sides include the per-kind collective
bytes of every fused-suffix dispatch — calibrated once from the lowered
HLO, added identically to counters and prediction — so the equality extends
to ``all_gather_bytes`` / ``all_reduce_bytes`` / ``reduce_scatter_bytes``.

Reliability (see :mod:`repro.serving.reliability`): the session is the
fault boundary of the serving stack, and its unit of failure is the
*group*, not the pump.

* **Deadlines** — a request with ``MultitaskRequest.deadline`` set is
  expired at the top of every pump once the session clock passes it:
  its future fails with :class:`DeadlineExceeded` and it never reaches
  planning.
* **Backpressure** — ``max_pending`` bounds the admission queue (and
  ``max_pending_per_tenant`` each tenant's share of it).  An over-limit
  submission is either rejected (its future fails immediately with
  :class:`QueueFull`) or, under ``overload="shed"``, admitted by evicting
  the lowest-priority pending request — strictly lower priority than the
  newcomer, youngest first — whose future fails with ``QueueFull(shed=
  True)`` instead.  Either way every submitted future reaches a terminal
  state; nothing blocks and nothing is silently dropped.
* **Failure isolation + crash-consistent recovery** — before each group
  executes, the executor's residency is snapshotted; if the group raises
  anywhere (planning prediction, weight load, dispatch, a user gate), the
  snapshot is rolled back (``set_residency``) so no half-loaded state
  leaks, and the group is retried under the session's
  :class:`~repro.serving.reliability.RetryPolicy`: bounded exponential
  backoff on the primary path, then the graceful-degradation ladder
  (re-run with fused dispatch off; re-run a sharded plan on a single
  device).  Each retry re-enters ``engine._execute_group``, which
  re-predicts the group from the *actual* post-rollback residency — so
  ``session.stats == session.predicted`` stays exact, field for field,
  across any number of rollbacks and retries (only successful attempts
  are merged into either side).  A group that exhausts the ladder fails
  only its own futures — each with a :class:`RequestError` carrying the
  request's ``seq``, task subset, tenant, and group id, the original
  traceback chained — and the pump moves on to the next group.

* **Intermittent power** — a session opened with a ``journal``
  (:class:`~repro.serving.journal.Journal`) writes ahead of every state
  transition: requests at admission, ``group_begin`` before a group
  executes, cost-model-placed mid-suffix activation checkpoints at
  segment boundaries, and an atomic ``group_commit`` (outputs + counters
  + residency) after.  A whole-process power failure
  (:class:`~repro.serving.reliability.PowerFailure` — a ``BaseException``
  the retry ladder never absorbs) leaves the journal as the only truth;
  :meth:`ServingSession.recover` rebuilds a fresh session from it with
  exactly-once response semantics: committed groups are never re-run
  (their responses are rebuilt from the journal), the interrupted group
  resumes from its last durable checkpoint (``use_checkpoints=False``
  restarts it from scratch instead — the benchmark's comparator arm), and
  everything still pending is re-enqueued.  An ``energy`` budget
  (:class:`~repro.serving.reliability.EnergyBudget`) duty-cycles the pump:
  a group only executes once its predicted joules (checkpoint writes
  included) fit the storage capacitor, else the pump sleeps exactly the
  harvest time the deficit needs.

Driving the loop: callers either poll :meth:`step` on their own cadence
(arrival-driven serving — the admission benchmark does this on a simulated
Poisson trace), call :meth:`flush` to force one admit-everything pass, or
call :meth:`drain` to serve until the queue is empty.  ``Future.result()``
drains the session if its response is not ready, so ``submit`` + ``result``
alone is a complete (if fully synchronous) usage.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import (
    TYPE_CHECKING, Callable, Deque, Dict, Iterable, List, Optional, Tuple,
)

from repro.core.types import ExecutionStats
from repro.serving.reliability import (
    DeadlineExceeded, QueueFull, RequestError, RetryPolicy, TenantStats,
)

if TYPE_CHECKING:
    from repro.serving.engine import (
        GroupExecution, MultitaskEngine, MultitaskRequest, MultitaskResponse,
    )
    from repro.serving.journal import Journal, JournalState
    from repro.serving.policies import SchedulingPolicy
    from repro.serving.reliability import EnergyBudget


class MultitaskFuture:
    """Handle for one submitted request's eventual response.

    ``done()`` is non-blocking; ``result()`` drives the owning session's
    :meth:`~ServingSession.drain` when the response is not yet available, so
    a future can always be resolved synchronously.  (Outputs inside the
    response are JAX arrays and may still be materialising on-device;
    reading them blocks as usual.)

    A future is *terminal* when ``done()`` is True: either resolved with a
    response, or failed — rejected/shed by backpressure, expired past its
    deadline, or riding in a group whose recovery ladder ran out.  A failed
    future's ``result()`` re-raises the recorded
    :class:`~repro.serving.reliability.RequestError` (original traceback
    chained); ``error()`` peeks at it without raising.  Futures are never
    stranded: after ``drain()`` every submitted future is terminal.
    """

    __slots__ = ("_session", "seq", "_response", "_error")

    def __init__(self, session: "ServingSession", seq: int):
        self._session = session
        self.seq = seq
        self._response: Optional["MultitaskResponse"] = None
        self._error: Optional[BaseException] = None

    def done(self) -> bool:
        return self._response is not None or self._error is not None

    def error(self) -> Optional[BaseException]:
        """The recorded failure, or ``None`` (also when still pending)."""
        return self._error

    def result(self) -> "MultitaskResponse":
        if not self.done():
            self._session.drain()
        if self._error is not None:
            raise self._error
        if self._response is None:  # pragma: no cover - drain() guarantees
            raise RuntimeError(f"request {self.seq} unresolved after drain")
        return self._response

    def _set(self, response: "MultitaskResponse") -> None:
        self._response = response

    def _fail(self, error: BaseException) -> None:
        self._error = error

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        state = (
            "failed" if self._error is not None
            else "done" if self._response is not None else "pending"
        )
        return f"MultitaskFuture(seq={self.seq}, {state})"


@dataclasses.dataclass
class PendingRequest:
    """One queued request awaiting admission.

    ``subset`` is the request's normalized task subset (the scheduler's
    bucket key), computed once at submit time so admission policies can
    bucket/score pending requests without re-normalizing the queue on
    every pump.
    """

    seq: int
    request: "MultitaskRequest"
    arrival: float
    future: MultitaskFuture
    subset: object = None

    @property
    def deadline(self) -> Optional[float]:
        return self.request.deadline

    @property
    def priority(self) -> int:
        return self.request.priority

    @property
    def tenant(self) -> Optional[str]:
        return self.request.tenant

    def slack(self, now: float) -> float:
        """Seconds until this request's deadline (``inf`` without one)."""
        if self.request.deadline is None:
            return float("inf")
        return self.request.deadline - now


class AdmissionQueue:
    """FIFO of pending requests with policy-directed selective removal.

    Policies read :attr:`pending` (an arrival-ordered snapshot) to score
    candidates, then remove what they admit with :meth:`pop_all`,
    :meth:`pop_first`, or :meth:`pop_seqs` — removal is explicit so a
    request can never be admitted twice or dropped silently.
    """

    def __init__(self) -> None:
        self._entries: List[PendingRequest] = []

    def push(self, entry: PendingRequest) -> None:
        self._entries.append(entry)

    def __len__(self) -> int:
        return len(self._entries)

    def __bool__(self) -> bool:
        return bool(self._entries)

    @property
    def pending(self) -> Tuple[PendingRequest, ...]:
        """Arrival-ordered snapshot of everything awaiting admission."""
        return tuple(self._entries)

    def oldest_arrival(self) -> float:
        if not self._entries:
            raise ValueError("queue is empty")
        return self._entries[0].arrival

    def tenant_count(self, tenant: Optional[str]) -> int:
        """Number of pending entries belonging to ``tenant``."""
        return sum(1 for e in self._entries if e.tenant == tenant)

    def pop_all(self) -> List[PendingRequest]:
        out, self._entries = self._entries, []
        return out

    def pop_first(self, n: int) -> List[PendingRequest]:
        out, self._entries = self._entries[:n], self._entries[n:]
        return out

    def pop_seqs(self, seqs: Iterable[int]) -> List[PendingRequest]:
        """Remove and return the entries with these seqs, arrival-ordered."""
        want = set(seqs)
        out = [e for e in self._entries if e.seq in want]
        missing = want - {e.seq for e in out}
        if missing:
            raise KeyError(f"seqs not pending: {sorted(missing)}")
        self._entries = [e for e in self._entries if e.seq not in want]
        return out


class ServingSession:
    """Async admission + pipelined planning/execution over one engine.

    Args:
      engine: the :class:`MultitaskEngine` to serve through.  A session
        assumes exclusive use of the engine's executor while it has work in
        flight (interleaving one-shot ``serve`` calls shifts residency and
        breaks the incremental prediction's exactness, though never
        correctness).
      policy: the admission :class:`SchedulingPolicy`; defaults to the
        engine's configured ``EnginePolicy.scheduling``.
      clock: time source for arrival stamps, deadlines, and wait/window
        decisions (``time.monotonic`` by default; benchmarks inject
        simulated clocks, and every public method also accepts an explicit
        ``now``).
      max_pending: bound on the admission queue (``None`` = unbounded).
        Over-limit submissions are rejected or shed per ``overload``.
      max_pending_per_tenant: per-tenant share of the queue (``None`` =
        no per-tenant quota); enforced the same way, with shedding
        restricted to the offending tenant's own entries.
      overload: ``"reject"`` fails the incoming future with
        :class:`QueueFull`; ``"shed"`` evicts the lowest-priority pending
        entry with priority strictly below the newcomer's (youngest first)
        and admits the newcomer — falling back to reject when no such
        victim exists.
      retry: the group-recovery :class:`RetryPolicy` (rollback + bounded
        backoff + degradation ladder).  ``RetryPolicy(max_retries=0,
        degrade=False)`` fails a group on its first error — still isolated
        to that group, never the whole pump.
      sleep: backoff sleep hook (``time.sleep``); tests and simulated-clock
        benchmarks inject a no-op.  Never called when the policy's
        backoff base is 0.
      streaming: double-buffered weight streaming (defaults to the engine's
        ``EnginePolicy.streaming``).  Before each group executes, the pump
        prefetches that group's non-resident block params
        (``engine.prefetch_group``) behind the *previous* group's modelled
        compute window — JAX dispatch is asynchronous, so the previous
        group is still executing on the device while the transfers stream.
        The first group of a session (and the group after any failure)
        loads synchronously: there is no window to hide behind.  Requires
        a warm-start engine.
    """

    def __init__(
        self,
        engine: "MultitaskEngine",
        policy: Optional["SchedulingPolicy"] = None,
        clock: Optional[Callable[[], float]] = None,
        max_pending: Optional[int] = None,
        max_pending_per_tenant: Optional[int] = None,
        overload: str = "reject",
        retry: Optional[RetryPolicy] = None,
        sleep: Optional[Callable[[float], None]] = None,
        streaming: Optional[bool] = None,
        journal: Optional["Journal"] = None,
        checkpointing: bool = True,
        energy: Optional["EnergyBudget"] = None,
    ):
        if overload not in ("reject", "shed"):
            raise ValueError(
                f"overload must be 'reject' or 'shed', got {overload!r}"
            )
        if max_pending is not None and max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {max_pending}")
        if max_pending_per_tenant is not None and max_pending_per_tenant < 1:
            raise ValueError(
                f"max_pending_per_tenant must be >= 1, "
                f"got {max_pending_per_tenant}"
            )
        self.engine = engine
        self.policy = policy if policy is not None else engine.policy.scheduling
        self._clock = clock if clock is not None else time.monotonic
        self.queue = AdmissionQueue()
        self.max_pending = max_pending
        self.max_pending_per_tenant = max_pending_per_tenant
        self.overload = overload
        self.retry = retry if retry is not None else RetryPolicy()
        self._sleep = sleep if sleep is not None else time.sleep
        self.streaming = (
            engine.policy.streaming if streaming is None else bool(streaming)
        )
        if self.streaming and not engine.warm_start:
            raise ValueError(
                "streaming sessions require a warm-start engine: a cold "
                "reset before every group cancels any staged prefetch"
            )
        # Intermittent-power serving (see repro.serving.journal): a write-
        # ahead journal makes the session power-failure-atomic, an energy
        # budget duty-cycles the pump.  ``checkpointing=False`` keeps the
        # journal's exactly-once semantics but never cuts a suffix — the
        # restart-from-scratch comparator the intermittent benchmark runs.
        self.journal = journal
        self.checkpointing = bool(checkpointing)
        self.energy = energy
        if journal is not None:
            if engine.mesh is not None:
                raise ValueError(
                    "journaled (intermittent) sessions are not supported on "
                    "mesh-sharded engines: segmented suffix dispatch would "
                    "split the fused programs the per-suffix HLO collective "
                    "calibration was computed for, breaking counter "
                    "exactness — run intermittent serving on a single-device "
                    "engine"
                )
            if not engine.warm_start:
                raise ValueError(
                    "journaled (intermittent) sessions require a warm-start "
                    "engine: the journal's residency records model weights "
                    "living in the durable tier across power cycles, which "
                    "is exactly what warm_start keeps — a cold engine would "
                    "discard the recovered residency before every group"
                )
        # The overlap window the next prefetch may hide behind: the modelled
        # compute seconds of the last successfully executed group (zero at
        # session start and after any group failure — synchronous recovery).
        self._stream_budget = 0.0
        self._seq = 0
        # ------------------------------------------------- running counters
        self.stats = ExecutionStats()       # executed, cumulative
        self.predicted = ExecutionStats()   # realized-trace prediction
        self.expected = ExecutionStats()    # a-priori expected prediction
        self.requests_submitted = 0
        self.requests_admitted = 0
        self.requests_rejected = 0
        self.requests_shed = 0
        self.requests_expired = 0
        self.requests_failed = 0
        self.admission_rounds = 0
        self.groups_executed = 0
        self.groups_failed = 0
        self.group_retries = 0          # failed attempts that were retried
        self.degraded_runs = 0          # groups served by a ladder rung
        self.plan_failures = 0          # planning batches that failed whole
        self.backoff_seconds = 0.0      # total retry backoff slept
        self.plan_seconds = 0.0
        self.prefetches_issued = 0      # groups whose loads were streamed
        self.prefetch_scheduled_bytes = 0.0
        self.prefetch_failures = 0      # prefetches that raised (degraded
                                        # to synchronous loads, never fatal)
        # Last prefetch failure's exception, kept for diagnosis (the
        # prefetch path swallows errors by design — counters alone cannot
        # say *why* streaming degraded to synchronous loads).
        self.last_prefetch_error: Optional[BaseException] = None
        self.energy_pauses = 0          # groups that waited for harvest
        self.energy_paused_seconds = 0.0
        self._group_seq = 0             # session-unique execution-group ids
        # seq -> future for every request recovered from a journal (filled
        # by ``ServingSession.recover``; empty for ordinary sessions).
        self.recovered: Dict[int, MultitaskFuture] = {}
        # Admission-latency tracking: running aggregates over every admitted
        # request (exact for the session's whole lifetime) plus a bounded
        # window of recent samples — a long-lived session must not grow a
        # per-request list forever.  ``tenants`` keeps the same exact
        # aggregates per tenant label (None = untenanted), so quota/SLO
        # policies can observe per-tenant starvation the global mean hides.
        self.waits: Deque[float] = collections.deque(maxlen=self.WAITS_WINDOW)
        self.wait_sum = 0.0
        self.wait_max = 0.0
        self.tenants: Dict[Optional[str], TenantStats] = {}

    #: recent admission-latency samples kept in ``waits`` (aggregates in
    #: ``wait_sum`` / ``wait_max`` / ``mean_admission_wait`` cover all).
    WAITS_WINDOW = 4096

    @property
    def mean_admission_wait(self) -> float:
        """Mean admission latency over every request ever admitted."""
        if not self.requests_admitted:
            return 0.0
        return self.wait_sum / self.requests_admitted

    @property
    def max_admission_wait(self) -> float:
        """Max admission latency over every request ever admitted."""
        return self.wait_max

    def tenant_stats(self, tenant: Optional[str]) -> TenantStats:
        """This tenant's exact admission aggregates (created on first use)."""
        if tenant not in self.tenants:
            self.tenants[tenant] = TenantStats()
        return self.tenants[tenant]

    def tenant_mean_admission_wait(self, tenant: Optional[str]) -> float:
        """Mean admission latency over ``tenant``'s admitted requests."""
        return self.tenant_stats(tenant).mean_admission_wait

    def tenant_max_admission_wait(self, tenant: Optional[str]) -> float:
        """Max admission latency over ``tenant``'s admitted requests."""
        return self.tenant_stats(tenant).max_admission_wait

    # ------------------------------------------------------------ admission
    def _now(self, now: Optional[float]) -> float:
        return self._clock() if now is None else float(now)

    def submit(
        self, request: "MultitaskRequest", now: Optional[float] = None
    ) -> MultitaskFuture:
        """Enqueue one request; returns its future immediately.

        Nothing executes until a pump (:meth:`step` / :meth:`flush` /
        :meth:`drain`) lets the scheduling policy admit it — that is what
        makes one-shot ``serve_batch`` (submit all, then drain) plan the
        whole list as a single batch.

        ``submit`` never raises for capacity: when the bounded queue (or
        the tenant's quota) is full and shedding finds no lower-priority
        victim, the returned future is already failed with
        :class:`QueueFull` — terminal immediately, so callers and load
        generators handle overload through the same future surface as
        every other outcome.
        """
        fut = MultitaskFuture(self, self._seq)
        entry = PendingRequest(
            seq=self._seq, request=request, arrival=self._now(now), future=fut,
            subset=self.engine.normalized_subset(request.tasks),
        )
        self._seq += 1
        self.requests_submitted += 1
        tstats = self.tenant_stats(entry.tenant)
        tstats.submitted += 1
        if self._admit_to_queue(entry):
            # Write-ahead: the request is durable the moment it is queued,
            # so a power failure never loses an acknowledged request.
            # (Rejected/shed-on-arrival submissions fail their future
            # immediately and are never journaled — nothing to recover.)
            if self.journal is not None:
                self.journal.admit(
                    entry.seq, request.x, request.tasks,
                    deadline=request.deadline, priority=request.priority,
                    tenant=request.tenant,
                )
            self.queue.push(entry)
        return fut

    def _admit_to_queue(self, entry: PendingRequest) -> bool:
        """Backpressure gate: may shed a victim or fail ``entry``'s future.

        Returns True when ``entry`` should be queued.  Quotas are checked
        innermost-first: the tenant's own share, then the global bound —
        shedding for a tenant-quota breach only ever evicts that tenant's
        entries, so one tenant's burst cannot push out another's work.
        """
        if self.max_pending_per_tenant is not None:
            if self.queue.tenant_count(entry.tenant) >= \
                    self.max_pending_per_tenant:
                if not self._try_shed(entry, tenant_scope=True):
                    self._reject(entry, scope="tenant quota")
                    return False
        if self.max_pending is not None and len(self.queue) >= self.max_pending:
            if not self._try_shed(entry, tenant_scope=False):
                self._reject(entry, scope="queue")
                return False
        return True

    def _try_shed(self, entry: PendingRequest, tenant_scope: bool) -> bool:
        """Evict the weakest strictly-lower-priority pending entry.

        Victim selection: lowest priority first, youngest arrival within a
        priority class (the oldest have waited longest and are closest to
        admission).  Only entries with priority *strictly below* the
        newcomer's qualify — shedding equals for a newcomer would let two
        same-priority streams evict each other forever.
        """
        if self.overload != "shed":
            return False
        candidates = [
            e for e in self.queue.pending
            if e.priority < entry.priority
            and (not tenant_scope or e.tenant == entry.tenant)
        ]
        if not candidates:
            return False
        victim = min(candidates, key=lambda e: (e.priority, -e.seq))
        self.queue.pop_seqs([victim.seq])
        self.requests_shed += 1
        self.tenant_stats(victim.tenant).shed += 1
        victim.future._fail(QueueFull(
            f"request {victim.seq} shed for a priority-{entry.priority} "
            f"arrival (own priority {victim.priority})",
            shed=True, seq=victim.seq, tasks=victim.subset,
            tenant=victim.tenant,
        ))
        if self.journal is not None:
            self.journal.request_failed(victim.seq)
        return True

    def _reject(self, entry: PendingRequest, scope: str) -> None:
        self.requests_rejected += 1
        self.tenant_stats(entry.tenant).rejected += 1
        entry.future._fail(QueueFull(
            f"request {entry.seq} rejected: {scope} full "
            f"(max_pending={self.max_pending}, "
            f"max_pending_per_tenant={self.max_pending_per_tenant})",
            seq=entry.seq, tasks=entry.subset, tenant=entry.tenant,
        ))

    # ------------------------------------------------------------- pumping
    def step(self, now: Optional[float] = None) -> List["MultitaskResponse"]:
        """One scheduling pump: admit/plan/execute whatever the policy says
        is ready at ``now``.  Returns the responses resolved by this pump
        (execution order, possibly including groups dispatched earlier)."""
        return self._pump(self._now(now), flush=False)

    def flush(self, now: Optional[float] = None) -> List["MultitaskResponse"]:
        """Pump with flush semantics: thresholds off, queue emptied, and the
        last in-flight group resolved."""
        return self._pump(self._now(now), flush=True)

    def drain(self) -> List["MultitaskResponse"]:
        """Serve until nothing is pending.

        Always terminates with every submitted future terminal: responses
        for served requests, typed failures for everything else (expired,
        shed, or in a group whose recovery ladder ran out).  Failures do
        not raise here — they are delivered through the futures — so one
        poisoned request can never wedge the drain of a multi-tenant queue.
        """
        out = self.flush()
        if self.queue:
            raise RuntimeError(
                f"drain incomplete: scheduling policy {self.policy!r} "
                f"returned no admissions on flush with "
                f"{len(self.queue)} request(s) still pending — flush=True "
                f"must empty the queue (see SchedulingPolicy.admit)"
            )
        return out

    def pending_count(self) -> int:
        return len(self.queue)

    def _expire_deadlines(self, now: float) -> None:
        """Fail every pending request whose deadline has passed.

        Runs at the top of each pump, before the policy sees the queue, so
        an overdue request is never planned, never pads a group, and never
        counts toward admission-wait aggregates — its future fails with
        :class:`DeadlineExceeded` and the queue entry is removed.
        """
        expired = [
            e for e in self.queue.pending
            if e.deadline is not None and e.deadline <= now
        ]
        if not expired:
            return
        self.queue.pop_seqs(e.seq for e in expired)
        for e in expired:
            self.requests_expired += 1
            self.tenant_stats(e.tenant).expired += 1
            e.future._fail(DeadlineExceeded(
                f"request {e.seq} missed its deadline "
                f"({e.request.deadline:.6g}) at t={now:.6g} before planning",
                seq=e.seq, tasks=e.subset, tenant=e.tenant,
            ))
            if self.journal is not None:
                self.journal.request_failed(e.seq)

    def _record_wait(self, entry: PendingRequest, now: float) -> None:
        wait = now - entry.arrival
        self.waits.append(wait)
        self.wait_sum += wait
        self.wait_max = max(self.wait_max, wait)
        tstats = self.tenant_stats(entry.tenant)
        tstats.admitted += 1
        tstats.wait_sum += wait
        tstats.wait_max = max(tstats.wait_max, wait)

    def _pump(self, now: float, flush: bool) -> List["MultitaskResponse"]:
        completed: List["MultitaskResponse"] = []
        self._expire_deadlines(now)
        while True:
            admitted = self.policy.admit(self.queue, self.engine, now, flush)
            if not admitted:
                break
            self.admission_rounds += 1
            self.requests_admitted += len(admitted)
            for p in admitted:
                self._record_wait(p, now)
            try:
                # Planning (bucketing, group-ordering TSP, per-plan
                # re-solve) is host-only work; any previously dispatched
                # group is still executing asynchronously on the device
                # underneath it.
                t0 = time.perf_counter()
                groups = self.engine.plan_groups(
                    [p.request for p in admitted])
                self.plan_seconds += time.perf_counter() - t0
            except Exception as err:
                # Planning failed before any group existed: group
                # membership is unknown, so the whole admitted batch fails
                # — but only this batch.  The queue, the executor, and the
                # counters are untouched (planning mutates none of them),
                # so the session keeps serving.
                self.plan_failures += 1
                self._fail_batch(admitted, err, group_id=None)
                continue
            for group in groups:
                group_id = self._group_seq
                self._group_seq += 1
                members = tuple(admitted[slot] for slot in group.indices)
                if self.journal is not None:
                    # Write-ahead: membership, order, and identity of the
                    # group are durable before anything executes, so a
                    # crash anywhere inside it leaves an *open* group the
                    # recovery can resume (or re-run) exactly once.
                    self.journal.group_begin(
                        group_id, [p.seq for p in members],
                        self.engine.group_order(group), group.valid,
                    )
                if self.energy is not None and not self._energy_gate(
                        group, members, group_id, now):
                    # Infeasible forever (needs more than the capacitor
                    # holds): members failed, pump moves on.
                    self._stream_budget = 0.0
                    continue
                if self.streaming and self._stream_budget > 0.0:
                    # Pipeline overlap: the previous group's dispatches are
                    # still executing asynchronously on the device; stream
                    # this group's non-resident weights behind them.
                    self._prefetch(group)
                execution, retries, degraded = self._run_group_guarded(
                    group, members, group_id,
                    adaptive_threshold=self._ladder_threshold(members, now))
                if execution is None:
                    # Ladder exhausted; members already failed.  No window
                    # survives a failed group — the next prefetch would
                    # overlap with compute that never completed.
                    self._stream_budget = 0.0
                    continue
                self.groups_executed += 1
                if self.streaming:
                    self._stream_budget = execution.predicted.compute_seconds(
                        self.engine.hw
                    )
                if self.energy is not None:
                    # Spend what the group actually cost (gated groups can
                    # undershoot the all-gates-fire reservation; clamp keeps
                    # rounding at the reservation boundary benign).
                    spent = execution.stats.energy(self.engine.hw)
                    self.energy.drain(min(spent, self.energy.available))
                self.stats = self.stats.merge(execution.stats)
                self.predicted = self.predicted.merge(execution.predicted)
                self.expected = self.expected.merge(
                    execution.expected if execution.expected is not None
                    else execution.predicted
                )
                if self.journal is not None:
                    # Atomic commit: outputs + counters + the residency the
                    # group leaves behind, in one durable record.  Futures
                    # resolve only after this point, so a delivered response
                    # is always a journaled response — exactly-once.
                    self.journal.group_commit(
                        group_id, [p.seq for p in members],
                        execution.outputs,
                        self.engine.executor.residency_state(),
                        execution.stats,
                    )
                # Resolve immediately: building responses is non-blocking
                # host work (outputs are unsynced JAX arrays, the modelled
                # seconds come from counters), so deferring resolution
                # would buy no extra overlap — and a failure in a later
                # group must not strand futures whose group already ran.
                completed.extend(self._resolve(
                    execution, members, retries=retries, degraded=degraded))
        return completed

    # --------------------------------------------------- energy budgeting
    def _group_required_joules(self, group) -> float:
        """The joules executing ``group`` from the executor's *current*
        residency will cost (checkpoint writes included) — the reservation
        the energy gate holds against the storage capacitor."""
        engine = self.engine
        eff = engine.group_order(group)
        resume = (
            engine.executor.residency_state() if engine.warm_start else None
        )
        plan = None
        if self.journal is not None and self.checkpointing:
            plan = engine.cost_model.plan_checkpoints(
                eff, batch_size=group.valid
            )
        pred = engine.cost_model.predicted_stats(
            eff, batch_size=group.valid, resume=resume, checkpoints=plan
        )
        return pred.energy(engine.hw)

    def _energy_gate(
        self,
        group,
        members: Tuple[PendingRequest, ...],
        group_id: int,
        now: float,
    ) -> bool:
        """Duty-cycle the pump: wait for harvest until ``group`` fits.

        Returns True when the group may execute.  When the group's
        predicted joules exceed the storage capacity outright (no amount of
        harvesting ever suffices), its members fail — isolated to the
        group, exactly like an exhausted retry ladder — and False comes
        back.  Otherwise the pump sleeps precisely the deficit's harvest
        time (``EnergyBudget.seconds_until``) and credits precisely that
        harvest (``EnergyBudget.advance``), so paused executions are
        deterministic under both real and simulated clocks.
        """
        budget = self.energy
        budget.harvest(now)
        need = self._group_required_joules(group)
        wait = budget.seconds_until(need)
        if wait == float("inf"):
            self.groups_failed += 1
            self._fail_batch(members, RuntimeError(
                f"group {group_id} needs {need:.6g} J but the energy "
                f"budget can never supply it (capacity "
                f"{budget.capacity_joules:.6g} J, harvest "
                f"{budget.harvest_watts:.6g} W)"
            ), group_id=group_id)
            return False
        if wait > 0.0:
            self.energy_pauses += 1
            self.energy_paused_seconds += wait
            self._sleep(wait)
            budget.advance(wait)
        return True

    # ------------------------------------------------- weight streaming
    def _prefetch(self, group) -> None:
        """Stage ``group``'s weight stream behind the current overlap window.

        Consumes the window either way (one compute window hides one
        group's loads).  A prefetch failure — including an injected
        ``"prefetch"`` fault — is never fatal: the streamer is cancelled
        and the group simply loads synchronously, with counters exact for
        the synchronous schedule it actually ran.
        """
        budget = self._stream_budget
        self._stream_budget = 0.0
        try:
            scheduled = self.engine.prefetch_group(
                group, overlap_seconds=budget
            )
        except Exception as err:
            self.prefetch_failures += 1
            # Retain the swallowed failure (type + chained traceback) so
            # operators can see *why* streaming degraded — the counter
            # alone cannot distinguish an injected fault from a real one.
            self.last_prefetch_error = err
            self.engine.executor.streamer.cancel()
            return
        if scheduled > 0.0:
            self.prefetches_issued += 1
            self.prefetch_scheduled_bytes += scheduled

    # --------------------------------------------- adaptive accuracy ladder
    def _ladder_threshold(
        self, members: Tuple[PendingRequest, ...], now: float
    ) -> Optional[float]:
        """The confidence threshold this group earns from its deadline room.

        ``None`` (keep the gater's base threshold) unless the engine is
        adaptive *and* its policy carries a ladder.  The group is scored by
        its *worst* member: the minimum remaining slack over members with
        deadlines (a group is as urgent as its most urgent request);
        all-deadline-free groups look up the ladder with ``None`` and get
        the base threshold.
        """
        adaptive = self.engine.adaptive
        if adaptive is None or not adaptive.ladder:
            return None
        slacks = [p.slack(now) for p in members if p.deadline is not None]
        return adaptive.threshold_for_slack(min(slacks) if slacks else None)

    # ------------------------------------------------- failure recovery
    def _run_group_guarded(
        self,
        group,
        members: Tuple[PendingRequest, ...],
        group_id: int,
        adaptive_threshold: Optional[float] = None,
    ) -> Tuple[Optional["GroupExecution"], int, Optional[str]]:
        """Execute one group with rollback, bounded retries, and the
        degradation ladder.  Returns ``(execution, failed_attempts,
        degraded_rung)``; ``execution`` is ``None`` when every rung failed
        (the members' futures are failed before returning).

        Each attempt snapshots the executor's residency first and rolls it
        back on failure, so a half-loaded crash state never leaks into the
        next attempt's (or the next group's) incremental prediction —
        ``engine._execute_group`` re-predicts every attempt from the
        executor's *actual* residency, which is what keeps
        ``session.stats == session.predicted`` exact through recoveries.
        """
        retry = self.retry
        failures = 0
        last_err: Optional[BaseException] = None
        for attempt in range(1 + retry.max_retries):
            if attempt > 0:
                self.group_retries += 1
                pause = retry.backoff_seconds(attempt - 1)
                if pause > 0.0:
                    self.backoff_seconds += pause
                    self._sleep(pause)
            try:
                return (
                    self._attempt_group(
                        group, group_id,
                        adaptive_threshold=adaptive_threshold,
                    ),
                    failures, None,
                )
            except Exception as err:
                failures += 1
                last_err = err
        if retry.degrade:
            if self.engine.mesh is None and self.engine.executor.fused:
                # Rung: unrolled per-block reference dispatch on the primary
                # executor — identical counters, identical outputs, no fused
                # program in the failure path.  (A journaled session keeps
                # journaling here: the per-block path fires the checkpoint
                # hooks at the same depth boundaries as the segmented one.)
                self.engine.executor.fused = False
                try:
                    execution = self._attempt_group(
                        group, group_id,
                        adaptive_threshold=adaptive_threshold,
                    )
                    self.degraded_runs += 1
                    return execution, failures, "unfused"
                except Exception as err:
                    failures += 1
                    last_err = err
                finally:
                    self.engine.executor.fused = True
            elif self.engine.mesh is not None:
                # Rung: cold single-device run on the engine's off-mesh
                # fallback executor (sharded plans cannot unfuse).
                snapshot = self.engine.executor.residency_state()
                try:
                    execution = self.engine.execute_group_fallback(
                        group, adaptive_threshold=adaptive_threshold
                    )
                    self.degraded_runs += 1
                    return execution, failures, "single_device"
                except Exception as err:
                    failures += 1
                    last_err = err
                    self.engine.executor.set_residency(snapshot)
        self.groups_failed += 1
        self._fail_batch(members, last_err, group_id=group_id)
        return None, failures, None

    def _attempt_group(
        self,
        group,
        group_id: Optional[int] = None,
        adaptive_threshold: Optional[float] = None,
    ) -> "GroupExecution":
        """One execution attempt with crash-consistent rollback.

        The residency snapshot taken here is the state every cost
        prediction after this group will be computed from if the attempt
        fails — restoring it on *any* exception is what makes a mid-group
        crash invisible to the counter-exactness invariant.  (A
        :class:`~repro.serving.reliability.PowerFailure` also passes
        through the rollback, harmlessly: the dying process's executor
        state is irrelevant — recovery re-seeds it from the journal.)
        """
        intermittent = None
        if self.journal is not None and group_id is not None:
            from repro.serving.engine import IntermittentContext

            intermittent = IntermittentContext(
                journal=self.journal, group_id=group_id,
                checkpointing=self.checkpointing,
            )
        snapshot = self.engine.executor.residency_state()
        try:
            return self.engine._execute_group(
                group, intermittent=intermittent,
                adaptive_threshold=adaptive_threshold,
            )
        except BaseException:
            self.engine.executor.set_residency(snapshot)
            raise

    def _fail_batch(
        self,
        entries: Tuple[PendingRequest, ...],
        err: Optional[BaseException],
        group_id: Optional[int],
    ) -> None:
        """Fail every unresolved entry with its own chained RequestError."""
        where = (
            "planning" if group_id is None else f"execution group {group_id}"
        )
        for p in entries:
            if p.future.done():
                continue
            self.requests_failed += 1
            self.tenant_stats(p.tenant).failed += 1
            wrapped = RequestError(
                f"request {p.seq} (tasks={sorted(p.subset) if p.subset else 'all'}) "
                f"failed in {where}: {err!r}",
                seq=p.seq, tasks=p.subset, tenant=p.tenant, group_id=group_id,
            )
            wrapped.__cause__ = err  # chain the original traceback
            p.future._fail(wrapped)
            if self.journal is not None:
                # Durable terminal outcome: recovery must not resurrect a
                # request whose failure was already delivered.
                self.journal.request_failed(p.seq)

    def _resolve(
        self,
        execution: "GroupExecution",
        members: Tuple[PendingRequest, ...],
        retries: int = 0,
        degraded: Optional[str] = None,
    ) -> List["MultitaskResponse"]:
        """Build responses for one executed group and fill its futures."""
        responses = self.engine._group_responses(execution)
        for entry, response in zip(members, responses):
            response.retries = retries
            response.degraded = degraded
            entry.future._set(response)
        return responses

    # ------------------------------------------------ power-failure recovery
    @classmethod
    def recover(
        cls,
        journal: "Journal",
        engine: "MultitaskEngine",
        use_checkpoints: bool = True,
        now: Optional[float] = None,
        **kwargs,
    ) -> "ServingSession":
        """Rebuild a session from a durable journal after a power failure.

        The journal (FRAM) is the only survivor of the crash; everything
        session-shaped (SRAM) is reconstructed from its replay:

        * **committed groups** are never re-run — their members' futures
          come back already resolved, rebuilt from the journaled outputs
          and counters (``MultitaskResponse.recovered`` is set).  Replay
          keeps the *first* commit per group, so even a journal containing
          a previous recovery's duplicate records stays exactly-once.
        * **the interrupted group** (begun, never committed) is resumed
          immediately under its original group id: residency is restored
          from the last committed transition, and with ``use_checkpoints``
          the journaled mid-suffix activation checkpoint seeds the
          executor, the group's order is rotated so the checkpointed task
          runs first, and its suffix resumes from the checkpoint depth —
          not from block 0.  ``use_checkpoints=False`` (the benchmark's
          restart-from-scratch arm) re-runs it cold instead.
        * **pending requests** (admitted, no durable outcome) are
          re-enqueued under their original seqs with fresh futures.

        Returns the new session; :attr:`recovered` maps every surviving
        seq to its future (resolved for committed work, pending for the
        re-enqueued backlog — drive :meth:`drain` to finish it).  Extra
        keyword arguments forward to the constructor (clock, retry,
        energy, …).  May itself die with a
        :class:`~repro.serving.reliability.PowerFailure` if the injector
        strikes during the resumed group — the journal stays consistent
        and a later ``recover`` picks up from the newest checkpoint.
        """
        state = journal.replay()
        kwargs.setdefault("checkpointing", use_checkpoints)
        session = cls(engine, journal=journal, **kwargs)
        t0 = session._now(now)
        session._seq = max(state.admitted, default=-1) + 1
        session._group_seq = state.next_group_id
        # The durable residency transition: weights live in FRAM in the
        # paper's deployment, so the last *committed* residency is what the
        # rebooted executor wakes up with.  The scratch arm models a
        # recovery that trusts nothing but the outputs.
        if use_checkpoints and state.residency is not None:
            engine.executor.set_residency(state.residency)
        else:
            engine.executor.reset()
        for seq, rec in state.responses.items():
            fut = MultitaskFuture(session, seq)
            fut._set(session._rebuild_response(rec))
            session.recovered[seq] = fut
        pending = set(state.pending_seqs)
        resumed: set = set()
        if state.inflight is not None:
            resumed = session._resume_inflight(state, use_checkpoints, pending)
        for seq in state.pending_seqs:
            if seq not in resumed:
                session._reenqueue(state.admitted[seq], t0)
        return session

    def _rebuild_response(self, rec: Dict) -> "MultitaskResponse":
        """A committed group's response, rebuilt from its journal record."""
        from repro.serving.engine import MultitaskResponse

        stats = dataclasses.replace(rec["stats"])
        group_size = max(int(rec["group_size"]), 1)
        per_req_seconds = stats.seconds(
            self.engine.hw, weight_shards=self.engine.weight_shards
        ) / group_size
        return MultitaskResponse(
            outputs=dict(rec["outputs"]),
            stats=stats,
            order=self.engine.order,
            predicted_seconds=per_req_seconds,
            group_size=int(rec["group_size"]),
            recovered=True,
        )

    def _reenqueue(self, admit_rec: Dict, now: float) -> MultitaskFuture:
        """Re-enqueue one journaled-but-unserved request under its original
        seq.  Bypasses :meth:`submit` on purpose: the request is already
        durable (re-journaling it would only bloat the log — replay
        deduplicates admits anyway) and backpressure does not re-apply to
        work the previous incarnation already accepted."""
        from repro.serving.engine import MultitaskRequest

        seq = int(admit_rec["seq"])
        tasks = admit_rec["tasks"]
        request = MultitaskRequest(
            x=admit_rec["x"],
            tasks=None if tasks is None else tuple(int(t) for t in tasks),
            deadline=admit_rec["deadline"],
            priority=int(admit_rec["priority"]),
            tenant=admit_rec["tenant"],
        )
        fut = MultitaskFuture(self, seq)
        self.queue.push(PendingRequest(
            seq=seq, request=request, arrival=now, future=fut,
            subset=self.engine.normalized_subset(request.tasks),
        ))
        self.requests_submitted += 1
        self.recovered[seq] = fut
        return fut

    def _resume_inflight(
        self,
        state: "JournalState",
        use_checkpoints: bool,
        pending: set,
    ) -> set:
        """Resume (or re-run) the journal's interrupted group right now.

        Reconstructs the group from its members' admit records, restores
        the journaled activation checkpoint when ``use_checkpoints``, and
        executes under the *original* group id so the commit closes the
        open ``group_begin``.  Returns the member seqs it completed; an
        empty set means the group could not be resumed in place (its
        members simply re-enter the queue and get re-planned — correct,
        just without mid-suffix credit).  Rotation is skipped for gated or
        conditionally-constrained engines: gates read outputs-so-far, so
        replaying a prefix-rotated order could change what fires.
        """
        from repro.core.executor import ActivationCheckpoint
        from repro.serving.engine import IntermittentContext, MultitaskRequest

        rec = state.inflight
        gid = int(rec["group_id"])
        member_seqs = [int(s) for s in rec["seqs"]]
        if not member_seqs or any(s not in pending for s in member_seqs):
            # Already terminal (the pre-crash ladder failed them) or
            # nothing to do — replanning owns whatever is left.
            return set()
        admits = [state.admitted.get(s) for s in member_seqs]
        if any(a is None for a in admits):
            return set()
        requests = []
        for a in admits:
            tasks = a["tasks"]
            requests.append(MultitaskRequest(
                x=a["x"],
                tasks=None if tasks is None else tuple(int(t) for t in tasks),
                deadline=a["deadline"],
                priority=int(a["priority"]),
                tenant=a["tenant"],
            ))
        groups = self.engine.plan_groups(requests)
        if len(groups) != 1 or groups[0].valid != len(requests):
            return set()  # cannot reconstruct the exact group; replan
        group = groups[0]
        order = tuple(int(t) for t in rec["order"])
        first_task_resume = 0
        if use_checkpoints and state.checkpoint is not None:
            ck = state.checkpoint
            # Rotate by the checkpoint's *task*, never its recorded ``pos``:
            # pos is relative to the order of the boot that wrote it, and a
            # previous recovery may already have rotated that order — after
            # two crashes the same pos can name a different task, and the
            # restored activation would seed the wrong path.
            ck_task = int(ck["task"])
            pos = order.index(ck_task) if ck_task in order else -1
            rotated = order[pos:] + order[:pos]
            rotation_safe = (
                not self.engine.gates
                and (self.engine.constraints is None
                     or self.engine.constraints.is_valid_order(rotated))
            )
            if rotation_safe and 0 <= pos < len(order):
                order = rotated
                first_task_resume = int(ck["depth"]) + 1
                self.engine.executor.restore_activation(ActivationCheckpoint(
                    depth=int(ck["depth"]),
                    node=state.checkpoint_node(),
                    value=ck["value"],
                    act_shape=(
                        tuple(int(s) for s in ck["act_shape"])
                        if ck["act_shape"] is not None else None
                    ),
                ))
        group = dataclasses.replace(group, order=order)
        ctx = IntermittentContext(
            journal=self.journal, group_id=gid,
            checkpointing=self.checkpointing,
        )
        try:
            execution = self.engine._execute_group(
                group, intermittent=ctx,
                first_task_resume=first_task_resume,
                keep_activations=first_task_resume > 0,
            )
        except Exception:
            # Roll back to the journaled state and let ordinary planning
            # re-run the members from scratch.  (PowerFailure is a
            # BaseException and deliberately propagates.)
            if use_checkpoints and state.residency is not None:
                self.engine.executor.set_residency(state.residency)
            else:
                self.engine.executor.reset()
            return set()
        self.groups_executed += 1
        self.stats = self.stats.merge(execution.stats)
        self.predicted = self.predicted.merge(execution.predicted)
        self.expected = self.expected.merge(
            execution.expected if execution.expected is not None
            else execution.predicted
        )
        if self.energy is not None:
            spent = execution.stats.energy(self.engine.hw)
            self.energy.drain(min(spent, self.energy.available))
        slot_seqs = [member_seqs[i] for i in group.indices]
        self.journal.group_commit(
            gid, slot_seqs, execution.outputs,
            self.engine.executor.residency_state(), execution.stats,
        )
        responses = self.engine._group_responses(execution)
        for seq, response in zip(slot_seqs, responses):
            fut = MultitaskFuture(self, seq)
            fut._set(response)
            self.recovered[seq] = fut
        return set(member_seqs)
