"""Serving engines.

* :class:`MultitaskEngine` — the Antler runtime: a task graph + optimal
  order + the block-cached executor, serving batched requests that each want
  some subset of the task set.  Conditional constraints become runtime gates
  (a dependent task is skipped when its prerequisite's outcome says so),
  which is exactly the paper's audio deployment (presence detector gating
  the other four classifiers).
* :class:`LMServer` — prefill + greedy decode loop over a
  :class:`~repro.models.registry.ModelApi` with a batched KV cache; used by
  the decode-shape dry-runs and the serving example.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.constraints import Constraints
from repro.core.cost_model import GraphCostModel
from repro.core.executor import MultitaskProgram, TaskGraphExecutor
from repro.core.ordering import optimal_order
from repro.core.types import ExecutionStats, HardwareModel, TPU_V5E
from repro.models.registry import ModelApi
from repro.serving.batching import RequestGroup, RequestGroupScheduler
from repro.sharding.policy import ShardingPolicy, TP_POLICY


@dataclasses.dataclass
class MultitaskRequest:
    """One inference request: an input and the tasks it wants."""

    x: Any
    tasks: Optional[Sequence[int]] = None  # None = all tasks


@dataclasses.dataclass
class MultitaskResponse:
    """Engine reply for one request.

    ``stats`` are the counters of the *execution group* the request was
    served in (``group_size`` requests share one batched pass, so loads
    amortise); ``predicted_seconds`` is this request's per-request share of
    the group's modelled cost.  With ``group_size == 1`` both reduce to the
    original single-request semantics.
    """

    outputs: Dict[int, jax.Array]
    stats: ExecutionStats
    order: Tuple[int, ...]
    predicted_seconds: float
    group_size: int = 1


class MultitaskEngine:
    """Antler end-to-end: ordering solved once at startup, executor reused.

    ``gates``: {task: fn(outputs_so_far) -> bool} runtime conditions
    implementing conditional constraints.
    """

    def __init__(
        self,
        program: MultitaskProgram,
        constraints: Optional[Constraints] = None,
        hw: HardwareModel = TPU_V5E,
        gates: Optional[Dict[int, Callable[[Dict[int, jax.Array]], bool]]] = None,
        order: Optional[Sequence[int]] = None,
        scheduler: Optional[RequestGroupScheduler] = None,
    ):
        self.program = program
        self.hw = hw
        self.constraints = constraints
        self.gates = gates or {}
        self.cost_model = GraphCostModel(program.graph, program.block_costs, hw)
        if order is None:
            res = optimal_order(self.cost_model.cost_matrix(), constraints)
            order = res.order
        self.order = tuple(order)
        if constraints is not None and not constraints.is_valid_order(self.order):
            raise ValueError("supplied order violates the constraints")
        self.executor = TaskGraphExecutor(program)
        self.scheduler = scheduler or RequestGroupScheduler()

    def _run_group(
        self, group: RequestGroup
    ) -> Tuple[List[Dict[int, jax.Array]], ExecutionStats]:
        """Execute one homogeneous request group through the batched path.

        Gates are evaluated per request row against that row's outputs so
        far.  A task runs (batched, once) when any row's gate fires; rows
        whose gate did not fire simply drop the task's output — exact,
        because a task's output depends only on its input row.  Flop/task
        counters are weighted by the fired-row count.  With uniform gate
        outcomes this equals the sequential per-request accounting; when
        outcomes diverge within a group, a partially-fired task's cached
        activations shorten the suffix of later tasks for *every* row, so
        the group can legitimately account fewer executed flops than the
        sum of solo serves — batching does strictly less work there.
        """
        v = group.valid
        per_request: List[Dict[int, jax.Array]] = [dict() for _ in range(v)]
        stats = ExecutionStats()
        for t in self.order:
            if group.tasks is not None and t not in group.tasks:
                stats.tasks_skipped += v
                continue
            g = self.gates.get(t)
            fire = [True] * v if g is None else [bool(g(per_request[i])) for i in range(v)]
            fired = sum(fire)
            stats.tasks_skipped += v - fired
            if fired == 0:
                continue
            out = self.executor.run_task_batch(t, group.xs, stats, weight=fired)
            for i in range(v):
                if fire[i]:
                    per_request[i][t] = out[i]
        return per_request, stats

    def serve_batch(
        self, requests: Sequence[MultitaskRequest]
    ) -> List[MultitaskResponse]:
        """Serve many requests via grouped batched execution.

        The scheduler buckets requests into homogeneous padded groups; each
        group runs the block-cached executor once with every block vmapped
        over the group, so weight loads amortise across the group's
        requests.  Responses come back in submission order.
        """
        groups = self.scheduler.plan(
            requests, num_tasks=self.program.graph.num_tasks
        )
        responses: List[Optional[MultitaskResponse]] = [None] * len(requests)
        for group in groups:
            self.executor.reset()  # cold per group: stats match predictions
            per_request, stats = self._run_group(group)
            per_req_seconds = stats.seconds(self.hw) / max(group.valid, 1)
            for slot, idx in enumerate(group.indices):
                responses[idx] = MultitaskResponse(
                    outputs=per_request[slot],
                    # Own copy per response: group-mates must not share a
                    # mutable counter object.
                    stats=dataclasses.replace(stats),
                    order=self.order,
                    predicted_seconds=per_req_seconds,
                    group_size=group.valid,
                )
        assert all(r is not None for r in responses)
        return responses  # type: ignore[return-value]

    def serve(self, request: MultitaskRequest) -> MultitaskResponse:
        return self.serve_batch([request])[0]

    def serve_many(self, requests: Sequence[MultitaskRequest]) -> List[MultitaskResponse]:
        return self.serve_batch(list(requests))


# --------------------------------------------------------------------------
# LM serving
# --------------------------------------------------------------------------

class LMServer:
    """Batched prefill + greedy decode for any architecture in the zoo."""

    def __init__(self, model: ModelApi, params: Any,
                 policy: ShardingPolicy = TP_POLICY, max_len: int = 512):
        self.model = model
        self.params = params
        self.policy = policy
        self.max_len = max_len
        self._prefill = jax.jit(
            lambda p, batch: model.prefill(p, batch, policy)
        )
        self._step = jax.jit(
            lambda p, tok, cache, n: model.decode_step(p, tok, cache, n, policy)
        )

    def generate(
        self, prompts: jax.Array, steps: int,
        features: Optional[jax.Array] = None,
    ) -> np.ndarray:
        """Greedy generation.  prompts: (B, S0) int32.  Returns (B, steps)."""
        cfg = self.model.cfg
        b, s0 = prompts.shape
        total = s0 + steps
        # Allocate a cache with full capacity, prefill into its prefix.
        if cfg.family == "encdec":
            batch = {"features": features, "tokens": prompts}
        else:
            batch = prompts
        logits, cache = self._prefill(self.params, batch)
        # Grow the prefill cache to full capacity (KV families only).
        cache = _grow_cache(self.model, cache, total, s0)
        out = []
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        cache_len = jnp.asarray(s0, jnp.int32)
        for _ in range(steps):
            out.append(np.asarray(jax.device_get(tok)))
            logits, cache = self._step(self.params, tok, cache, cache_len)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            cache_len = cache_len + 1
        return np.stack(out, axis=1)


def _grow_cache(model: ModelApi, cache: Any, total: int, filled: int) -> Any:
    """Pad a prefill-sized KV cache out to ``total`` slots."""
    from repro.models.cache import EncDecCache, HybridCache, KVCache, SSMCache

    def grow_kv(kv: KVCache) -> KVCache:
        t = kv.k.shape[2]
        if t >= total:
            return kv
        pad = [(0, 0)] * kv.k.ndim
        pad[2] = (0, total - t)
        return KVCache(k=jnp.pad(kv.k, pad), v=jnp.pad(kv.v, pad))

    if isinstance(cache, KVCache):
        cfg = model.cfg
        if cfg.sliding_window is not None:
            # SWA ring never needs more than ``window`` slots; prefill's
            # linear layout (positions < window) is already ring-consistent.
            total = min(total, cfg.sliding_window)
        return grow_kv(cache)
    if isinstance(cache, SSMCache):
        return cache
    if isinstance(cache, HybridCache):
        return HybridCache(ssm=cache.ssm, kv=grow_kv(cache.kv))
    if isinstance(cache, EncDecCache):
        return EncDecCache(
            self_kv=grow_kv(cache.self_kv),
            cross_k=cache.cross_k, cross_v=cache.cross_v,
        )
    return cache
