"""Serving engines.

* :class:`MultitaskEngine` — the Antler runtime: a task graph + optimal
  order + the block-cached executor, serving batched requests that each want
  some subset of the task set.  Conditional constraints become runtime gates
  (a dependent task is skipped when its prerequisite's outcome says so),
  which is exactly the paper's audio deployment (presence detector gating
  the other four classifiers).
* :class:`LMServer` — prefill + greedy decode loop over a
  :class:`~repro.models.registry.ModelApi` with a batched KV cache; used by
  the decode-shape dry-runs and the serving example.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.constraints import Constraints
from repro.core.cost_model import GraphCostModel
from repro.core.executor import MultitaskProgram, TaskGraphExecutor
from repro.core.ordering import optimal_order
from repro.core.types import ExecutionStats, HardwareModel, TPU_V5E
from repro.models.registry import ModelApi
from repro.sharding.policy import ShardingPolicy, TP_POLICY


@dataclasses.dataclass
class MultitaskRequest:
    """One inference request: an input and the tasks it wants."""

    x: Any
    tasks: Optional[Sequence[int]] = None  # None = all tasks


@dataclasses.dataclass
class MultitaskResponse:
    outputs: Dict[int, jax.Array]
    stats: ExecutionStats
    order: Tuple[int, ...]
    predicted_seconds: float


class MultitaskEngine:
    """Antler end-to-end: ordering solved once at startup, executor reused.

    ``gates``: {task: fn(outputs_so_far) -> bool} runtime conditions
    implementing conditional constraints.
    """

    def __init__(
        self,
        program: MultitaskProgram,
        constraints: Optional[Constraints] = None,
        hw: HardwareModel = TPU_V5E,
        gates: Optional[Dict[int, Callable[[Dict[int, jax.Array]], bool]]] = None,
        order: Optional[Sequence[int]] = None,
    ):
        self.program = program
        self.hw = hw
        self.constraints = constraints
        self.gates = gates or {}
        self.cost_model = GraphCostModel(program.graph, program.block_costs, hw)
        if order is None:
            res = optimal_order(self.cost_model.cost_matrix(), constraints)
            order = res.order
        self.order = tuple(order)
        if constraints is not None and not constraints.is_valid_order(self.order):
            raise ValueError("supplied order violates the constraints")
        self.executor = TaskGraphExecutor(program)

    def _gate(self, wanted: Optional[set]):
        def gate(task: int, outputs: Dict[int, jax.Array]) -> bool:
            if wanted is not None and task not in wanted:
                return False
            g = self.gates.get(task)
            return True if g is None else bool(g(outputs))

        return gate

    def serve(self, request: MultitaskRequest) -> MultitaskResponse:
        wanted = set(request.tasks) if request.tasks is not None else None
        self.executor.reset()
        outputs, stats = self.executor.run(request.x, self.order, self._gate(wanted))
        return MultitaskResponse(
            outputs=outputs,
            stats=stats,
            order=self.order,
            predicted_seconds=stats.seconds(self.hw),
        )

    def serve_many(self, requests: Sequence[MultitaskRequest]) -> List[MultitaskResponse]:
        return [self.serve(r) for r in requests]


# --------------------------------------------------------------------------
# LM serving
# --------------------------------------------------------------------------

class LMServer:
    """Batched prefill + greedy decode for any architecture in the zoo."""

    def __init__(self, model: ModelApi, params: Any,
                 policy: ShardingPolicy = TP_POLICY, max_len: int = 512):
        self.model = model
        self.params = params
        self.policy = policy
        self.max_len = max_len
        self._prefill = jax.jit(
            lambda p, batch: model.prefill(p, batch, policy)
        )
        self._step = jax.jit(
            lambda p, tok, cache, n: model.decode_step(p, tok, cache, n, policy)
        )

    def generate(
        self, prompts: jax.Array, steps: int,
        features: Optional[jax.Array] = None,
    ) -> np.ndarray:
        """Greedy generation.  prompts: (B, S0) int32.  Returns (B, steps)."""
        cfg = self.model.cfg
        b, s0 = prompts.shape
        total = s0 + steps
        # Allocate a cache with full capacity, prefill into its prefix.
        if cfg.family == "encdec":
            batch = {"features": features, "tokens": prompts}
        else:
            batch = prompts
        logits, cache = self._prefill(self.params, batch)
        # Grow the prefill cache to full capacity (KV families only).
        cache = _grow_cache(self.model, cache, total, s0)
        out = []
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        cache_len = jnp.asarray(s0, jnp.int32)
        for _ in range(steps):
            out.append(np.asarray(jax.device_get(tok)))
            logits, cache = self._step(self.params, tok, cache, cache_len)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            cache_len = cache_len + 1
        return np.stack(out, axis=1)


def _grow_cache(model: ModelApi, cache: Any, total: int, filled: int) -> Any:
    """Pad a prefill-sized KV cache out to ``total`` slots."""
    from repro.models.cache import EncDecCache, HybridCache, KVCache, SSMCache

    def grow_kv(kv: KVCache) -> KVCache:
        t = kv.k.shape[2]
        if t >= total:
            return kv
        pad = [(0, 0)] * kv.k.ndim
        pad[2] = (0, total - t)
        return KVCache(k=jnp.pad(kv.k, pad), v=jnp.pad(kv.v, pad))

    if isinstance(cache, KVCache):
        cfg = model.cfg
        if cfg.sliding_window is not None:
            # SWA ring never needs more than ``window`` slots; prefill's
            # linear layout (positions < window) is already ring-consistent.
            total = min(total, cfg.sliding_window)
        return grow_kv(cache)
    if isinstance(cache, SSMCache):
        return cache
    if isinstance(cache, HybridCache):
        return HybridCache(ssm=cache.ssm, kv=grow_kv(cache.kv))
    if isinstance(cache, EncDecCache):
        return EncDecCache(
            self_kv=grow_kv(cache.self_kv),
            cross_k=cache.cross_k, cross_v=cache.cross_v,
        )
    return cache
