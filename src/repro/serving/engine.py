"""Serving engines.

* :class:`MultitaskEngine` — the Antler runtime: a task graph + optimal
  order + the block-cached executor, serving batched requests that each want
  some subset of the task set.  Conditional constraints become runtime gates
  (a dependent task is skipped when its prerequisite's outcome says so),
  which is exactly the paper's audio deployment (presence detector gating
  the other four classifiers).
* :class:`LMServer` — prefill + greedy decode loop over a
  :class:`~repro.models.registry.ModelApi` with a batched KV cache; used by
  the decode-shape dry-runs and the serving example.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.constraints import Constraints
from repro.core.cost_model import GraphCostModel
from repro.core.executor import MultitaskProgram, TaskGraphExecutor
from repro.core.ordering import optimal_order
from repro.core.types import ExecutionStats, HardwareModel, TPU_V5E
from repro.models.registry import ModelApi
from repro.serving.batching import (
    RequestGroup, RequestGroupScheduler, effective_order,
)
from repro.sharding.policy import ShardingPolicy, TP_POLICY


@dataclasses.dataclass
class MultitaskRequest:
    """One inference request: an input and the tasks it wants."""

    x: Any
    tasks: Optional[Sequence[int]] = None  # None = all tasks


@dataclasses.dataclass
class MultitaskResponse:
    """Engine reply for one request.

    ``stats`` are the counters of the *execution group* the request was
    served in (``group_size`` requests share one batched pass, so loads
    amortise); ``predicted_seconds`` is this request's per-request share of
    the group's cost **as it actually ran** — for a warm group that means
    the warm-start counters (loads skipped through cross-group residency),
    not a cold estimate.  ``warm_weight_bytes_saved`` is the group's total
    weight bytes *not* loaded because of warmth alone — the cold-minus-warm
    modelled loads, separating the cross-group saving from the intra-order
    prefix sharing already counted in ``stats.weight_bytes_skipped``.  With
    ``group_size == 1`` and a cold engine everything reduces to the original
    single-request semantics.
    """

    outputs: Dict[int, jax.Array]
    stats: ExecutionStats
    order: Tuple[int, ...]
    predicted_seconds: float
    group_size: int = 1
    warm_weight_bytes_saved: float = 0.0


class MultitaskEngine:
    """Antler end-to-end: ordering solved once at startup, executor reused.

    ``gates``: {task: fn(outputs_so_far) -> bool} runtime conditions
    implementing conditional constraints.

    ``warm_start`` keeps the executor's weight residency across request
    groups (and across ``serve_batch`` calls): a group whose first task
    shares a prefix with the previous group's boundary task skips those
    loads entirely.  Activations are always invalidated at group boundaries
    — they belong to the previous group's inputs — so outputs are identical
    to cold-per-group serving.  ``group_ordering`` sequences the planned
    groups by the cost model's warm boundary costs (see
    ``repro.serving.batching.order_groups``); neither flag changes results,
    only how much gets loaded.
    """

    def __init__(
        self,
        program: MultitaskProgram,
        constraints: Optional[Constraints] = None,
        hw: HardwareModel = TPU_V5E,
        gates: Optional[Dict[int, Callable[[Dict[int, jax.Array]], bool]]] = None,
        order: Optional[Sequence[int]] = None,
        scheduler: Optional[RequestGroupScheduler] = None,
        warm_start: bool = True,
        group_ordering: bool = True,
    ):
        self.program = program
        self.hw = hw
        self.constraints = constraints
        self.gates = gates or {}
        self.warm_start = warm_start
        self.group_ordering = group_ordering
        self.cost_model = GraphCostModel(program.graph, program.block_costs, hw)
        if order is None:
            res = optimal_order(self.cost_model.cost_matrix(), constraints)
            order = res.order
        self.order = tuple(order)
        if constraints is not None and not constraints.is_valid_order(self.order):
            raise ValueError("supplied order violates the constraints")
        self.executor = TaskGraphExecutor(program)
        self.scheduler = scheduler or RequestGroupScheduler()
        # Cumulative counters of the most recent serve_batch call; with no
        # gates these equal predicted_group_stats(plan_groups(requests))
        # computed before that call (property-tested).
        self.last_batch_stats = ExecutionStats()

    # ------------------------------------------------------------- planning
    def plan_groups(
        self, requests: Sequence[MultitaskRequest]
    ) -> List[RequestGroup]:
        """The exact group plan ``serve_batch`` will execute, in sequence.

        Deterministic, so callers can plan, predict (via
        :meth:`predicted_group_stats`), and then serve the same requests.
        """
        use_order = self.group_ordering
        return self.scheduler.plan(
            requests,
            num_tasks=self.program.graph.num_tasks,
            cost_model=self.cost_model if use_order else None,
            task_order=self.order if use_order else None,
            initial_resident=(
                self.executor.residency_state()
                if use_order and self.warm_start else None
            ),
        )

    def predicted_group_stats(
        self, groups: Sequence[RequestGroup]
    ) -> ExecutionStats:
        """Cumulative counter prediction for serving ``groups`` in sequence.

        Warm engines carry residency group-to-group (seeded from the
        executor's *current* residency), cold engines re-predict each group
        from scratch; tasks outside a group's subset count as skipped.
        Assumes every gate fires (gate outcomes are input-dependent); with
        no gates the executor's cumulative counters match this exactly.
        """
        plan = []
        subset_skipped = 0
        for g in groups:
            eff = effective_order(self.order, g.tasks)
            subset_skipped += (len(self.order) - len(eff)) * g.valid
            plan.append((eff, g.valid))
        if self.warm_start:
            stats = self.cost_model.predicted_group_stats(
                plan, resume=self.executor.residency_state()
            )
        else:
            stats = ExecutionStats()
            for eff, b in plan:
                stats = stats.merge(
                    self.cost_model.predicted_stats(eff, batch_size=b)
                )
        stats.tasks_skipped += subset_skipped
        return stats

    def _run_group(
        self, group: RequestGroup
    ) -> Tuple[List[Dict[int, jax.Array]], ExecutionStats]:
        """Execute one homogeneous request group through the batched path.

        Gates are evaluated per request row against that row's outputs so
        far.  A task runs (batched, once) when any row's gate fires; rows
        whose gate did not fire simply drop the task's output — exact,
        because a task's output depends only on its input row.  Flop/task
        counters are weighted by the fired-row count.  With uniform gate
        outcomes this equals the sequential per-request accounting; when
        outcomes diverge within a group, a partially-fired task's cached
        activations shorten the suffix of later tasks for *every* row, so
        the group can legitimately account fewer executed flops than the
        sum of solo serves — batching does strictly less work there.
        """
        v = group.valid
        per_request: List[Dict[int, jax.Array]] = [dict() for _ in range(v)]
        stats = ExecutionStats()
        for t in self.order:
            if group.tasks is not None and t not in group.tasks:
                stats.tasks_skipped += v
                continue
            g = self.gates.get(t)
            fire = [True] * v if g is None else [bool(g(per_request[i])) for i in range(v)]
            fired = sum(fire)
            stats.tasks_skipped += v - fired
            if fired == 0:
                continue
            out = self.executor.run_task_batch(t, group.xs, stats, weight=fired)
            for i in range(v):
                if fire[i]:
                    per_request[i][t] = out[i]
        return per_request, stats

    def serve_batch(
        self, requests: Sequence[MultitaskRequest]
    ) -> List[MultitaskResponse]:
        """Serve many requests via grouped batched execution.

        The scheduler buckets requests into homogeneous padded groups (and,
        with group ordering on, sequences them by warm boundary cost); each
        group runs the block-cached executor once with every block vmapped
        over the group, so weight loads amortise across the group's
        requests.  A warm engine keeps residency between groups — only the
        input-dependent activation caches are dropped at each boundary — so
        consecutive groups sharing a prefix skip those weight loads too.
        Responses come back in submission order.
        """
        groups = self.plan_groups(requests)
        responses: List[Optional[MultitaskResponse]] = [None] * len(requests)
        self.last_batch_stats = ExecutionStats()
        for group in groups:
            if self.warm_start:
                # Warm boundary: keep residency, never the previous group's
                # activations (they belong to different inputs).
                self.executor.clear_activations()
            else:
                self.executor.reset()  # cold per group (reference semantics)
            eff = effective_order(self.order, group.tasks)
            warm_saved = 0.0
            if self.warm_start:
                warm_pred = self.cost_model.predicted_stats(
                    eff, batch_size=group.valid,
                    resume=self.executor.residency_state(),
                )
                cold_pred = self.cost_model.predicted_stats(
                    eff, batch_size=group.valid
                )
                warm_saved = (
                    cold_pred.weight_bytes_loaded - warm_pred.weight_bytes_loaded
                )
            per_request, stats = self._run_group(group)
            self.last_batch_stats = self.last_batch_stats.merge(stats)
            # Per-request share of the group's cost as executed (warm stats
            # for a warm group) — not a cold-group estimate.
            per_req_seconds = stats.seconds(self.hw) / max(group.valid, 1)
            for slot, idx in enumerate(group.indices):
                responses[idx] = MultitaskResponse(
                    outputs=per_request[slot],
                    # Own copy per response: group-mates must not share a
                    # mutable counter object.
                    stats=dataclasses.replace(stats),
                    order=self.order,
                    predicted_seconds=per_req_seconds,
                    group_size=group.valid,
                    warm_weight_bytes_saved=warm_saved,
                )
        assert all(r is not None for r in responses)
        return responses  # type: ignore[return-value]

    def serve(self, request: MultitaskRequest) -> MultitaskResponse:
        return self.serve_batch([request])[0]

    def serve_many(self, requests: Sequence[MultitaskRequest]) -> List[MultitaskResponse]:
        return self.serve_batch(list(requests))


# --------------------------------------------------------------------------
# LM serving
# --------------------------------------------------------------------------

class LMServer:
    """Batched prefill + greedy decode for any architecture in the zoo."""

    def __init__(self, model: ModelApi, params: Any,
                 policy: ShardingPolicy = TP_POLICY, max_len: int = 512):
        self.model = model
        self.params = params
        self.policy = policy
        self.max_len = max_len
        self._prefill = jax.jit(
            lambda p, batch: model.prefill(p, batch, policy)
        )
        self._step = jax.jit(
            lambda p, tok, cache, n: model.decode_step(p, tok, cache, n, policy)
        )

    def generate(
        self, prompts: jax.Array, steps: int,
        features: Optional[jax.Array] = None,
    ) -> np.ndarray:
        """Greedy generation.  prompts: (B, S0) int32.  Returns (B, steps)."""
        cfg = self.model.cfg
        b, s0 = prompts.shape
        total = s0 + steps
        # Allocate a cache with full capacity, prefill into its prefix.
        if cfg.family == "encdec":
            batch = {"features": features, "tokens": prompts}
        else:
            batch = prompts
        logits, cache = self._prefill(self.params, batch)
        # Grow the prefill cache to full capacity (KV families only).
        cache = _grow_cache(self.model, cache, total, s0)
        out = []
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        cache_len = jnp.asarray(s0, jnp.int32)
        for _ in range(steps):
            out.append(np.asarray(jax.device_get(tok)))
            logits, cache = self._step(self.params, tok, cache, cache_len)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            cache_len = cache_len + 1
        return np.stack(out, axis=1)


def _grow_cache(model: ModelApi, cache: Any, total: int, filled: int) -> Any:
    """Pad a prefill-sized KV cache out to ``total`` slots."""
    from repro.models.cache import EncDecCache, HybridCache, KVCache, SSMCache

    def grow_kv(kv: KVCache) -> KVCache:
        t = kv.k.shape[2]
        if t >= total:
            return kv
        pad = [(0, 0)] * kv.k.ndim
        pad[2] = (0, total - t)
        return KVCache(k=jnp.pad(kv.k, pad), v=jnp.pad(kv.v, pad))

    if isinstance(cache, KVCache):
        cfg = model.cfg
        if cfg.sliding_window is not None:
            # SWA ring never needs more than ``window`` slots; prefill's
            # linear layout (positions < window) is already ring-consistent.
            total = min(total, cfg.sliding_window)
        return grow_kv(cache)
    if isinstance(cache, SSMCache):
        return cache
    if isinstance(cache, HybridCache):
        return HybridCache(ssm=cache.ssm, kv=grow_kv(cache.kv))
    if isinstance(cache, EncDecCache):
        return EncDecCache(
            self_kv=grow_kv(cache.self_kv),
            cross_k=cache.cross_k, cross_v=cache.cross_v,
        )
    return cache
