"""Serving engines.

* :class:`MultitaskEngine` — the Antler runtime: a task graph + optimal
  order + the block-cached executor, serving batched requests that each want
  some subset of the task set.  Conditional constraints become runtime gates
  (a dependent task is skipped when its prerequisite's outcome says so),
  which is exactly the paper's audio deployment (presence detector gating
  the other four classifiers).
* :class:`LMServer` — prefill + greedy decode loop over a
  :class:`~repro.models.registry.ModelApi` with a batched KV cache; used by
  the decode-shape dry-runs and the serving example.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import (
    TYPE_CHECKING, Any, Callable, Dict, FrozenSet, List, Optional, Sequence,
    Tuple,
)

import jax
import jax.numpy as jnp
import numpy as np

from repro.adaptive.gate_model import GateModel, GateModelCalibrator
from repro.adaptive.gating import BlockGater
from repro.adaptive.policy import AdaptivePolicy
from repro.core.constraints import Constraints
from repro.core.cost_model import CheckpointSite, GraphCostModel
from repro.core.executor import MultitaskProgram, TaskGraphExecutor
from repro.core.ordering import optimal_order, solve_suborder
from repro.core.types import (
    ExecutionStats, HardwareModel, TPU_V5E, TaskGateRecord,
)
from repro.models.registry import ModelApi
from repro.serving.batching import (
    RequestGroup, RequestGroupScheduler, effective_order, normalize_subset,
)
from repro.serving.policies import EnginePolicy
from repro.sharding.policy import ShardingPolicy, TP_POLICY

if TYPE_CHECKING:  # session imports engine; keep the runtime import lazy
    from repro.serving.journal import Journal
    from repro.serving.policies import SchedulingPolicy
    from repro.serving.reliability import FaultInjector, PowerFailureInjector
    from repro.serving.session import ServingSession


@dataclasses.dataclass
class MultitaskRequest:
    """One inference request: an input, the tasks it wants, and its SLOs.

    The SLO fields are advisory metadata the *session* layer acts on; the
    engine's execution path ignores them (they never change what computes):

    ``deadline`` is an absolute time on the session's clock by which the
    request must have been admitted for planning — a pump finding it overdue
    fails its future with :class:`~repro.serving.reliability.DeadlineExceeded`
    instead of planning it.  ``priority`` orders load shedding (higher wins)
    when a bounded session queue overflows.  ``tenant`` labels the request
    for per-tenant quota and admission-wait accounting.
    """

    x: Any
    tasks: Optional[Sequence[int]] = None  # None = all tasks
    deadline: Optional[float] = None       # session-clock absolute seconds
    priority: int = 0                      # higher survives shedding longer
    tenant: Optional[str] = None           # quota / wait-accounting label


@dataclasses.dataclass
class MultitaskResponse:
    """Engine reply for one request.

    ``stats`` are the counters of the *execution group* the request was
    served in (``group_size`` requests share one batched pass, so loads
    amortise); each response in a group carries its **own**
    ``dataclasses.replace`` copy, so group-mates never share a mutable
    counter object.  ``predicted_seconds`` is this request's per-request
    share of the group's cost **as it actually ran** — for a warm group
    that means the warm-start counters (loads skipped through cross-group
    residency), not a cold estimate.  ``warm_weight_bytes_saved`` is the
    group's total weight bytes *not* loaded because of warmth alone — the
    cold-minus-warm modelled loads, separating the cross-group saving from
    the intra-order prefix sharing already counted in
    ``stats.weight_bytes_skipped``.

    ``order`` is the engine's *global* task order (solved once at startup);
    ``effective_order`` is the sequence the request's group **actually
    ran** — the global order filtered to the group's task subset, or the
    group's re-solved per-plan order when
    ``EnginePolicy.resolve_order_per_plan`` is on.  ``stats`` always
    describe the effective order's execution, so consumers correlating
    counters with a task sequence must read ``effective_order``, not
    ``order``.  With ``group_size == 1``, a cold engine, and an all-tasks
    request, everything reduces to the original single-request semantics
    (and ``effective_order == order``).
    """

    outputs: Dict[int, jax.Array]
    stats: ExecutionStats
    order: Tuple[int, ...]
    predicted_seconds: float
    group_size: int = 1
    warm_weight_bytes_saved: float = 0.0
    effective_order: Tuple[int, ...] = ()
    # Recovery provenance (set by the session's reliability layer):
    # ``retries`` = failed attempts before the one that produced this
    # response; ``degraded`` names the fallback-ladder rung that succeeded
    # ("unfused" = per-block reference dispatch, "single_device" = off-mesh
    # fallback executor), ``None`` for the primary path.
    retries: int = 0
    degraded: Optional[str] = None
    # True when this response was rebuilt from a durable journal commit by
    # ``ServingSession.recover`` instead of produced by a live execution —
    # the exactly-once path after a power failure.
    recovered: bool = False


@dataclasses.dataclass
class IntermittentContext:
    """Journaling context threaded through one group's execution.

    Built by the session (the journal's owner) per group: ``journal`` /
    ``group_id`` let the engine's checkpoint hook write durable mid-suffix
    activation records under the group's identity, and ``checkpointing``
    turns the segmented dispatch on or off (the restart-from-scratch
    comparator arm journals begins/commits but never cuts a suffix).
    """

    journal: "Journal"
    group_id: int
    checkpointing: bool = True


@dataclasses.dataclass
class GroupExecution:
    """One executed request group — the session's unit of completed work.

    ``outputs`` holds the per-slot (valid rows only) task outputs;
    ``stats`` the executed counters of this group alone; ``predicted`` the
    cost model's prediction for the same group computed from the executor's
    residency immediately before execution (the incremental form of
    ``predicted_group_stats`` — merging the per-group predictions of a
    schedule equals the one-shot prediction of the whole schedule).
    ``predicted`` is conditioned on ``gate_trace``, the realized per-task
    gate outcomes of the execution (legacy ``gate=`` skips and adaptive
    per-block fire counts), which is what keeps ``stats == predicted``
    field-exact even for gated/adaptive groups.  ``expected`` is the
    *a-priori* expected-counter prediction under the engine's
    :class:`~repro.adaptive.gate_model.GateModel` — computed before
    execution, without peeking at the trace — or ``None`` when the engine
    is not adaptive.
    """

    group: RequestGroup
    eff: Tuple[int, ...]
    outputs: List[Dict[int, jax.Array]]
    stats: ExecutionStats
    predicted: ExecutionStats
    warm_saved: float
    expected: Optional[ExecutionStats] = None
    gate_trace: Optional[List[TaskGateRecord]] = None


class MultitaskEngine:
    """Antler end-to-end: ordering solved once at startup, executor reused.

    ``gates``: {task: fn(outputs_so_far) -> bool} runtime conditions
    implementing conditional constraints.

    Everything schedule-shaped is configured through one
    :class:`~repro.serving.policies.EnginePolicy` value (``policy``):

    * ``policy.warm_start`` keeps the executor's weight residency across
      request groups (and across ``serve_batch`` calls): a group whose
      first task shares a prefix with the previous group's boundary task
      skips those loads entirely.  Activations are always invalidated at
      group boundaries — they belong to the previous group's inputs — so
      outputs are identical to cold-per-group serving.
    * ``policy.group_ordering`` sequences the planned groups by the cost
      model's warm boundary costs (``repro.serving.batching.order_groups``).
    * ``policy.resolve_order_per_plan`` re-solves each group's *internal*
      task order seeded with the residency the engine will have when the
      group runs (see :meth:`plan_groups`).
    * ``policy.scheduling`` is the admission policy sessions (and the
      one-shot wrappers' internal sessions) run under.
    * ``policy.adaptive`` turns on input-adaptive execution: the executor
      gains a per-row confidence gater (early exit / per-block gating
      inside the fused suffixes), the cost model an expected-counter gate
      model the order solvers optimize, and sessions a deadline-ladder
      threshold knob.  ``gate_deps`` (or conditional constraint edges)
      declare which outputs each legacy runtime gate reads, which makes
      per-plan order re-solving sound for gated engines.

    None of these change results, only how much gets loaded.  The
    ``warm_start`` / ``group_ordering`` / ``scheduler`` keyword arguments
    are retained as conveniences that override the corresponding
    ``EnginePolicy`` field.

    Long-lived serving goes through :meth:`session` (async admission,
    futures, planning overlapped with execution); ``serve`` /
    ``serve_batch`` are thin wrappers that run a one-shot session.
    """

    def __init__(
        self,
        program: MultitaskProgram,
        constraints: Optional[Constraints] = None,
        hw: HardwareModel = TPU_V5E,
        gates: Optional[Dict[int, Callable[[Dict[int, jax.Array]], bool]]] = None,
        gate_deps: Optional[Dict[int, Sequence[int]]] = None,
        order: Optional[Sequence[int]] = None,
        scheduler: Optional[RequestGroupScheduler] = None,
        warm_start: Optional[bool] = None,
        group_ordering: Optional[bool] = None,
        policy: Optional[EnginePolicy] = None,
        fault_injector: Optional["FaultInjector"] = None,
        power_injector: Optional["PowerFailureInjector"] = None,
    ):
        self.program = program
        self.hw = hw
        self.constraints = constraints
        self.gates = gates or {}
        policy = policy if policy is not None else EnginePolicy()
        overrides: Dict[str, Any] = {}
        if warm_start is not None:
            overrides["warm_start"] = bool(warm_start)
        if group_ordering is not None:
            overrides["group_ordering"] = bool(group_ordering)
        if scheduler is not None:
            overrides["scheduler"] = scheduler
        if overrides:
            policy = dataclasses.replace(policy, **overrides)
        if policy.scheduler is None:
            # Fold the default in so engine.policy alone reconstructs the
            # engine's full scheduling behavior.
            policy = dataclasses.replace(
                policy, scheduler=RequestGroupScheduler()
            )
        self.mesh = policy.mesh
        self.sharding: Optional[ShardingPolicy] = (
            policy.sharding if policy.sharding is not None
            else (TP_POLICY if self.mesh is not None else None)
        )
        self.data_shards = (
            self.sharding.data_shards(self.mesh) if self.sharding else 1
        )
        self.weight_shards = (
            self.sharding.weight_shards(self.mesh) if self.sharding else 1
        )
        if self.data_shards > 1 and any(
            s % self.data_shards for s in policy.scheduler.batch_shapes
        ):
            # Fold the mesh's per-shard multiple into the scheduler so every
            # padded group splits evenly over the batch axes.
            policy = dataclasses.replace(
                policy,
                scheduler=RequestGroupScheduler(
                    batch_shapes=policy.scheduler.batch_shapes,
                    shard_multiple=self.data_shards,
                ),
            )
        if policy.streaming and not policy.warm_start:
            raise ValueError(
                "EnginePolicy.streaming requires warm_start: a cold engine "
                "resets the executor before every group, which cancels any "
                "staged prefetch — nothing could ever stream"
            )
        self.policy = policy
        # -------------------------------------------- input-adaptive gating
        self.adaptive: Optional[AdaptivePolicy] = policy.adaptive
        self._gater: Optional[BlockGater] = None
        self._calibrator: Optional[GateModelCalibrator] = None
        if self.adaptive is not None:
            self._gater = BlockGater(
                confidence_fn=self.adaptive.confidence,
                mode=self.adaptive.mode,
                threshold=float(self.adaptive.threshold),
                min_blocks=self.adaptive.min_blocks,
            )
            if self.adaptive.calibrate_online:
                self._calibrator = GateModelCalibrator()
        # Which tasks each runtime gate reads: {gated_task: (input_tasks,)}.
        # Declared deps make gates safe under per-plan order re-solving (the
        # inputs become precedence edges of the re-solve).  When not given
        # explicitly, derived from the conditional constraint edges — the
        # paper's gates *are* conditional constraints acted on at runtime.
        self.gate_deps: Dict[int, Tuple[int, ...]] = {}
        if gate_deps is not None:
            self.gate_deps = {
                int(t): tuple(int(i) for i in deps)
                for t, deps in gate_deps.items()
            }
        elif constraints is not None and self.gates:
            for t in self.gates:
                deps = tuple(sorted(
                    i for (i, j, _p) in constraints.conditional if j == t
                ))
                if deps:
                    self.gate_deps[t] = deps
        self._plan_constraints = self._build_plan_constraints(
            program.graph.num_tasks, constraints
        )
        self.cost_model = GraphCostModel(
            program.graph, program.block_costs, hw,
            weight_shards=self.weight_shards,
            gate_model=(
                self.adaptive.gate_model if self.adaptive is not None else None
            ),
        )
        self._cost_matrix = self.cost_model.cost_matrix()
        # Lazy per-plan re-solve matrix (expected costs when a gate model or
        # conditional constraints exist); dirtied by online calibration.
        self._resolve_mat: Optional[np.ndarray] = None
        if order is None:
            # optimal_order applies the Eq.-8 conditional weighting itself,
            # so the matrix folds in only the *adaptive* gate model here —
            # folding the constraints' probabilities too would double-count.
            init_matrix = (
                self.cost_model.expected_cost_matrix()
                if self.cost_model.gate_model is not None
                else self._cost_matrix
            )
            res = optimal_order(init_matrix, constraints)
            order = res.order
        self.order = tuple(order)
        if constraints is not None and not constraints.is_valid_order(self.order):
            raise ValueError("supplied order violates the constraints")
        if (
            self._plan_constraints is not None
            and not self._plan_constraints.is_valid_order(self.order)
        ):
            raise ValueError(
                "gate_deps edges conflict with the engine's task order: a "
                "gate would read an output its order produces later"
            )
        self.executor = TaskGraphExecutor(
            program, mesh=self.mesh, sharding=self.sharding,
            gater=self._gater,
        )
        # Deterministic chaos hook (see repro.serving.reliability): when
        # set, ``check`` is called at the plan/load/dispatch boundaries and
        # may raise.  Mutable on purpose — the chaos harness arms and
        # disarms it around specific traces.
        self.fault_injector = fault_injector
        # Whole-session power-failure hook (intermittent computing; see
        # repro.serving.reliability.PowerFailureInjector).  Checked at the
        # "group" / "suffix" / "prefetch" sites; raises PowerFailure — a
        # BaseException the session's retry machinery never absorbs.  Like
        # the fault injector, mutable on purpose; unlike it, the instance
        # should live *outside* the session so its schedule survives the
        # reboots it causes.
        self.power_injector = power_injector
        # Lazily built off-mesh executor for the degradation ladder's
        # "single_device" rung (mesh engines only; see execute_group_fallback).
        self._fallback_executor: Optional[TaskGraphExecutor] = None
        # Cumulative counters of the most recent serve_batch call; with no
        # gates and the default greedy scheduling these equal
        # predicted_group_stats(plan_groups(requests)) computed before that
        # call (property-tested; non-greedy policies admit in rounds, each
        # planned separately — see plan_groups).
        self.last_batch_stats = ExecutionStats()

    # Schedule flags read through the policy so there is exactly one source
    # of truth for "how this engine schedules".
    @property
    def warm_start(self) -> bool:
        return self.policy.warm_start

    @property
    def group_ordering(self) -> bool:
        return self.policy.group_ordering

    @property
    def streaming(self) -> bool:
        return self.policy.streaming

    @property
    def scheduler(self) -> RequestGroupScheduler:
        return self.policy.scheduler

    def normalized_subset(
        self, tasks: Optional[Sequence[int]]
    ) -> Optional[FrozenSet[int]]:
        """A request's task subset in the scheduler's bucket-key form:
        ``None`` for all-tasks (explicit or implicit), a frozenset else —
        the same :func:`~repro.serving.batching.normalize_subset` the
        scheduler buckets by, so policies score the groups that will form."""
        return normalize_subset(tasks, self.program.graph.num_tasks)

    def session(
        self,
        policy: Optional["SchedulingPolicy"] = None,
        clock: Optional[Callable[[], float]] = None,
        **kwargs: Any,
    ) -> "ServingSession":
        """Open a :class:`~repro.serving.session.ServingSession` on this
        engine (``policy`` defaults to ``self.policy.scheduling``).  Extra
        keyword arguments — ``max_pending``, ``overload``, ``retry``, … —
        forward to the session constructor."""
        from repro.serving.session import ServingSession

        return ServingSession(self, policy=policy, clock=clock, **kwargs)

    # ------------------------------------------------------------- planning
    def _build_plan_constraints(
        self, num_tasks: int, constraints: Optional[Constraints]
    ) -> Optional[Constraints]:
        """Constraints for per-plan re-solving: the engine's own, plus one
        precedence edge per declared gate input so a re-solved order can
        never move a gated task ahead of an output its gate reads."""
        edges = {
            (i, t) for t, deps in self.gate_deps.items() for i in deps
        }
        base = constraints.precedence if constraints is not None else frozenset()
        if not (edges - set(base)):
            return constraints
        return Constraints.make(
            num_tasks,
            precedence=set(base) | edges,
            conditional=(
                constraints.conditional if constraints is not None else ()
            ),
        )

    def _planning_gate_model(self) -> Optional[GateModel]:
        """The gate model per-plan re-solves price costs with.

        ``solve_suborder`` rebuilds precedence-only constraints, so the
        conditional constraints' Eq.-8 execution probabilities would be
        dropped on the floor — fold them into the gate model's task
        probabilities instead.  A *calibrated* (adaptive) task probability
        wins over the constraints' prior where both exist: it is the same
        quantity, measured rather than assumed.
        """
        gm = self.cost_model.gate_model
        if self.constraints is None or not self.constraints.conditional:
            return gm
        cgm = GateModel.from_constraints(self.constraints)
        if gm is None:
            return cgm
        task_fire = dict(cgm.task_fire)
        task_fire.update(gm.task_fire)
        return GateModel(fire=dict(gm.fire), task_fire=task_fire)

    def _resolve_matrix(self) -> np.ndarray:
        """Switching-cost matrix for per-plan re-solving: expected costs
        when any probability surface exists (adaptive gate model and/or
        conditional constraints), the exact matrix otherwise.  Cached;
        online calibration dirties the cache."""
        if self._resolve_mat is None:
            gm = self._planning_gate_model()
            self._resolve_mat = (
                self.cost_model.expected_cost_matrix(gm)
                if gm is not None else self._cost_matrix
            )
        return self._resolve_mat

    def plan_groups(
        self, requests: Sequence[MultitaskRequest]
    ) -> List[RequestGroup]:
        """The group plan one admitted planning batch over ``requests`` runs.

        Deterministic, so callers can plan, predict (via
        :meth:`predicted_group_stats`), and then serve the same requests.
        Note the plan/predict/serve equality is per *planning batch*: under
        the default :class:`GreedyBatchPolicy` a one-shot serve admits the
        whole request list as one batch, so ``plan_groups(requests)`` is
        exactly what ``serve_batch(requests)`` executes — but a windowed or
        affinity ``policy.scheduling`` admits in several policy-chosen
        rounds, each planned separately, so predict each round's admitted
        requests (as sessions do internally) rather than the full list.
        With ``policy.resolve_order_per_plan`` on, each group's internal
        task order is re-solved here (after group sequencing) and recorded
        on ``RequestGroup.order``, so planning, prediction, and execution
        all see the same per-plan orders.
        """
        use_order = self.group_ordering
        groups = self.scheduler.plan(
            requests,
            num_tasks=self.program.graph.num_tasks,
            cost_model=self.cost_model if use_order else None,
            task_order=self.order if use_order else None,
            initial_resident=(
                self.executor.residency_state()
                if use_order and self.warm_start else None
            ),
        )
        if self.policy.resolve_order_per_plan and all(
            t in self.gate_deps for t in self.gates
        ):
            # Gates are order-sensitive (a gate reads the outputs produced
            # so far), so re-solving requires every gate's inputs to be
            # declared (gate_deps) — they become precedence edges of the
            # re-solve, which keeps each gate's inputs ahead of it in any
            # solved order.  Conditional-probability constraints and
            # adaptive gate models are handled by pricing the re-solve with
            # the *expected* cost matrix (see _resolve_matrix), so the
            # per-plan orders optimize the same probability-weighted
            # objective (Eq. 8) as the global solve.
            groups = self._resolve_plan_orders(groups)
        return groups

    def group_order(self, group: RequestGroup) -> Tuple[int, ...]:
        """The task sequence ``group`` executes: its re-solved per-plan
        order when one was recorded, else the global order filtered to the
        group's subset."""
        if group.order is not None:
            return tuple(group.order)
        return tuple(effective_order(self.order, group.tasks))

    def _resolve_plan_orders(
        self, groups: Sequence[RequestGroup]
    ) -> List[RequestGroup]:
        """Residency-aware per-plan task-order re-solving.

        The global order is solved once, cold, over the full task set; a
        group serving only a subset — warm from whatever ran before — can
        have a strictly cheaper internal order.  Walking the planned groups
        in execution sequence, each group's subset is re-solved
        (:func:`repro.core.ordering.solve_suborder`) over the engine's
        switching-cost matrix with a virtual start node whose edges are the
        residency-conditioned entry loads (``resume_load_cost``), then the
        simulated residency advances to what executing that order leaves
        behind.  Outputs are order-independent (every task's output depends
        only on its input and path), so this changes loads, never results.

        Deliberately runs *after* ``order_groups``: inter-group sequencing
        and intra-group re-solving are mutually dependent (the boundary
        TSP needs each group's entry/exit task, the re-solve needs the
        execution sequence to carry residency), so we fix the sequence
        first using the filtered global orders as boundary estimates, then
        refine each group's interior for the residency that sequence
        actually produces.  (``order_groups`` itself honors a pre-set
        ``RequestGroup.order`` for callers that re-sequence resolved
        plans.)
        """
        depth = self.program.graph.depth
        resident = (
            self.executor.residency_state() if self.warm_start
            else (None,) * depth
        )
        matrix = self._resolve_matrix()
        gm = self._planning_gate_model()
        out: List[RequestGroup] = []
        for group in groups:
            eff = effective_order(self.order, group.tasks)
            if len(eff) > 1:
                start = [
                    self.cost_model.expected_resume_load_cost(
                        resident, t, gate_model=gm
                    )
                    for t in eff
                ]
                solved = solve_suborder(
                    matrix, eff,
                    start_costs=start, constraints=self._plan_constraints,
                )
                group = dataclasses.replace(group, order=tuple(solved))
            out.append(group)
            if self.warm_start:
                resident = self.cost_model.residency_after(
                    self.group_order(group), resident
                )
            # Cold engines reset before every group: the virtual start sees
            # an empty slate each time, so ``resident`` stays all-None.
        return out

    def predicted_group_stats(
        self, groups: Sequence[RequestGroup]
    ) -> ExecutionStats:
        """Cumulative counter prediction for serving ``groups`` in sequence.

        Warm engines carry residency group-to-group (seeded from the
        executor's *current* residency), cold engines re-predict each group
        from scratch; tasks outside a group's subset count as skipped, and
        a group's re-solved per-plan order (when present) is predicted in
        place of the filtered global order.  Assumes every gate fires (gate
        outcomes are input-dependent); with no gates the executor's
        cumulative counters match this exactly.
        """
        predictor = self.cost_model.plan_predictor(
            resume=(
                self.executor.residency_state() if self.warm_start else None
            ),
            carry_residency=self.warm_start,
        )
        for g in groups:
            eff = self.group_order(g)
            predictor.append(
                eff, batch_size=g.valid,
                extra_tasks_skipped=(len(self.order) - len(eff)) * g.valid,
                collectives=(
                    self.executor.collective_view(g.xs)
                    if self.mesh is not None else None
                ),
            )
        return predictor.stats

    def expected_group_stats(
        self, groups: Sequence[RequestGroup]
    ) -> ExecutionStats:
        """Expected-counter analogue of :meth:`predicted_group_stats`:
        FLOP/task counters weighted by the cost model's gate model (fire
        and task-execution probabilities) instead of the all-gates-fire
        floor.  With no gate model this equals
        :meth:`predicted_group_stats` exactly; with a calibrated one it is
        the mean the realized counters converge to over traffic drawn from
        the calibration distribution."""
        predictor = self.cost_model.plan_predictor(
            resume=(
                self.executor.residency_state() if self.warm_start else None
            ),
            carry_residency=self.warm_start,
        )
        gm = (
            (self.cost_model.gate_model or GateModel())
            if self.adaptive is not None else None
        )
        for g in groups:
            eff = self.group_order(g)
            predictor.append(
                eff, batch_size=g.valid,
                extra_tasks_skipped=(len(self.order) - len(eff)) * g.valid,
                collectives=(
                    self.executor.collective_view(g.xs)
                    if self.mesh is not None else None
                ),
                gate_model=gm,
            )
        return predictor.expected

    # ------------------------------------------------------------ execution
    def _inject(self, site: str, **context: Any) -> None:
        """Fault-injection hook: delegates to :attr:`fault_injector` when
        armed (see ``repro.serving.reliability.FaultInjector``); a no-op
        otherwise.  Sites sit at boundaries where an injected exception is
        indistinguishable from a real one to the session's rollback/retry
        machinery."""
        if self.fault_injector is not None:
            self.fault_injector.check(site, **context)

    def _power(self, site: str, **context: Any) -> None:
        """Power-failure hook: delegates to :attr:`power_injector` when
        armed; a no-op otherwise.  Unlike :meth:`_inject`, a firing site
        raises a ``BaseException`` that kills the whole session — the
        recovery story is the durable journal, not the retry ladder."""
        if self.power_injector is not None:
            self.power_injector.check(site, **context)

    def _run_group(
        self,
        group: RequestGroup,
        eff: Sequence[int],
        executor: Optional[TaskGraphExecutor] = None,
        intermittent: Optional[IntermittentContext] = None,
        ckpt_plan: Optional[Sequence["CheckpointSite"]] = None,
    ) -> Tuple[List[Dict[int, jax.Array]], ExecutionStats,
               List[TaskGateRecord]]:
        """Execute one homogeneous request group through the batched path.

        ``eff`` is the group's execution order (see :meth:`group_order`);
        ``executor`` defaults to the engine's own (the degradation ladder
        passes the off-mesh fallback executor instead).  Gates are evaluated
        per request row against that row's outputs so far.  A task runs
        (batched, once) when any row's gate fires; rows whose gate did not
        fire simply drop the task's output — exact, because a task's output
        depends only on its input row.  Flop/task counters are weighted by
        the fired-row count.  With uniform gate outcomes this equals the
        sequential per-request accounting; when outcomes diverge within a
        group, a partially-fired task's cached activations shorten the
        suffix of later tasks for *every* row, so the group can legitimately
        account fewer executed flops than the sum of solo serves — batching
        does strictly less work there.

        The third return value is the group's realized gate trace: one
        :class:`~repro.core.types.TaskGateRecord` per task of ``eff``, in
        execution order — weight-0 records for tasks every row's gate
        skipped, per-block fired-row counts when the executor carries an
        adaptive gater.  ``offered`` is always the group's valid count, so
        the trace is what :class:`~repro.adaptive.gate_model.\
GateModelCalibrator` consumes and what
        ``GraphCostModel.predicted_stats(..., gate_trace=...)`` replays to
        reproduce ``stats`` field-exactly.
        """
        ex = executor if executor is not None else self.executor
        v = group.valid
        per_request: List[Dict[int, jax.Array]] = [dict() for _ in range(v)]
        stats = ExecutionStats()
        stats.tasks_skipped += (len(self.order) - len(eff)) * v
        trace: List[TaskGateRecord] = []
        for t in eff:
            g = self.gates.get(t)
            fire = [True] * v if g is None else [bool(g(per_request[i])) for i in range(v)]
            fired = sum(fire)
            stats.tasks_skipped += v - fired
            if fired == 0:
                trace.append(TaskGateRecord(task=t, weight=0, offered=v))
                continue
            self._inject("dispatch", task=t, group_tasks=group.tasks)
            if intermittent is not None:
                # ``stats`` rides along so a crash's PowerFailure carries
                # the partial (about-to-be-lost) counters — the benchmark's
                # re-executed-energy accounting reads them off the context.
                self._power(
                    "group", task=t, group_id=intermittent.group_id,
                    group_tasks=group.tasks, stats=stats,
                )
            row_mask = None
            if ex.gater is not None:
                # Realized-fire accounting must ignore padded rows and rows
                # whose legacy gate kept them out of this task.
                row_mask = np.zeros(int(group.xs.shape[0]), dtype=bool)
                row_mask[:v] = fire
            sites = [s for s in (ckpt_plan or ()) if s.task == t]
            if sites and intermittent is not None:
                hook = self._checkpoint_hook(
                    ex, stats, intermittent, t, sites, fired,
                )
                out = ex.run_task_batch(
                    t, group.xs, stats, weight=fired,
                    checkpoint_depths=[s.depth for s in sites],
                    checkpoint_hook=hook, row_mask=row_mask,
                )
            else:
                out = ex.run_task_batch(
                    t, group.xs, stats, weight=fired, row_mask=row_mask
                )
            if ex.last_gate_record is not None:
                trace.append(dataclasses.replace(
                    ex.last_gate_record, offered=v
                ))
            for i in range(v):
                if fire[i]:
                    per_request[i][t] = out[i]
        return per_request, stats, trace

    def _checkpoint_hook(
        self,
        ex: TaskGraphExecutor,
        stats: ExecutionStats,
        intermittent: IntermittentContext,
        task: int,
        sites: Sequence[CheckpointSite],
        weight: int = 1,
    ) -> Callable[[int], None]:
        """Build the commit-point callback for one task's segmented suffix.

        Fired by the executor right after the block at a planned depth has
        executed: journal the freshly cached activation durably, account the
        write with the *planned* site's bytes/seconds (the same values
        :meth:`GraphCostModel.predicted_stats` adds from the same plan — the
        counter-exactness invariant extended to checkpoints), then give the
        power injector its "suffix" site — a failure here dies *after* the
        durable write, which is exactly what makes the checkpoint useful.
        """
        by_depth = {s.depth: s for s in sites}

        def hook(depth: int) -> None:
            site = by_depth[depth]
            ck = ex.activation_checkpoint(task)
            if ck is not None:
                intermittent.journal.checkpoint(
                    intermittent.group_id, site.pos, task,
                    ck.depth, ck.node, ck.value, ck.act_shape,
                )
            stats.checkpoint_bytes += site.bytes
            stats.checkpoint_seconds += site.seconds
            # ``weight`` lets a crash's consumer correct the task's upfront
            # flop accounting down to the blocks actually executed by
            # ``depth`` — the executor charges a task's whole suffix to
            # ``stats`` before dispatching it.
            self._power(
                "suffix", task=task, depth=depth,
                group_id=intermittent.group_id, stats=stats, weight=weight,
            )

        return hook

    def prefetch_group(
        self, group: RequestGroup, overlap_seconds: float = 0.0
    ) -> float:
        """Stage the next group's weight stream; returns the bytes scheduled.

        The prefetch schedule comes for free from the cost model:
        ``plan_loads`` over the group's execution order and the executor's
        *current* residency is exactly the load set ``_execute_group`` will
        account, so staging it makes the executor's ``prefetched_bytes``
        equal that group's ``weight_bytes_loaded`` by construction.  JAX
        dispatch is asynchronous, so the ``device_put`` transfers issued
        here overlap with whatever previously dispatched group is still
        executing on the device — ``overlap_seconds`` is that group's
        modelled compute window, and whatever load time exceeds it is
        staged alongside as the batch's modelled stall
        (``GraphCostModel.prefetch_stall_seconds``).

        Returns ``0.0`` without staging when the group needs no loads.
        Raising (including an injected ``"prefetch"`` fault) leaves any
        previously staged batch untouched; callers degrade to synchronous
        loading.
        """
        self._inject("prefetch", group_tasks=group.tasks, valid=group.valid)
        self._power("prefetch", group_tasks=group.tasks, valid=group.valid)
        eff = self.group_order(group)
        loads = self.cost_model.plan_loads(
            eff, self.executor.residency_state()
        )
        if not loads:
            return 0.0
        stall = self.cost_model.prefetch_stall_seconds(
            [d for d, _node in loads], overlap_seconds
        )
        self.executor.streamer.stage(loads, stall_seconds=stall)
        return float(sum(
            self.program.block_costs[d].weight_bytes for d, _node in loads
        ))

    def _execute_group(
        self,
        group: RequestGroup,
        intermittent: Optional[IntermittentContext] = None,
        first_task_resume: int = 0,
        keep_activations: bool = False,
        adaptive_threshold: Optional[float] = None,
    ) -> GroupExecution:
        """Run one planned group; the session's execution primitive.

        Handles the warm/cold group boundary (keep residency and drop
        activations, or full reset), computes the group's cost prediction
        from the executor's *actual* residency right before execution (the
        incremental-prediction contract sessions rely on), executes, and
        returns everything a response needs — without building responses,
        so the session can defer future resolution behind the next group's
        planning.

        The counter prediction is computed *after* execution, conditioned
        on the realized gate trace — it still uses only the pre-execution
        residency (captured before the run), so the incremental-prediction
        contract is unchanged, and for ungated non-adaptive engines the
        trace is all-fire and the result is identical to the historical
        pre-execution prediction.  An adaptive engine additionally computes
        ``expected``, the a-priori expected-counter prediction under the
        cost model's gate model, *before* the run (it must not peek).

        ``adaptive_threshold`` overrides the gater's confidence threshold
        for this group (the session's deadline-ladder rung); thresholds are
        runtime scan inputs, so this never retraces a compiled program.

        ``intermittent`` (journal + group id) selects the power-failure-
        atomic path: the cost model places mid-suffix checkpoints
        (:meth:`GraphCostModel.plan_checkpoints`) and execution journals
        each one at the matching segment boundary.  ``first_task_resume`` /
        ``keep_activations`` serve crash recovery: a group resuming from a
        restored activation checkpoint at depth ``d`` enters with
        ``first_task_resume=d+1`` and must *not* clear the activation cache
        at the boundary — the restored checkpoint is the whole point.
        """
        self._inject("plan", group_tasks=group.tasks, valid=group.valid)
        if keep_activations:
            # Crash recovery: residency and the restored checkpoint were
            # seeded by ``ServingSession.recover`` — touch neither.
            pass
        elif self.warm_start:
            # Warm boundary: keep residency, never the previous group's
            # activations (they belong to different inputs).
            self.executor.clear_activations()
        else:
            self.executor.reset()  # cold per group (reference semantics)
        eff = self.group_order(group)
        resume = self.executor.residency_state() if self.warm_start else None
        ckpt_plan: Optional[List[CheckpointSite]] = None
        if intermittent is not None and intermittent.checkpointing:
            ckpt_plan = self.cost_model.plan_checkpoints(
                eff, batch_size=group.valid,
                first_task_resume=first_task_resume,
            )
        if self._gater is not None and adaptive_threshold is not None:
            self._gater.threshold = float(adaptive_threshold)
        expected: Optional[ExecutionStats] = None
        if self.adaptive is not None:
            # A-priori expected counters — computed before the run so it
            # provably never peeks at realized gate outcomes.  An
            # uncalibrated engine uses the *empty* gate model (all fire
            # probabilities 1.0) rather than none at all, so the fire-row
            # counters are present and the expectation degrades to the
            # all-blocks floor instead of to the non-adaptive prediction.
            expected = self.cost_model.expected_stats(
                eff, batch_size=group.valid, resume=resume,
                collectives=self.executor.collective_view(group.xs),
                first_task_resume=first_task_resume,
                checkpoints=ckpt_plan,
                gate_model=self.cost_model.gate_model or GateModel(),
            )
            expected.tasks_skipped += (
                (len(self.order) - len(eff)) * group.valid
            )
        streamer = self.executor.streamer
        # Snapshot the stream state before the run consumes staged copies.
        staged = streamer.staged_nodes()
        pending_stall = streamer.pending_stall_seconds
        self._inject("load", group_tasks=group.tasks, resume=resume)
        per_request, stats, trace = self._run_group(
            group, eff, intermittent=intermittent, ckpt_plan=ckpt_plan
        )
        stats.stream_stall_seconds += streamer.finish_group()
        # Realized-conditional prediction: replay the gate trace over the
        # *pre-execution* residency.  All-fire traces reproduce the
        # historical pre-execution prediction bit for bit; gated/adaptive
        # traces keep ``stats == predicted`` field-exact.
        predicted = self.cost_model.predicted_stats(
            eff, batch_size=group.valid, resume=resume,
            collectives=self.executor.collective_view(group.xs),
            first_task_resume=first_task_resume,
            checkpoints=ckpt_plan,
            gate_trace=trace,
        )
        warm_saved = 0.0
        if self.warm_start:
            # Collectives are resume-independent (they key on the intra-order
            # shared prefix), and warm_saved only reads the load counter —
            # the cold reference needs no collective terms.  It DOES need
            # ``first_task_resume``: the trace's resume depths come from the
            # executed walk, and a crash-recovered group resumed mid-suffix
            # — a cold-from-0 walk would reject its trace as divergent.
            cold_pred = self.cost_model.predicted_stats(
                eff, batch_size=group.valid, gate_trace=trace,
                first_task_resume=first_task_resume,
            )
            warm_saved = (
                cold_pred.weight_bytes_loaded - predicted.weight_bytes_loaded
            )
        if staged:
            # A prefetched group: the loads that hit staged copies arrived
            # over the stream, so predict them as prefetched plus the
            # staged batch's modelled stall.  For an ungated engine the
            # staged set *is* the load set (prefetch_group planned it from
            # the same residency), making this exact by construction; a
            # legacy gate that skipped a whole task drops its staged-but-
            # unused loads from both sides via the trace.
            pf_bytes = sum(
                self.program.block_costs[d].weight_bytes
                for d, node in self.cost_model.plan_loads(
                    eff, resume, gate_trace=trace
                )
                if node in staged
            )
            if pf_bytes > 0.0:
                predicted.prefetched_bytes = pf_bytes
                predicted.stream_stall_seconds = pending_stall
        predicted.tasks_skipped += (len(self.order) - len(eff)) * group.valid
        if self._calibrator is not None:
            # Online calibration: fold this group's realized trace into the
            # gate model so expected-cost planning tracks traffic drift.
            self._calibrator.observe(trace)
            self.cost_model = dataclasses.replace(
                self.cost_model, gate_model=self._calibrator.model()
            )
            self._resolve_mat = None
        return GroupExecution(
            group=group, eff=eff, outputs=per_request, stats=stats,
            predicted=predicted, warm_saved=warm_saved,
            expected=expected, gate_trace=trace,
        )

    def execute_group_fallback(
        self,
        group: RequestGroup,
        adaptive_threshold: Optional[float] = None,
    ) -> GroupExecution:
        """Degradation-ladder rung for mesh engines: run ``group`` cold on a
        lazily built single-device executor.

        The fallback executor shares the program (and therefore produces
        identical outputs) but has no mesh, so its counters carry no
        collective bytes — and its prediction, computed cold without a
        collective view from the *same* cost model, matches those counters
        field for field (``weight_shards`` only scales derived seconds,
        never the byte counters).  It is reset before every use: degraded
        runs are the rare recovery path, and a cold run keeps the primary
        executor's rolled-back residency authoritative for every subsequent
        group's incremental prediction.
        """
        if self._fallback_executor is None:
            # Shares the engine's gater (same threshold/mode object), so a
            # degraded adaptive run gates identically to the primary path.
            self._fallback_executor = TaskGraphExecutor(
                self.program, gater=self._gater
            )
        ex = self._fallback_executor
        ex.reset()
        if self._gater is not None and adaptive_threshold is not None:
            self._gater.threshold = float(adaptive_threshold)
        eff = self.group_order(group)
        expected: Optional[ExecutionStats] = None
        if self.adaptive is not None:
            expected = self.cost_model.expected_stats(
                eff, batch_size=group.valid,
                gate_model=self.cost_model.gate_model or GateModel(),
            )
            expected.tasks_skipped += (
                (len(self.order) - len(eff)) * group.valid
            )
        per_request, stats, trace = self._run_group(group, eff, executor=ex)
        predicted = self.cost_model.predicted_stats(
            eff, batch_size=group.valid, gate_trace=trace
        )
        predicted.tasks_skipped += (len(self.order) - len(eff)) * group.valid
        return GroupExecution(
            group=group, eff=eff, outputs=per_request, stats=stats,
            predicted=predicted, warm_saved=0.0,
            expected=expected, gate_trace=trace,
        )

    def _group_responses(
        self, execution: GroupExecution
    ) -> List[MultitaskResponse]:
        """Responses for one executed group, in group-slot order."""
        stats = execution.stats
        group = execution.group
        # Per-request share of the group's cost as executed (warm stats
        # for a warm group) — not a cold-group estimate.  On a mesh each
        # chip streams only its weight slice, hence the shard divisor.
        per_req_seconds = stats.seconds(
            self.hw, weight_shards=self.weight_shards
        ) / max(group.valid, 1)
        return [
            MultitaskResponse(
                outputs=execution.outputs[slot],
                # Own copy per response: group-mates must not share a
                # mutable counter object.
                stats=dataclasses.replace(stats),
                order=self.order,
                predicted_seconds=per_req_seconds,
                group_size=group.valid,
                warm_weight_bytes_saved=execution.warm_saved,
                effective_order=execution.eff,
            )
            for slot in range(group.valid)
        ]

    # ---------------------------------------------------- one-shot wrappers
    def _serve_via_session(
        self, requests: Sequence[MultitaskRequest]
    ) -> List[MultitaskResponse]:
        """One-shot session: submit everything, drain, collect in order."""
        session = self.session()
        futures = [session.submit(r) for r in requests]
        session.drain()
        self.last_batch_stats = session.stats
        return [f.result() for f in futures]

    def serve_batch(
        self, requests: Sequence[MultitaskRequest]
    ) -> List[MultitaskResponse]:
        """Serve many requests via grouped batched execution.

        A thin wrapper over a one-shot :meth:`session`: every request is
        submitted, then the session drains under the engine's scheduling
        policy (the default :class:`GreedyBatchPolicy` admits the whole
        list as one planning batch — the exact pre-session semantics).  The
        scheduler buckets requests into homogeneous padded groups (and,
        with group ordering on, sequences them by warm boundary cost); each
        group runs the block-cached executor once with every block vmapped
        over the group, so weight loads amortise across the group's
        requests.  A warm engine keeps residency between groups — only the
        input-dependent activation caches are dropped at each boundary — so
        consecutive groups sharing a prefix skip those weight loads too.
        Responses come back in submission order.
        """
        return self._serve_via_session(requests)

    def serve(self, request: MultitaskRequest) -> MultitaskResponse:
        return self.serve_batch([request])[0]

    def serve_many(self, requests: Sequence[MultitaskRequest]) -> List[MultitaskResponse]:
        """Deprecated alias of :meth:`serve_batch` (kept for one release).

        Historically this simply aliased ``serve_batch``; it now routes
        through the same one-shot session and warns so callers migrate to
        ``serve_batch`` or an explicit :meth:`session`.
        """
        warnings.warn(
            "MultitaskEngine.serve_many is deprecated; use serve_batch() or "
            "a ServingSession (engine.session()) instead",
            DeprecationWarning,
            stacklevel=2,
        )
        return self._serve_via_session(list(requests))


# --------------------------------------------------------------------------
# LM serving
# --------------------------------------------------------------------------

class LMServer:
    """Batched prefill + greedy decode for any architecture in the zoo."""

    def __init__(self, model: ModelApi, params: Any,
                 policy: ShardingPolicy = TP_POLICY, max_len: int = 512):
        self.model = model
        self.params = params
        self.policy = policy
        self.max_len = max_len
        self._prefill = jax.jit(
            lambda p, batch: model.prefill(p, batch, policy)
        )
        self._step = jax.jit(
            lambda p, tok, cache, n: model.decode_step(p, tok, cache, n, policy)
        )

    def generate(
        self, prompts: jax.Array, steps: int,
        features: Optional[jax.Array] = None,
    ) -> np.ndarray:
        """Greedy generation.  prompts: (B, S0) int32.  Returns (B, steps)."""
        cfg = self.model.cfg
        b, s0 = prompts.shape
        total = s0 + steps
        # Allocate a cache with full capacity, prefill into its prefix.
        if cfg.family == "encdec":
            batch = {"features": features, "tokens": prompts}
        else:
            batch = prompts
        logits, cache = self._prefill(self.params, batch)
        # Grow the prefill cache to full capacity (KV families only).
        cache = _grow_cache(self.model, cache, total, s0)
        out = []
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        cache_len = jnp.asarray(s0, jnp.int32)
        for _ in range(steps):
            out.append(np.asarray(jax.device_get(tok)))
            logits, cache = self._step(self.params, tok, cache, cache_len)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            cache_len = cache_len + 1
        return np.stack(out, axis=1)


def _grow_cache(model: ModelApi, cache: Any, total: int, filled: int) -> Any:
    """Pad a prefill-sized KV cache out to ``total`` slots."""
    from repro.models.cache import EncDecCache, HybridCache, KVCache, SSMCache

    def grow_kv(kv: KVCache) -> KVCache:
        t = kv.k.shape[2]
        if t >= total:
            return kv
        pad = [(0, 0)] * kv.k.ndim
        pad[2] = (0, total - t)
        return KVCache(k=jnp.pad(kv.k, pad), v=jnp.pad(kv.v, pad))

    if isinstance(cache, KVCache):
        cfg = model.cfg
        if cfg.sliding_window is not None:
            # SWA ring never needs more than ``window`` slots; prefill's
            # linear layout (positions < window) is already ring-consistent.
            total = min(total, cfg.sliding_window)
        return grow_kv(cache)
    if isinstance(cache, SSMCache):
        return cache
    if isinstance(cache, HybridCache):
        return HybridCache(ssm=cache.ssm, kv=grow_kv(cache.kv))
    if isinstance(cache, EncDecCache):
        return EncDecCache(
            self_kv=grow_kv(cache.self_kv),
            cross_k=cache.cross_k, cross_v=cache.cross_v,
        )
    return cache
