"""Reliability primitives for fault-tolerant multi-tenant serving.

The serving stack's failure story used to be binary: any exception mid-pump
failed *every* admitted future and left the executor's residency in whatever
half-loaded state the crash produced.  This module holds the pieces the
session uses to do better:

* **typed per-request errors** — every failed future carries a
  :class:`RequestError` naming the request (``seq``), its task subset, its
  tenant, and (for execution failures) the group it was riding in, with the
  original exception chained as ``__cause__`` so tracebacks survive;
* **deadline / backpressure outcomes** — :class:`DeadlineExceeded` for
  requests that aged past their SLO before planning, :class:`QueueFull` for
  submissions rejected (or pending entries shed) by the session's bounded
  admission queue;
* **:class:`RetryPolicy`** — how a session recovers a failed group: bounded
  exponential backoff on the primary path, then a graceful-degradation
  ladder (re-run the fused dispatch as the unrolled per-block reference;
  re-run a sharded plan on a single device) before giving up;
* **:class:`FaultInjector`** — deterministic, seeded fault injection at the
  plan/load/dispatch boundaries of the engine, the hook both the chaos
  benchmark (``benchmarks/serving_chaos.py``) and the property tests drive.

Everything here is host-side control flow: none of it changes what executes
on the device, which is what keeps the engine's counter-exact
``session.stats == session.predicted`` invariant provable *through*
failures — a rolled-back group contributes nothing to either side.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, FrozenSet, Iterable, Mapping, Optional, Tuple

import numpy as np

__all__ = [
    "RequestError",
    "DeadlineExceeded",
    "QueueFull",
    "InjectedFault",
    "RetryPolicy",
    "FaultInjector",
    "TenantStats",
    "FAULT_SITES",
    "PowerFailure",
    "PowerFailureInjector",
    "POWER_SITES",
    "EnergyBudget",
]


class RequestError(RuntimeError):
    """One request's serving failure, with the request's identity attached.

    Attributes:
      seq: the failed request's session sequence number.
      tasks: its normalized task subset (``None`` = all tasks).
      tenant: its tenant label (``None`` = untenanted).
      group_id: the session-assigned id of the execution group the failure
        happened in, or ``None`` when the request never reached a group
        (planning failures, deadline expiry, queue rejection).

    The causing exception, when there is one, is chained as ``__cause__``
    (original traceback included), so ``future.result()`` re-raising this
    error still shows where the engine actually blew up.
    """

    def __init__(
        self,
        message: str,
        *,
        seq: int,
        tasks: Optional[FrozenSet[int]] = None,
        tenant: Optional[str] = None,
        group_id: Optional[int] = None,
    ):
        super().__init__(message)
        self.seq = seq
        self.tasks = tasks
        self.tenant = tenant
        self.group_id = group_id


class DeadlineExceeded(RequestError):
    """The request aged past its deadline before it could be planned."""


class QueueFull(RequestError):
    """The request was rejected at submit, or shed while pending, because
    the session's bounded queue (global or per-tenant) was over capacity.

    ``shed`` distinguishes the two: ``False`` means this request itself was
    refused admission; ``True`` means it had been queued and was evicted to
    make room for a higher-priority arrival.
    """

    def __init__(self, message: str, *, shed: bool = False, **kwargs: Any):
        super().__init__(message, **kwargs)
        self.shed = shed


class InjectedFault(RuntimeError):
    """A fault deliberately raised by a :class:`FaultInjector`.

    Attributes:
      site: which boundary fired (one of :data:`FAULT_SITES`).
      index: the site's invocation count when it fired (0-based).
      context: the keyword context the engine passed to ``check``.
    """

    def __init__(self, site: str, index: int, context: Dict[str, Any]):
        super().__init__(f"injected fault at {site!r} (invocation {index})")
        self.site = site
        self.index = index
        self.context = dict(context)


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """How a :class:`~repro.serving.session.ServingSession` recovers a group.

    A failed group attempt is always rolled back first (the executor's
    residency snapshot taken before the attempt is restored), so every
    retry re-predicts and re-executes from a consistent state.  Then:

    1. the primary path is retried up to ``max_retries`` times, sleeping
       ``backoff_base * backoff_factor**attempt`` (capped at ``backoff_max``)
       between attempts — classic bounded exponential backoff, aimed at
       transient faults;
    2. with ``degrade=True``, a still-failing group walks the fallback
       ladder: a single-device engine re-runs the group with fused dispatch
       off (the unrolled per-block reference path, identical counters); a
       mesh-sharded engine re-runs the group cold on a lazily built
       single-device executor.  Successful degraded runs are recorded on the
       response (``MultitaskResponse.degraded``);
    3. only when every rung fails do the group's futures fail, each with its
       own :class:`RequestError` — the rest of the session is untouched.

    ``backoff_base=0.0`` (the default) disables sleeping entirely, which is
    what deterministic tests and simulated-clock benchmarks want.
    """

    max_retries: int = 2
    backoff_base: float = 0.0
    backoff_factor: float = 2.0
    backoff_max: float = 1.0
    degrade: bool = True

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {self.max_retries}")
        if self.backoff_base < 0:
            raise ValueError(
                f"backoff_base must be >= 0, got {self.backoff_base}"
            )

    def backoff_seconds(self, attempt: int) -> float:
        """Sleep before retry ``attempt`` (0-based retry index)."""
        if self.backoff_base <= 0.0:
            return 0.0
        return min(
            self.backoff_base * self.backoff_factor ** attempt,
            self.backoff_max,
        )


#: The engine boundaries a :class:`FaultInjector` can fire at.
#:
#: * ``"plan"`` — entry of ``MultitaskEngine._execute_group``, before the
#:   group's prediction is computed (planning/prediction boundary);
#: * ``"load"`` — after the warm/cold residency boundary, immediately before
#:   the group starts executing (the weight-load boundary);
#: * ``"dispatch"`` — inside ``MultitaskEngine._run_group``, before each
#:   task's batched dispatch;
#: * ``"prefetch"`` — entry of ``MultitaskEngine.prefetch_group``, before
#:   the next group's weight stream is staged (streaming sessions only; a
#:   fault here degrades that group to synchronous loads, never fails it).
FAULT_SITES = ("plan", "load", "dispatch", "prefetch")


class FaultInjector:
    """Deterministic seeded fault injection at the engine's boundaries.

    Two triggering modes, combinable:

    * ``rates`` — per-site Bernoulli fault probability, drawn from a seeded
      ``numpy`` generator.  Deterministic for a fixed seed and call
      sequence: the chaos benchmark replays the exact same fault schedule
      every run, so its gates cannot flake.
    * ``script`` — per-site sets of invocation indices that *always* fault
      (0-based, counted per site).  This is how tests stage exact scenarios:
      "the first two dispatches fail, then everything works" exercises the
      retry path without probability.

    ``max_faults`` bounds the total injected across all sites (``None`` =
    unbounded); :attr:`invocations` and :attr:`injected` expose per-site
    counts for assertions and benchmark reporting.

    The injector only *raises* (:class:`InjectedFault`) — it never touches
    engine state itself, so a fired fault looks exactly like any other
    mid-group exception to the session's rollback/retry machinery.
    """

    def __init__(
        self,
        rates: Optional[Mapping[str, float]] = None,
        seed: int = 0,
        script: Optional[Mapping[str, Iterable[int]]] = None,
        max_faults: Optional[int] = None,
    ):
        self.rates = {k: float(v) for k, v in (rates or {}).items()}
        for site, rate in self.rates.items():
            if site not in FAULT_SITES:
                raise ValueError(
                    f"unknown fault site {site!r}; expected one of {FAULT_SITES}"
                )
            if not 0.0 <= rate <= 1.0:
                raise ValueError(f"rate for {site!r} must be in [0, 1], got {rate}")
        self.script = {
            site: frozenset(int(i) for i in idxs)
            for site, idxs in (script or {}).items()
        }
        for site in self.script:
            if site not in FAULT_SITES:
                raise ValueError(
                    f"unknown fault site {site!r}; expected one of {FAULT_SITES}"
                )
        self._rng = np.random.default_rng(seed)
        self.max_faults = max_faults
        self.invocations: Dict[str, int] = {s: 0 for s in FAULT_SITES}
        self.injected: Dict[str, int] = {s: 0 for s in FAULT_SITES}

    @property
    def total_injected(self) -> int:
        return sum(self.injected.values())

    def check(self, site: str, **context: Any) -> None:
        """Raise :class:`InjectedFault` if this invocation is scheduled to
        fail; otherwise return.  Called by the engine at each boundary."""
        index = self.invocations[site]
        self.invocations[site] = index + 1
        fire = index in self.script.get(site, frozenset())
        rate = self.rates.get(site, 0.0)
        if not fire and rate > 0.0:
            # Draw even when capped so the schedule beyond the cap is the
            # schedule an uncapped run would have produced.
            fire = bool(self._rng.random() < rate)
        if not fire:
            return
        if (
            self.max_faults is not None
            and self.total_injected >= self.max_faults
        ):
            return
        self.injected[site] += 1
        raise InjectedFault(site, index, context)


@dataclasses.dataclass
class TenantStats:
    """Per-tenant admission aggregates a :class:`ServingSession` maintains.

    The session's global ``waits`` deque hides per-tenant starvation: a
    quota/SLO policy needs to see that tenant B's requests wait 10x tenant
    A's even when the global mean looks healthy.  Aggregates are exact over
    the tenant's whole lifetime (running sum/max, not a window).
    """

    submitted: int = 0
    admitted: int = 0
    rejected: int = 0
    shed: int = 0
    expired: int = 0
    failed: int = 0
    wait_sum: float = 0.0
    wait_max: float = 0.0

    @property
    def mean_admission_wait(self) -> float:
        """Mean admission latency over this tenant's admitted requests."""
        if not self.admitted:
            return 0.0
        return self.wait_sum / self.admitted

    @property
    def max_admission_wait(self) -> float:
        """Max admission latency over this tenant's admitted requests."""
        return self.wait_max


# --------------------------------------------------------------------------
# Intermittent power (batteryless / energy-harvesting deployments)
# --------------------------------------------------------------------------

#: The boundaries a :class:`PowerFailureInjector` can kill the session at.
#:
#: * ``"group"`` — inside ``MultitaskEngine._run_group``, before a task's
#:   batched dispatch (mid-group, between tasks);
#: * ``"suffix"`` — at a segmented suffix's block-depth commit point, right
#:   after the checkpoint hook journaled the activation (mid-suffix,
#:   between blocks);
#: * ``"prefetch"`` — entry of ``MultitaskEngine.prefetch_group``
#:   (mid-prefetch, with a stream staged but uncommitted).
POWER_SITES = ("group", "suffix", "prefetch")


class PowerFailure(BaseException):
    """The whole session lost power.

    Deliberately **not** an :class:`Exception`: the session's per-group
    rollback/retry/degradation machinery catches ``Exception``, and a power
    failure must never be "recovered" in-process — it kills everything and
    propagates to the harness, which reboots by building a fresh session
    with :meth:`~repro.serving.session.ServingSession.recover` over the
    durable journal.  (``KeyboardInterrupt`` uses the same idiom for the
    same reason.)
    """

    def __init__(self, site: str, index: int, context: Dict[str, Any]):
        super().__init__(f"power failure at {site!r} (invocation {index})")
        self.site = site
        self.index = index
        self.context = dict(context)


class PowerFailureInjector:
    """Deterministic seeded whole-session power-failure injection.

    The intermittent-computing sibling of :class:`FaultInjector`: same two
    triggering modes (per-site Bernoulli ``rates`` from a seeded generator,
    and per-site ``script`` sets of invocation indices that always fire),
    same per-site :attr:`invocations` / :attr:`injected` counters, same
    ``max_failures`` cap — but it raises :class:`PowerFailure` (a
    ``BaseException``), so the session's group-isolation machinery never
    absorbs it.  The injector itself lives *outside* the session (like the
    FRAM journal), so the same instance keeps its schedule across reboots —
    that is what makes "~20 failures over this trace" reproducible.
    """

    def __init__(
        self,
        rates: Optional[Mapping[str, float]] = None,
        seed: int = 0,
        script: Optional[Mapping[str, Iterable[int]]] = None,
        max_failures: Optional[int] = None,
    ):
        self.rates = {k: float(v) for k, v in (rates or {}).items()}
        for site, rate in self.rates.items():
            if site not in POWER_SITES:
                raise ValueError(
                    f"unknown power site {site!r}; expected one of {POWER_SITES}"
                )
            if not 0.0 <= rate <= 1.0:
                raise ValueError(f"rate for {site!r} must be in [0, 1], got {rate}")
        self.script = {
            site: frozenset(int(i) for i in idxs)
            for site, idxs in (script or {}).items()
        }
        for site in self.script:
            if site not in POWER_SITES:
                raise ValueError(
                    f"unknown power site {site!r}; expected one of {POWER_SITES}"
                )
        self._rng = np.random.default_rng(seed)
        self.max_failures = max_failures
        self.invocations: Dict[str, int] = {s: 0 for s in POWER_SITES}
        self.injected: Dict[str, int] = {s: 0 for s in POWER_SITES}

    @property
    def total_injected(self) -> int:
        return sum(self.injected.values())

    def check(self, site: str, **context: Any) -> None:
        """Raise :class:`PowerFailure` if this invocation is scheduled to
        lose power; otherwise return."""
        index = self.invocations[site]
        self.invocations[site] = index + 1
        fire = index in self.script.get(site, frozenset())
        rate = self.rates.get(site, 0.0)
        if not fire and rate > 0.0:
            # Draw even when capped so the schedule beyond the cap matches
            # what an uncapped run would have produced.
            fire = bool(self._rng.random() < rate)
        if not fire:
            return
        if (
            self.max_failures is not None
            and self.total_injected >= self.max_failures
        ):
            return
        self.injected[site] += 1
        raise PowerFailure(site, index, context)


class EnergyBudget:
    """Duty-cycled energy store: a harvester charging a storage capacitor.

    The session treats this as the paper's batteryless power supply: before
    a group executes, its modelled energy (the cost model's prediction
    through ``hw.energy_joules``, checkpoint writes included) must fit in
    :attr:`available` — otherwise the pump *pauses*, sleeping exactly the
    harvest time the deficit needs (``seconds_until``) before draining and
    proceeding.  All host-side bookkeeping on the session's clock; nothing
    here touches device execution.

    Attributes:
      capacity_joules: storage capacitance ceiling (harvest beyond it is
        spilled, as a real capacitor would).
      harvest_watts: harvest rate in J/s while paused or between groups.
      available: joules currently stored.
      drained_joules / harvested_joules / spilled_joules: lifetime totals.
    """

    def __init__(
        self,
        capacity_joules: float,
        harvest_watts: float,
        initial_joules: Optional[float] = None,
    ):
        if capacity_joules <= 0.0:
            raise ValueError(
                f"capacity_joules must be > 0, got {capacity_joules}"
            )
        if harvest_watts < 0.0:
            raise ValueError(
                f"harvest_watts must be >= 0, got {harvest_watts}"
            )
        self.capacity_joules = float(capacity_joules)
        self.harvest_watts = float(harvest_watts)
        self.available = (
            self.capacity_joules if initial_joules is None
            else min(float(initial_joules), self.capacity_joules)
        )
        if self.available < 0.0:
            raise ValueError(f"initial_joules must be >= 0, got {initial_joules}")
        self._last_harvest: Optional[float] = None
        self.drained_joules = 0.0
        self.harvested_joules = 0.0
        self.spilled_joules = 0.0

    def harvest(self, now: float) -> None:
        """Accrue harvest up to ``now`` (session-clock seconds), clamped to
        capacity.  The first call only anchors the clock."""
        if self._last_harvest is not None and now > self._last_harvest:
            gained = (now - self._last_harvest) * self.harvest_watts
            fits = min(gained, self.capacity_joules - self.available)
            self.available += fits
            self.harvested_joules += fits
            self.spilled_joules += gained - fits
        self._last_harvest = max(
            now,
            self._last_harvest if self._last_harvest is not None else now,
        )

    def advance(self, seconds: float) -> None:
        """Accrue exactly ``seconds`` of harvest, moving the anchor with it.

        The session's pause path uses this instead of :meth:`harvest`: it
        sleeps precisely ``seconds_until(need)`` and credits precisely that
        much harvest, so the pause is deterministic regardless of how the
        injected sleep hook relates to the session clock (a real
        ``time.sleep`` and a simulated-clock no-op behave identically).
        The anchor advances too, so a later ``harvest(now)`` on a clock the
        sleep also advanced does not double-count the paused interval.
        """
        if seconds < 0.0:
            raise ValueError(f"cannot advance {seconds} s")
        gained = seconds * self.harvest_watts
        fits = min(gained, self.capacity_joules - self.available)
        self.available += fits
        self.harvested_joules += fits
        self.spilled_joules += gained - fits
        if self._last_harvest is not None:
            self._last_harvest += seconds

    def can_spend(self, joules: float) -> bool:
        return joules <= self.available

    def seconds_until(self, joules: float) -> float:
        """Harvest seconds until ``joules`` are available (0 if they are).

        ``inf`` when the deficit can never be harvested — the caller should
        fail loudly rather than sleep forever.
        """
        deficit = joules - self.available
        if deficit <= 0.0:
            return 0.0
        if joules > self.capacity_joules or self.harvest_watts <= 0.0:
            return float("inf")
        return deficit / self.harvest_watts

    def drain(self, joules: float) -> None:
        """Spend ``joules``; callers must have checked :meth:`can_spend`."""
        if joules < 0.0:
            raise ValueError(f"cannot drain {joules} J")
        if joules > self.available + 1e-12:
            raise ValueError(
                f"drain of {joules:.6g} J exceeds available "
                f"{self.available:.6g} J — pause and harvest first"
            )
        self.available = max(self.available - joules, 0.0)
        self.drained_joules += joules
